"""Benchmark package: paper tables, engine/serve trajectories, and the
ERT-style machine probe (``benchmarks.roofline``).  A real package (not a
namespace dir) so ``python -m benchmarks.run``, the perf gate's replay
subprocesses, and the import-cleanliness test all resolve the same modules.
"""
