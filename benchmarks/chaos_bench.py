"""Chaos soak of the serving failure plane (ISSUE 10).

``serve_bench.py`` measures the front end under load it did not agree to;
this benchmark measures it under load *and* failures it did not agree to.
One open-loop run is measured twice at the same offered rate (0.25x the
measured burst capacity — sized so the *surviving* fleet under phase-B
faults still has ~1.5x headroom; see the comment at the rate choice):
phase A fault-free, phase B with a seeded
:class:`repro.faults.FaultPlan` armed —

* one of the three replicas **crashes** mid-run (its 5th armed batch) and
  stays down until the router quarantines and rebuilds it;
* ~1% of requests are **poisoned** (they fail deterministically on every
  replica — retrying them would be wasted work);
* one replica becomes a 10x **straggler** (every batch stretched).

The headline metric is **goodput retained**: phase-B goodput over phase-A
goodput.  The soak also checks the failure plane's bookkeeping: every
submitted future resolves exactly once, the crashed replica is quarantined
and rebuilt, and the rebuilt engine's results are bit-identical to the
source database's.

    PYTHONPATH=src python benchmarks/chaos_bench.py
    PYTHONPATH=src python benchmarks/chaos_bench.py --smoke   # CI gate

Output: ``results/bench/chaos.json`` and an appended machine-stamped
record in the committed ``BENCH_chaos.json`` trajectory, gated by
``python -m tools.perfgate`` (goodput retained, rebuild, bit-identity).

``--smoke`` asserts the ISSUE 10 acceptance criteria: goodput under chaos
>= 70% of fault-free goodput, zero unresolved futures, the killed replica
quarantined and rebuilt with post-rebuild results bit-identical, and the
p99 of completed requests within the deadline.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import os
import time

import numpy as np

from repro.data import synth
from repro.db import GraphDB
from repro.faults import FaultPlan, InjectedPoison
from repro.serve import OUTCOMES, AsyncServer

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
BENCH_TOP = os.path.join(os.path.dirname(__file__), "..", "BENCH_chaos.json")

QUERY = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"

CRASH_REPLICA = "r1"
SLOW_REPLICA = "r2"
POISON_MARKER = "PoisonedConstant"


def _requests(db: GraphDB, n: int, seed: int, poison_every: int) -> list[str]:
    """``n`` request texts; every ``poison_every``-th carries the marker."""
    unis = [x for x in db.graph.node_names if x.startswith("Univ")]
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        if poison_every and i % poison_every == poison_every // 2:
            out.append(QUERY.format(uni=f"{POISON_MARKER}{i}"))
        else:
            out.append(QUERY.format(uni=unis[rng.integers(len(unis))]))
    return out


async def _warmup(server: AsyncServer, db: GraphDB, seed: int) -> float:
    """Warm every (bucket, replica) plan; return burst capacity (req/s)."""
    unis = [x for x in db.graph.node_names if x.startswith("Univ")]
    distinct = [QUERY.format(uni=u) for u in unis]
    buckets = server.router.replicas[0].engine.buckets
    sizes = sorted(
        {b for b in buckets if b <= min(server.max_batch, len(distinct))}
        | {1}
    )
    for size in sizes:
        for _ in range(2 * len(server.router) + 1):
            await asyncio.gather(*[
                server.submit(q, deadline_ms=60_000)
                for q in distinct[:size]
            ])
    reqs = _requests(db, server.max_batch, seed, poison_every=0)
    t0 = time.monotonic()
    burst = [server.submit(q, deadline_ms=60_000) for q in reqs * 4]
    results = await asyncio.gather(*burst)
    dt = time.monotonic() - t0
    assert all(r.ok for r in results), "warmup burst must not shed"
    return len(burst) / dt


async def _offer(
    server: AsyncServer,
    texts: list[str],
    *,
    rate: float,
    seed: int,
    deadline_ms: float,
) -> dict:
    """Offer ``texts`` at Poisson rate ``rate``; return phase measurements.

    Arrival times are pre-drawn and absolute (late arrivals fire
    back-to-back), same discipline as ``serve_bench``.
    """
    n = len(texts)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t_start = time.monotonic()
    arrivals = t_start + np.cumsum(gaps)
    futs = []
    for q, t_due in zip(texts, arrivals):
        delay = t_due - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        futs.append(server.submit(
            q, tenant=f"t{len(futs) % 2}", deadline_ms=deadline_ms
        ))
    results = await asyncio.gather(*futs)
    wall = time.monotonic() - t_start

    assert len(results) == n, "every submitted request must resolve"
    outcomes = {o: 0 for o in OUTCOMES}
    for r in results:
        outcomes[r.outcome] += 1
    poison_errors = sum(
        1 for r in results
        if r.outcome == "error" and isinstance(r.error, InjectedPoison)
    )
    done = sorted(r.total_ms for r in results if r.ok)

    def pct(xs, q):
        return float(xs[min(int(q * len(xs)), len(xs) - 1)]) if xs else 0.0

    return {
        "offered_req_s": rate,
        "n": n,
        "duration_s": wall,
        "completed": outcomes["ok"],
        "goodput_req_s": outcomes["ok"] / wall,
        "ok_rate": outcomes["ok"] / n,
        "outcomes": outcomes,
        "poison_errors": poison_errors,
        "p50_ms": pct(done, 0.50),
        "p99_ms": pct(done, 0.99),
    }


def _bit_identical(server: AsyncServer, db: GraphDB, texts: list[str]) -> bool:
    """Rebuilt-replica results vs the source engine, raw mask equality."""
    rep = next(
        r for r in server.router.replicas if r.name == CRASH_REPLICA
    )
    for text in texts:
        prepared = db._engine.prepare(db._coerce(text))
        with rep.lock:
            theirs = rep.engine.execute_prepared([prepared])[0]
        ours = db._engine.execute_prepared([prepared])[0]
        if not np.array_equal(theirs.survivors, ours.survivors):
            return False
    return True


async def _soak(args) -> dict:
    db = GraphDB(synth.lubm_like(n_universities=args.universities, seed=0))
    print(f"# database: {db.n_triples} triples / {db.n_nodes} nodes, "
          f"{args.replicas} replicas")
    plan = (
        FaultPlan(args.seed)
        .crash_replica(CRASH_REPLICA, at_batch=args.crash_at_batch)
        .slow_replica(SLOW_REPLICA, factor=args.slow_factor, extra_s=0.02)
        .poison_matching(POISON_MARKER)
    )
    async with AsyncServer(
        db,
        replicas=args.replicas,
        max_queue=args.max_queue,
        max_batch=args.max_batch,
        max_delay_ms=args.max_delay_ms,
        default_deadline_ms=args.deadline_ms,
        fault_plan=plan,
        max_retries=2,
        hedge=True,
    ) as server:
        capacity = await _warmup(server, db, seed=args.seed)
        # pin the failure-plane budgets only after warmup: a cold compile
        # legitimately exceeds any budget sized for warm service
        server.watchdog_budget = args.deadline_ms / 2e3
        server.hedge_delay = 0.150
        # Offered rate is sized against the *surviving* fleet, not the
        # healthy one: with 1 of 3 replicas crash-looping while armed and
        # another slowed 10x, surviving capacity is ~(1 + 1/slow_factor)/3
        # ~ 0.37x — offering 0.5x would make >= 70% retention unreachable
        # even with perfect routing.  0.25x leaves ~1.5x headroom, so the
        # retention gate measures routing quality (does the remnant's
        # capacity get wasted on the straggler/crasher?), not arithmetic.
        rate = 0.25 * capacity
        # a soak has a *duration*, not a request count: goodput is
        # completed/wall, and on a phase shorter than a few hundred ms the
        # wall is dominated by the tail of the last handful of requests
        # (one 200 ms retry would halve the "goodput" of a 100 ms phase).
        # Floor the phase length so the ratio measures steady-state
        # throughput under faults, not last-request latency.
        n_phase = max(args.n_per_phase, int(rate * args.min_phase_s))
        print(f"# warm burst capacity ~{capacity:.0f} req/s; "
              f"soaking both phases at {rate:.0f} req/s (0.25x), "
              f"{n_phase} requests/phase (>= {args.min_phase_s:.1f}s)")

        # phase A: fault-free baseline at the common offered rate
        clean = _requests(db, n_phase, args.seed + 1, poison_every=0)
        base = await _offer(
            server, clean, rate=rate, seed=args.seed + 2,
            deadline_ms=args.deadline_ms,
        )
        print(f"chaos/baseline,goodput={base['goodput_req_s']:.0f},"
              f"p50_ms={base['p50_ms']:.2f},p99_ms={base['p99_ms']:.2f},"
              f"ok_rate={base['ok_rate']:.3f}")

        # phase B: same rate, plan armed — crash + straggler + poison
        dirty = _requests(
            db, n_phase, args.seed + 3,
            poison_every=args.poison_every,
        )
        plan.arm()
        chaos = await _offer(
            server, dirty, rate=rate, seed=args.seed + 4,
            deadline_ms=args.deadline_ms,
        )
        plan.disarm()
        rebuilt = server.router.wait_rebuilt(timeout=15.0)
        snap = server.metrics.snapshot()
        events = server.router.events()
        health = {h["name"]: h for h in server.router.health()}

        crash = plan.crash_fired(CRASH_REPLICA)
        quarantined_t = next(
            (e["t"] for e in events
             if e["replica"] == CRASH_REPLICA and e["event"] == "quarantined"),
            None,
        )
        time_to_quarantine_s = (
            quarantined_t - crash["t"]
            if crash is not None and quarantined_t is not None else None
        )
        # bit-identity probe AFTER the soak: the rebuilt engine must agree
        # with the source engine on fresh fault-free requests
        probes = _requests(db, 4, args.seed + 5, poison_every=0)
        identical = rebuilt and _bit_identical(server, db, probes)

        print(f"chaos/faulted,goodput={chaos['goodput_req_s']:.0f},"
              f"p50_ms={chaos['p50_ms']:.2f},p99_ms={chaos['p99_ms']:.2f},"
              f"ok_rate={chaos['ok_rate']:.3f},"
              f"retries={snap.retries},hedges={snap.hedges},"
              f"timeouts={snap.timeouts},overruns={snap.watchdog_overruns}")
        retained = (
            chaos["goodput_req_s"] / base["goodput_req_s"]
            if base["goodput_req_s"] > 0 else 0.0
        )
        print(f"chaos/verdict,goodput_retained={retained:.3f},"
              f"rebuilt={int(rebuilt)},bit_identical={int(identical)},"
              f"time_to_quarantine_s="
              f"{-1.0 if time_to_quarantine_s is None else time_to_quarantine_s:.3f}")

    return {
        "capacity_burst_req_s": capacity,
        "offered_req_s": rate,
        "baseline": base,
        "chaos": chaos,
        "goodput_retained": retained,
        "goodput_chaos_req_s": chaos["goodput_req_s"],
        "p99_chaos_ms": chaos["p99_ms"],
        "ok_rate_chaos": chaos["ok_rate"],
        "rebuilt": float(rebuilt),
        "bit_identical": float(identical),
        "time_to_quarantine_s": time_to_quarantine_s,
        "injections": plan.counts(),
        "health": {name: h["state"] for name, h in health.items()},
        "counters": {
            "retries": snap.retries,
            "hedges": snap.hedges,
            "timeouts": snap.timeouts,
            "watchdog_overruns": snap.watchdog_overruns,
        },
        "resolved_identity": snap.submitted == snap.resolved,
        "metrics": dataclasses.asdict(snap),
        "n_triples": db.n_triples,
    }


def _append_trajectory(entry: dict) -> None:
    """Append one machine-stamped record to ``BENCH_chaos.json``."""
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from repro.engine.machine import machine_fingerprint
    from tools.perfgate.history import append_record

    entry.setdefault("machine", machine_fingerprint())
    append_record(BENCH_TOP, entry)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--n-per-phase", type=int, default=400,
                    help="minimum requests per phase (raised to cover "
                         "--min-phase-s at the offered rate)")
    ap.add_argument("--min-phase-s", type=float, default=4.0,
                    help="minimum phase duration in seconds")
    ap.add_argument("--deadline-ms", type=float, default=2000.0)
    ap.add_argument("--max-queue", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--crash-at-batch", type=int, default=5)
    ap.add_argument("--slow-factor", type=float, default=10.0)
    ap.add_argument("--poison-every", type=int, default=100,
                    help="poison every N-th phase-B request (~1%%)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: small fixed-seed soak + the ISSUE 10 "
                         "acceptance asserts")
    args = ap.parse_args()
    if args.smoke:
        args.universities = min(args.universities, 2)
        args.n_per_phase = min(args.n_per_phase, 150)
        args.min_phase_s = min(args.min_phase_s, 2.0)

    out = asyncio.run(_soak(args))

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "chaos.json"), "w") as f:
        json.dump(out, f, indent=1, default=str)

    _append_trajectory({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": bool(args.smoke),
        "replicas": args.replicas,
        "n_triples": out["n_triples"],
        "deadline_ms": args.deadline_ms,
        "capacity_burst_req_s": out["capacity_burst_req_s"],
        "goodput_retained": out["goodput_retained"],
        "goodput_chaos_req_s": out["goodput_chaos_req_s"],
        "ok_rate_chaos": out["ok_rate_chaos"],
        "p99_chaos_ms": out["p99_chaos_ms"],
        "rebuilt": out["rebuilt"],
        "bit_identical": out["bit_identical"],
        "time_to_quarantine_s": out["time_to_quarantine_s"],
        "counters": out["counters"],
        "injections": out["injections"],
    })

    if args.smoke:
        # acceptance (ISSUE 10): the chaos phase keeps >= 70% of fault-free
        # goodput, nothing leaks, the crashed replica comes back bit-exact,
        # and the served tail stays inside the deadline
        assert out["resolved_identity"], \
            "drained server left futures unaccounted"
        assert out["goodput_retained"] >= 0.70, (
            f"chaos goodput retained {out['goodput_retained']:.2f} < 0.70 "
            "of the fault-free baseline"
        )
        assert out["rebuilt"] == 1.0, \
            f"crashed replica not rebuilt (health={out['health']})"
        assert out["bit_identical"] == 1.0, \
            "rebuilt replica disagrees with the source engine"
        assert out["injections"].get("crash", 0) >= 1, \
            "the crash injection never fired"
        assert out["time_to_quarantine_s"] is not None, \
            "crashed replica was never quarantined"
        assert out["p99_chaos_ms"] <= args.deadline_ms, (
            f"chaos p99 of completed requests {out['p99_chaos_ms']:.1f} ms "
            f"exceeds the {args.deadline_ms:.0f} ms deadline"
        )
        print("# smoke acceptance: goodput retained, replica rebuilt "
              "bit-identical, zero unresolved futures, p99 in deadline")


if __name__ == "__main__":
    main()
