"""Engine-subsystem benchmark: cold vs warm plan-cache latency and
microbatched throughput (issue acceptance: warm-path latency of a
constant-rebound template >= 5x lower than the cold path).

    PYTHONPATH=src python -m benchmarks.engine_bench
    PYTHONPATH=src python benchmarks/engine_bench.py --universities 8

Two sections, printed as ``name,us_per_call,derived`` CSV lines (scaffold
contract of benchmarks/run.py) and written to results/bench/engine.json:

* ``cold_warm`` — first execution of a template (parse + SOI build/compile +
  operand upload + jit trace) vs repeated executions that only rebind
  constants (cache hit, zero retraces).  The ratio is the whole point of the
  plan cache: serving latency is the fixpoint, not compilation.
* ``throughput`` — requests/second through ``Engine.execute_many`` at
  several microbatch sizes over the LUBM-like "same template, many
  constants" workload.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.data import synth
from repro.engine import Engine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def _mk_requests(db, n: int, seed: int = 0) -> list[str]:
    unis = [x for x in db.node_names if x.startswith("Univ")]
    rng = np.random.default_rng(seed)
    return [
        f"{{ ?d subOrganizationOf {unis[rng.integers(len(unis))]} . "
        f"?s memberOf ?d }}"
        for _ in range(n)
    ]


def cold_warm(db, *, engine: str = "auto", warm_iters: int = 20) -> dict:
    """Cold (first-ever) vs warm (constant-rebound) execute latency."""
    eng = Engine(db, engine=engine)
    reqs = _mk_requests(db, warm_iters + 1)

    t0 = time.perf_counter()
    first = eng.execute(reqs[0])
    t_cold = time.perf_counter() - t0

    warm_times = []
    for q in reqs[1:]:
        t0 = time.perf_counter()
        res = eng.execute(q)
        warm_times.append(time.perf_counter() - t0)
        assert res.cache_hit, "warm request missed the plan cache"
    t_warm = float(np.median(warm_times))

    m = eng.metrics()
    return {
        "bench": "cold_warm",
        "engine": first.engine,
        "t_cold": t_cold,
        "t_warm": t_warm,
        "speedup": t_cold / t_warm,
        "plan_builds": m.plan_builds,
        "cache_hits": m.cache.hits,
        "n_nodes": db.n_nodes,
        "n_triples": db.n_edges,
    }


def throughput(db, *, engine: str = "auto", batch_sizes=(1, 4, 8, 16),
               n_requests: int = 64) -> list[dict]:
    """Requests/second through execute_many at several microbatch sizes."""
    rows = []
    for batch in batch_sizes:
        eng = Engine(db, engine=engine)
        reqs = _mk_requests(db, n_requests, seed=batch)
        # warm pass: chunks with fewer unique constants hit smaller buckets,
        # so a full pass is needed to build every (template, bucket) plan
        for s in range(0, n_requests, batch):
            eng.execute_many(reqs[s : s + batch])
        t0 = time.perf_counter()
        for s in range(0, n_requests, batch):
            eng.execute_many(reqs[s : s + batch])
        dt = time.perf_counter() - t0
        m = eng.metrics()
        rows.append({
            "bench": f"throughput_b{batch}",
            "batch": batch,
            "req_per_s": n_requests / dt,
            "t_total": dt,
            "engines": m.engine_counts,
            "cache_hit_rate": m.cache.hit_rate,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=8)
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--requests", type=int, default=64)
    args = ap.parse_args()

    db = synth.lubm_like(n_universities=args.universities, seed=0)
    print(f"# database: {db.n_edges} triples / {db.n_nodes} nodes")

    rows = [cold_warm(db, engine=args.engine)]
    rows += throughput(db, engine=args.engine, n_requests=args.requests)

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "engine.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)

    cw = rows[0]
    print(f"engine/cold,{cw['t_cold']*1e6:.1f},engine={cw['engine']}")
    print(f"engine/warm,{cw['t_warm']*1e6:.1f},speedup={cw['speedup']:.1f}x")
    for r in rows[1:]:
        print(f"engine/{r['bench']},{r['t_total']*1e6:.1f},"
              f"req_per_s={r['req_per_s']:.1f}")
    ok = cw["speedup"] >= 5.0
    print(f"# warm-path speedup {cw['speedup']:.1f}x "
          f"({'meets' if ok else 'BELOW'} the 5x acceptance bar)")


if __name__ == "__main__":
    main()
