"""Engine-subsystem benchmark on the `repro.db` API: cold vs warm
plan-cache latency, session throughput, and invalidation cost (issue
acceptance: warm-path latency of a constant-rebound template >= 5x lower
than the cold path).

    PYTHONPATH=src python -m benchmarks.engine_bench
    PYTHONPATH=src python benchmarks/engine_bench.py --universities 8
    PYTHONPATH=src python benchmarks/engine_bench.py --tiny   # CI smoke

Sections, printed as ``name,us_per_call,derived`` CSV lines (scaffold
contract of benchmarks/run.py) and written to results/bench/engine.json:

* ``cold_warm`` — first execution of a template (parse + SOI build/compile +
  operand upload + jit trace) vs repeated executions that only rebind
  constants (cache hit, zero retraces).  The ratio is the whole point of the
  plan cache: serving latency is the fixpoint, not compilation.
* ``throughput`` — requests/second through deadline-batched sessions at
  several bucket caps over the LUBM-like "same template, many constants"
  workload.  **Closed-loop**: the driver waits for each wave before
  offering the next, so offered load can never exceed service rate — this
  is the engine's best case, NOT a serving-capacity claim.  The open-loop
  (Poisson-arrival) capacity curve with p50/p99 vs offered load and shed
  rates lives in ``benchmarks/serve_bench.py`` / ``BENCH_serve.json``; the
  two headlines must not be conflated.
* ``invalidation`` — latency of the first query after an insert (plan
  rebuild) vs a warm query, the price of a version bump.
* ``partitioned`` (``--engine partitioned``) — the full section set runs
  through the destination-partitioned engine on a mesh of ``--devices``
  simulated host devices (``XLA_FLAGS=--xla_force_host_platform_device_
  count=N``, set before the backend initializes) and the results JSON is
  written per engine (``engine.partitioned.json``).
* ``packed_fused`` — sweep throughput of the end-to-end bit-packed engine
  (ISSUE 5): repeated solves of one compiled SOI on identical packed
  operands, normalized by sweep count, fused ``bitmm_apply`` path vs the
  pre-existing ``packed`` engine (bitmm → unpack → gather → AND chain).
  Every engine's chi — fused included — is asserted bit-identical to the
  paper's sequential ``solve_worklist`` first.  The acceptance bar is a
  >= 2x fused-over-packed sweep throughput; the run also appends a summary
  record (req/s, warm/cold, fused-vs-packed speedup) to the top-level
  ``BENCH_engine.json`` so the perf trajectory is visible across PRs.
  ``--fused-only`` runs just this section (the CI perf-smoke replay);
  the 2x bar — and the per-metric regression bands over the appended
  trajectory — are enforced afterwards by ``python -m tools.perfgate
  --check``, not by this script's exit code.  ``--tiny`` runs without it
  skip the section so a CI pipeline times the cross-engine sweep exactly
  once.
* ``rdf`` (``--rdf``) — the DBpedia/LUBM-scale RDF workload (ISSUE 8): a
  LUBM-shaped N-Triples file is stream-generated
  (``synth.lubm_stream`` -> ``rdf.dump_stream``), ingested back through the
  chunked dictionary-encoding ``rdf.load_stream``, and queried at a node
  count where the dense ``[n, n]`` operand tier is *structurally
  impossible* — the section asserts ``dense_adjacency`` raises
  ``MemoryError``, that the cost model hard-infs every dense-layout tier,
  and that auto-selection lands on an edge-list engine before timing
  cold/warm queries.  Writes ``results/bench/engine.rdf.json`` and appends
  ingest rate + query latency to ``BENCH_engine.json``.  ``--tiny`` keeps
  the workload just past the dense budget (CI smoke).
* ``mutation`` (``--mutation``) — incremental maintenance under churn
  (DESIGN.md Sect. 8): at each mutation rate, a round deletes / re-inserts
  ``rate * |E|`` random edges against two databases fed identical updates —
  one with warm-resume plan maintenance (the default), one with
  ``incremental=False`` (cold rebuild per version).  Per-round first-query
  latencies are compared, survivor masks are asserted bit-identical, and
  ``results/bench/engine.incremental.json`` records the speedups
  (ISSUE 4 acceptance: >= 5x at a <= 1% mutation rate).

    PYTHONPATH=src python benchmarks/engine_bench.py --engine partitioned --devices 8
    PYTHONPATH=src python benchmarks/engine_bench.py --mutation
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.data import synth
from repro.db import GraphDB
from repro.distributed import ctx as dctx
from repro.engine.cost import ENGINES as ALL_ENGINES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
BENCH_TOP = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")


def _mk_requests(db: GraphDB, n: int, seed: int = 0) -> list[str]:
    unis = [x for x in db.graph.node_names if x.startswith("Univ")]
    rng = np.random.default_rng(seed)
    return [
        f"{{ ?d subOrganizationOf {unis[rng.integers(len(unis))]} . "
        f"?s memberOf ?d }}"
        for _ in range(n)
    ]


def cold_warm(graph, *, engine: str = "auto", warm_iters: int = 20,
              mesh=None) -> dict:
    """Cold (first-ever) vs warm (constant-rebound) query latency."""
    db = GraphDB(graph, engine=engine, mesh=mesh)
    reqs = _mk_requests(db, warm_iters + 1)

    t0 = time.perf_counter()
    first = db.query(reqs[0])
    t_cold = time.perf_counter() - t0

    warm_times = []
    for q in reqs[1:]:
        t0 = time.perf_counter()
        res = db.query(q)
        warm_times.append(time.perf_counter() - t0)
        assert res.cache_hit, "warm request missed the plan cache"
    t_warm = float(np.median(warm_times))

    m = db.metrics()
    return {
        "bench": "cold_warm",
        "engine": first.engine,
        "t_cold": t_cold,
        "t_warm": t_warm,
        "speedup": t_cold / t_warm,
        "plan_builds": m.plan_builds,
        "cache_hits": m.cache.hits,
        "n_nodes": db.n_nodes,
        "n_triples": db.n_triples,
    }


def throughput(graph, *, engine: str = "auto", batch_sizes=(1, 4, 8, 16),
               n_requests: int = 64, mesh=None) -> list[dict]:
    """Closed-loop requests/second through sessions per bucket cap.

    Lock-step submission: a best-case engine number, not serving capacity
    — see ``benchmarks/serve_bench.py`` for the open-loop curve.
    """
    rows = []
    for batch in batch_sizes:
        db = GraphDB(graph, engine=engine, mesh=mesh)
        reqs = _mk_requests(db, n_requests, seed=batch)
        # warm pass: chunks with fewer unique constants hit smaller buckets,
        # so a full pass is needed to build every (template, bucket) plan
        for pass_no in range(2):
            if pass_no == 1:
                t0 = time.perf_counter()
            with db.session(max_delay_ms=1e6, max_pending=batch) as s:
                futures = [s.submit(q) for q in reqs]
                for f in futures:
                    f.result()
        dt = time.perf_counter() - t0
        m = db.metrics()
        rows.append({
            "bench": f"throughput_b{batch}",
            "batch": batch,
            "req_per_s": n_requests / dt,
            "t_total": dt,
            "flushes": s.flushes,
            "engines": m.engine_counts,
            "cache_hit_rate": m.cache.hit_rate,
        })
    return rows


def invalidation(graph, *, engine: str = "auto", mesh=None) -> dict:
    """Warm query vs first query after an insert (stale-plan rebuild)."""
    db = GraphDB(graph, engine=engine, mesh=mesh)
    q = _mk_requests(db, 1)[0]
    db.query(q)  # cold build
    t0 = time.perf_counter()
    db.query(q)
    t_warm = time.perf_counter() - t0

    db.insert([("DeptBench", "subOrganizationOf", "Univ0"),
               ("StudentBench", "memberOf", "DeptBench")])
    t0 = time.perf_counter()
    db.query(q)
    t_rebuild = time.perf_counter() - t0
    m = db.metrics()
    return {
        "bench": "invalidation",
        "t_warm": t_warm,
        "t_rebuild": t_rebuild,
        "rebuild_over_warm": t_rebuild / t_warm,
        "plans_invalidated": m.plan_invalidations,
        "invalidation_events": m.invalidation_events,
    }


def packed_fused(graph, *, reps: int = 5) -> dict:
    """Sweep throughput: fused packed engine vs the packed baseline.

    Both engines run the same Gauss–Seidel operator order on identical
    packed operands, so they take identical sweep counts.  Two baselines
    are timed: the packed engine in its *shipping* configuration (the
    acceptance bar — on CPU that is the interpreted Pallas kernel, exactly
    what ``plan.py`` serves today) and the packed engine on its pure-XLA
    ``use_ref`` lowering (``fused_vs_xla_speedup`` — emulation overhead
    removed, so the trajectory also records the representation + fusion
    win alone).  Before timing, every batched engine's chi is asserted
    bit-identical to the paper's sequential ``solve_worklist`` (ISSUE 5
    acceptance).
    """
    import functools

    import jax

    from repro.core import dualsim, soi, sparql
    from repro.kernels.bitmm import ops as bitmm_ops

    q = sparql.parse("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }")
    c = soi.compile_soi(soi.build_soi(q), graph)
    ref, _ = dualsim.solve_worklist(c, graph)
    for eng in ALL_ENGINES:
        chi, _ = dualsim.solve_compiled(c, graph, engine=eng)
        assert np.array_equal(chi, ref), \
            f"{eng} chi diverged from solve_worklist"

    ops = dualsim.make_packed_operands(c, graph)

    @functools.partial(jax.jit)
    def solve_packed_xla(ops):
        # the packed baseline minus kernel emulation: same bool-chi sweep,
        # boolean product via the pure-jnp bitmm oracle
        def propagate_m(chi, m):
            return bitmm_ops.bitmm(chi, ops.adj_packed[m], use_ref=True)

        return dualsim._fixpoint(propagate_m, ops, None, None, None)

    def timed(solve):
        chi, sweeps = solve(ops)  # warmup: compile outside the timing
        np.asarray(chi)
        t0 = time.perf_counter()
        for _ in range(reps):
            chi, sweeps = solve(ops)
            np.asarray(chi)  # block on the result
        return (time.perf_counter() - t0) / reps, int(sweeps), np.asarray(chi)

    t_packed, s_packed, chi_p = timed(dualsim.solve_packed)
    t_xla, s_xla, chi_x = timed(solve_packed_xla)
    t_fused, s_fused, chi_f = timed(dualsim.solve_packed_fused)
    for chi in (chi_p, chi_x, chi_f):
        assert np.array_equal(chi, ref), \
            "timed solves diverged from solve_worklist"
    per_packed = t_packed / max(s_packed, 1)
    per_xla = t_xla / max(s_xla, 1)
    per_fused = t_fused / max(s_fused, 1)
    return {
        "bench": "packed_fused",
        "sweeps": s_fused,
        "t_packed": t_packed,
        "t_packed_xla": t_xla,
        "t_fused": t_fused,
        "sweeps_per_s_packed": 1.0 / per_packed,
        "sweeps_per_s_packed_xla": 1.0 / per_xla,
        "sweeps_per_s_fused": 1.0 / per_fused,
        "fused_speedup": per_packed / per_fused,
        "fused_vs_xla_speedup": per_xla / per_fused,
        "bit_identical": True,
    }


def rdf_scale(*, universities: int, warm_iters: int = 5) -> dict:
    """Streaming RDF ingest + query past the dense-tier memory budget.

    The point of the section is the *negative space*: at this node count no
    ``[n, n]`` operand can exist, so the run first proves the dense tier is
    gone (construction raises, the cost model hard-infs it) and then shows
    the edge-list engines serving the workload anyway.
    """
    import tempfile

    from repro.core import soi, sparql
    from repro.core.graph import DENSE_ADJ_MAX_BYTES
    from repro.data import rdf
    from repro.engine.cost import choose_engine

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "lubm.nt")
        t0 = time.perf_counter()
        n_triples = rdf.dump_stream(
            synth.lubm_stream(n_universities=universities, seed=0), path
        )
        t_gen = time.perf_counter() - t0
        nt_bytes = os.path.getsize(path)
        t0 = time.perf_counter()
        graph = rdf.load_stream(path)
        t_ingest = time.perf_counter() - t0

    # -- the dense tier must be structurally impossible here ------------- #
    assert graph.n_nodes * graph.n_nodes > DENSE_ADJ_MAX_BYTES, (
        f"{graph.n_nodes} nodes still fit the dense budget; "
        "raise --universities"
    )
    try:
        graph.dense_adjacency(0)
    except MemoryError:
        pass
    else:
        raise AssertionError(
            "dense [n, n] adjacency was constructible at RDF scale"
        )
    q = "{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }"
    c = soi.compile_soi(soi.build_soi(sparql.parse(q)), graph)
    est = choose_engine(graph, c)
    for tier in ("dense", "packed", "packed_fused"):
        assert est.costs[tier] == float("inf"), (
            f"cost model priced the infeasible {tier} tier finitely"
        )
    assert est.engine in ("sparse", "jacobi_packed", "partitioned")

    # -- and the edge-list engines serve the workload anyway ------------- #
    db = GraphDB(graph, engine="auto")
    reqs = _mk_requests(db, warm_iters + 1)
    t0 = time.perf_counter()
    first = db.query(reqs[0])
    t_cold = time.perf_counter() - t0
    warm_times = []
    for req in reqs[1:]:
        t0 = time.perf_counter()
        res = db.query(req)
        warm_times.append(time.perf_counter() - t0)
        assert res.cache_hit, "warm RDF request missed the plan cache"
    return {
        "bench": "rdf",
        "universities": universities,
        "n_nodes": graph.n_nodes,
        "n_triples": n_triples,
        "nt_bytes": nt_bytes,
        "t_generate": t_gen,
        "t_ingest": t_ingest,
        "ingest_triples_per_s": n_triples / t_ingest,
        "engine": first.engine,
        "chosen_engine": est.engine,
        "t_cold": t_cold,
        "t_warm": float(np.median(warm_times)),
        "n_survivor_triples": int(np.count_nonzero(first.survivor_mask)),
        "dense_tier_infeasible": True,
    }


def append_bench_summary(entry: dict) -> None:
    """Append one run record to the top-level ``BENCH_engine.json``.

    Append-style on purpose: the *committed* file is the cross-PR perf
    trajectory — each PR that deliberately refreshes the bench commits the
    appended records (regressions were invisible while BENCH history
    stayed empty).  CI's uploaded copy is a per-run snapshot on top of
    that history, not the accumulation mechanism itself.

    The write goes through ``tools.perfgate.history`` (atomic temp-file
    replace, never drops earlier records) and every record is stamped with
    the machine fingerprint so the perf gate compares each machine only
    against its own past.
    """
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from repro.engine.machine import machine_fingerprint
    from tools.perfgate.history import append_record

    entry.setdefault("machine", machine_fingerprint())
    append_record(BENCH_TOP, entry)


def mutation(graph, *, engine: str = "auto", rates=(0.001, 0.01),
             rounds: int = 5, mesh=None) -> list[dict]:
    """Warm-resume vs cold re-solve latency under insert/delete churn.

    Each round deletes ``k = max(1, rate * |E|)`` random existing triples,
    times the first query after the version bump, then re-inserts the same
    triples and times again — every mutation is shape-stable (names stay in
    the dictionary), which is exactly the regime the resumable path serves.
    The same update + query stream drives a warm (incremental) and a cold
    (``incremental=False``) database; results are asserted identical.
    """
    rows = []
    for rate in rates:
        warm_db = GraphDB(graph, engine=engine, mesh=mesh)
        cold_db = GraphDB(graph, engine=engine, mesh=mesh, incremental=False)
        q = _mk_requests(warm_db, 1)[0]
        names = graph.node_names
        labels = graph.label_names
        rng = np.random.default_rng(int(rate * 1e6))
        k = max(1, int(rate * graph.n_edges))

        for db in (warm_db, cold_db):
            db.query(q)
        # priming round: the first warm resume traces the chi0 path once;
        # steady-state churn (what the rates measure) reuses that trace
        prime = [tuple(names[s] if i != 1 else labels[s]
                       for i, s in enumerate(graph.triples[0]))]
        for db in (warm_db, cold_db):
            db.delete(prime); db.query(q)
            db.insert(prime); db.query(q)

        t_warm, t_cold = [], []
        for _ in range(rounds):
            ids = rng.choice(graph.n_edges, size=k, replace=False)
            # dedupe: the synthetic graph may hold repeated rows, and set
            # semantics would make the delete count fall short otherwise
            batch = sorted({
                (names[s], labels[p], names[o])
                for s, p, o in graph.triples[ids]
            })
            for step in ("delete", "insert"):
                results = []
                for db, times in ((warm_db, t_warm), (cold_db, t_cold)):
                    assert getattr(db, step)(batch) == len(batch)
                    t0 = time.perf_counter()
                    results.append(db.query(q))
                    times.append(time.perf_counter() - t0)
                assert np.array_equal(
                    results[0].survivor_mask, results[1].survivor_mask
                ), "warm-resumed result diverged from cold re-solve"
        mw = warm_db.metrics()
        t_w, t_c = float(np.median(t_warm)), float(np.median(t_cold))
        rows.append({
            "bench": f"mutation_r{rate:g}",
            "rate": rate,
            "edges_per_round": k,
            "t_warm_resume": t_w,
            "t_cold_resolve": t_c,
            "speedup": t_c / t_w,
            "plans_resumed": mw.plans_resumed,
            "warm_resume_solves": mw.warm_resume_solves,
            "adj_rebuilds_saved": mw.adj_rebuilds_saved,
            "resumes_declined": mw.resumes_declined,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=8)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", *ALL_ENGINES])
    ap.add_argument("--devices", type=int, default=0,
                    help="mesh of N simulated host devices (default: 8 for "
                         "--engine partitioned, else no mesh)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--mutation", action="store_true",
                    help="also run the incremental-maintenance section and "
                         "write results/bench/engine.incremental.json")
    ap.add_argument("--fused-only", action="store_true",
                    help="run only the packed_fused sweep-throughput section "
                         "(CI perf smoke) and append to BENCH_engine.json")
    ap.add_argument("--rdf", action="store_true",
                    help="run only the RDF-scale streaming-ingest section at "
                         "a node count past the dense [n, n] budget")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: small graph, few requests")
    args = ap.parse_args()
    if args.tiny:
        args.universities = min(args.universities, 2)
        args.requests = min(args.requests, 12)
    if args.devices == 0 and args.engine == "partitioned":
        args.devices = 8

    if args.rdf:
        # ~181 nodes/university: 285 is the smallest --tiny size that still
        # clears the ~46341-node dense-infeasibility threshold
        unis = 285 if args.tiny else 600
        row = rdf_scale(universities=unis, warm_iters=3 if args.tiny else 5)
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "engine.rdf.json"), "w") as f:
            json.dump([row], f, indent=1, default=str)
        print(f"# rdf: {row['n_triples']} triples / {row['n_nodes']} nodes "
              f"({row['nt_bytes'] / 1e6:.1f} MB N-Triples); dense tier "
              f"asserted infeasible, auto chose {row['chosen_engine']}")
        print(f"engine/rdf_ingest,{row['t_ingest']*1e6:.1f},"
              f"triples_per_s={row['ingest_triples_per_s']:.0f}")
        print(f"engine/rdf_cold,{row['t_cold']*1e6:.1f},"
              f"engine={row['engine']}")
        print(f"engine/rdf_warm,{row['t_warm']*1e6:.1f},"
              f"speedup={row['t_cold'] / row['t_warm']:.1f}x")
        append_bench_summary({
            "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "bench": "rdf",
            "tiny": bool(args.tiny),
            "universities": unis,
            "n_nodes": row["n_nodes"],
            "n_triples": row["n_triples"],
            "ingest_triples_per_s": row["ingest_triples_per_s"],
            "engine": row["engine"],
            "t_cold": row["t_cold"],
            "t_warm": row["t_warm"],
            "dense_tier_infeasible": True,
        })
        return

    mesh = None
    if args.devices > 1:
        # must run before the first JAX computation initializes the backend
        dctx.force_host_device_count(args.devices)
        mesh = dctx.node_mesh(args.devices)

    graph = synth.lubm_like(n_universities=args.universities, seed=0)
    print(f"# database: {graph.n_edges} triples / {graph.n_nodes} nodes"
          + (f" on a mesh of {args.devices} devices" if mesh is not None else ""))

    # the fused section runs once per CI pipeline: the dedicated
    # --fused-only perf-smoke step covers --tiny runs, full runs keep it
    fused = None
    if args.fused_only or not args.tiny:
        fused = packed_fused(graph, reps=3 if args.tiny else 5)
        fused["n_devices"] = max(args.devices, 1)
        # informational only: the 2x fused-over-packed and 0.5x vs-XLA bars
        # are now enforced (as absolute floors, plus relative regression
        # bands) by `python -m tools.perfgate --check` over the appended
        # BENCH_engine.json record — not by an exit code here
        ok_fused = fused["fused_speedup"] >= 2.0
        ok_xla = fused["fused_vs_xla_speedup"] >= 0.5
        print(f"engine/packed_fused,{fused['t_fused']*1e6:.1f},"
              f"sweep_speedup={fused['fused_speedup']:.1f}x")
        print(f"# fused sweep throughput {fused['fused_speedup']:.1f}x over "
              f"packed ({'meets' if ok_fused else 'BELOW'} the 2x acceptance "
              f"bar), {fused['fused_vs_xla_speedup']:.1f}x over the packed "
              f"engine's pure-XLA lowering "
              f"({'meets' if ok_xla else 'BELOW'} the 0.5x floor); chi "
              f"bit-identical to solve_worklist across all engines")
    if args.fused_only:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, "engine.packed_fused.json"), "w") as f:
            json.dump([fused], f, indent=1, default=str)
        append_bench_summary({
            "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "engine": args.engine,
            "tiny": bool(args.tiny),
            "n_devices": max(args.devices, 1),
            "fused_vs_packed_sweep_speedup": fused["fused_speedup"],
            "fused_vs_xla_speedup": fused["fused_vs_xla_speedup"],
            "fused_sweeps_per_s": fused["sweeps_per_s_fused"],
            "packed_sweeps_per_s": fused["sweeps_per_s_packed"],
        })
        return

    warm_iters = 5 if args.tiny else 20
    batch_sizes = (1, 4) if args.tiny else (1, 4, 8, 16)
    rows = [cold_warm(graph, engine=args.engine, warm_iters=warm_iters,
                      mesh=mesh)]
    rows += throughput(graph, engine=args.engine, n_requests=args.requests,
                       batch_sizes=batch_sizes, mesh=mesh)
    rows.append(invalidation(graph, engine=args.engine, mesh=mesh))
    for r in rows:
        r["n_devices"] = max(args.devices, 1)

    os.makedirs(RESULTS, exist_ok=True)
    # per-engine result files so a partitioned run never clobbers the
    # single-device trajectory (CI uploads results/bench/*.json)
    name = "engine.json" if args.engine == "auto" else f"engine.{args.engine}.json"
    with open(os.path.join(RESULTS, name), "w") as f:
        json.dump(rows + ([fused] if fused else []), f, indent=1, default=str)

    mut_rows = []
    if args.mutation:
        mut_rows = mutation(graph, engine=args.engine, mesh=mesh,
                            rounds=2 if args.tiny else 5)
        for r in mut_rows:
            r["n_devices"] = max(args.devices, 1)
        with open(os.path.join(RESULTS, "engine.incremental.json"), "w") as f:
            json.dump(mut_rows, f, indent=1, default=str)

    cw = rows[0]
    print(f"engine/cold,{cw['t_cold']*1e6:.1f},engine={cw['engine']}")
    print(f"engine/warm,{cw['t_warm']*1e6:.1f},speedup={cw['speedup']:.1f}x")
    for r in rows[1:-1]:
        print(f"engine/{r['bench']},{r['t_total']*1e6:.1f},"
              f"req_per_s={r['req_per_s']:.1f}")
    print("# throughput req/s above is closed-loop (lock-step submission);"
          " open-loop capacity + shed curve: benchmarks/serve_bench.py")
    inv = rows[-1]
    print(f"engine/invalidation,{inv['t_rebuild']*1e6:.1f},"
          f"rebuild_over_warm={inv['rebuild_over_warm']:.1f}x")
    ok = cw["speedup"] >= 5.0
    print(f"# warm-path speedup {cw['speedup']:.1f}x "
          f"({'meets' if ok else 'BELOW'} the 5x acceptance bar)")
    for r in mut_rows:
        print(f"engine/{r['bench']},{r['t_warm_resume']*1e6:.1f},"
              f"speedup={r['speedup']:.1f}x")
    if mut_rows:
        best = max(r["speedup"] for r in mut_rows if r["rate"] <= 0.01)
        print(f"# warm-resume speedup {best:.1f}x at <=1% mutation rate "
              f"({'meets' if best >= 5.0 else 'BELOW'} the 5x acceptance bar)")

    append_bench_summary({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "engine": args.engine,
        "tiny": bool(args.tiny),
        "n_devices": max(args.devices, 1),
        # closed-loop: lock-step offered load (engine best case).  The
        # open-loop capacity trajectory is BENCH_serve.json.
        "loop": "closed",
        "req_per_s_best": max(r["req_per_s"] for r in rows[1:-1]),
        "t_cold": cw["t_cold"],
        "t_warm": cw["t_warm"],
        "warm_speedup": cw["speedup"],
        "fused_vs_packed_sweep_speedup": fused["fused_speedup"] if fused else None,
        "fused_vs_xla_speedup": fused["fused_vs_xla_speedup"] if fused else None,
        "fused_sweeps_per_s": fused["sweeps_per_s_fused"] if fused else None,
        "packed_sweeps_per_s": fused["sweeps_per_s_packed"] if fused else None,
        "mutation_best_speedup": (
            max(r["speedup"] for r in mut_rows) if mut_rows else None
        ),
    })


if __name__ == "__main__":
    main()
