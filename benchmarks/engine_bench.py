"""Engine-subsystem benchmark on the `repro.db` API: cold vs warm
plan-cache latency, session throughput, and invalidation cost (issue
acceptance: warm-path latency of a constant-rebound template >= 5x lower
than the cold path).

    PYTHONPATH=src python -m benchmarks.engine_bench
    PYTHONPATH=src python benchmarks/engine_bench.py --universities 8
    PYTHONPATH=src python benchmarks/engine_bench.py --tiny   # CI smoke

Sections, printed as ``name,us_per_call,derived`` CSV lines (scaffold
contract of benchmarks/run.py) and written to results/bench/engine.json:

* ``cold_warm`` — first execution of a template (parse + SOI build/compile +
  operand upload + jit trace) vs repeated executions that only rebind
  constants (cache hit, zero retraces).  The ratio is the whole point of the
  plan cache: serving latency is the fixpoint, not compilation.
* ``throughput`` — requests/second through deadline-batched sessions at
  several bucket caps over the LUBM-like "same template, many constants"
  workload.
* ``invalidation`` — latency of the first query after an insert (plan
  rebuild) vs a warm query, the price of a version bump.
* ``partitioned`` (``--engine partitioned``) — the full section set runs
  through the destination-partitioned engine on a mesh of ``--devices``
  simulated host devices (``XLA_FLAGS=--xla_force_host_platform_device_
  count=N``, set before the backend initializes) and the results JSON is
  written per engine (``engine.partitioned.json``).

    PYTHONPATH=src python benchmarks/engine_bench.py --engine partitioned --devices 8
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.data import synth
from repro.db import GraphDB
from repro.distributed import ctx as dctx

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def _mk_requests(db: GraphDB, n: int, seed: int = 0) -> list[str]:
    unis = [x for x in db.graph.node_names if x.startswith("Univ")]
    rng = np.random.default_rng(seed)
    return [
        f"{{ ?d subOrganizationOf {unis[rng.integers(len(unis))]} . "
        f"?s memberOf ?d }}"
        for _ in range(n)
    ]


def cold_warm(graph, *, engine: str = "auto", warm_iters: int = 20,
              mesh=None) -> dict:
    """Cold (first-ever) vs warm (constant-rebound) query latency."""
    db = GraphDB(graph, engine=engine, mesh=mesh)
    reqs = _mk_requests(db, warm_iters + 1)

    t0 = time.perf_counter()
    first = db.query(reqs[0])
    t_cold = time.perf_counter() - t0

    warm_times = []
    for q in reqs[1:]:
        t0 = time.perf_counter()
        res = db.query(q)
        warm_times.append(time.perf_counter() - t0)
        assert res.cache_hit, "warm request missed the plan cache"
    t_warm = float(np.median(warm_times))

    m = db.metrics()
    return {
        "bench": "cold_warm",
        "engine": first.engine,
        "t_cold": t_cold,
        "t_warm": t_warm,
        "speedup": t_cold / t_warm,
        "plan_builds": m.plan_builds,
        "cache_hits": m.cache.hits,
        "n_nodes": db.n_nodes,
        "n_triples": db.n_triples,
    }


def throughput(graph, *, engine: str = "auto", batch_sizes=(1, 4, 8, 16),
               n_requests: int = 64, mesh=None) -> list[dict]:
    """Requests/second through deadline-batched sessions per bucket cap."""
    rows = []
    for batch in batch_sizes:
        db = GraphDB(graph, engine=engine, mesh=mesh)
        reqs = _mk_requests(db, n_requests, seed=batch)
        # warm pass: chunks with fewer unique constants hit smaller buckets,
        # so a full pass is needed to build every (template, bucket) plan
        for pass_no in range(2):
            if pass_no == 1:
                t0 = time.perf_counter()
            with db.session(max_delay_ms=1e6, max_pending=batch) as s:
                futures = [s.submit(q) for q in reqs]
                for f in futures:
                    f.result()
        dt = time.perf_counter() - t0
        m = db.metrics()
        rows.append({
            "bench": f"throughput_b{batch}",
            "batch": batch,
            "req_per_s": n_requests / dt,
            "t_total": dt,
            "flushes": s.flushes,
            "engines": m.engine_counts,
            "cache_hit_rate": m.cache.hit_rate,
        })
    return rows


def invalidation(graph, *, engine: str = "auto", mesh=None) -> dict:
    """Warm query vs first query after an insert (stale-plan rebuild)."""
    db = GraphDB(graph, engine=engine, mesh=mesh)
    q = _mk_requests(db, 1)[0]
    db.query(q)  # cold build
    t0 = time.perf_counter()
    db.query(q)
    t_warm = time.perf_counter() - t0

    db.insert([("DeptBench", "subOrganizationOf", "Univ0"),
               ("StudentBench", "memberOf", "DeptBench")])
    t0 = time.perf_counter()
    db.query(q)
    t_rebuild = time.perf_counter() - t0
    m = db.metrics()
    return {
        "bench": "invalidation",
        "t_warm": t_warm,
        "t_rebuild": t_rebuild,
        "rebuild_over_warm": t_rebuild / t_warm,
        "plans_invalidated": m.plan_invalidations,
        "invalidation_events": m.invalidation_events,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=8)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "dense", "packed", "sparse",
                             "jacobi_packed", "partitioned"])
    ap.add_argument("--devices", type=int, default=0,
                    help="mesh of N simulated host devices (default: 8 for "
                         "--engine partitioned, else no mesh)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: small graph, few requests")
    args = ap.parse_args()
    if args.tiny:
        args.universities = min(args.universities, 2)
        args.requests = min(args.requests, 12)
    if args.devices == 0 and args.engine == "partitioned":
        args.devices = 8

    mesh = None
    if args.devices > 1:
        # must run before the first JAX computation initializes the backend
        dctx.force_host_device_count(args.devices)
        mesh = dctx.node_mesh(args.devices)

    graph = synth.lubm_like(n_universities=args.universities, seed=0)
    print(f"# database: {graph.n_edges} triples / {graph.n_nodes} nodes"
          + (f" on a mesh of {args.devices} devices" if mesh is not None else ""))

    warm_iters = 5 if args.tiny else 20
    batch_sizes = (1, 4) if args.tiny else (1, 4, 8, 16)
    rows = [cold_warm(graph, engine=args.engine, warm_iters=warm_iters,
                      mesh=mesh)]
    rows += throughput(graph, engine=args.engine, n_requests=args.requests,
                       batch_sizes=batch_sizes, mesh=mesh)
    rows.append(invalidation(graph, engine=args.engine, mesh=mesh))
    for r in rows:
        r["n_devices"] = max(args.devices, 1)

    os.makedirs(RESULTS, exist_ok=True)
    # per-engine result files so a partitioned run never clobbers the
    # single-device trajectory (CI uploads results/bench/*.json)
    name = "engine.json" if args.engine == "auto" else f"engine.{args.engine}.json"
    with open(os.path.join(RESULTS, name), "w") as f:
        json.dump(rows, f, indent=1, default=str)

    cw = rows[0]
    print(f"engine/cold,{cw['t_cold']*1e6:.1f},engine={cw['engine']}")
    print(f"engine/warm,{cw['t_warm']*1e6:.1f},speedup={cw['speedup']:.1f}x")
    for r in rows[1:-1]:
        print(f"engine/{r['bench']},{r['t_total']*1e6:.1f},"
              f"req_per_s={r['req_per_s']:.1f}")
    inv = rows[-1]
    print(f"engine/invalidation,{inv['t_rebuild']*1e6:.1f},"
          f"rebuild_over_warm={inv['rebuild_over_warm']:.1f}x")
    ok = cw["speedup"] >= 5.0
    print(f"# warm-path speedup {cw['speedup']:.1f}x "
          f"({'meets' if ok else 'BELOW'} the 5x acceptance bar)")


if __name__ == "__main__":
    main()
