"""Generate the EXPERIMENTS.md §Roofline table from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import sys

PEAK = {"compute_s": "compute", "memory_s": "memory", "collective_s": "collective"}


def main(mesh_filter: str | None = None) -> None:
    rows = [json.load(open(f)) for f in sorted(glob.glob("results/dryrun/*.json"))]
    order = {"pod": 0, "multipod": 1}
    rows.sort(key=lambda r: (r["arch"], r["cell"], order.get(r["mesh"], 2)))
    print("| arch | cell | mesh | GiB/dev | compute_s | memory_s | coll_s "
          "| dominant | frac@dom | MODEL/HLO |")
    print("|---|---|---|---:|---:|---:|---:|---|---:|---:|")
    for r in rows:
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['cell']} | {r['mesh']} | — | — | — | — "
                  f"| *skip: sub-quadratic attn required* | — | — |")
            continue
        t = r["roofline"]
        tot = sum(t.values())
        dom = t[r["dominant"]]
        # roofline fraction: time the dominant term would take alone over the
        # sum (overlap-free pessimistic bound); 1.0 = perfectly balanced on
        # the bottleneck.
        frac = dom / tot if tot else 0.0
        ur = r.get("useful_flops_ratio")
        urs = f"{ur:.2f}" if ur is not None else "—"
        print(f"| {r['arch']} | {r['cell']} | {r['mesh']} "
              f"| {r['bytes_per_device']/2**30:.2f} "
              f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
              f"| {t['collective_s']:.2e} | {PEAK[r['dominant']]} "
              f"| {frac:.2f} | {urs} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
