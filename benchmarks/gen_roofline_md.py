"""Render the probed machine specs under ``results/machine/`` as Markdown.

The seed version of this script globbed a results directory nothing
produces anymore.  It now renders the output of the live probe
(``benchmarks/roofline.py`` → ``MachineSpec`` JSON): one row per
probed machine, the ceilings the calibrated cost model is derived from
(DESIGN.md Sect. 13.2), suitable for pasting into EXPERIMENTS.md or a PR
description.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(spec_dir: str | None = None) -> list[dict]:
    """All persisted machine specs, sorted by fingerprint."""
    if spec_dir is None:
        spec_dir = os.path.join(
            os.path.dirname(__file__), "..", "results", "machine"
        )
    rows = []
    for fn in sorted(glob.glob(os.path.join(spec_dir, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return sorted(rows, key=lambda r: r.get("fingerprint", ""))


def main(spec_dir: str | None = None) -> None:
    """Print the Markdown table of probed machines."""
    rows = load(spec_dir)
    if not rows:
        print("(no machine specs probed yet — run "
              "`PYTHONPATH=src python benchmarks/roofline.py`)")
        return
    print("| machine | backend | stream GB/s | dense Gelem/s "
          "| packed Mw/s | xla Mw/s | launch µs | dispatch µs "
          "| trace ms | coll GB/s | fast |")
    print("|---|---|---:|---:|---:|---:|---:|---:|---:|---:|---|")
    for r in rows:
        coll = r.get("collective_bytes_per_s")
        coll_s = f"{coll / 1e9:.2f}" if coll else "—"
        print(
            f"| `{r['fingerprint']}` | {r['backend']} "
            f"| {r['stream_bytes_per_s'] / 1e9:.2f} "
            f"| {r['dense_elems_per_s'] / 1e9:.2f} "
            f"| {r['packed_words_per_s'] / 1e6:.1f} "
            f"| {r['packed_words_per_s_xla'] / 1e6:.1f} "
            f"| {r['kernel_launch_s'] * 1e6:.1f} "
            f"| {r['dispatch_s'] * 1e6:.1f} "
            f"| {r['trace_s'] * 1e3:.1f} "
            f"| {coll_s} | {r.get('fast', False)} |"
        )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
