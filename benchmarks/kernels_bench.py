"""Engine micro-benchmarks: boolean-product engines on one sweep operator.

CPU numbers are indicative only (the Pallas kernel runs in interpret mode);
the architectural comparison that matters on TPU is captured by the roofline
analysis.  Reported anyway so `benchmarks.run` exercises every engine.

Two sections:

* :func:`bitmm_micro` — dense/packed boolean product (the adjacency-matrix
  tier).
* :func:`segor_micro` — the ISSUE-8 segmented-OR sweep step of the
  edge-list tier: the retired bool path (unpack chi -> bool messages ->
  ``segment_max`` -> bool y plane -> bool per-var gather+all ->
  ``bitops.pack`` -> AND) against the packed path (word gather ->
  ``segor`` -> word per-var gather+AND), kernel vs ref vs XLA-words
  lowerings, with the >= 2x packed-over-bool bar documented in the output.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.kernels.bitmm import ops as bitmm_ops
from repro.kernels.bitmm import ref as bitmm_ref
from repro.kernels.segsum import kernel as seg_kernel
from repro.kernels.segsum import ref as seg_ref


def bitmm_micro(n: int = 2048, v: int = 8, density: float = 0.01,
                repeats: int = 5) -> list[dict]:
    rng = np.random.default_rng(0)
    a = rng.random((n, n)) < density
    x = rng.random((v, n)) < 0.5
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    xj = jnp.asarray(x)
    af = jnp.asarray(a, jnp.float32)

    def t(fn):
        fn()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t_ref = t(jax.jit(lambda: bitmm_ref.bitmm_ref(xj, ap, n)))
    t_mxu = t(jax.jit(lambda: (xj.astype(jnp.float32) @ af) > 0))
    t_pallas = t(lambda: bitmm_ops.bitmm(xj, ap, interpret=True))
    bytes_packed = n * n / 8
    bytes_f32 = n * n * 4
    return [dict(
        bench="bitmm", n=n, v=v, density=density,
        t_ref_unpack_matmul=t_ref, t_dense_f32_matmul=t_mxu,
        t_pallas_interpret=t_pallas,
        hbm_bytes_packed=bytes_packed, hbm_bytes_f32=bytes_f32,
        packed_traffic_ratio=bytes_f32 / bytes_packed,
    )]


def segor_micro(n: int = 131_072, v: int = 24, e: int = 32_768,
                repeats: int = 5) -> list[dict]:
    """One edge-list sweep step (propagate + per-var mask + chi AND).

    ``t_bool_path`` is the exact pre-ISSUE-8 composition the edge engines
    ran per sweep per operator: unpack the packed chi, gather bool
    messages, segment-reduce into a bool ``[V, n]`` y plane, bool per-var
    gather + ``all``, then ``bitops.pack`` the result back.  The packed
    path never leaves uint32 words — the n-proportional traffic shrinks
    8x as bytes (32x as lanes) and both plane converts disappear.  The
    acceptance bar is ``packed_over_bool >= 2``.

    The default shape is the *serving* regime the edge engines run at:
    ``v = 24`` chi rows is a batched plan (bucket of 8 constants x a
    3-variable template), ``e = 32k`` is one label's edge list in a
    LUBM-like graph of ``n = 128k`` nodes (per-operator edges are E/M,
    far below n*v).  There the n-proportional plane traffic dominates and
    the packed representation pays off; edge-dominated shapes (e >> n*v/8)
    pin both paths on the shared int8 segment reduce and show ~1x.
    """
    rng = np.random.default_rng(1)
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst_np = rng.integers(0, n, e).astype(np.int32)
    dst = jnp.asarray(dst_np)
    chi = rng.random((v, n)) < 0.5
    chi_p = jnp.asarray(bitops.pack_np(chi))
    # a representative operator table: v inequalities, 2 rhs vars each
    rhs = jnp.asarray(rng.integers(0, v, v).astype(np.int32))
    table = jnp.asarray(rng.integers(0, v, (v, 2)).astype(np.int32))
    ones_row = np.uint32(0xFFFFFFFF)

    def edge_bits(cp):
        word = cp[:, src // 32]
        return ((word >> (src % 32).astype(jnp.uint32)) & 1).astype(jnp.int8)

    @jax.jit
    def bool_path():
        cb = bitops.unpack(chi_p, n)  # [V, n] bool plane
        msgs = cb[:, src].astype(jnp.int8)
        y = jax.ops.segment_max(msgs.T, dst, num_segments=n)
        yb = (jnp.maximum(y, 0) > 0).T  # bool y plane
        vals = jnp.concatenate([yb[rhs], jnp.ones((1, n), bool)])
        per_var = jnp.all(vals[table], axis=1)
        return bitops.pack(jnp.logical_and(cb, per_var))  # per-sweep pack

    def masked_and(y_p):
        nw = y_p.shape[-1]
        vals = jnp.concatenate([y_p[rhs], jnp.full((1, nw), ones_row)])
        per_var = jax.lax.reduce(
            vals[table], ones_row, jax.lax.bitwise_and, (1,)
        )
        return jnp.bitwise_and(chi_p, per_var)

    @jax.jit
    def packed_words():
        return masked_and(seg_ref.segor_words(edge_bits(chi_p), dst, n))

    @jax.jit
    def packed_ref():
        return masked_and(seg_ref.segor_ref(edge_bits(chi_p), dst, n))

    outs = [np.asarray(f()) for f in (bool_path, packed_words, packed_ref)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)

    def t(fn):
        fn()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t_bool = t(bool_path)
    t_words = t(packed_words)
    t_ref = t(packed_ref)

    # Pallas lowering at a reduced shape: interpret mode emulates the grid
    # step by step, so full-shape timings would be all emulator (the bitmm
    # caveat at the top of the module).  Parity is still asserted.
    nk, ek = 4096, 4096
    src_k = rng.integers(0, nk, ek).astype(np.int32)
    dst_k = rng.integers(0, nk, ek).astype(np.int32)
    bits_k = (rng.random((v, ek)) < 0.4).astype(np.int8)
    idx_b, seg_b, win, _ = seg_kernel.prepare_segor(dst_k, nk)
    vals_b = jnp.asarray(bits_k[:, idx_b].transpose(1, 2, 0))
    seg_bj, winj = jnp.asarray(seg_b), jnp.asarray(win)

    def packed_kernel():
        return seg_kernel.segor_blocks(
            vals_b, seg_bj, winj, num_segments=nk, interpret=True
        )

    np.testing.assert_array_equal(
        np.asarray(packed_kernel()),
        np.asarray(seg_ref.segor_ref(jnp.asarray(bits_k),
                                     jnp.asarray(dst_k), nk)),
    )
    t_kernel = t(packed_kernel)

    speedup = t_bool / t_words
    return [dict(
        bench="segor", n=n, v=v, e=e,
        t_bool_path=t_bool, t_packed_words=t_words, t_packed_ref=t_ref,
        t_pallas_interpret=t_kernel, kernel_shape=f"n={nk},e={ek}",
        packed_over_bool=speedup,
        meets_2x_bar=bool(speedup >= 2.0),
        bit_identical=True,
    )]
