"""Engine micro-benchmarks: boolean-product engines on one sweep operator.

CPU numbers are indicative only (the Pallas kernel runs in interpret mode);
the architectural comparison that matters on TPU is captured by the roofline
analysis.  Reported anyway so `benchmarks.run` exercises every engine.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.kernels.bitmm import ops as bitmm_ops
from repro.kernels.bitmm import ref as bitmm_ref


def bitmm_micro(n: int = 2048, v: int = 8, density: float = 0.01,
                repeats: int = 5) -> list[dict]:
    rng = np.random.default_rng(0)
    a = rng.random((n, n)) < density
    x = rng.random((v, n)) < 0.5
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    xj = jnp.asarray(x)
    af = jnp.asarray(a, jnp.float32)

    def t(fn):
        fn()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t_ref = t(jax.jit(lambda: bitmm_ref.bitmm_ref(xj, ap, n)))
    t_mxu = t(jax.jit(lambda: (xj.astype(jnp.float32) @ af) > 0))
    t_pallas = t(lambda: bitmm_ops.bitmm(xj, ap, interpret=True))
    bytes_packed = n * n / 8
    bytes_f32 = n * n * 4
    return [dict(
        bench="bitmm", n=n, v=v, density=density,
        t_ref_unpack_matmul=t_ref, t_dense_f32_matmul=t_mxu,
        t_pallas_interpret=t_pallas,
        hbm_bytes_packed=bytes_packed, hbm_bytes_f32=bytes_f32,
        packed_traffic_ratio=bytes_f32 / bytes_packed,
    )]
