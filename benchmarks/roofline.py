"""Roofline table assembly: reads results/dryrun/*.json produced by
launch/dryrun.py and emits the per-(arch x cell x mesh) roofline terms
(EXPERIMENTS.md §Roofline is generated from this)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load() -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def table(mesh: str = "pod") -> list[dict]:
    out = []
    for r in load():
        if r.get("mesh") != mesh:
            continue
        if r.get("skipped"):
            out.append(dict(arch=r["arch"], cell=r["cell"], mesh=mesh,
                            skipped=r["skipped"]))
            continue
        if not r.get("ok"):
            out.append(dict(arch=r["arch"], cell=r["cell"], mesh=mesh,
                            error=r.get("error")))
            continue
        t = r["roofline"]
        out.append(dict(
            arch=r["arch"], cell=r["cell"], mesh=mesh,
            gib_per_dev=round(r["bytes_per_device"] / 2**30, 2),
            compute_s=t["compute_s"], memory_s=t["memory_s"],
            collective_s=t["collective_s"], dominant=r["dominant"],
            model_flops=r["model_flops"], hlo_flops=r["hlo_flops"],
            useful_ratio=r["useful_flops_ratio"],
        ))
    return out
