"""Berkeley-ERT-style machine probe → ``MachineSpec`` (DESIGN.md 13.1).

Replaces the seed's dead roofline-table assembly (which read a results
directory no launcher produces anymore) with the measurement layer the
calibrated cost model runs on.  Four sweeps, each a
micro-kernel the engines actually execute, best-of-N timed like ERT:

* **stream** — sustained memory bandwidth: a jitted ``uint32`` XOR stream
  over working sets from cache-resident to HBM/DRAM-resident (the
  segmented-OR sweep is this workload: packed chi planes + edge id
  streams).  Peak across sizes is the spec's ``stream_bytes_per_s``.
* **bitop** — ``bitmm_apply`` word throughput at increasing ``n`` (the
  arithmetic intensity grows with ``n``: ``V*n*n/32`` word-ops over
  ``n*n/8`` resident bytes), under BOTH lowerings the plans ship — the
  kernel path (interpret mode on CPU, compiled Pallas elsewhere) and the
  word-wise XLA path.  The smallest size gives the per-call overheads
  (``kernel_launch_s`` / ``dispatch_s``), the largest the sustained
  words/s, launch-corrected.
* **dense** — boolean matmul via the f32 MXU/BLAS path, exactly the dense
  engine's product, giving ``dense_elems_per_s``.
* **collective** — on a >= 2-device mesh only: a pmap'd ``psum`` over a
  replicated plane, giving ``collective_bytes_per_s`` (per-byte collective
  cost for the comm terms); ``None`` on one device.

Plus a **trace** probe: wall time to ``jit``-lower-and-compile a
representative packed ``while_loop`` fixpoint — the resume-vs-cold model's
``trace_cost``.

The result persists as a versioned JSON under ``results/machine/`` keyed by
:func:`repro.engine.machine.machine_fingerprint`, where
:func:`repro.engine.machine.default_spec` (and so the engine/serving cost
paths) and ``tools/perfgate`` find it.  ``--fast`` runs the reduced CI
sweep (fewer sizes/repeats — noisier, still valid calibration).
"""
from __future__ import annotations

import argparse
import datetime
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.kernels.bitmm import ops as bitmm_ops


def _best_s(fn, repeats: int) -> float:
    """Best-of-N wall seconds of ``fn`` (first call compiles, untimed)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def stream_probe(fast: bool = False, repeats: int | None = None) -> dict:
    """Peak sustained streaming bandwidth over ``uint32`` word traffic.

    Read + write one word per element (8 bytes moved per word): the traffic
    shape of the packed-chi planes and edge-id streams the segor sweep
    moves.  Sweeps working sets past typical LLC sizes so the peak is a
    memory number, not a cache number.
    """
    sizes = [1 << 20, 1 << 22] if fast else [1 << 20, 1 << 22, 1 << 24]
    repeats = repeats or (3 if fast else 5)
    rng = np.random.default_rng(0)
    rows = []
    for words in sizes:
        x = jnp.asarray(
            rng.integers(0, 2**32, words, dtype=np.uint64).astype(np.uint32)
        )
        f = jax.jit(lambda x: x ^ np.uint32(0x9E3779B9))
        t = _best_s(lambda: f(x), repeats)
        rows.append(dict(words=words, seconds=t, bytes_per_s=8.0 * words / t))
    return dict(rows=rows, bytes_per_s=max(r["bytes_per_s"] for r in rows))


def bitop_probe(
    backend: str, fast: bool = False, repeats: int | None = None
) -> dict:
    """``bitmm_apply`` word throughput + per-call overheads, both lowerings.

    Work per call is ``V * n * n/32`` word-ops (every output word ORs over
    all ``n`` adjacency rows).  ``shipping`` is the lowering plans actually
    run on this backend (interpret-mode kernel on CPU, compiled Pallas
    kernel elsewhere); ``xla`` is the word-wise pure-jnp lowering.  The
    cheapest call bounds the per-call overhead; the best words/seconds
    across sizes is the sustained rate — max/min extraction instead of a
    launch subtraction, which is fragile when small-size timings are
    non-monotonic (observed with the interpret emulator).
    """
    repeats = repeats or (3 if fast else 5)
    v = 8
    rng = np.random.default_rng(1)
    # interpret mode emulates the grid step by step: it needs modest shapes
    # to finish in CI time, and its measured throughput IS the shipping
    # cost the calibrated model should charge packed plans on CPU
    ship_ns = [64, 256, 1024] if backend == "cpu" else [64, 1024, 4096]
    xla_ns = [64, 1024, 2048] if fast else [64, 1024, 4096]
    if fast and backend == "cpu":
        ship_ns = [64, 512]

    def measure(ns, run):
        rows = []
        for n in ns:
            nw = bitops.packed_width(n)
            a = jnp.asarray(bitops.pack_np(rng.random((n, n)) < 0.01))
            chi = jnp.asarray(bitops.pack_np(rng.random((v, n)) < 0.5))
            flags = jnp.asarray(rng.integers(0, 2, (v, v)).astype(np.uint32))
            t = _best_s(lambda: run(chi, a, flags), repeats)
            rows.append(dict(n=n, seconds=t, words=v * n * nw))
        overhead = min(r["seconds"] for r in rows)
        words_per_s = max(r["words"] / r["seconds"] for r in rows)
        return dict(rows=rows, overhead_s=overhead, words_per_s=words_per_s)

    ship = measure(
        ship_ns,
        lambda c, a, f: bitmm_ops.bitmm_apply(
            c, a, f, interpret=(backend == "cpu")
        ),
    )
    xla = measure(
        xla_ns, lambda c, a, f: bitmm_ops.bitmm_apply(c, a, f, use_ref=True)
    )
    return dict(shipping=ship, xla=xla)


def dense_probe(fast: bool = False, repeats: int | None = None) -> dict:
    """Boolean-matmul element throughput via the dense engine's f32 path."""
    repeats = repeats or (3 if fast else 5)
    v = 16
    ns = [1024, 2048] if fast else [1024, 2048, 4096]
    rng = np.random.default_rng(2)
    rows = []
    for n in ns:
        x = jnp.asarray(rng.random((v, n)) < 0.5)
        af = jnp.asarray((rng.random((n, n)) < 0.01).astype(np.float32))
        f = jax.jit(lambda x, a: (x.astype(jnp.float32) @ a) > 0)
        t = _best_s(lambda: f(x, af), repeats)
        rows.append(dict(n=n, seconds=t, elems_per_s=v * n * n / t))
    return dict(rows=rows, elems_per_s=max(r["elems_per_s"] for r in rows))


def collective_probe(
    backend: str, fast: bool = False, repeats: int | None = None
) -> dict | None:
    """Per-byte collective cost over the visible mesh; ``None`` below 2 devices.

    An all-reduce ``psum`` of a float32 plane: the measured bytes/s is the
    *payload* rate (one plane's bytes over the call's wall time) — an
    envelope for the comm terms, not a bisection-bandwidth claim.
    """
    devices = jax.devices(backend)
    d = len(devices)
    if d < 2:
        return None
    repeats = repeats or (3 if fast else 5)
    words = 1 << 16 if fast else 1 << 18
    x = jnp.ones((d, words), jnp.float32)
    f = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
    t = _best_s(lambda: f(x), repeats)
    payload = words * 4.0
    return dict(n_devices=d, words=words, seconds=t, bytes_per_s=payload / t)


def trace_probe(n: int = 2048, v: int = 8, sweeps: int = 8) -> float:
    """Seconds to jit-trace + lower + compile a packed while_loop fixpoint.

    The shape of every plan's solver: packed chi state, a
    changed-word-driven ``while_loop``, one fused operator application per
    step.  A lower bound on a real plan's cold trace (which adds SOI build
    and operand upload), measured rather than folklore.
    """
    nw = bitops.packed_width(n)
    rng = np.random.default_rng(3)
    a = jnp.asarray(bitops.pack_np(rng.random((n, n)) < 0.01))
    flags = jnp.asarray(rng.integers(0, 2, (v, v)).astype(np.uint32))

    def fixpoint(chi):
        def cond(state):
            _, it, changed = state
            return jnp.logical_and(it < sweeps, changed != 0)

        def body(state):
            chi, it, _ = state
            chi2, changed = bitmm_ops.bitmm_apply(chi, a, flags, use_ref=True)
            return chi2, it + 1, changed

        out, _, _ = jax.lax.while_loop(
            cond, body, (chi, jnp.int32(0), jnp.uint32(1))
        )
        return out

    shape = jax.ShapeDtypeStruct((v, nw), jnp.uint32)
    t0 = time.perf_counter()
    jax.jit(fixpoint).lower(shape).compile()
    return time.perf_counter() - t0


def probe(fast: bool = False, backend: str | None = None):
    """Run every sweep; returns ``(MachineSpec, per-sweep detail dict)``."""
    from repro.engine import machine

    backend = backend or jax.default_backend()
    devices = jax.devices(backend)
    stream = stream_probe(fast)
    bitop = bitop_probe(backend, fast)
    dense = dense_probe(fast)
    coll = collective_probe(backend, fast)
    trace_s = trace_probe(1024 if fast else 2048)
    cpu = backend == "cpu"
    ship_wps = bitop["shipping"]["words_per_s"]
    xla_wps = bitop["xla"]["words_per_s"]
    spec = machine.MachineSpec(
        backend=backend,
        device_kind=devices[0].device_kind if devices else "unknown",
        fingerprint=machine.machine_fingerprint(backend),
        n_devices=len(devices),
        stream_bytes_per_s=stream["bytes_per_s"],
        dense_elems_per_s=dense["elems_per_s"],
        packed_words_per_s=ship_wps,
        packed_words_per_s_xla=xla_wps,
        # the fused engine ships the words lowering on CPU, the kernel
        # elsewhere — same measurement base as the packed engine's; the
        # fusion advantage shows up in the launch/overhead terms
        fused_words_per_s=xla_wps if cpu else ship_wps,
        kernel_launch_s=bitop["shipping"]["overhead_s"],
        dispatch_s=bitop["xla"]["overhead_s"],
        trace_s=trace_s,
        collective_bytes_per_s=coll["bytes_per_s"] if coll else None,
        probed_at=datetime.datetime.now(datetime.timezone.utc).isoformat(),
        fast=fast,
    )
    detail = dict(stream=stream, bitop=bitop, dense=dense, collective=coll)
    return spec, detail


def main(argv: list[str] | None = None) -> int:
    """CLI: run the probe, print the spec, persist under ``results/machine/``."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="reduced CI sweep (fewer sizes/repeats)")
    ap.add_argument("--no-save", action="store_true",
                    help="print only; do not persist the spec")
    ap.add_argument("--json", action="store_true",
                    help="dump the spec as JSON to stdout")
    ap.add_argument("--backend", default=None,
                    help="jax backend to probe (default: process default)")
    args = ap.parse_args(argv)
    spec, _ = probe(fast=args.fast, backend=args.backend)
    if args.json:
        print(json.dumps(spec.to_json(), indent=1, sort_keys=True))
    else:
        for k, v in sorted(spec.to_json().items()):
            print(f"machine/{k},{v}")
    if not args.no_save:
        from repro.engine import machine

        path = machine.save_spec(spec)
        print(f"# saved {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
