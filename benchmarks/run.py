"""Benchmark runner: one section per paper table + engine micro-bench +
the machine probe.  Prints ``name,us_per_call,derived`` CSV lines per
row (scaffold contract) and writes results/bench/*.json."""
from __future__ import annotations

import json
import os
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def _emit(section: str, rows: list[dict], time_key: str | None) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{section}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    for r in rows:
        us = (r.get(time_key, 0.0) or 0.0) * 1e6 if time_key else 0.0
        derived = ";".join(
            f"{k}={v}" for k, v in r.items()
            if k != time_key and not isinstance(v, (list, dict))
        )
        print(f"{section}/{r.get('query', r.get('bench', r.get('arch', '?')))},"
              f"{us:.1f},{derived}")


def main() -> None:
    from . import kernels_bench, roofline, tables

    sections = sys.argv[1:] or [
        "table2", "table3", "table4", "table5", "iterations",
        "kernels", "roofline",
    ]
    t0 = time.time()
    if "table2" in sections:
        _emit("table2_soi_vs_ma", tables.table2_soi_vs_ma(), "t_soi_dense")
    if "table3" in sections:
        _emit("table3_pruning", tables.table3_pruning(), "t_sparqlsim")
    if "table4" in sections:
        _emit("table4_rdfox_style", tables.table4_join_pruned_selectivity(),
              "t_db_pruned")
    if "table5" in sections:
        _emit("table5_virtuoso_style", tables.table5_join_pruned_syntactic(),
              "t_db_pruned")
    if "iterations" in sections:
        _emit("iterations_sect53", tables.iterations_analysis(), None)
    if "kernels" in sections:
        _emit("kernels_micro", kernels_bench.bitmm_micro(), "t_pallas_interpret")
        _emit("kernels_segor", kernels_bench.segor_micro(), "t_packed_words")
    if "roofline" in sections:
        # ERT-style machine probe (DESIGN.md 13.1): persists the MachineSpec
        # under results/machine/ for the calibrated cost model + perf gate,
        # and mirrors it into results/bench/ like every other section
        spec, _ = roofline.probe(fast=True)
        from repro.engine import machine as machine_mod

        machine_mod.save_spec(spec)
        _emit("machine_probe", [dict(bench="machine", **spec.to_json())], None)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
