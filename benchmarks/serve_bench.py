"""Open-loop saturation benchmark of the async serving loop (ISSUE 6).

``benchmarks/engine_bench.py`` reports *closed-loop* req/s: the driver
submits a batch, waits for it, submits the next — offered load can never
exceed service rate, so the number measures the engine at its best, not
the server under pressure.  This benchmark is the honest complement: a
Poisson arrival process offers load the server did not agree to, swept
from below to far above capacity, and reports what a capacity claim
actually needs — goodput, p50/p99 latency of *completed* requests, and the
shed rate (explicit ``overloaded`` / ``deadline`` / ``cost`` outcomes from
:class:`repro.serve.AsyncServer`; an overloaded open-loop server that
*doesn't* shed shows unbounded queue growth instead, which is the failure
mode admission control exists to prevent).

    PYTHONPATH=src python benchmarks/serve_bench.py
    PYTHONPATH=src python benchmarks/serve_bench.py --smoke   # CI gate

Output: one ``offered,goodput,p50_ms,p99_ms,shed_rate`` CSV row per load
point, ``results/bench/serve.json``, and an appended record in the
top-level ``BENCH_serve.json`` trajectory (append-style like
``BENCH_engine.json``: committed history, not a per-run snapshot).

``--smoke`` asserts the ISSUE 6 acceptance criteria on a fixed-seed sweep:
>= 3 offered-load points; every submitted future resolved with an explicit
outcome; the overload point sheds; and the tail of what *was* served stays
bounded — every completed request's queue wait is below the deadline
(dispatch sheds expired requests instead of executing them), so p99
latency is bounded by ``deadline + slowest service`` no matter how hard
the arrival process overshoots.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import numpy as np

from repro.data import synth
from repro.db import GraphDB
from repro.serve import OUTCOMES, AsyncServer

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
BENCH_TOP = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

QUERY = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


def _requests(db: GraphDB, n: int, seed: int) -> list[str]:
    unis = [x for x in db.graph.node_names if x.startswith("Univ")]
    rng = np.random.default_rng(seed)
    return [QUERY.format(uni=unis[rng.integers(len(unis))]) for _ in range(n)]


async def _warmup(server: AsyncServer, db: GraphDB, seed: int) -> float:
    """Build every (bucket, replica) plan the sweep will hit; returns the
    burst capacity (closed-loop req/s through the server) used to place
    the offered-load points relative to what the machine can actually do.
    """
    unis = [x for x in db.graph.node_names if x.startswith("Univ")]
    distinct = [QUERY.format(uni=u) for u in unis]
    # the microbatcher dedups by constants, so a dispatched batch holds at
    # most len(distinct) unique instances; warm every bucket size a sweep
    # batch can chunk into, on every replica — a cold jit trace landing
    # mid-sweep would otherwise stall the queue and poison the low-load
    # point's tail
    buckets = server.router.replicas[0].engine.buckets
    sizes = sorted(
        {b for b in buckets if b <= min(server.max_batch, len(distinct))}
        | {1}
    )
    for size in sizes:
        # enough rounds that least-in-flight routing lands every replica
        for _ in range(2 * len(server.router) + 1):
            await asyncio.gather(*[
                server.submit(q, deadline_ms=60_000)
                for q in distinct[:size]
            ])
    reqs = _requests(db, server.max_batch, seed)
    t0 = time.monotonic()
    burst = [server.submit(q, deadline_ms=60_000) for q in reqs * 4]
    results = await asyncio.gather(*burst)
    dt = time.monotonic() - t0
    assert all(r.ok for r in results), "warmup burst must not shed"
    return len(burst) / dt


async def _run_point(
    server: AsyncServer,
    db: GraphDB,
    *,
    rate: float,
    n: int,
    seed: int,
    deadline_ms: float,
) -> dict:
    """Offer ``n`` requests at Poisson rate ``rate``; measure the outcome.

    Arrival times are pre-drawn and absolute: when the event loop falls
    behind the schedule (overload is the whole point), late arrivals fire
    back-to-back instead of silently stretching the offered rate.
    """
    rng = np.random.default_rng(seed)
    reqs = _requests(db, n, seed + 1)
    gaps = rng.exponential(1.0 / rate, size=n)
    t_start = time.monotonic()
    arrivals = t_start + np.cumsum(gaps)
    futs = []
    for q, t_due in zip(reqs, arrivals):
        delay = t_due - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        futs.append(server.submit(
            q, tenant=f"t{len(futs) % 2}", deadline_ms=deadline_ms
        ))
    results = await asyncio.gather(*futs)
    wall = time.monotonic() - t_start

    assert len(results) == n, "every submitted request must resolve"
    outcomes = {o: 0 for o in OUTCOMES}
    for r in results:
        outcomes[r.outcome] += 1
    done = sorted(r.total_ms for r in results if r.ok)
    queue_waits = [r.queue_ms for r in results if r.ok]
    service = [r.service_ms for r in results if r.ok]
    shed = n - outcomes["ok"] - outcomes["error"]

    def pct(xs, q):
        return float(xs[min(int(q * len(xs)), len(xs) - 1)]) if xs else 0.0

    return {
        "offered_req_s": rate,
        "n": n,
        "duration_s": wall,
        "completed": outcomes["ok"],
        "goodput_req_s": outcomes["ok"] / wall,
        "outcomes": outcomes,
        "shed_rate": shed / n,
        "p50_ms": pct(done, 0.50),
        "p99_ms": pct(done, 0.99),
        "queue_p99_ms": pct(sorted(queue_waits), 0.99),
        "queue_max_ms": max(queue_waits, default=0.0),
        "service_max_ms": max(service, default=0.0),
    }


async def _sweep(args) -> tuple:
    db = GraphDB(synth.lubm_like(n_universities=args.universities, seed=0))
    print(f"# database: {db.n_triples} triples / {db.n_nodes} nodes, "
          f"{args.replicas} replicas")
    async with AsyncServer(
        db,
        replicas=args.replicas,
        max_queue=args.max_queue,
        max_batch=args.max_batch,
        max_delay_ms=args.max_delay_ms,
        default_deadline_ms=args.deadline_ms,
    ) as server:
        capacity = await _warmup(server, db, seed=args.seed)
        print(f"# warm burst capacity ~{capacity:.0f} req/s "
              f"(closed-loop, the ceiling the sweep is placed against)")
        points = []
        for mult in args.multipliers:
            point = await _run_point(
                server, db,
                rate=mult * capacity,
                n=args.n_per_point,
                seed=args.seed + int(mult * 1000),
                deadline_ms=args.deadline_ms,
            )
            point["load_multiplier"] = mult
            points.append(point)
            print(
                f"serve/open_loop_x{mult:g},{point['p99_ms']*1e3:.0f},"
                f"offered={point['offered_req_s']:.0f},"
                f"goodput={point['goodput_req_s']:.0f},"
                f"p50_ms={point['p50_ms']:.2f},p99_ms={point['p99_ms']:.2f},"
                f"shed_rate={point['shed_rate']:.2f}"
            )
        snap = server.metrics.snapshot()
    return points, capacity, snap, db


def _append_trajectory(entry: dict) -> None:
    """Append one record to the committed ``BENCH_serve.json`` history.

    Shares ``tools.perfgate.history`` with ``engine_bench`` so the write is
    atomic and append-only, and stamps the machine fingerprint so the perf
    gate keeps per-machine series separate.
    """
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from repro.engine.machine import machine_fingerprint
    from tools.perfgate.history import append_record

    entry.setdefault("machine", machine_fingerprint())
    append_record(BENCH_TOP, entry)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--n-per-point", type=int, default=400)
    ap.add_argument("--multipliers", type=float, nargs="+",
                    default=[0.5, 1.0, 1.5, 4.0],
                    help="offered load as multiples of measured capacity")
    ap.add_argument("--deadline-ms", type=float, default=500.0)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: small fixed-seed sweep + acceptance "
                         "asserts (explicit sheds under overload, bounded "
                         "p99 of completed requests, zero unresolved)")
    args = ap.parse_args()
    if args.smoke:
        args.universities = min(args.universities, 2)
        args.n_per_point = min(args.n_per_point, 120)
        if len(args.multipliers) < 3:
            raise SystemExit("--smoke needs >= 3 offered-load points")

    points, capacity, snap, db = asyncio.run(_sweep(args))

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "serve.json"), "w") as f:
        json.dump({"capacity_burst_req_s": capacity, "points": points,
                   "metrics": dataclass_dict(snap)}, f, indent=1, default=str)

    _append_trajectory({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": bool(args.smoke),
        "replicas": args.replicas,
        "n_triples": db.n_triples,
        "deadline_ms": args.deadline_ms,
        "max_queue": args.max_queue,
        "capacity_burst_req_s": capacity,
        "points": [
            {k: p[k] for k in (
                "load_multiplier", "offered_req_s", "goodput_req_s",
                "p50_ms", "p99_ms", "shed_rate", "outcomes",
            )}
            for p in points
        ],
    })

    low, high = points[0], points[-1]
    bound_ms = args.deadline_ms * 1.25 + high["service_max_ms"]
    print(f"# sweep: {len(points)} load points, shed rate "
          f"{low['shed_rate']:.2f} -> {high['shed_rate']:.2f}, "
          f"overload p99 {high['p99_ms']:.1f} ms "
          f"(bound {bound_ms:.1f} ms = 1.25x deadline + slowest batch)")

    if args.smoke:
        # acceptance (ISSUE 6): explicit sheds under overload, and the tail
        # of admitted-and-served requests bounded by the deadline contract
        assert len(points) >= 3, "saturation sweep needs >= 3 points"
        assert high["shed_rate"] > 0.0, \
            "overload point must shed with explicit outcomes"
        assert low["shed_rate"] <= 0.5, \
            f"below-capacity point shed {low['shed_rate']:.0%}"
        for p in points:
            assert p["queue_max_ms"] <= args.deadline_ms * 1.25, (
                f"completed request waited {p['queue_max_ms']:.1f} ms "
                f"past the {args.deadline_ms} ms deadline"
            )
            assert p["p99_ms"] <= bound_ms, \
                f"p99 {p['p99_ms']:.1f} ms exceeds the {bound_ms:.1f} ms bound"
        # after stop() drains, every submitted request (warmup included)
        # must be accounted for by exactly one explicit outcome
        assert snap.submitted == snap.resolved, \
            "drained server left futures unaccounted"
        print("# smoke acceptance: sheds explicit, p99 bounded, "
              "zero unresolved futures")


def dataclass_dict(snap) -> dict:
    """MetricsSnapshot -> plain json-able dict."""
    import dataclasses

    return dataclasses.asdict(snap)


if __name__ == "__main__":
    main()
