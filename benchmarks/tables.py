"""Paper-table benchmark implementations (Tables 2-5 + Sect. 5.3 analysis).

Each function returns a list of row dicts and is invoked by benchmarks.run.
Databases are scaled-down synthetics (CPU container); the comparisons are
the paper's own: SOI engines vs Ma et al. (Table 2), pruning effectiveness
(Table 3), downstream join evaluation full-vs-pruned under two join-order
policies (Tables 4/5), and the sweep-count analysis (Sect. 5.3).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import dualsim, join, pruning, soi, sparql
from repro.core.graph import Graph, subgraph_triples
from repro.core.ma_baseline import dual_simulation_ma
from repro.core.hhk import dual_simulation_hhk
from . import workloads


def _best_of(fn, n=3):
    best = float("inf")
    out = None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _pattern_of(query, g):
    """Union-free BGP-ish pattern graph for the Ma/HHK baselines (they take
    graphs, not queries — the paper strips OPTIONAL for Table 2 likewise)."""
    parts = sparql.union_split(query)
    triples = []
    var_ids: dict[str, int] = {}
    for part in parts[:1]:
        s = soi.build_soi(part)
        for v, a, w in s.pattern_edges:
            la = (
                g.label_names.index(a)
                if isinstance(a, str) and a in g.label_names
                else (a if isinstance(a, int) else 0)
            )
            triples.append((v, la, w))
        n = s.n_vars
    return Graph.from_arrays(n, g.n_labels, np.asarray(triples, np.int64))


def table2_soi_vs_ma(repeats: int = 3) -> list[dict]:
    """Runtime of the SOI engines vs Ma et al.'s algorithm (+HHK).

    Matches the paper's setup: adjacency structures are resident (operand
    construction excluded); timed portion = fixpoint solve only."""
    import jax

    dbs = workloads.databases()
    rows = []
    for name, dbk, q in workloads.queries():
        g = dbs[dbk]
        pat = _pattern_of(q, g)
        c = soi.compile_soi(dualsim.pattern_graph_soi(pat), g)
        ops_d = dualsim.make_dense_operands(c, g)
        ops_s = dualsim.make_sparse_operands(c, g)
        g._build_csr()  # Ma/HHK/worklist adjacency maps resident too

        def run_dense():
            return jax.block_until_ready(dualsim.solve_dense(ops_d))

        def run_sparse():
            return jax.block_until_ready(dualsim.solve_sparse(ops_s))

        run_dense(), run_sparse()  # compile warmup
        t_dense, (_, it_d) = _best_of(run_dense, repeats)
        t_sparse, _ = _best_of(run_sparse, repeats)
        t_wl, (_, evals) = _best_of(lambda: dualsim.solve_worklist(c, g), repeats)
        t_ma, (s_ma, passes) = _best_of(lambda: dual_simulation_ma(pat, g), 1)
        t_hhk, _ = _best_of(lambda: dual_simulation_hhk(pat, g), 1)
        rows.append(dict(
            query=name, db=dbk,
            t_soi_dense=t_dense,
            t_soi_sparse=t_sparse,
            t_worklist=t_wl,
            t_ma=t_ma, t_hhk=t_hhk,
            sweeps=int(it_d), worklist_evals=int(evals), ma_passes=passes,
            speedup_vs_ma=t_ma / max(t_sparse, 1e-9),
        ))
    return rows


def table3_pruning() -> list[dict]:
    """Result sizes, required triples, t_sim, triples after pruning."""
    dbs = workloads.databases()
    rows = []
    for name, dbk, q in workloads.queries():
        g = dbs[dbk]
        t0 = time.perf_counter()
        mask = np.zeros(g.n_edges, dtype=bool)
        for part in sparql.union_split(q):
            s = soi.build_soi(part)
            c = soi.compile_soi(s, g)
            chi, _ = dualsim.solve_worklist(c, g)  # compile-free SOI solve
            m, _ = pruning.prune_triples(s, chi, g)
            mask |= m
        t_sim = time.perf_counter() - t0
        matches = join.evaluate(q, g)
        req = join.required_triples(q, g, matches)
        rows.append(dict(
            query=name, db=dbk, results=matches.n_rows, req_triples=req,
            t_sparqlsim=t_sim, triples_after=int(mask.sum()),
            db_triples=g.n_edges,
            pruned_frac=1 - int(mask.sum()) / g.n_edges,
        ))
    return rows


def _table_45(join_order: str) -> list[dict]:
    dbs = workloads.databases()
    rows = []
    for name, dbk, q in workloads.queries():
        g = dbs[dbk]
        t0 = time.perf_counter()
        mask = np.zeros(g.n_edges, dtype=bool)
        for part in sparql.union_split(q):
            s = soi.build_soi(part)
            c = soi.compile_soi(s, g)
            chi, _ = dualsim.solve_worklist(c, g)  # compile-free SOI solve
            m, _ = pruning.prune_triples(s, chi, g)
            mask |= m
        t_sim = time.perf_counter() - t0
        pruned = subgraph_triples(g, mask)
        t_full, full = _best_of(lambda: join.evaluate(q, g, join_order=join_order))
        t_pruned, pr = _best_of(
            lambda: join.evaluate(q, pruned, join_order=join_order))
        # soundness: no match lost.  Non-well-designed patterns may GAIN
        # rows (pruned optional partners turn bound rows into unbound ones
        # that cross-join more freely — paper Sect. 4.5); equality holds for
        # well-designed queries (asserted in tests/test_system.py).
        assert pr.n_rows >= full.n_rows, (name, full.n_rows, pr.n_rows)
        rows.append(dict(
            query=name, db=dbk, t_db=t_full, t_db_pruned=t_pruned,
            t_pruned_plus_sim=t_pruned + t_sim, results=full.n_rows,
        ))
    return rows


def table4_join_pruned_selectivity() -> list[dict]:
    """RDFox-style (selectivity-ordered) downstream joins."""
    return _table_45("selectivity")


def table5_join_pruned_syntactic() -> list[dict]:
    """Virtuoso-default-style (syntactic-order) downstream joins."""
    return _table_45("syntactic")


def iterations_analysis() -> list[dict]:
    """Sect. 5.3: sweep counts, Jacobi batched vs sequential worklist, on the
    cyclic low-selectivity queries where the paper observed >30 iterations."""
    dbs = workloads.databases()
    rows = []
    for name, dbk, q in workloads.queries():
        if not name.startswith(("L0", "L1", "L2")):
            continue
        g = dbs[dbk]
        for part in sparql.union_split(q):
            s = soi.build_soi(part)
            c = soi.compile_soi(s, g)
            _, sweeps = dualsim.solve_compiled(c, g, engine="dense")
            _, evals_sparse = dualsim.solve_worklist(c, g, heuristic="sparse_first")
            _, evals_fifo = dualsim.solve_worklist(c, g, heuristic="fifo")
            rows.append(dict(
                query=name, db=dbk, jacobi_sweeps=sweeps,
                worklist_evals_sparse_first=evals_sparse,
                worklist_evals_fifo=evals_fifo,
                ineqs=len(c.ineq_lhs),
            ))
    return rows
