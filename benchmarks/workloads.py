"""Shared benchmark workloads: scaled-down LUBM/DBpedia instances and the
query set mirroring the paper's B/L/D families (Sect. 5.1)."""
from __future__ import annotations

from repro.core import sparql
from repro.data import synth


def databases():
    return {
        "lubm": synth.lubm_like(
            n_universities=12, depts_per_uni=6, profs_per_dept=8,
            students_per_dept=40, pubs_per_prof=4, seed=0,
        ),
        "dbpedia": synth.dbpedia_like(
            n_nodes=4000, n_labels=40, n_edges=24_000, seed=0
        ),
    }


def queries():
    """(name, db_key, query) — cyclic/low-selectivity (L-family), chain and
    star patterns (B-family), constants and OPTIONALs (D-family)."""
    qs = []
    qs.append(("L0_cyclic", "lubm", synth.lubm_l0_like()))
    qs.append(("L1_pub2auth", "lubm", synth.lubm_l1_like()))
    qs.append(("L2_advisor", "lubm", sparql.parse(
        "{ ?s advisor ?p . ?s memberOf ?d . ?p worksFor ?d }")))
    qs.append(("L3_opt", "lubm", synth.optional_query()))
    qs.append(("L4_deep_star", "lubm", sparql.parse(
        "{ ?p worksFor ?d . ?s advisor ?p . ?pub publicationAuthor ?p }")))
    qs.append(("L5_const", "lubm", sparql.parse(
        "{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }")))
    for i in range(6):
        a, b, c = f"p{i}", f"p{i+1}", f"p{i+2}"
        qs.append((f"B{i}_chain", "dbpedia", sparql.parse(
            f"{{ ?x {a} ?y . ?y {b} ?z }}")))
        qs.append((f"B{i}_star", "dbpedia", sparql.parse(
            f"{{ ?x {a} ?y . ?x {b} ?z . ?x {c} ?w }}")))
    qs.append(("D0_opt", "dbpedia", sparql.parse(
        "{ ?x p0 ?y } OPTIONAL { ?y p1 ?z }")))
    qs.append(("D1_nwd", "dbpedia", sparql.parse(
        "{ { ?a p0 ?b } OPTIONAL { ?c p1 ?b } } AND { ?c p2 ?d }")))
    qs.append(("D2_union", "dbpedia", sparql.parse(
        "{ ?x p0 ?y } UNION { ?x p1 ?y }")))
    return qs
