"""Mutate-and-requery loop on the `repro.db` API: incremental maintenance
of dual-simulation plans across graph updates (DESIGN.md Sect. 8).

A serving process that mutates its graph used to pay a full plan rebuild
(SOI compile + operand upload + jit trace) on the first query after every
version bump.  With the delta log + warm-resume machinery the same loop
patches the superseded plan in place and resumes the fixpoint from the
previous solution chi — deletions resume directly (the greatest dual
simulation only shrinks), insertions re-seed just the destabilized rows.

    PYTHONPATH=src python examples/incremental_updates.py
"""
import os
import sys
import time

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # allow running from any cwd without PYTHONPATH
    sys.path.insert(
        0,
        os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        ),
    )

from repro.data import synth
from repro.db import GraphDB, Q


def main() -> None:
    db = GraphDB(synth.lubm_like(n_universities=3, seed=0))
    print(db)

    members_of = (
        Q.triple("?d", "subOrganizationOf", "Univ0")
         .triple("?s", "memberOf", "?d")
    )

    # cold build: SOI compile + operand upload + jit trace
    t0 = time.perf_counter()
    rs = db.query(members_of)
    print(f"cold    v{db.version}: {len(rs)} survivors "
          f"in {(time.perf_counter() - t0) * 1e3:7.1f} ms")

    # pick a surviving member edge to churn (names stay in the dictionary,
    # so every following mutation is shape-stable => resumable)
    edge = [next(t for t in rs.survivor_triples() if t[1] == "memberOf")]

    for round_no in range(3):
        assert db.delete(edge) == 1
        t0 = time.perf_counter()
        rs = db.query(members_of)  # superseded plan patched + warm-resumed
        print(f"delete  v{db.version}: {len(rs)} survivors "
              f"in {(time.perf_counter() - t0) * 1e3:7.1f} ms (warm resume)")

        assert db.insert(edge) == 1
        t0 = time.perf_counter()
        rs = db.query(members_of)  # insertion re-seeds destabilized rows
        print(f"insert  v{db.version}: {len(rs)} survivors "
              f"in {(time.perf_counter() - t0) * 1e3:7.1f} ms (warm resume)")

    # a dictionary-growing insert cannot be patched: classified cold
    db.insert([("DeptNew", "subOrganizationOf", "Univ0"),
               ("StudentNew", "memberOf", "DeptNew")])
    t0 = time.perf_counter()
    rs = db.query(members_of)
    print(f"cold    v{db.version}: {len(rs)} survivors "
          f"in {(time.perf_counter() - t0) * 1e3:7.1f} ms (new nodes)")

    m = db.metrics()
    print(
        f"\nmetrics: {m.plans_resumable} plans reclassified resumable, "
        f"{m.plans_resumed} patched + resumed, {m.warm_resume_solves} "
        f"warm-started solves, {m.resumes_declined} declined, "
        f"{m.plan_invalidations} cold invalidations, "
        f"{m.adj_rebuilds_saved} adjacency rebuilds saved"
    )


if __name__ == "__main__":
    main()
