"""End-to-end per-query database pruning on a LUBM-like instance — the
paper's Sect. 5 application: dual simulation as a pruning preprocessor for a
downstream join engine, with timings for full vs pruned evaluation.

    PYTHONPATH=src python examples/pruning_pipeline.py
"""
import time

import numpy as np

from repro.core import dualsim, join, pruning, soi, sparql
from repro.core.graph import subgraph_triples
from repro.data import synth

db = synth.lubm_like(n_universities=10, depts_per_uni=5, profs_per_dept=6,
                     students_per_dept=30, pubs_per_prof=3, seed=0)
print(f"database: {db.n_edges} triples, {db.n_nodes} nodes, "
      f"{db.n_labels} predicates")

for qname, query in [("L1 (publication/2 authors)", synth.lubm_l1_like()),
                     ("L0 (cyclic triangle)", synth.lubm_l0_like()),
                     ("optional-heavy", synth.optional_query())]:
    print(f"\n=== {qname} ===")
    t0 = time.perf_counter()
    mask = np.zeros(db.n_edges, dtype=bool)
    sweeps = 0
    for part in sparql.union_split(query):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, db)
        chi, it = dualsim.solve_compiled(c, db, engine="dense")
        m, _ = pruning.prune_triples(s, chi, db)
        mask |= m
        sweeps = max(sweeps, int(it))
    t_sim = time.perf_counter() - t0
    pruned = subgraph_triples(db, mask)

    t0 = time.perf_counter()
    full = join.evaluate(query, db)
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    pr = join.evaluate(query, pruned)
    t_pruned = time.perf_counter() - t0
    assert full.n_rows == pr.n_rows  # soundness: identical result sets

    print(f"  dual simulation: {t_sim*1e3:8.1f} ms  ({sweeps} sweeps)")
    print(f"  triples: {db.n_edges} -> {int(mask.sum())} "
          f"({1 - mask.sum()/db.n_edges:.1%} pruned)")
    print(f"  join on full DB:   {t_full*1e3:8.1f} ms  ({full.n_rows} results)")
    print(f"  join on pruned DB: {t_pruned*1e3:8.1f} ms  "
          f"(speedup {t_full/max(t_pruned,1e-9):.1f}x)")
