"""Quickstart: dual-simulation query processing on the paper's Fig. 1 data.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dualsim, join, pruning, soi, sparql
from repro.core.graph import Graph

# the movie database from Fig. 1(a)
db = Graph.from_triples([
    ("B._De_Palma", "directed", "Mission_Impossible"),
    ("B._De_Palma", "worked_with", "D._Koepp"),
    ("D._Koepp", "worked_with", "B._De_Palma"),
    ("D._Koepp", "directed", "Secret_Window"),
    ("G._Hamilton", "directed", "Goldfinger"),
    ("G._Hamilton", "worked_with", "T._Young"),
    ("T._Young", "directed", "Dr._No"),
    ("Saint_John", "population", "70063"),
])

# query (X2): directors of movies, optionally with a coworker
query = sparql.parse(
    "{ ?director directed ?movie } OPTIONAL { ?director worked_with ?coworker }"
)

# 1. build + solve the system of inequalities (largest dual simulation)
s = soi.build_soi(query)
c = soi.compile_soi(s, db)
chi, sweeps = dualsim.solve_compiled(c, db, engine="dense")
names = np.array(db.node_names)
print(f"largest dual simulation ({sweeps} sweeps):")
for var, row in soi.collect(s, chi).items():
    print(f"  ?{var:<10} -> {list(names[row])}")

# 2. prune the database (Sect. 5: >95% of triples disqualified at scale)
pruned, stats = pruning.pruned_graph(s, chi, db)
print(f"\npruning: {stats.n_triples} -> {stats.n_after} triples "
      f"({stats.fraction_pruned:.0%} pruned)")

# 3. evaluate the query (downstream join processor) on the pruned DB
matches = join.evaluate(query, pruned)
print(f"\n{matches.n_rows} SPARQL matches on the pruned database:")
for i in range(matches.n_rows):
    row = {v: (names[x[i]] if x[i] >= 0 else "-") for v, x in matches.cols.items()}
    print("  ", row)
