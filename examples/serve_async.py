"""`repro.serve` tour: admission control, tenant fairness, replica
routing, and streaming delivery over one GraphDB (DESIGN.md Sect. 10).

    PYTHONPATH=src python examples/serve_async.py
"""
import asyncio
import os
import sys

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # allow running from any cwd without PYTHONPATH
    sys.path.insert(
        0,
        os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        ),
    )

from repro.data import synth
from repro.db import GraphDB, Q
from repro.serve import AsyncServer, stream_pages


def member_query(uni: str) -> Q:
    return (Q.triple("?d", "subOrganizationOf", uni)
             .triple("?s", "memberOf", "?d"))


async def main() -> None:
    db = GraphDB(synth.lubm_like(n_universities=3, seed=0))
    print(db)

    async with AsyncServer(
        db, replicas=2, max_queue=32, max_delay_ms=5.0,
        default_deadline_ms=5000.0,
        tenant_weights={"alice": 1.0, "bob": 1.0},
    ) as server:
        # two tenants share the warm engine; deficit round robin keeps
        # bob's trickle served while alice storms
        futs = [
            server.submit(member_query(f"Univ{i % 3}"), tenant="alice")
            for i in range(16)
        ]
        futs += [
            server.submit(member_query("Univ0"), tenant="bob")
            for _ in range(2)
        ]
        results = await asyncio.gather(*futs)
        outcomes = {r.outcome for r in results}
        print(f"outcomes: {sorted(outcomes)} "
              f"(every request resolves to an explicit outcome)")

        # streaming delivery: paginate a survivor set asynchronously
        first_ok = next(r for r in results if r.ok)
        pages = 0
        async for page in stream_pages(first_ok.result, page_size=25):
            pages += 1
        print(f"streamed {len(first_ok.result)} survivors in {pages} pages "
              f"of <= 25 (replica {first_ok.replica}, "
              f"queue {first_ok.queue_ms:.2f} ms)")

        # a request with an impossible deadline is shed, never executed
        shed = await server.submit(member_query("Univ1"), tenant="alice",
                                   deadline_ms=0.0)
        print(f"impossible deadline -> outcome={shed.outcome!r} "
              f"({shed.detail})")

        # mutation epoch: writers go through the GraphDB as usual; a fence
        # advances every replica so later reads see the new version
        db.insert([("DeptNew", "subOrganizationOf", "Univ0"),
                   ("StudentNew", "memberOf", "DeptNew")])
        version = await server.fence()
        after = await server.submit(member_query("Univ0"), tenant="bob")
        assert ("StudentNew", "memberOf", "DeptNew") in after.result.page(
            0, len(after.result)
        )
        print(f"after insert (fenced to v{version}): "
              f"{len(after.result)} survivors")

        snap = server.metrics.snapshot()
        print(
            f"metrics: {snap.completed}/{snap.submitted} completed, "
            f"shed={dict(snap.shed)}, queue peak {snap.queue_peak}, "
            f"p50 {snap.latency['p50_ms']:.1f} ms, per-tenant "
            + str({t: d["completed"] for t, d in sorted(
                snap.per_tenant.items())})
        )


if __name__ == "__main__":
    asyncio.run(main())
