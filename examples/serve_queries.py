"""Batched dual-simulation query serving demo (see launch/serve.py).

    PYTHONPATH=src python examples/serve_queries.py
"""
import os
import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.serve", "--batch", "4",
       "--requests", "12", "--engine", "auto"]
print("+", " ".join(cmd))
# inherit the full environment (virtualenvs need their own PATH/PYTHONPATH);
# just make sure the repo's src/ is importable from any cwd.
env = dict(os.environ)
src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
env["PYTHONPATH"] = src + (
    os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
)
subprocess.run(cmd, check=True, env=env)
