"""`repro.db` public-API tour: GraphDB, fluent builder, sessions, lazy
result sets, and versioned plan invalidation (DESIGN.md Sect. 6).

Throughput printed here is *closed-loop* (the driver waits for each batch
before submitting more) — an engine number, not a serving-capacity claim.
For the admission-controlled async front end and the open-loop saturation
benchmark, see ``examples/serve_async.py`` and
``benchmarks/serve_bench.py``.

    PYTHONPATH=src python examples/serve_queries.py
"""
import os
import sys

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # allow running from any cwd without PYTHONPATH
    sys.path.insert(
        0,
        os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        ),
    )

from repro.data import synth
from repro.db import GraphDB, Q


def main() -> None:
    db = GraphDB(synth.lubm_like(n_universities=3, seed=0))
    print(db)

    # fluent builder instead of string formatting; round-trips via parse()
    members_of = (
        Q.triple("?d", "subOrganizationOf", "Univ0")
         .triple("?s", "memberOf", "?d")
    )
    print("query:", members_of.sparql())

    rs = db.query(members_of)
    print(rs)
    print("  departments:", rs.bindings("d"))
    print("  first page of survivors:", rs.page(0, 3))

    # sessions batch same-template requests into one fixpoint solve
    with db.session(max_delay_ms=50, max_pending=8) as session:
        futures = [
            session.submit(
                Q.triple("?d", "subOrganizationOf", f"Univ{i % 3}")
                 .triple("?s", "memberOf", "?d")
            )
            for i in range(8)
        ]
        results = [f.result() for f in futures]
    m = db.metrics()
    print(
        f"session: {len(results)} requests in {session.flushes} flush(es), "
        f"{m.microbatches} fixpoint solves, cache hit rate "
        f"{m.cache.hit_rate:.0%}"
    )

    # mutation: version bump -> precise plan invalidation, lazily rebuilt
    db.insert([("DeptNew", "subOrganizationOf", "Univ0"),
               ("StudentNew", "memberOf", "DeptNew")])
    rs2 = db.query(members_of)
    assert ("StudentNew", "memberOf", "DeptNew") in list(rs2.survivor_triples())
    m = db.metrics()
    print(
        f"after insert (v{db.version}): {len(rs2)} survivors, "
        f"{m.plan_invalidations} plans invalidated, "
        f"{m.invalidation_events} invalidation event(s)"
    )


if __name__ == "__main__":
    main()
