"""Batched dual-simulation query serving demo (see launch/serve.py).

    PYTHONPATH=src python examples/serve_queries.py
"""
import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.serve", "--batch", "4",
       "--requests", "12", "--engine", "sparse"]
print("+", " ".join(cmd))
subprocess.run(cmd, check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
