"""End-to-end LM training driver demo (reduced config, CPU-runnable):
trains a reduced internlm2-family model for a few hundred steps with
checkpointing, then simulates a node failure and restarts from the last
committed checkpoint — the fault-tolerance path of launch/train.py.

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys
import tempfile

with tempfile.TemporaryDirectory() as d:
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "internlm2-1.8b", "--reduced",
        "--steps", "120", "--batch", "8", "--seq", "64",
        "--ckpt-dir", d, "--ckpt-every", "40",
        "--inject-failure-at", "90",
    ]
    print("+", " ".join(cmd))
    subprocess.run(cmd, check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
