"""Sharded, fault-tolerant checkpointing.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per leaf (flattened tree
paths as file names), a ``manifest.json`` with tree structure, mesh shape,
step and integrity hashes, and a ``COMMIT`` marker written last — a
half-written checkpoint (host died mid-save) is never considered loadable.

* **async** — ``save(..., background=True)`` runs serialization on a worker
  thread so the train loop only blocks on device->host transfer.
* **elastic restore** — leaves are saved unsharded (gathered); ``restore``
  re-shards onto whatever mesh the new job runs with, so scaling the
  ``data`` axis up/down between runs just works.
* **integrity** — sha256 per leaf, verified on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    raw = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    return _SAFE.sub("_", raw) or "root"


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    background: bool = False,
    keep: int = 3,
) -> threading.Thread | None:
    """Write a checkpoint; returns the worker thread if background=True."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    # device->host happens here (the only synchronous part)
    host = [(_leaf_name(p), np.asarray(l)) for p, l in leaves]
    treedef = jax.tree_util.tree_structure(tree)

    def _write():
        out = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = out + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": [], "treedef": str(treedef)}
        for name, arr in host:
            np.save(os.path.join(tmp, name + ".npy"), arr)
            with open(os.path.join(tmp, name + ".npy"), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
                 "sha256": digest}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.isdir(out):
            shutil.rmtree(out)
        os.rename(tmp, out)
        _gc(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore(
    ckpt_dir: str,
    like: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
    verify: bool = True,
) -> tuple[Any, int]:
    """Load the latest (or given) committed step into the structure of
    ``like``; re-shard with ``shardings`` (tree of NamedSharding) if given."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {ckpt_dir}")
    step = steps[-1] if step is None else step
    base = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    digests = {l["name"]: l["sha256"] for l in manifest["leaves"]}

    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
    )
    leaves = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        name = _leaf_name(path)
        fn = os.path.join(base, name + ".npy")
        if verify:
            with open(fn, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != digests[name]:
                    raise IOError(f"checksum mismatch for {name}")
        arr = np.load(fn)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), step
