"""Architecture registry: ``get(arch_id)`` returns an ArchSpec."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "internlm2-1.8b",
    "qwen3-8b",
    "yi-6b",
    "olmoe-1b-7b",
    "mixtral-8x7b",
    "gatedgcn",
    "gat-cora",
    "pna",
    "schnet",
    "dcn-v2",
    "dualsim-lubm",
    "dualsim-dbpedia",
]


def get(arch_id: str):
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}"
    )
    return mod.SPEC
