"""ArchSpec: the uniform contract between configs, the dry-run driver and
the roofline analyzer.

Each spec exposes, per shape cell:

* ``abstract_state(cell)`` / ``abstract_inputs(cell)`` — ShapeDtypeStruct
  pytrees (no allocation; the full configs are only ever lowered).
* ``step(cell)``          — the jit-able function: ``step(state, batch)``.
* ``state_shardings/input_shardings(mesh, cell)`` — PartitionSpec pytrees.
* ``model_flops(cell)``   — "useful" FLOPs (6·N·D train / 2·N·D inference;
  family-specific for GNN/recsys/dualsim) for the roofline's
  MODEL_FLOPS / HLO_FLOPs ratio.
* ``reduced()``           — a tiny same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import shard as sh
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import sampler as sampler_mod
from repro.models import steps as steps_mod
from repro.models import transformer as tr
from repro.optimizer import adamw


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | dualsim
    batch: int = 0
    seq: int = 0
    microbatches: int = 1
    extras: dict = dataclasses.field(default_factory=dict)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _specs_to_shardings(mesh: Mesh, tree_specs, tree_shapes):
    """PartitionSpec tree -> NamedSharding tree, with safe_spec fallback."""

    def one(spec, leaf):
        return NamedSharding(mesh, sh.safe_spec(tuple(leaf.shape), spec, mesh))

    return jax.tree.map(one, tree_specs, tree_shapes,
                        is_leaf=lambda x: isinstance(x, P))


# ===================================================================== #
# LM family
# ===================================================================== #
LM_SHAPES = {
    # microbatches=8: 4 would halve FSDP gather traffic (−32% collective,
    # §Perf qwen3 iteration 3) but blows the 16 GiB/dev budget at 8B scale
    # (17.4/20.4 GiB) — rejected on memory; revisit with sequence sharding.
    "train_4k": Cell("train_4k", "train", batch=256, seq=4096, microbatches=8),
    "prefill_32k": Cell("prefill_32k", "prefill", batch=32, seq=32768),
    "decode_32k": Cell("decode_32k", "decode", batch=128, seq=32768),
    "long_500k": Cell("long_500k", "decode", batch=1, seq=524288),
}


class LMArch:
    family = "lm"

    def __init__(self, cfg: tr.LMConfig, opt: adamw.AdamWConfig | None = None):
        self.id = cfg.name
        self.cfg = cfg
        self.opt = opt or adamw.AdamWConfig()

    def cells(self) -> dict[str, Cell]:
        return dict(LM_SHAPES)

    def skip_reason(self, cell_name: str) -> str | None:
        if cell_name == "long_500k" and self.cfg.full_attention:
            return (
                "long_500k requires sub-quadratic attention; "
                f"{self.id} uses full attention (see DESIGN.md)"
            )
        return None

    # -------------------------- state ------------------------------- #
    def _serve_cfg(self) -> tr.LMConfig:
        return dataclasses.replace(
            self.cfg, param_dtype=jnp.bfloat16, remat=False
        )

    def abstract_params(self, serve: bool) -> Any:
        cfg = self._serve_cfg() if serve else self.cfg
        return jax.eval_shape(
            functools.partial(tr.init_params, cfg), jax.random.PRNGKey(0)
        )

    def abstract_state(self, cell: Cell) -> Any:
        if cell.kind == "train":
            params = self.abstract_params(serve=False)
            opt = jax.eval_shape(adamw.init, params)
            return {"params": params, "opt": opt}
        params = self.abstract_params(serve=True)
        if cell.kind == "decode":
            cfg = self._serve_cfg()
            cache = jax.eval_shape(
                functools.partial(tr.init_kv_cache, cfg, cell.batch, cell.seq)
            )
            return {"params": params, "cache": cache}
        return {"params": params}

    def abstract_inputs(self, cell: Cell) -> dict:
        if cell.kind == "train":
            return {
                "tokens": sds((cell.batch, cell.seq), jnp.int32),
                "labels": sds((cell.batch, cell.seq), jnp.int32),
            }
        if cell.kind == "prefill":
            return {"tokens": sds((cell.batch, cell.seq), jnp.int32)}
        return {"tokens": sds((cell.batch, 1), jnp.int32)}  # decode

    # -------------------------- step -------------------------------- #
    def step(self, cell: Cell) -> Callable:
        if cell.kind == "train":
            cfg, opt = self.cfg, self.opt
            inner = steps_mod.make_train_step(
                lambda p, b: tr.loss_fn(cfg, p, b),
                opt,
                microbatches=cell.microbatches,
            )

            def train(state, batch):
                params, opt_state, metrics = inner(
                    state["params"], state["opt"], batch
                )
                return {"params": params, "opt": opt_state}, metrics

            return train
        scfg = self._serve_cfg()
        if cell.kind == "prefill":

            def prefill(state, batch):
                return tr.prefill_step(scfg, state["params"], batch["tokens"])

            return prefill

        def decode(state, batch):
            logits, cache = tr.decode_step(
                scfg, state["params"], state["cache"], batch["tokens"]
            )
            return logits, cache

        return decode

    # ------------------------ shardings ----------------------------- #
    def state_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        rules = sh.lm_param_rules(self.cfg, mesh)
        params = self.abstract_state(cell)
        out = {}
        out["params"] = sh.shard_by_rules(params["params"], mesh, rules)
        if "opt" in params:
            out["opt"] = {
                "mu": sh.shard_by_rules(params["opt"]["mu"], mesh, rules),
                "nu": sh.shard_by_rules(params["opt"]["nu"], mesh, rules),
                "step": NamedSharding(mesh, P()),
            }
        if "cache" in params:
            specs = sh.lm_cache_spec(mesh, self.cfg, cell.batch, cell.seq)
            out["cache"] = jax.tree.map(
                lambda leaf, spec: NamedSharding(
                    mesh, sh.safe_spec(tuple(leaf.shape), spec, mesh)
                ),
                params["cache"],
                {"k": specs["k"], "v": specs["v"], "pos": specs["pos"]},
                is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
            )
        return out

    def input_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        bs = sh.batch_spec(mesh, cell.batch)
        ins = self.abstract_inputs(cell)
        return jax.tree.map(
            lambda leaf: NamedSharding(
                mesh, sh.safe_spec(tuple(leaf.shape), P(*bs, None), mesh)
            ),
            ins,
        )

    def model_flops(self, cell: Cell) -> float:
        n = self.cfg.active_param_count()
        if cell.kind == "train":
            return 6.0 * n * cell.batch * cell.seq
        if cell.kind == "prefill":
            return 2.0 * n * cell.batch * cell.seq
        return 2.0 * n * cell.batch  # decode: one token per sequence

    def hlo_trip_factor(self, cell: Cell) -> float:
        """XLA cost_analysis counts each while/scan body once; the layer
        scan (and the microbatch accumulation scan for training) dominate
        the hidden trip count.  Inner attention/CE chunk scans are a noted
        residual undercount (EXPERIMENTS.md §Roofline)."""
        f = float(self.cfg.n_layers)
        if cell.kind == "train":
            f *= cell.microbatches
        return f

    def trip_schedule(self, cell: Cell) -> list[float]:
        """Per-loop-depth trip counts for collective weighting: depth 1 =
        microbatch scan (train) or layer scan (serve); depth 2 = layer scan
        under the microbatch scan."""
        if cell.kind == "train":
            return [float(cell.microbatches), float(self.cfg.n_layers)]
        return [float(self.cfg.n_layers)]

    def reduced(self) -> tr.LMConfig:
        return dataclasses.replace(
            self.cfg,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.cfg.n_kv_heads // self.cfg.n_heads),
            d_ff=128,
            vocab=128,
            head_dim=16,
            sliding_window=8 if self.cfg.sliding_window else None,
            moe=dataclasses.replace(self.cfg.moe, n_experts=4, top_k=2, d_expert=32)
            if self.cfg.moe
            else None,
            dtype=jnp.float32,
            remat=False,
        )


# ===================================================================== #
# GNN family
# ===================================================================== #
GNN_SHAPES = {
    "full_graph_sm": Cell(
        "full_graph_sm", "train",
        extras=dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_out=7,
                    task="node_class", n_graphs=1),
    ),
    "minibatch_lg": Cell(
        "minibatch_lg", "train",
        extras=dict(batch_nodes=1024, fanout=(15, 10), d_feat=602, n_out=41,
                    task="node_class", n_graphs=1),
    ),
    "ogb_products": Cell(
        "ogb_products", "train",
        extras=dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                    n_out=47, task="node_class", n_graphs=1),
    ),
    "molecule": Cell(
        "molecule", "train",
        extras=dict(n_graphs=128, nodes_per=30, edges_per=64, d_feat=1,
                    n_out=1, task="graph_reg"),
    ),
}


class GNNArch:
    family = "gnn"

    def __init__(self, arch_id: str, base_cfg: gnn_mod.GNNConfig,
                 opt: adamw.AdamWConfig | None = None):
        self.id = arch_id
        self.base_cfg = base_cfg
        self.opt = opt or adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)

    def cells(self) -> dict[str, Cell]:
        return dict(GNN_SHAPES)

    def skip_reason(self, cell_name: str) -> str | None:
        return None

    def cell_cfg(self, cell: Cell) -> gnn_mod.GNNConfig:
        ex = cell.extras
        return dataclasses.replace(
            self.base_cfg,
            d_in=ex["d_feat"],
            n_out=ex["n_out"],
            task=ex["task"],
            # bf16 activations at full-batch-large scale (EXPERIMENTS §Perf)
            dtype=jnp.bfloat16 if cell.name == "ogb_products" else jnp.float32,
        )

    def _shapes(self, cell: Cell) -> tuple[int, int, int]:
        ex = cell.extras
        if cell.name == "minibatch_lg":
            n, e = sampler_mod.block_sizes(ex["batch_nodes"], ex["fanout"])
        elif cell.name == "molecule":
            g = ex["n_graphs"]
            n, e = g * ex["nodes_per"], g * ex["edges_per"]
            return n, e, g
        else:
            n, e = ex["n_nodes"], ex["n_edges"]
        # pad node/edge counts to a 512 multiple so every mesh axis divides;
        # padding rides in masked edges / isolated dummy nodes (edge_mask).
        pad = lambda x: -(-x // 512) * 512
        return pad(n), pad(e), 1

    def abstract_state(self, cell: Cell) -> Any:
        cfg = self.cell_cfg(cell)
        params = jax.eval_shape(
            functools.partial(gnn_mod.init_params, cfg), jax.random.PRNGKey(0)
        )
        opt = jax.eval_shape(adamw.init, params)
        return {"params": params, "opt": opt}

    def abstract_inputs(self, cell: Cell) -> dict:
        n, e, g = self._shapes(cell)
        ex = cell.extras
        feat = (
            sds((n,), jnp.int32)
            if self.id == "schnet" and ex["task"] == "graph_reg"
            else sds((n, ex["d_feat"]), jnp.float32)
        )
        labels = (
            sds((g,), jnp.float32)
            if ex["task"] == "graph_reg"
            else sds((n,), jnp.int32)
        )
        out = {
            "feat": feat,
            "edges": sds((e, 2), jnp.int32),
            "edge_mask": sds((e,), jnp.bool_),
            "labels": labels,
            "node_graph": sds((n,), jnp.int32),
        }
        if self.id == "schnet":
            out["positions"] = sds((n, 3), jnp.float32)
        return out

    def step(self, cell: Cell) -> Callable:
        cfg = self.cell_cfg(cell)
        ex = cell.extras

        def loss(params, batch):
            b = dict(batch)
            if cfg.task == "graph_reg":
                b["n_graphs"] = ex["n_graphs"]
            if "positions" not in b:
                b["positions"] = jnp.zeros((b["feat"].shape[0], 3), jnp.float32)
            return gnn_mod.loss_fn(cfg, params, b)

        inner = steps_mod.make_train_step(loss, self.opt, microbatches=1)

        def train(state, batch):
            params, opt_state, metrics = inner(state["params"], state["opt"], batch)
            return {"params": params, "opt": opt_state}, metrics

        return train

    def state_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        state = self.abstract_state(cell)
        return jax.tree.map(lambda _: NamedSharding(mesh, P()), state)

    def input_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        ins = self.abstract_inputs(cell)
        specs = sh.gnn_input_specs(mesh)

        def one(path, leaf):
            key = str(path[0].key)
            spec = specs.get(key, P())
            return NamedSharding(mesh, sh.safe_spec(tuple(leaf.shape), spec, mesh))

        return jax.tree_util.tree_map_with_path(one, ins)

    def model_flops(self, cell: Cell) -> float:
        n, e, _ = self._shapes(cell)
        cfg = self.cell_cfg(cell)
        d = cfg.d_hidden
        # messages over edges + node transforms, x3 for fwd+bwd
        per_layer = 2.0 * e * d + 4.0 * n * d * d
        return 3.0 * cfg.n_layers * per_layer

    def hlo_trip_factor(self, cell: Cell) -> float:
        # gatedgcn/pna/schnet scan over layers; gat is a 2-layer unrolled loop
        return 1.0 if self.id == "gat-cora" else float(self.base_cfg.n_layers)

    def trip_schedule(self, cell: Cell) -> list[float]:
        return [self.hlo_trip_factor(cell)]

    def reduced(self) -> gnn_mod.GNNConfig:
        return dataclasses.replace(
            self.base_cfg, n_layers=2, d_hidden=16, d_in=8, n_out=3, n_rbf=16
        )


# ===================================================================== #
# RecSys family
# ===================================================================== #
REC_SHAPES = {
    "train_batch": Cell("train_batch", "train", batch=65536, microbatches=4),
    "serve_p99": Cell("serve_p99", "serve", batch=512),
    "serve_bulk": Cell("serve_bulk", "serve", batch=262144),
    "retrieval_cand": Cell(
        "retrieval_cand", "retrieval", batch=1,
        extras=dict(n_candidates=1_000_000),
    ),
}


class RecsysArch:
    family = "recsys"

    def __init__(self, cfg: rec_mod.RecsysConfig,
                 opt: adamw.AdamWConfig | None = None):
        self.id = cfg.name
        self.cfg = cfg
        self.opt = opt or adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)

    def cells(self) -> dict[str, Cell]:
        return dict(REC_SHAPES)

    def skip_reason(self, cell_name: str) -> str | None:
        return None

    def abstract_state(self, cell: Cell) -> Any:
        params = jax.eval_shape(
            functools.partial(rec_mod.init_params, self.cfg),
            jax.random.PRNGKey(0),
        )
        if cell.kind == "train":
            return {"params": params, "opt": jax.eval_shape(adamw.init, params)}
        return {"params": params}

    def abstract_inputs(self, cell: Cell) -> dict:
        b = cell.batch
        out = {
            "dense": sds((b, self.cfg.n_dense), jnp.float32),
            "sparse": sds((b, self.cfg.n_sparse), jnp.int32),
        }
        if cell.kind == "train":
            out["labels"] = sds((b,), jnp.float32)
        if cell.kind == "retrieval":
            out["candidates"] = sds(
                (cell.extras["n_candidates"], self.cfg.mlp[-1]), jnp.float32
            )
        return out

    def step(self, cell: Cell) -> Callable:
        cfg = self.cfg
        if cell.kind == "train":
            inner = steps_mod.make_train_step(
                lambda p, b: rec_mod.loss_fn(cfg, p, b),
                self.opt,
                microbatches=cell.microbatches,
            )

            def train(state, batch):
                params, opt_state, metrics = inner(
                    state["params"], state["opt"], batch
                )
                return {"params": params, "opt": opt_state}, metrics

            return train
        if cell.kind == "retrieval":

            def retrieve(state, batch):
                return rec_mod.retrieval_score(cfg, state["params"], batch)

            return retrieve

        def serve(state, batch):
            return jax.nn.sigmoid(rec_mod.forward(cfg, state["params"], batch))

        return serve

    def state_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        rules = sh.recsys_param_rules(self.cfg)
        state = self.abstract_state(cell)
        out = {"params": sh.shard_by_rules(state["params"], mesh, rules)}
        if "opt" in state:
            out["opt"] = {
                "mu": sh.shard_by_rules(state["opt"]["mu"], mesh, rules),
                "nu": sh.shard_by_rules(state["opt"]["nu"], mesh, rules),
                "step": NamedSharding(mesh, P()),
            }
        return out

    def input_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        ins = self.abstract_inputs(cell)
        bs = sh.batch_spec(mesh, cell.batch)

        def one(path, leaf):
            key = str(path[0].key)
            if key == "candidates":
                spec = P(("data", "model"), None)
            elif leaf.ndim == 2:
                spec = P(*bs, None)
            else:
                spec = P(*bs)
            return NamedSharding(mesh, sh.safe_spec(tuple(leaf.shape), spec, mesh))

        return jax.tree_util.tree_map_with_path(one, ins)

    def model_flops(self, cell: Cell) -> float:
        cfg = self.cfg
        d = cfg.d_interact
        widths = [d] + list(cfg.mlp)
        mlp = sum(2 * a * b for a, b in zip(widths[:-1], widths[1:]))
        per_ex = cfg.n_cross * 2 * d * d + mlp
        b = cell.batch
        mult = 3.0 if cell.kind == "train" else 1.0
        flops = mult * b * per_ex
        if cell.kind == "retrieval":
            flops += 2.0 * cell.extras["n_candidates"] * cfg.mlp[-1] * b
        return flops

    def hlo_trip_factor(self, cell: Cell) -> float:
        return float(cell.microbatches) if cell.kind == "train" else 1.0

    def trip_schedule(self, cell: Cell) -> list[float]:
        return [self.hlo_trip_factor(cell)]

    def reduced(self) -> rec_mod.RecsysConfig:
        return dataclasses.replace(
            self.cfg, vocab_sizes=(97, 31, 53), n_sparse=3, n_dense=4,
            embed_dim=8, mlp=(32, 16),
        )
