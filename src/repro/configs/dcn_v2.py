"""dcn-v2 [arXiv:2008.13535]: 13 dense + 26 sparse(embed 16), 3 cross layers,
MLP 1024-1024-512, full-rank cross interaction."""
from repro.models.recsys import RecsysConfig
from .base import RecsysArch

CFG = RecsysConfig(name="dcn-v2", n_dense=13, n_sparse=26, embed_dim=16,
                   n_cross=3, mlp=(1024, 1024, 512))
SPEC = RecsysArch(CFG)
