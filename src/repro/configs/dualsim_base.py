"""ArchSpec for the paper's own workloads: dual-simulation query processing
over LUBM-scale and DBpedia-scale graph databases (dry-run + roofline).

Cells (all ``kind="dualsim"``):

* ``*_q_sparse``   — one query (paper-faithful SOI sweep), sparse engine.
* ``*_batch16``    — 16 constant-parameterized instances of one query
  template solved together (vmap over the Eq.-13 init), the serving regime.
* ``block_dense``  — dense/MXU engine on a 16k-node partition block (the
  bit-matrix regime the paper's Sect. 3.2 engineering targets).
* ``q_partitioned`` — beyond-paper optimized engine (EXPERIMENTS §Perf):
  destination-partitioned (vertex-cut) edge blocks + one bit-packed
  frontier broadcast per sweep — 38x lower collective term than q_sparse.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dualsim
from repro.distributed import shard as sh
from .base import Cell, sds


@dataclasses.dataclass(frozen=True)
class DualsimScale:
    n_nodes: int
    edges_per_mat: tuple[int, ...]  # one entry per (label, dir) operator
    n_vars: int
    n_ineqs: int
    n_copies: int = 0


class DualsimArch:
    family = "dualsim"

    def __init__(self, arch_id: str, scale: DualsimScale, batch16_nodes: int,
                 dense_block: int = 16384):
        self.id = arch_id
        self.scale = scale
        self.batch16_nodes = batch16_nodes
        self.dense_block = dense_block

    def cells(self) -> dict[str, Cell]:
        return {
            "q_sparse": Cell("q_sparse", "dualsim"),
            "batch16_sparse": Cell("batch16_sparse", "dualsim",
                                   extras=dict(n_queries=16)),
            "block_dense": Cell("block_dense", "dualsim"),
            # beyond-paper optimized engine (EXPERIMENTS §Perf): vertex-cut
            # destination-partitioned edges + bit-packed frontier broadcast.
            "q_partitioned": Cell("q_partitioned", "dualsim",
                                  extras=dict(n_blocks=256)),
        }

    def skip_reason(self, cell_name: str) -> str | None:
        return None

    # ------------------------------------------------------------------ #
    def _abstract_operands(self, n_nodes: int, dense: bool,
                           q: int = 1) -> dualsim.Operands:
        """q > 1 = disjoint-union batching: q constant-parameterized copies
        of the query template solved as one SOI (n_vars and the per-operator
        inequality counts scale by q; edges are shared)."""
        s = self.scale
        n_mats = len(s.edges_per_mat)
        per_mat = max(1, s.n_ineqs // n_mats)
        kw = dict(
            init=sds((q * s.n_vars, n_nodes), jnp.bool_),
            mat_rhs=tuple(sds((q * per_mat,), jnp.int32) for _ in range(n_mats)),
            mat_table=tuple(
                sds((q * s.n_vars, 1), jnp.int32) for _ in range(n_mats)
            ),
            copy_rhs=sds((q * s.n_copies,), jnp.int32),
            var_copy=sds((q * s.n_vars, max(s.n_copies, 1)), jnp.int32),
        )
        if dense:
            kw["adj_dense"] = sds((n_mats, n_nodes, n_nodes), jnp.bool_)
        else:
            kw["edge_src"] = tuple(sds((e,), jnp.int32) for e in s.edges_per_mat)
            kw["edge_dst"] = tuple(sds((e,), jnp.int32) for e in s.edges_per_mat)
        return dualsim.Operands(**kw)

    def abstract_state(self, cell: Cell) -> Any:
        if cell.name == "block_dense":
            return self._abstract_operands(self.dense_block, dense=True)
        if cell.name == "batch16_sparse":
            return self._abstract_operands(
                self.batch16_nodes, dense=False, q=cell.extras["n_queries"]
            )
        if cell.name == "q_partitioned":
            s = self.scale
            w = cell.extras["n_blocks"]
            n = -(-s.n_nodes // 8192) * 8192  # pad for packed sharding
            ops = self._abstract_operands(n, dense=False)
            eb = [int(e / w * 1.2) for e in s.edges_per_mat]  # 20% imbalance
            return dataclasses.replace(
                ops,
                edge_src=None, edge_dst=None,
                edge_src_b=tuple(sds((w, e), jnp.int32) for e in eb),
                edge_dst_b=tuple(sds((w, e), jnp.int32) for e in eb),
            )
        return self._abstract_operands(self.scale.n_nodes, dense=False)

    def abstract_inputs(self, cell: Cell) -> dict:
        return {}

    def step(self, cell: Cell) -> Callable:
        if cell.name == "block_dense":

            def run_dense(state, batch):
                return dualsim.solve_dense(
                    state, dtype=jnp.bfloat16, max_sweeps=30,
                    chi_spec=P(None, "model"),
                )

            return run_dense
        # single query: chi columns over every axis; batched queries:
        # query-variable dim over 'data' (query parallelism), columns over
        # 'model'.
        batched = cell.name == "batch16_sparse"
        chi_spec = P("data", "model") if batched else P(None, ("data", "model"))
        if cell.name == "q_partitioned":

            def run_part(state, batch):
                return dualsim.solve_partitioned(
                    state, max_sweeps=60, chi_spec=chi_spec
                )

            return run_part

        def run_sparse(state, batch):
            return dualsim.solve_sparse(
                state, max_sweeps=30, chi_spec=chi_spec
            )

        return run_sparse

    # ------------------------------------------------------------------ #
    def state_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        dense = cell.name == "block_dense"
        specs = (
            sh.dualsim_dense_specs(mesh) if dense else sh.dualsim_sparse_specs(mesh)
        )
        state = self.abstract_state(cell)

        batched = cell.name == "batch16_sparse"

        def one(path, leaf):
            key = str(path[0].name)
            spec = specs.get(key, P())
            if key == "init" and batched:
                spec = P("data", "model")  # query-parallel over 'data'
            if key in ("edge_src_b", "edge_dst_b"):
                spec = P(("data", "model"), None)  # block dim = chi shards
            return NamedSharding(mesh, sh.safe_spec(tuple(leaf.shape), spec, mesh))

        return jax.tree_util.tree_map_with_path(one, state)

    def input_shardings(self, mesh: Mesh, cell: Cell) -> Any:
        return {}

    def model_flops(self, cell: Cell) -> float:
        """Useful ops: per sweep each edge feeds V OR-AND ops per direction;
        assume the paper's observed ~5 sweep average (Sect. 5.3)."""
        s = self.scale
        sweeps = 5.0
        if cell.name == "block_dense":
            e = sum(self.scale.edges_per_mat) * (
                self.dense_block / self.scale.n_nodes
            )
            return 2.0 * sweeps * s.n_vars * e
        q = cell.extras.get("n_queries", 1)
        return 2.0 * sweeps * q * s.n_vars * sum(s.edges_per_mat)

    def hlo_trip_factor(self, cell: Cell) -> float:
        # fixpoint while body counted once; ~5 GS sweeps typical; the
        # Jacobi-style partitioned engine inflates ~2x (measured).
        return 10.0 if cell.name == "q_partitioned" else 5.0

    def trip_schedule(self, cell: Cell) -> list[float]:
        return [self.hlo_trip_factor(cell)]

    def reduced(self):
        return None
