"""Paper workload: DBpedia (751M triples, 65430 predicates, 216M nodes).
High-selectivity predicates: a 5-edge query touches ~2M edges/operator."""
from .dualsim_base import DualsimArch, DualsimScale

SPEC = DualsimArch(
    "dualsim-dbpedia",
    DualsimScale(
        n_nodes=216_132_665,
        edges_per_mat=(2_000_000,) * 10,  # 5 predicates x fwd/bwd
        n_vars=5,
        n_ineqs=10,
    ),
    batch16_nodes=216_132_665,
)
