"""Paper workload: LUBM (1.38B triples, 18 predicates, 329M nodes).
Query shape mirrors L1 (6 pattern edges -> 12 inequality operators over 6
low-selectivity predicates; ~77M edges per operator direction)."""
from .dualsim_base import DualsimArch, DualsimScale

SPEC = DualsimArch(
    "dualsim-lubm",
    DualsimScale(
        n_nodes=328_620_750,
        edges_per_mat=(77_000_000,) * 12,  # 6 predicates x fwd/bwd
        n_vars=6,
        n_ineqs=12,
    ),
    batch16_nodes=328_620_750,
)
