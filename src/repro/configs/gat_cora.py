"""gat-cora [arXiv:1710.10903]: 2L d_hidden=8 8 heads, attn aggregator."""
from repro.models.gnn import GNNConfig
from .base import GNNArch

CFG = GNNConfig(name="gat-cora", arch="gat", n_layers=2, d_hidden=8,
                n_heads=8, d_in=1433, n_out=7)
SPEC = GNNArch("gat-cora", CFG)
