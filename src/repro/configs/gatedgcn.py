"""gatedgcn [arXiv:2003.00982]: 16L d_hidden=70, gated edge aggregation."""
from repro.models.gnn import GNNConfig
from .base import GNNArch

CFG = GNNConfig(name="gatedgcn", arch="gatedgcn", n_layers=16, d_hidden=70,
                d_in=1433, n_out=7)
SPEC = GNNArch("gatedgcn", CFG)
