"""internlm2-1.8b [arXiv:2403.17297; hf]: 24L d=2048 16H GQA(kv=8) ff=8192."""
from repro.models.transformer import LMConfig
from .base import LMArch

CFG = LMConfig(
    name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab=92544, head_dim=128,
)
SPEC = LMArch(CFG)
