"""mixtral-8x7b [arXiv:2401.04088; hf]: 32L d=4096 32H GQA(kv=8) MoE 8e top-2,
sliding-window attention (window 4096) -> runs the long_500k cell."""
from repro.models.transformer import LMConfig, MoEConfig
from .base import LMArch

CFG = LMConfig(
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
)
SPEC = LMArch(CFG)
