"""olmoe-1b-7b [arXiv:2409.02060; hf]: 16L d=2048 16H GQA(kv=16) MoE 64e top-8."""
from repro.models.transformer import LMConfig, MoEConfig
from .base import LMArch

CFG = LMConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
)
SPEC = LMArch(CFG)
