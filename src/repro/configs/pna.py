"""pna [arXiv:2004.05718]: 4L d_hidden=75, mean/max/min/std x id/amp/atten."""
from repro.models.gnn import GNNConfig
from .base import GNNArch

CFG = GNNConfig(name="pna", arch="pna", n_layers=4, d_hidden=75,
                d_in=1433, n_out=7)
SPEC = GNNArch("pna", CFG)
