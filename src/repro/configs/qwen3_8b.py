"""qwen3-8b [hf:Qwen/Qwen3-8B]: 36L d=4096 32H GQA(kv=8) ff=12288, qk_norm."""
from repro.models.transformer import LMConfig
from .base import LMArch

CFG = LMConfig(
    name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936, head_dim=128, qk_norm=True,
)
SPEC = LMArch(CFG)
