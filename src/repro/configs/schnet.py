"""schnet [arXiv:1706.08566]: 3 interactions d=64 rbf=300 cutoff=10.
Non-geometric cells get synthesized positions (DESIGN.md §Arch-applicability)."""
from repro.models.gnn import GNNConfig
from .base import GNNArch

CFG = GNNConfig(name="schnet", arch="schnet", n_layers=3, d_hidden=64,
                n_rbf=300, cutoff=10.0, d_in=1, n_out=1)
SPEC = GNNArch("schnet", CFG)
