"""yi-6b [arXiv:2403.04652; hf]: 32L d=4096 32H GQA(kv=4) ff=11008 (llama arch)."""
from repro.models.transformer import LMConfig
from .base import LMArch

CFG = LMConfig(
    name="yi-6b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128,
)
SPEC = LMArch(CFG)
