"""Bit-packing utilities for boolean node-set vectors.

The paper (Sect. 3.2) stores node sets as bit-vectors and adjacency as bit
matrices.  On TPU we keep dense ``uint32`` lanes (``N/32`` words per set) so
the 8x128 VPU streams them; gap-length encoding from the paper does not map to
fixed-width SIMD (see DESIGN.md Sect. 2).

All functions are pure jnp and jit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32
_BIT_DTYPE = jnp.uint32


def packed_width(n: int) -> int:
    """Number of uint32 words needed to hold ``n`` bits."""
    return (n + WORD - 1) // WORD


def pack(bits: jax.Array) -> jax.Array:
    """Pack a boolean array along the last axis into uint32 words.

    ``bits[..., n] -> packed[..., ceil(n/32)]``; bit ``i`` of word ``w`` holds
    element ``32*w + i`` (little-endian within the word).
    """
    n = bits.shape[-1]
    w = packed_width(n)
    pad = w * WORD - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1
        )
    b = bits.astype(_BIT_DTYPE).reshape(bits.shape[:-1] + (w, WORD))
    shifts = jnp.arange(WORD, dtype=_BIT_DTYPE)
    return jnp.sum(b << shifts, axis=-1, dtype=_BIT_DTYPE)


def unpack(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack`: ``packed[..., w] -> bool[..., n]``."""
    shifts = jnp.arange(WORD, dtype=_BIT_DTYPE)
    bits = (packed[..., None] >> shifts) & _BIT_DTYPE.dtype.type(1)
    bits = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * WORD,))
    return bits[..., :n].astype(jnp.bool_)


def pack_np(bits: np.ndarray) -> np.ndarray:
    """Host-side :func:`pack`: numpy in, ``uint32`` words out, same layout.

    Used where device round trips would defeat the purpose — building the
    packed Eq.-13 init once per plan and storing bit-packed chi memos that
    feed straight back into a packed solver.  Assumes a little-endian host
    (the ``uint8 -> uint32`` view identifies byte k with bits ``8k..8k+7``),
    which matches every platform jaxlib ships for.
    """
    bits = np.asarray(bits, dtype=bool)
    packed8 = np.packbits(bits, axis=-1, bitorder="little")
    pad = (-packed8.shape[-1]) % 4
    if pad:
        packed8 = np.concatenate(
            [packed8, np.zeros(packed8.shape[:-1] + (pad,), np.uint8)], axis=-1
        )
    return np.ascontiguousarray(packed8).view(np.uint32)


def unpack_np(packed: np.ndarray, n: int) -> np.ndarray:
    """Host-side :func:`unpack`: inverse of :func:`pack_np`."""
    packed8 = np.ascontiguousarray(np.asarray(packed, np.uint32)).view(np.uint8)
    bits = np.unpackbits(packed8, axis=-1, bitorder="little")
    return bits[..., :n].astype(bool)


def popcount(packed: jax.Array) -> jax.Array:
    """Total number of set bits over the last axis (int32)."""
    cnt = jax.lax.population_count(packed)
    return jnp.sum(cnt.astype(jnp.int32), axis=-1)


def any_set(packed: jax.Array) -> jax.Array:
    """Whether any bit is set along the last axis."""
    return jax.lax.reduce(
        packed, _BIT_DTYPE.dtype.type(0), jax.lax.bitwise_or, (packed.ndim - 1,)
    ) != 0


def band(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.bitwise_and(a, b)


def bor(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.bitwise_or(a, b)


def bnot(a: jax.Array) -> jax.Array:
    return jnp.bitwise_not(a)


def ones_mask(n: int) -> np.ndarray:
    """Packed all-ones vector of logical length ``n`` (trailing bits zero)."""
    w = packed_width(n)
    out = np.full((w,), np.uint32(0xFFFFFFFF), dtype=np.uint32)
    rem = n % WORD
    if rem:
        out[-1] = np.uint32((1 << rem) - 1)
    return out


def leq(a: jax.Array, b: jax.Array) -> jax.Array:
    """Bit-set inclusion a <= b (as sets), reduced over the last axis."""
    return ~any_set(jnp.bitwise_and(a, jnp.bitwise_not(b)))
