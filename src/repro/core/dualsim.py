"""Dual-simulation fixpoint engines (paper Sect. 3).

Four engines compute the largest solution of a compiled SOI:

* ``solve_dense``  — batched Jacobi sweep over dense boolean adjacency, one
  matmul per (label, direction) operator per sweep.  This is the MXU path:
  ``Y = chi @ A`` in ``dtype`` (bf16 on TPU) followed by ``> 0``.
* ``solve_packed`` — same sweep over bit-packed ``uint32`` adjacency via the
  Pallas ``bitmm`` kernel (64x less HBM traffic than bf16 dense); chi is
  boolean between kernel calls (the pre-ISSUE-5 baseline the fused engine
  is benchmarked against).
* ``solve_packed_fused`` — the paper's Sect.-3.2 representation end to end:
  chi stays bit-packed ``uint32 [V, nw]`` through the whole
  ``lax.while_loop`` and one fused ``bitmm_apply`` launch per operator does
  product + AND-combine + changed detection on packed words (DESIGN.md
  Sect. 9).
* ``solve_sparse`` — edge-list engine: the boolean product is a segmented
  OR over edges, i.e. message passing in the OR-AND semiring.  Since
  ISSUE 8 *both* modes carry bit-packed chi through the whole while_loop:
  the segmented-OR primitive (``kernels/segsum``) emits ``y`` already
  packed ``uint32 [V, nw]``, so no bool plane and no per-sweep
  ``bitops.pack`` exist anywhere in the loop.  ``mode="gs"`` applies
  operators sequentially (paper-faithful ordering); ``mode="jacobi_packed"``
  reads every operator's frontier bits out of ONE replicated copy of the
  packed words per sweep.
* ``solve_partitioned`` — destination-partitioned (vertex-cut) edge blocks
  over a device mesh: block-local segmented ORs emit block-local packed
  words (the block size is 32-aligned so local words concatenate into the
  global word order); the ONLY cross-shard traffic per sweep is replicating
  the n/8-byte packed words chi already lives in (DESIGN.md Sect. 7 / 9 /
  12).
* ``solve_worklist`` — the paper's own sequential strategy (Sect. 3.2 steps
  1–2 with the Sect. 3.3 heuristics); numpy, used for Table-2 parity and
  iteration-count studies.

All batched engines iterate their sweep through the single
:func:`_sweep_fixpoint` driver — they differ only in the sweep body.

All batched engines implement the same monotone operator

    chi[lhs] &= chi[rhs] ×b M        (edge inequalities, Eq. 11)
    chi[lhs] &= chi[rhs]             (copy inequalities, Eq. 15)

iterated to the (unique) greatest fixpoint; order of application does not
change the fixpoint (Knaster–Tarski on the finite powerset lattice), which is
exactly the degree of freedom the paper exploits — we spend it on batching
instead of worklist heuristics (DESIGN.md Sect. 2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import bitops
from .graph import Graph
from .soi import BWD, FWD, CompiledSOI, SOI, build_soi, compile_soi

# --------------------------------------------------------------------- #
# operand construction (numpy -> pytrees)
# --------------------------------------------------------------------- #


def _pad_table(groups: list[list[int]], pad: int) -> np.ndarray:
    k = max((len(g) for g in groups), default=0)
    k = max(k, 1)
    out = np.full((len(groups), k), pad, dtype=np.int32)
    for i, g in enumerate(groups):
        out[i, : len(g)] = g
    return out


def _per_mat_tables(c: CompiledSOI) -> tuple[tuple, tuple]:
    """Per-operator inequality tables.

    For operator m: ``mat_rhs[m]`` lists the RHS variable of each inequality
    using m; ``mat_table[m]`` is the per-variable padded index list into
    those inequalities (pad = I_m, pointing at an appended all-ones row) so
    multiple inequalities on the same LHS AND-combine with gathers only.
    """
    n_mats = len(c.mats)
    rhs_by_mat: list[list[int]] = [[] for _ in range(n_mats)]
    var_by_mat: list[list[list[int]]] = [
        [[] for _ in range(c.n_vars)] for _ in range(n_mats)
    ]
    for l, r, m in zip(c.ineq_lhs, c.ineq_rhs, c.ineq_mat):
        var_by_mat[m][l].append(len(rhs_by_mat[m]))
        rhs_by_mat[m].append(r)
    mat_rhs = tuple(jnp.asarray(r, jnp.int32) for r in rhs_by_mat)
    mat_table = tuple(
        jnp.asarray(_pad_table(v, pad=len(rhs_by_mat[m])), jnp.int32)
        for m, v in enumerate(var_by_mat)
    )
    return mat_rhs, mat_table


def _mat_lhs_flags(c: CompiledSOI) -> tuple:
    """Per-operator [V, V] inequality flag matrices for the fused kernel.

    ``flags[m][l, r] = 1`` iff the SOI holds ``chi[l] <= chi[r] ×b M_m``;
    ``bitmm_apply`` turns the AND-combine into a tiny masked OR-reduce
    (``chi[l] &= ~OR_{r:F[l,r]} ~y[r]``) so no gather tables enter the
    kernel.  Semantically identical to ``mat_rhs``/``mat_table`` (duplicate
    inequalities collapse idempotently under AND).
    """
    flags = [np.zeros((c.n_vars, c.n_vars), np.uint32) for _ in c.mats]
    for l, r, m in zip(c.ineq_lhs, c.ineq_rhs, c.ineq_mat):
        flags[m][l, r] = 1
    return tuple(jnp.asarray(f) for f in flags)


def _copy_tables(c: CompiledSOI) -> tuple[jax.Array, jax.Array]:
    by_copy: list[list[int]] = [[] for _ in range(c.n_vars)]
    for i, l in enumerate(c.copy_lhs):
        by_copy[l].append(i)
    return (
        jnp.asarray(c.copy_rhs, jnp.int32),
        jnp.asarray(_pad_table(by_copy, pad=len(c.copy_lhs)), jnp.int32),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Operands:
    """Device operands shared by the batched engines.

    Adjacency comes in an engine-specific layout: dense ``bool[M, n, n]``,
    packed ``uint32[M, n, nw]``, or per-operator edge lists (sparse engine).
    Exactly one layout is populated.
    """

    init: jax.Array  # bool [V, n]
    mat_rhs: tuple  # per mat: int32 [I_m]
    mat_table: tuple  # per mat: int32 [V, K_m] (padded with I_m)
    copy_rhs: jax.Array  # int32 [C]
    var_copy: jax.Array  # int32 [V, Kc]  (padded with C)
    # packed-chi extras (ISSUE 5): host-packed init and per-mat [V, V]
    # inequality flag matrices; optional so hand-built / abstract Operands
    # stay valid (the packed engines fall back to packing init on device,
    # and only the fused engine requires the flags)
    init_packed: jax.Array | None = None  # uint32 [V, nw]
    mat_lhs_flags: tuple | None = None  # per mat: uint32 [V, V]
    adj_dense: jax.Array | None = None  # bool [M, n, n]
    adj_packed: jax.Array | None = None  # uint32 [M, n, nw]
    edge_src: tuple | None = None  # per-mat int32 [E_m] source nodes
    edge_dst: tuple | None = None  # per-mat int32 [E_m] destination nodes
    # destination-partitioned layout (mode="partitioned"): block w only
    # holds edges whose dst lies in chi block w; dst ids are block-local
    # (pad rows use dst = n_local, dropped by the segment reduce).
    edge_src_b: tuple | None = None  # per-mat int32 [W, Eb] global src
    edge_dst_b: tuple | None = None  # per-mat int32 [W, Eb] local dst
    # blocked segmented-OR layout (ISSUE 8): edges sorted and blocked by
    # destination word window for the Pallas segor kernel.  Built alongside
    # the flat edge lists in make_sparse_operands; pad rows carry the
    # sentinel destination n_pad (never a bit), see prepare_segor.
    seg_src_b: tuple | None = None  # per-mat int32 [G_m, BE] source nodes
    seg_dst_b: tuple | None = None  # per-mat int32 [G_m, BE] absolute dst
    seg_win: tuple | None = None  # per-mat int32 [G_m] dst-word window


def _base_operands(c: CompiledSOI) -> dict:
    mat_rhs, mat_table = _per_mat_tables(c)
    copy_rhs, var_copy = _copy_tables(c)
    return dict(
        init=jnp.asarray(c.init),
        # packed once on the host: the packed-chi engines start their
        # while_loop from this without ever packing on device
        init_packed=jnp.asarray(bitops.pack_np(c.init)),
        mat_rhs=mat_rhs,
        mat_table=mat_table,
        mat_lhs_flags=_mat_lhs_flags(c),
        copy_rhs=copy_rhs,
        var_copy=var_copy,
    )


def _cached_adj(adj_cache: dict | None, key, g: Graph, build):
    """Adjacency depends only on (engine, mats, graph) — never on the SOI's
    variables — so plan caches share it across templates and batch buckets.
    Entries store the graph they were built from and only hit on the *same*
    graph object: sharing one cache dict across graphs can never return
    another graph's adjacency (it just misses and rebuilds)."""
    if adj_cache is not None:
        try:
            hit_g, adj = adj_cache[key]
        except KeyError:
            pass
        else:
            if hit_g is g:
                return adj
    adj = build()
    if adj_cache is not None:
        adj_cache[key] = (g, adj)
    return adj


def make_dense_operands(
    c: CompiledSOI, g: Graph, adj_cache: dict | None = None
) -> Operands:
    def build():
        adj = np.stack(
            [g.dense_adjacency(a, backward=(d == BWD)) for (a, d) in c.mats]
        ) if c.mats else np.zeros((0, g.n_nodes, g.n_nodes), dtype=bool)
        return jnp.asarray(adj)

    adj = _cached_adj(adj_cache, ("dense", tuple(c.mats)), g, build)
    return Operands(adj_dense=adj, **_base_operands(c))


def make_packed_operands(
    c: CompiledSOI, g: Graph, adj_cache: dict | None = None
) -> Operands:
    def build():
        adj = np.stack(
            [g.packed_adjacency(a, backward=(d == BWD)) for (a, d) in c.mats]
        ) if c.mats else np.zeros((0, g.n_nodes, bitops.packed_width(g.n_nodes)), np.uint32)
        return jnp.asarray(adj)

    adj = _cached_adj(adj_cache, ("packed", tuple(c.mats)), g, build)
    return Operands(adj_packed=adj, **_base_operands(c))


# Per-operator edge lists round up to this capacity multiple; pad rows use
# the out-of-range destination id ``n`` and are dropped by the segment
# reduce.  Rounding keeps operand shapes stable under small insert/delete
# deltas, so a patched plan re-runs its existing trace instead of retracing
# (DESIGN.md Sect. 8).
EDGE_PAD = 64


def _padded_edge_list(
    s: np.ndarray, t: np.ndarray, n: int, min_cap: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """(src, dst) int32 arrays padded to an ``EDGE_PAD`` multiple >= min_cap."""
    e = len(s)
    cap = max(-(-e // EDGE_PAD) * EDGE_PAD if e else 0, min_cap)
    if cap == e:
        return np.asarray(s, np.int32), np.asarray(t, np.int32)
    ps = np.zeros(cap, np.int32)
    pt = np.full(cap, n, np.int32)  # pad dst = n -> dropped by segment reduce
    ps[:e], pt[:e] = s, t
    return ps, pt


def _oriented_edges(g: Graph, a: int, d: int) -> tuple[np.ndarray, np.ndarray]:
    e = g.edges_for_label(a)
    return (e[:, 0], e[:, 1]) if d == FWD else (e[:, 1], e[:, 0])


def _segor_mat(
    s: np.ndarray, t: np.ndarray, n: int, min_g: int = 0
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked segmented-OR layout for one operator's RAW edge list.

    Feeds the Pallas segor kernel: edges sorted by destination and split
    into blocks that each touch one destination-word window.  Pad rows
    gather source 0 but carry the sentinel destination ``n_pad``, which can
    never turn on a bit (:func:`repro.kernels.segsum.kernel.prepare_segor`)
    — crucially NOT the flat layout's pad id ``n``, which would alias bit
    ``n`` whenever ``n`` lies inside a live window.
    """
    from repro.kernels.segsum import kernel as segsum_kernel

    idx_b, seg_b, win, _ = segsum_kernel.prepare_segor(t, n, min_g=min_g)
    src_b = (
        np.asarray(s, np.int32)[idx_b]
        if len(s)
        else np.zeros(idx_b.shape, np.int32)
    )
    return jnp.asarray(src_b), jnp.asarray(seg_b), jnp.asarray(win)


def make_sparse_operands(
    c: CompiledSOI, g: Graph, adj_cache: dict | None = None
) -> Operands:
    def build():
        srcs, dsts, sbs, dbs, wbs = [], [], [], [], []
        for a, d in c.mats:
            s, t = _oriented_edges(g, a, d)
            ps, pt = _padded_edge_list(s, t, g.n_nodes)
            srcs.append(jnp.asarray(ps, jnp.int32))
            dsts.append(jnp.asarray(pt, jnp.int32))
            sb, db, wb = _segor_mat(s, t, g.n_nodes)
            sbs.append(sb)
            dbs.append(db)
            wbs.append(wb)
        return tuple(srcs), tuple(dsts), tuple(sbs), tuple(dbs), tuple(wbs)

    src, dst, sb, db, wb = _cached_adj(
        adj_cache, ("sparse", tuple(c.mats)), g, build
    )
    return Operands(
        edge_src=src, edge_dst=dst,
        seg_src_b=sb, seg_dst_b=db, seg_win=wb,
        **_base_operands(c),
    )


def _partitioned_mat(
    s: np.ndarray, t: np.ndarray, n_blocks: int, n_local: int, min_eb: int = 1
) -> tuple[np.ndarray, np.ndarray]:
    """Destination-partitioned (block, local-dst) layout for one operator.

    Blocks pad to a common edge count ``>= min_eb`` (pad rows use the
    out-of-range local id ``n_local`` and are dropped by the segment
    reduce); ``min_eb`` lets an operand patch keep the superseded shape so
    the plan's trace stays valid.
    """
    blk = t // n_local
    order = np.argsort(blk, kind="stable")
    s, t, blk = s[order], t[order], blk[order]
    counts = np.bincount(blk, minlength=n_blocks)
    eb = max(int(counts.max()) if counts.size else 1, 1, min_eb)
    src_b = np.zeros((n_blocks, eb), np.int32)
    dst_b = np.full((n_blocks, eb), n_local, np.int32)  # pad -> dropped
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for w in range(n_blocks):
        k = counts[w]
        src_b[w, :k] = s[starts[w] : starts[w] + k]
        dst_b[w, :k] = t[starts[w] : starts[w] + k] - w * n_local
    return src_b, dst_b


def padded_node_count(n: int, n_blocks: int) -> int:
    """Smallest node count splitting into ``n_blocks`` uniform blocks of
    whole 32-bit words (block size is a word multiple since ISSUE 8, so the
    blocks' packed local words concatenate directly into the global word
    order; pad columns are dead and sliced off after the solve)."""
    n_local = -(-max(-(-n // n_blocks), 1) // bitops.WORD) * bitops.WORD
    return n_local * n_blocks


def make_partitioned_operands(
    c: CompiledSOI, g: Graph, n_blocks: int, adj_cache: dict | None = None
) -> Operands:
    """Destination-partitioned (vertex-cut) edge layout: the host-side graph
    partitioner of the ``partitioned`` engine.

    The node axis is padded up to a multiple of ``n_blocks``
    (:func:`padded_node_count`) so callers never have to align the graph
    themselves — pad columns start all-False in ``init``, receive no edges,
    and stay dead through every monotone sweep; slice ``chi[:, :g.n_nodes]``
    after solving.  Blocks are padded to a common edge count (pad rows use
    the out-of-range local id ``n_local`` and are dropped by the segment
    reduce).  Like the other layouts, the edge blocks depend only on
    (mats, graph, n_blocks) and are shared across plans via ``adj_cache``.
    """
    n = g.n_nodes
    n_pad = padded_node_count(n, n_blocks)
    n_local = n_pad // n_blocks

    def build():
        srcs_b, dsts_b = [], []
        for a, d in c.mats:
            src_b, dst_b = _partitioned_mat(
                *_oriented_edges(g, a, d), n_blocks, n_local
            )
            srcs_b.append(jnp.asarray(src_b))
            dsts_b.append(jnp.asarray(dst_b))
        return tuple(srcs_b), tuple(dsts_b)

    src_b, dst_b = _cached_adj(
        adj_cache, ("partitioned", tuple(c.mats), n_blocks), g, build
    )
    base = _base_operands(c)
    if n_pad != n:
        init_np = np.pad(np.asarray(c.init, bool), ((0, 0), (0, n_pad - n)))
        base["init"] = jnp.asarray(init_np)
        base["init_packed"] = jnp.asarray(bitops.pack_np(init_np))
    return Operands(edge_src_b=src_b, edge_dst_b=dst_b, **base)


# --------------------------------------------------------------------- #
# incremental maintenance: operand patching + destabilization closure
# --------------------------------------------------------------------- #
def patch_operands(
    ops: Operands,
    c_new: CompiledSOI,
    g: Graph,
    touched_labels: set[int],
    *,
    n_blocks: int = 4,
    adj_cache: dict | None = None,
) -> Operands:
    """Patch device operands in place of a full rebuild (DESIGN.md Sect. 8).

    Precondition: the delta from the operands' snapshot to ``g`` is
    *shape-stable* (no new nodes or labels) and the SOI structure is
    unchanged, so ``c_new.mats`` matches the old operator list and all
    inequality tables stay valid.  Only operators whose label appears in
    ``touched_labels`` are rebuilt against ``g``; untouched adjacency rows
    and edge lists carry over from ``ops`` unchanged (their content is
    identical by construction).  Sparse / partitioned edge lists keep their
    superseded padded capacity whenever the new edge count still fits, so
    patched operand *shapes* — and therefore the plan's jit trace — stay
    stable.  The Eq.-13 ``init`` always refreshes (summaries shift with the
    delta).  The shared ``adj_cache`` entry is re-keyed to ``g`` so sibling
    plans (other batch buckets) pick the patched arrays up as a hit.
    """
    n = g.n_nodes
    touched = [
        m for m, (la, _) in enumerate(c_new.mats) if la in touched_labels
    ]
    init_np = np.asarray(c_new.init, bool)
    # the shared adjacency cache keys on graph identity, so a sibling plan
    # that already patched against this same snapshot is a hit and the
    # patch closure below never runs twice per (layout, mats, graph)
    kw: dict = {}
    if ops.adj_dense is not None:

        def patch_dense():
            adj = ops.adj_dense
            if touched:
                rows = np.stack(
                    [
                        g.dense_adjacency(c_new.mats[m][0],
                                          backward=(c_new.mats[m][1] == BWD))
                        for m in touched
                    ]
                )
                adj = adj.at[jnp.asarray(touched)].set(jnp.asarray(rows))
            return adj

        kw["adj_dense"] = _cached_adj(
            adj_cache, ("dense", tuple(c_new.mats)), g, patch_dense
        )
    elif ops.adj_packed is not None:

        def patch_packed():
            adj = ops.adj_packed
            if touched:
                rows = np.stack(
                    [
                        g.packed_adjacency(c_new.mats[m][0],
                                           backward=(c_new.mats[m][1] == BWD))
                        for m in touched
                    ]
                )
                adj = adj.at[jnp.asarray(touched)].set(jnp.asarray(rows))
            return adj

        kw["adj_packed"] = _cached_adj(
            adj_cache, ("packed", tuple(c_new.mats)), g, patch_packed
        )
    elif ops.edge_src_b is not None:
        n_pad = padded_node_count(n, n_blocks)
        n_local = n_pad // n_blocks
        if n_pad != n:
            init_np = np.pad(init_np, ((0, 0), (0, n_pad - n)))

        def patch_blocks():
            src_b, dst_b = list(ops.edge_src_b), list(ops.edge_dst_b)
            for m in touched:
                a, d = c_new.mats[m]
                sb, db = _partitioned_mat(
                    *_oriented_edges(g, a, d), n_blocks, n_local,
                    min_eb=int(ops.edge_src_b[m].shape[1]),
                )
                src_b[m], dst_b[m] = jnp.asarray(sb), jnp.asarray(db)
            return tuple(src_b), tuple(dst_b)

        kw["edge_src_b"], kw["edge_dst_b"] = _cached_adj(
            adj_cache, ("partitioned", tuple(c_new.mats), n_blocks), g,
            patch_blocks,
        )
    else:

        def patch_edges():
            src, dst = list(ops.edge_src), list(ops.edge_dst)
            sbs = list(ops.seg_src_b) if ops.seg_src_b is not None else None
            dbs = list(ops.seg_dst_b) if ops.seg_dst_b is not None else None
            wbs = list(ops.seg_win) if ops.seg_win is not None else None
            for m in touched:
                a, d = c_new.mats[m]
                s, t = _oriented_edges(g, a, d)
                ps, pt = _padded_edge_list(
                    s, t, n, min_cap=int(ops.edge_src[m].shape[0])
                )
                src[m], dst[m] = jnp.asarray(ps), jnp.asarray(pt)
                if sbs is not None:
                    # the blocked layout keeps its superseded block count
                    # whenever the churned edges still fit, mirroring the
                    # flat lists' EDGE_PAD capacity rule (zero retraces)
                    sbs[m], dbs[m], wbs[m] = _segor_mat(
                        s, t, n, min_g=int(ops.seg_src_b[m].shape[0])
                    )
            seg = (
                (tuple(sbs), tuple(dbs), tuple(wbs))
                if sbs is not None
                else (None, None, None)
            )
            return (tuple(src), tuple(dst)) + seg

        (
            kw["edge_src"], kw["edge_dst"],
            kw["seg_src_b"], kw["seg_dst_b"], kw["seg_win"],
        ) = _cached_adj(
            adj_cache, ("sparse", tuple(c_new.mats)), g, patch_edges
        )
    return dataclasses.replace(
        ops,
        init=jnp.asarray(init_np),
        init_packed=jnp.asarray(bitops.pack_np(init_np)),
        **kw,
    )


def destabilized_rows(c: CompiledSOI, inserted_labels: set[int]) -> np.ndarray:
    """SOI rows whose greatest solution can *grow* under an edge insertion.

    Returns a ``bool[n_vars]`` mask.  Seed: the LHS of every inequality
    whose operator carries an inserted label (their bound ``chi[rhs] x_b M``
    gains columns — the Sect.-3.3 "destabilize dependents" trigger).  The
    seed then closes transitively over the dependency direction *lhs
    depends on rhs* (edge and copy inequalities alike): a row constrained
    by a grown row can grow too.  Rows OUTSIDE the closure provably keep
    ``gfp_new[row] <= gfp_old[row]`` — their whole constraint cone uses
    untouched (or only shrunken) operators — which is the soundness
    argument for re-seeding exactly the closure to ⊤ before a warm resume
    (DESIGN.md Sect. 8.2).
    """
    touched_mats = {
        m for m, (la, _) in enumerate(c.mats) if la in inserted_labels
    }
    grow = np.zeros(c.n_vars, dtype=bool)
    if not touched_mats:
        return grow
    for lhs, m in zip(c.ineq_lhs, c.ineq_mat):
        if int(m) in touched_mats:
            grow[lhs] = True
    deps = list(zip(c.ineq_lhs, c.ineq_rhs)) + list(
        zip(c.copy_lhs, c.copy_rhs)
    )
    changed = True
    while changed:
        changed = False
        for lhs, rhs in deps:
            if grow[rhs] and not grow[lhs]:
                grow[lhs] = True
                changed = True
    return grow


# --------------------------------------------------------------------- #
# batched sweep engines (per-operator Gauss–Seidel within a sweep)
# --------------------------------------------------------------------- #


def _wsc(x: jax.Array, spec) -> jax.Array:
    """Optional sharding constraint (no-op when spec is None / no mesh)."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _replicated(spec):
    """The fully-replicated counterpart of a chi sharding spec."""
    if spec is None:
        return None
    if isinstance(spec, jax.sharding.NamedSharding):
        return jax.sharding.NamedSharding(
            spec.mesh, jax.sharding.PartitionSpec()
        )
    return jax.sharding.PartitionSpec()


def _per_var_mask(y: jax.Array, m: int, ops: Operands) -> jax.Array:
    """``AND_{(l,r) in ineqs_m} y[r]`` per LHS variable l (gather-only).

    Returns bool [V, n]; rows with no operator-m inequality are all-True
    (the padded table entry points at an appended all-ones row).
    """
    n = y.shape[-1]
    vals = y[ops.mat_rhs[m]]  # [I_m, n]
    vals = jnp.concatenate([vals, jnp.ones((1, n), vals.dtype)])
    return jnp.all(vals[ops.mat_table[m]], axis=1)  # [V, n]


def _apply_mat(chi: jax.Array, y: jax.Array, m: int, ops: Operands) -> jax.Array:
    """chi[l] &= y[rhs_l] for every inequality of operator m."""
    return jnp.logical_and(chi, _per_var_mask(y, m, ops))


def _apply_copies(chi: jax.Array, ops: Operands) -> jax.Array:
    if ops.copy_rhs.shape[0] == 0:
        return chi
    n = chi.shape[-1]
    cvals = chi[ops.copy_rhs]
    cvals = jnp.concatenate([cvals, jnp.ones((1, n), cvals.dtype)])
    per_var = jnp.all(cvals[ops.var_copy], axis=1)
    return jnp.logical_and(chi, per_var)


# numpy scalar on purpose: a jnp constant here would initialize the JAX
# backend at import time (breaking XLA_FLAGS device-count forcing)
_ALL_ONES = np.uint32(0xFFFFFFFF)


def _apply_copies_packed(chi_p: jax.Array, ops: Operands) -> jax.Array:
    """Copy inequalities on bit-packed chi: word-wise gathers and ANDs.

    The appended pad row is all-ones *including* trailing pad bits — AND is
    its identity, and chi's own pad bits are already zero, so no pad bit can
    ever turn on (the invariant the packed convergence test relies on).
    """
    if ops.copy_rhs.shape[0] == 0:
        return chi_p
    nw = chi_p.shape[-1]
    cvals = chi_p[ops.copy_rhs]  # [C, nw]
    cvals = jnp.concatenate([cvals, jnp.full((1, nw), _ALL_ONES)])
    per_var = jax.lax.reduce(
        cvals[ops.var_copy], _ALL_ONES, jax.lax.bitwise_and, (1,)
    )  # [V, nw]
    return jnp.bitwise_and(chi_p, per_var)


def _sweep_fixpoint(
    sweep: Callable[[jax.Array], jax.Array],
    init: jax.Array,
    max_sweeps: int | None,
    chi_spec=None,
) -> tuple[jax.Array, jax.Array]:
    """The one fixpoint driver every batched engine runs on.

    Iterates ``sweep`` (any monotone shrink of chi) from ``init`` until chi
    stops changing (or ``max_sweeps``); engines differ only in the sweep
    body they plug in.  Knaster–Tarski on the finite powerset lattice makes
    this safe: every sweep order reaches the same greatest fixpoint.
    Returns (chi, n_sweeps).
    """

    def cond(state):
        _, _, changed = state
        return changed

    def body(state):
        chi, it, _ = state
        new = sweep(chi)
        changed = jnp.any(new != chi)
        if max_sweeps is not None:
            changed = jnp.logical_and(changed, it + 1 < max_sweeps)
        return new, it + 1, changed

    state = (_wsc(init, chi_spec), jnp.int32(0), jnp.bool_(True))
    chi, it, _ = jax.lax.while_loop(cond, body, state)
    return chi, it


def _replicated_frontier(chi_p: jax.Array, chi_spec=None) -> jax.Array:
    """Replicate the packed chi words across the mesh: ONE n/8-byte
    broadcast serves every operator of a Jacobi sweep (vs M chi-sized
    gathers under Gauss–Seidel).  chi already *is* packed words now, so on
    a single device (``chi_spec is None``) this is the identity — the old
    per-sweep pack→broadcast→unpack round trip is gone entirely."""
    if chi_spec is None:
        return chi_p
    return _wsc(chi_p, _replicated(chi_spec))


def _edge_bits(frontier_p: jax.Array, src: jax.Array) -> jax.Array:
    """Per-edge source bits gathered straight out of packed frontier words.

    ``int8 [V, E]``: bit ``src[e] % 32`` of word ``src[e] // 32`` — the
    gathered table is 32x smaller than a boolean frontier.
    """
    word = frontier_p[:, src // 32]  # [V, E] uint32
    return ((word >> (src % 32).astype(jnp.uint32)) & 1).astype(jnp.int8)


def _warm_init(ops: Operands, chi0: jax.Array | None) -> jax.Array:
    """The sweep start point: Eq.-13 init, optionally warm-started.

    ``chi0`` (a previous fixpoint, re-seeded by the caller where an
    insertion may grow the solution — :func:`destabilized_rows`) is ANDed
    into the init: every sweep only shrinks chi, so starting anywhere above
    the greatest fixpoint converges to exactly that fixpoint, in far fewer
    sweeps when ``chi0`` is already close (DESIGN.md Sect. 8.2).
    """
    if chi0 is None:
        return ops.init
    return jnp.logical_and(ops.init, chi0)


def _packed_start(ops: Operands, chi0: jax.Array | None) -> jax.Array:
    """:func:`_warm_init` for the packed-chi engines — all on uint32 words.

    ``chi0`` may be bool ``[V, n]`` or already-packed ``uint32 [V, nw]``;
    the packed form is what the plan cache's chi memo feeds back, with no
    unpack round trip anywhere between memo and while_loop.
    """
    init_p = ops.init_packed
    if init_p is None:  # hand-built Operands: pack once, outside the loop
        init_p = bitops.pack(ops.init)
    if chi0 is None:
        return init_p
    if not jnp.issubdtype(jnp.asarray(chi0).dtype, jnp.unsignedinteger):
        chi0 = bitops.pack(chi0)
    return jnp.bitwise_and(init_p, chi0)


def _per_var_mask_packed(y_p: jax.Array, m: int, ops: Operands) -> jax.Array:
    """:func:`_per_var_mask` on bit-packed ``y``: word-wise gathers + ANDs.

    ``uint32 [V, nw]``; the appended pad row is all-ones (AND identity) and
    chi's own pad bits are already zero, so no pad bit can ever turn on —
    the same argument as :func:`_apply_copies_packed`.
    """
    nw = y_p.shape[-1]
    vals = y_p[ops.mat_rhs[m]]  # [I_m, nw]
    vals = jnp.concatenate([vals, jnp.full((1, nw), _ALL_ONES)])
    return jax.lax.reduce(
        vals[ops.mat_table[m]], _ALL_ONES, jax.lax.bitwise_and, (1,)
    )  # [V, nw]


def _packed_edge_fixpoint(
    propagate: Callable[[jax.Array, int], jax.Array],
    ops: Operands,
    max_sweeps: int | None,
    chi_spec=None,
    chi0: jax.Array | None = None,
    *,
    jacobi: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Shared driver of the packed-state edge-list engines (sparse-gs,
    jacobi_packed, partitioned).  ``propagate(chi_words, m)`` is operator
    m's segmented OR and returns ``y`` already bit-packed ``uint32 [V,
    nw]`` (the ISSUE-8 primitive) — no bool plane and no ``bitops.pack``
    exist anywhere in the while body, which the ``tools.reprolint.dynamic``
    audit enforces.

    Jacobi: ONE replicate of the packed chi words serves every operator,
    all per-operator shrink masks AND together (order-free) and fold into
    chi word-wise.  Gauss–Seidel (``jacobi=False``): operators apply
    sequentially, each reading the freshly-shrunk chi — the identical
    per-operator order the bool-era GS ran, so sweep counts carry over
    verbatim (DESIGN.md Sect. 12).  Convergence is the word-level ``new !=
    chi`` of :func:`_sweep_fixpoint`.  Returns (bool chi, sweeps), unpacked
    once after the fixpoint.
    """
    n = ops.init.shape[-1]
    n_mats = len(ops.mat_rhs)

    if jacobi:

        def sweep(chi_p: jax.Array) -> jax.Array:
            frontier_p = _replicated_frontier(chi_p, chi_spec)
            shrink = None
            for m in range(n_mats):
                y_p = _wsc(propagate(frontier_p, m), chi_spec)
                pv = _per_var_mask_packed(y_p, m, ops)
                shrink = pv if shrink is None else jnp.bitwise_and(shrink, pv)
            if shrink is not None:
                chi_p = _wsc(jnp.bitwise_and(chi_p, shrink), chi_spec)
            return _apply_copies_packed(chi_p, ops)

    else:

        def sweep(chi_p: jax.Array) -> jax.Array:
            for m in range(n_mats):
                y_p = _wsc(propagate(chi_p, m), chi_spec)
                chi_p = _wsc(
                    jnp.bitwise_and(chi_p, _per_var_mask_packed(y_p, m, ops)),
                    chi_spec,
                )
            return _apply_copies_packed(chi_p, ops)

    chi_p, it = _sweep_fixpoint(
        sweep, _packed_start(ops, chi0), max_sweeps, chi_spec
    )
    return bitops.unpack(chi_p, n), it


def _fixpoint(
    propagate_m: Callable[[jax.Array, int], jax.Array],
    ops: Operands,
    max_sweeps: int | None,
    chi_spec=None,
    chi0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gauss–Seidel sweeps: one boolean product ``y = chi x_b M_m`` per
    operator m (all variables batched), AND-updates applied immediately —
    one y tensor live at a time."""
    n_mats = len(ops.mat_rhs)

    def sweep(chi: jax.Array) -> jax.Array:
        for m in range(n_mats):
            y = propagate_m(chi, m)  # [V, n] bool
            chi = _wsc(_apply_mat(chi, y, m, ops), chi_spec)
        return _apply_copies(chi, ops)

    return _sweep_fixpoint(sweep, _warm_init(ops, chi0), max_sweeps, chi_spec)


@functools.partial(jax.jit, static_argnames=("dtype", "max_sweeps", "chi_spec"))
def solve_dense(
    ops: Operands, *, dtype=jnp.float32, max_sweeps: int | None = None,
    chi_spec=None, chi0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sweeps with dense boolean matmuls on the MXU (OR-AND via (+,x), >0)."""

    def propagate_m(chi: jax.Array, m: int) -> jax.Array:
        x = chi.astype(dtype)
        y = x @ ops.adj_dense[m].astype(dtype)
        return y > 0

    return _fixpoint(propagate_m, ops, max_sweeps, chi_spec, chi0)


@functools.partial(
    jax.jit, static_argnames=("max_sweeps", "interpret", "chi_spec")
)
def solve_packed(
    ops: Operands, *, max_sweeps: int | None = None,
    interpret: bool | None = None, chi_spec=None,
    chi0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sweeps over bit-packed adjacency via the Pallas bitmm kernel.

    chi itself stays boolean between kernel calls — this is the baseline
    the fused engine (:func:`solve_packed_fused`) is measured against.
    ``interpret=None`` auto-detects the backend (interpret only on CPU), so
    direct callers no longer silently interpret the kernel on accelerators.
    """
    from repro.kernels.bitmm import ops as bitmm_ops

    def propagate_m(chi: jax.Array, m: int) -> jax.Array:
        return bitmm_ops.bitmm(chi, ops.adj_packed[m], interpret=interpret)

    return _fixpoint(propagate_m, ops, max_sweeps, chi_spec, chi0)


@functools.partial(jax.jit, static_argnames=("max_sweeps", "impl", "chi_spec"))
def solve_packed_fused(
    ops: Operands, *, max_sweeps: int | None = None, impl: str | None = None,
    chi_spec=None, chi0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Bit-packed chi end to end: one fused launch per operator application.

    The ``lax.while_loop`` carries ``uint32 [V, nw]`` — 32x less state than
    the boolean engines — and every sweep is ``M`` ``bitmm_apply`` calls
    (packed product + AND-combine + changed words in one grid) plus the
    word-wise copy step.  Convergence comes from the kernels' own changed
    flags; chi is unpacked exactly once, after the fixpoint (DESIGN.md
    Sect. 9).

    ``impl``: ``"kernel"`` (compiled Pallas), ``"interpret"`` (Pallas in
    interpret mode), ``"words"`` (pure-jnp word-wise lowering), or ``None``
    for backend auto-detection — kernel on accelerators, words on CPU,
    where XLA beats kernel emulation ~9x.
    """
    from repro.kernels.bitmm import ops as bitmm_ops

    if impl is None:
        impl = "words" if jax.default_backend() == "cpu" else "kernel"
    n = ops.init.shape[-1]
    n_mats = len(ops.mat_rhs)

    def apply_m(chi_p: jax.Array, m: int) -> tuple[jax.Array, jax.Array]:
        if impl == "words":
            from repro.kernels.bitmm import ref as bitmm_ref

            return bitmm_ref.bitmm_apply_words(
                chi_p, ops.adj_packed[m], ops.mat_lhs_flags[m]
            )
        return bitmm_ops.bitmm_apply(
            chi_p, ops.adj_packed[m], ops.mat_lhs_flags[m],
            interpret=(impl == "interpret"),
        )

    def cond(state):
        return state[2]

    def body(state):
        chi_p, it, _ = state
        changed = jnp.uint32(0)
        for m in range(n_mats):
            chi_p, ch = apply_m(chi_p, m)
            chi_p = _wsc(chi_p, chi_spec)
            changed = jnp.bitwise_or(changed, jnp.uint32(ch))
        before = chi_p
        chi_p = _apply_copies_packed(chi_p, ops)
        moved = jnp.logical_or(changed != 0, jnp.any(chi_p != before))
        if max_sweeps is not None:
            moved = jnp.logical_and(moved, it + 1 < max_sweeps)
        return chi_p, it + 1, moved

    state = (
        _wsc(_packed_start(ops, chi0), chi_spec),
        jnp.int32(0),
        jnp.bool_(True),
    )
    chi_p, it, _ = jax.lax.while_loop(cond, body, state)
    return bitops.unpack(chi_p, n), it


@functools.partial(
    jax.jit,
    static_argnames=("max_sweeps", "chi_spec", "mode", "impl", "interpret"),
)
def solve_sparse(
    ops: Operands, *, max_sweeps: int | None = None, chi_spec=None,
    mode: str = "gs", impl: str | None = None,
    interpret: bool | None = None, chi0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Edge-list engine: segmented-OR message passing over bit-packed chi.

    One segmented OR per (label, direction) operator — the GNN scatter
    regime; int32-safe at billion-edge scale because segments are
    per-operator node ids.  Since ISSUE 8 chi lives bit-packed ``uint32
    [V, nw]`` through the whole while_loop in BOTH modes: frontier bits
    come straight out of the packed words (:func:`_edge_bits`) and ``y``
    comes back already packed from the segmented-OR primitive, so no
    ``[V, n]`` bool plane exists anywhere in the loop.

    ``mode``:
    * ``"gs"`` (paper-faithful ordering): operators applied sequentially
      within a sweep, each reading the freshly-shrunk chi — fewest sweeps,
      identical per-operator order (and therefore sweep counts) to the
      bool-era engine, but O(M) chi-sized collectives per sweep on a mesh.
    * ``"jacobi_packed"`` (beyond-paper, §Perf): all operators read
      frontier bits out of ONE replicated copy of the packed words per
      sweep — 32x fewer collective bytes.  Same fixpoint either way
      (monotone operator on a finite lattice).

    ``impl`` picks the segmented-OR lowering: ``"words"`` (word-wise XLA,
    the CPU path), ``"kernel"`` (the blocked Pallas kernel over the
    ``seg_*`` operand layout; ``interpret`` auto-enables off-TPU), or
    ``None`` for backend auto-detection — kernel on accelerators, words on
    CPU.  Operands without the blocked layout fall back to ``"words"``.
    """
    from repro.kernels.segsum import kernel as segsum_kernel
    from repro.kernels.segsum import ref as segsum_ref

    n = ops.init.shape[-1]
    if impl is None:
        impl = "words" if jax.default_backend() == "cpu" else "kernel"
    # trace-ok: seg_win's None-ness is pytree *structure*, static under jit
    if impl == "kernel" and ops.seg_win is None:
        impl = "words"  # hand-built / abstract Operands: flat lists only
    if impl not in ("words", "kernel"):
        raise ValueError(f"unknown sparse impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    def propagate(frontier_p: jax.Array, m: int) -> jax.Array:
        if impl == "kernel":
            bits = _edge_bits(frontier_p, ops.seg_src_b[m])  # [V, G, BE]
            return segsum_kernel.segor_blocks(
                bits.transpose(1, 2, 0), ops.seg_dst_b[m], ops.seg_win[m],
                num_segments=n, interpret=interpret,
            )
        msgs = _edge_bits(frontier_p, ops.edge_src[m])  # int8 [V, E_m]
        return segsum_ref.segor_words(msgs, ops.edge_dst[m], n)

    if mode not in ("gs", "jacobi_packed"):
        raise ValueError(f"unknown sparse mode {mode!r}")
    return _packed_edge_fixpoint(
        propagate, ops, max_sweeps, chi_spec, chi0, jacobi=(mode != "gs")
    )


@functools.partial(jax.jit, static_argnames=("max_sweeps", "chi_spec"))
def solve_partitioned(
    ops: Operands, *, max_sweeps: int | None = None, chi_spec=None,
    chi0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Vertex-cut partitioned engine (beyond-paper, EXPERIMENTS §Perf).

    Edges are pre-partitioned by destination chi-block
    (:func:`make_partitioned_operands`), so every segmented OR is
    block-local and emits block-local *packed words* directly (the block
    size is a 32-multiple by :func:`padded_node_count`, so block words
    concatenate into the global word order with a reshape); chi lives
    bit-packed through the while_loop, and the ONLY cross-shard traffic per
    sweep is replicating the n/8-byte packed words chi already is (instead
    of M chi-sized all-gathers plus scatter all-reduces — and, since
    ISSUE 8, with no bool y plane or per-sweep pack either).  Jacobi sweeps
    (all operators read the same frontier); same fixpoint as the other
    engines.
    """
    from repro.kernels.segsum import ref as segsum_ref

    v, n = ops.init.shape
    w = ops.edge_src_b[0].shape[0]
    n_local = n // w
    if n_local % bitops.WORD:
        raise ValueError(
            "partitioned operands need 32-aligned blocks "
            f"(n={n}, n_blocks={w}); build them via make_partitioned_operands"
        )
    nlw = n_local // bitops.WORD

    def propagate_blocks(frontier_p: jax.Array, m: int) -> jax.Array:
        def block(src_w, dst_w):
            msgs = _edge_bits(frontier_p, src_w)  # int8 [V, Eb]
            # pad rows (dst = n_local) dropped by the segment reduce
            return segsum_ref.segor_words(msgs, dst_w, n_local)  # [V, nlw]

        yw = jax.vmap(block)(ops.edge_src_b[m], ops.edge_dst_b[m])  # [W,V,nlw]
        return yw.transpose(1, 0, 2).reshape(v, w * nlw)  # [V, nw] block-major

    return _packed_edge_fixpoint(
        propagate_blocks, ops, max_sweeps, chi_spec, chi0, jacobi=True
    )


# --------------------------------------------------------------------- #
# the paper's sequential worklist engine (numpy reference)
# --------------------------------------------------------------------- #
def solve_worklist(
    c: CompiledSOI,
    g: Graph,
    *,
    heuristic: str = "sparse_first",
    eq13_init: bool = True,
) -> tuple[np.ndarray, int]:
    """Paper Sect. 3.2 algorithm: pick an unstable inequality, validate or
    update, destabilize dependents.  Heuristics from Sect. 3.3:

    * ``sparse_first`` — static order preferring operators with more empty
      columns (sparser matrices shrink the relation earlier);
    * ``fifo`` — arrival order;
    * row- vs column-wise evaluation of ``r`` chosen dynamically by comparing
      ``|chi(rhs)|`` with ``|chi(lhs)|``.

    Returns (chi, number of inequality evaluations).
    """
    n = g.n_nodes
    chi = (
        c.init.copy()
        if eq13_init
        else _eq12_init(c, g)
    )
    ineqs = list(zip(c.ineq_lhs, c.ineq_rhs, c.ineq_mat))
    copies = list(zip(c.copy_lhs, c.copy_rhs))

    # CSR per operator for row-wise evaluation.
    csr: list[tuple[np.ndarray, np.ndarray]] = []
    csc: list[tuple[np.ndarray, np.ndarray]] = []
    nonempty_cols: list[int] = []
    for a, d in c.mats:
        e = g.edges_for_label(a)
        s, t = (e[:, 0], e[:, 1]) if d == FWD else (e[:, 1], e[:, 0])
        csr.append(_csr(s, t, n))
        csc.append(_csr(t, s, n))
        nonempty_cols.append(len(np.unique(t)))

    if heuristic == "sparse_first":
        order = sorted(range(len(ineqs)), key=lambda i: nonempty_cols[ineqs[i][2]])
    else:
        order = list(range(len(ineqs)))

    # dependents: inequalities whose rhs is a given variable.
    dep_edge: list[list[int]] = [[] for _ in range(c.n_vars)]
    for i, (_, r, _) in enumerate(ineqs):
        dep_edge[r].append(i)
    dep_copy: list[list[int]] = [[] for _ in range(c.n_vars)]
    for i, (_, r) in enumerate(copies):
        dep_copy[r].append(i)

    unstable = set(range(len(ineqs)))
    unstable_c = set(range(len(copies)))
    evaluations = 0
    while unstable or unstable_c:
        if unstable:
            idx = next(i for i in order if i in unstable)
            unstable.discard(idx)
            l, r, m = ineqs[idx]
            evaluations += 1
            rr = _bit_product(chi[r], chi[l], csr[m], csc[m], n)
            new = chi[l] & rr
            if not np.array_equal(new, chi[l]):
                chi[l] = new
                # destabilize dependents (rhs == l); a self-loop inequality
                # (l == r) legitimately re-enters the worklist here.
                unstable.update(dep_edge[l])
                unstable_c.update(dep_copy[l])
        else:
            idx = unstable_c.pop()
            l, r = copies[idx]
            evaluations += 1
            new = chi[l] & chi[r]
            if not np.array_equal(new, chi[l]):
                chi[l] = new
                unstable.update(dep_edge[l])
                unstable_c.update(dep_copy[l])
    return chi, evaluations


def _eq12_init(c: CompiledSOI, g: Graph) -> np.ndarray:
    init = np.ones((c.n_vars, g.n_nodes), dtype=bool)
    for i, const in enumerate(c.soi.is_const):
        if const is not None:
            init[i] = c.init[i]
    # labels absent from the DB still force emptiness
    for i in range(c.n_vars):
        if not c.init[i].any():
            init[i] = False
    return init


def _csr(src: np.ndarray, dst: np.ndarray, n: int):
    order = np.argsort(src, kind="stable")
    s, t = src[order], dst[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, s + 1, 1)
    np.cumsum(ptr, out=ptr)
    return ptr, t.astype(np.int32)


def _bit_product(
    x: np.ndarray, lhs: np.ndarray, csr, csc, n: int
) -> np.ndarray:
    """r = x ×b A, evaluated row- or column-wise per the paper's heuristic."""
    if x.sum() <= lhs.sum():
        # row-wise: union the A-rows of set bits of x.
        ptr, idx = csr
        out = np.zeros(n, dtype=bool)
        for i in np.flatnonzero(x):
            out[idx[ptr[i] : ptr[i + 1]]] = True
        return out
    # column-wise: only decide the columns where lhs is set.
    ptr, idx = csc
    out = np.zeros(n, dtype=bool)
    for j in np.flatnonzero(lhs):
        out[j] = x[idx[ptr[j] : ptr[j + 1]]].any()
    return out


# --------------------------------------------------------------------- #
# high-level API
# --------------------------------------------------------------------- #
def pattern_graph_soi(pattern: Graph) -> SOI:
    """SOI for classic graph-to-graph dual simulation (pattern = G1)."""
    from .sparql import BGP, Triple, Var

    trs = tuple(
        Triple(Var(f"v{s}"), int(a), Var(f"v{o}"))
        for (s, a, o) in pattern.triples
    )
    return build_soi(BGP(trs))


def largest_dual_simulation(
    pattern: Graph,
    db: Graph,
    *,
    engine: str = "dense",
    dtype=jnp.float32,
    n_blocks: int = 4,
) -> tuple[np.ndarray, int]:
    """Largest dual simulation between ``pattern`` and ``db`` (Prop. 1).

    Returns ``(S, sweeps)`` with ``S`` a bool matrix of shape
    ``(pattern.n_nodes, db.n_nodes)``: ``S[v, x]`` iff x dual-simulates v.
    """
    soi = pattern_graph_soi(pattern)
    # map var ids back to pattern node order: vars are created in triple
    # order, so build the permutation explicitly.  Isolated pattern nodes
    # (no incident edges) are unconstrained: simulated by every db node.
    c = compile_soi(soi, db)
    seen = {b: i for i, b in enumerate(soi.base)}
    isolated = [n for n in range(pattern.n_nodes) if f"v{n}" not in seen]

    def reorder(chi: np.ndarray) -> np.ndarray:
        out = np.ones((pattern.n_nodes, db.n_nodes), dtype=bool)
        for node in range(pattern.n_nodes):
            if node not in isolated:
                out[node] = chi[seen[f"v{node}"]]
        return out

    if engine == "worklist":
        chi, it = solve_worklist(c, db)
        return reorder(np.asarray(chi)), int(it)
    chi, it = solve_compiled(c, db, engine=engine, dtype=dtype, n_blocks=n_blocks)
    return reorder(chi), it


def solve_compiled(
    c: CompiledSOI,
    g: Graph,
    *,
    engine: str = "dense",
    dtype=jnp.float32,
    n_blocks: int = 4,
    chi0: np.ndarray | None = None,
) -> tuple[np.ndarray, int]:
    """Solve a compiled SOI with the chosen engine; returns (chi, iters).

    Engines: ``dense``, ``packed``, ``packed_fused`` (bit-packed chi end to
    end through the fused ``bitmm_apply`` kernel), ``sparse``
    (Gauss–Seidel), ``jacobi_packed`` (edge lists over a bit-packed chi
    state, one packed frontier replicate per sweep), ``partitioned``
    (destination-partitioned edge blocks over packed chi; ``n_blocks``
    shards, node axis auto-padded), ``worklist`` (numpy reference).

    ``chi0`` warm-starts any batched engine from a previous fixpoint
    (callers are responsible for the re-seeding rule — use
    :func:`resume_fixpoint` for the safe high-level path).
    """
    if chi0 is not None:
        if engine == "worklist":
            raise ValueError("the worklist engine does not take a warm start")
        chi0 = jnp.asarray(chi0, dtype=bool)
    if engine == "dense":
        chi, it = solve_dense(make_dense_operands(c, g), dtype=dtype, chi0=chi0)
    elif engine == "packed":
        chi, it = solve_packed(make_packed_operands(c, g), chi0=chi0)
    elif engine == "packed_fused":
        chi, it = solve_packed_fused(make_packed_operands(c, g), chi0=chi0)
    elif engine == "sparse":
        chi, it = solve_sparse(make_sparse_operands(c, g), chi0=chi0)
    elif engine == "jacobi_packed":
        chi, it = solve_sparse(
            make_sparse_operands(c, g), mode="jacobi_packed", chi0=chi0
        )
    elif engine == "partitioned":
        ops = make_partitioned_operands(c, g, n_blocks)
        if chi0 is not None and chi0.shape[-1] != ops.init.shape[-1]:
            chi0 = jnp.pad(
                chi0, ((0, 0), (0, ops.init.shape[-1] - chi0.shape[-1]))
            )
        chi, it = solve_partitioned(ops, chi0=chi0)
        chi = chi[:, : g.n_nodes]  # drop block-padding columns
    elif engine == "worklist":
        return solve_worklist(c, g)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    return np.asarray(chi), int(it)


def resume_fixpoint(
    c: CompiledSOI,
    g: Graph,
    chi0: np.ndarray,
    *,
    inserted_labels: set[int] | frozenset[int] = frozenset(),
    engine: str = "dense",
    dtype=jnp.float32,
    n_blocks: int = 4,
) -> tuple[np.ndarray, int]:
    """Warm-started fixpoint: resume from a previous snapshot's solution.

    ``chi0`` is the greatest solution computed against the *previous* graph
    snapshot; ``c`` is the SOI re-compiled against the mutated graph ``g``
    (same SOI structure, new Eq.-13 init).  Correctness (DESIGN.md 8.2):

    * **deletions only** — the greatest solution can only shrink, and every
      sweep is monotone-decreasing, so resuming from ``chi0 ∧ init_new``
      converges to exactly the new greatest fixpoint;
    * **insertions** — rows in the :func:`destabilized_rows` closure of the
      inserted labels are re-seeded to ⊤ (their fresh Eq.-13 init) first;
      rows outside the closure provably cannot grow, so the re-seeded start
      still dominates the new fixpoint.

    Returns ``(chi, sweeps)`` bit-identical to a cold solve on ``g``.
    """
    chi0 = np.array(chi0, dtype=bool, copy=True)
    if inserted_labels:
        chi0[destabilized_rows(c, set(inserted_labels))] = True
    return solve_compiled(
        c, g, engine=engine, dtype=dtype, n_blocks=n_blocks, chi0=chi0
    )
