"""Edge-labeled directed graphs and graph databases (paper Sect. 2).

A graph is ``G = (V, Sigma, E)`` with ``E ⊆ V × Sigma × V``.  Nodes and labels
are dictionary-encoded to dense ints.  Three physical layouts coexist:

* **triples** — ``(E, 3) int32`` array of (src, label, dst); canonical form.
* **per-label CSR** — forward map F_a / backward map B_a (paper's adjacency
  maps) as index arrays; used by the numpy reference engines and the join
  evaluator.
* **dense boolean / bit-packed adjacency** — per-label ``bool[n, n]`` or
  ``uint32[n, n/32]`` matrices; used by the MXU / Pallas engines (viable up to
  ~64k nodes per shard; the sparse edge-list engine covers DB scale).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from . import bitops

# Hard budget on any [n, n] plane: past this, a dense adjacency (or the
# transient dense build inside packed_adjacency) cannot be materialized at
# all — construction raises MemoryError instead of OOMing the host, and
# engine.cost refuses the dense-layout engine tier before it gets here
# (ISSUE 8: the RDF workload runs where this is structurally impossible).
DENSE_ADJ_MAX_BYTES = 2 << 30


@dataclasses.dataclass
class Graph:
    """An edge-labeled directed graph over dense int ids."""

    n_nodes: int
    n_labels: int
    triples: np.ndarray  # (E, 3) int32: (src, label, dst)
    node_names: list[str] | None = None
    label_names: list[str] | None = None

    # lazily built indexes
    _fwd_csr: dict | None = dataclasses.field(default=None, repr=False)
    _bwd_csr: dict | None = dataclasses.field(default=None, repr=False)
    _node_index: dict | None = dataclasses.field(default=None, repr=False)
    _label_index: dict | None = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_triples(
        triples: Iterable[tuple[str, str, str]],
    ) -> "Graph":
        """Build from (subject, predicate, object) string triples."""
        nodes: dict[str, int] = {}
        labels: dict[str, int] = {}
        enc = []
        for s, p, o in triples:
            si = nodes.setdefault(s, len(nodes))
            pi = labels.setdefault(p, len(labels))
            oi = nodes.setdefault(o, len(nodes))
            enc.append((si, pi, oi))
        arr = np.asarray(enc, dtype=np.int32).reshape(-1, 3)
        return Graph(
            n_nodes=len(nodes),
            n_labels=len(labels),
            triples=arr,
            node_names=list(nodes),
            label_names=list(labels),
        )

    @staticmethod
    def from_arrays(n_nodes: int, n_labels: int, triples: np.ndarray) -> "Graph":
        triples = np.asarray(triples, dtype=np.int32).reshape(-1, 3)
        if len(triples):
            assert triples[:, [0, 2]].max() < n_nodes, "node id out of range"
            assert triples[:, 1].max() < n_labels, "label id out of range"
        return Graph(n_nodes=n_nodes, n_labels=n_labels, triples=triples)

    # ------------------------------------------------------------------ #
    # id helpers
    # ------------------------------------------------------------------ #
    def node_index(self) -> dict[str, int]:
        """Cached name -> id map over ``node_names`` (snapshots are
        immutable, so building it once per graph is safe)."""
        if self._node_index is None:
            assert self.node_names is not None
            self._node_index = {n: i for i, n in enumerate(self.node_names)}
        return self._node_index

    def label_index(self) -> dict[str, int]:
        """Cached name -> id map over ``label_names``."""
        if self._label_index is None:
            assert self.label_names is not None
            self._label_index = {n: i for i, n in enumerate(self.label_names)}
        return self._label_index

    def node_id(self, name: str) -> int:
        return self.node_index()[name]

    def label_id(self, name: str) -> int:
        return self.label_index()[name]

    @property
    def n_edges(self) -> int:
        return int(self.triples.shape[0])

    # ------------------------------------------------------------------ #
    # per-label edge lists (sparse engine / segment message passing)
    # ------------------------------------------------------------------ #
    def edges_for_label(self, a: int) -> np.ndarray:
        """(Ea, 2) int32 (src, dst) rows with label ``a``."""
        m = self.triples[:, 1] == a
        return self.triples[m][:, [0, 2]]

    def label_histogram(self) -> np.ndarray:
        return np.bincount(self.triples[:, 1], minlength=self.n_labels)

    # ------------------------------------------------------------------ #
    # CSR adjacency maps (paper's F^a / B^a) — numpy reference engines
    # ------------------------------------------------------------------ #
    def fwd(self, a: int, v: int) -> np.ndarray:
        """F^a(v): successor set of v via a-labeled edges."""
        self._build_csr()
        ptr, idx = self._fwd_csr[a]
        return idx[ptr[v] : ptr[v + 1]]

    def bwd(self, a: int, v: int) -> np.ndarray:
        """B^a(v): predecessor set of v via a-labeled edges."""
        self._build_csr()
        ptr, idx = self._bwd_csr[a]
        return idx[ptr[v] : ptr[v + 1]]

    def _build_csr(self) -> None:
        if self._fwd_csr is not None:
            return
        self._fwd_csr, self._bwd_csr = {}, {}
        for a in range(self.n_labels):
            e = self.edges_for_label(a)
            self._fwd_csr[a] = _csr(e[:, 0], e[:, 1], self.n_nodes)
            self._bwd_csr[a] = _csr(e[:, 1], e[:, 0], self.n_nodes)

    # ------------------------------------------------------------------ #
    # dense / packed adjacency (MXU + Pallas engines)
    # ------------------------------------------------------------------ #
    def dense_adjacency(self, a: int, backward: bool = False) -> np.ndarray:
        """bool[n, n] forward (or backward) adjacency matrix for label a.

        Raises ``MemoryError`` when the [n, n] plane would exceed
        ``DENSE_ADJ_MAX_BYTES`` — at RDF scale the dense tier does not
        exist, and failing here (cheaply, before allocation) is what the
        ``--rdf`` bench asserts.
        """
        if self.n_nodes * self.n_nodes > DENSE_ADJ_MAX_BYTES:
            raise MemoryError(
                f"dense [n, n] adjacency at n={self.n_nodes} needs "
                f"{self.n_nodes * self.n_nodes} bytes > budget "
                f"{DENSE_ADJ_MAX_BYTES}; use the edge-list engines"
            )
        e = self.edges_for_label(a)
        m = np.zeros((self.n_nodes, self.n_nodes), dtype=bool)
        if backward:
            m[e[:, 1], e[:, 0]] = True
        else:
            m[e[:, 0], e[:, 1]] = True
        return m

    def packed_adjacency(self, a: int, backward: bool = False) -> np.ndarray:
        """uint32[n, ceil(n/32)] bit-packed adjacency for label a."""
        return np.asarray(bitops.pack(self.dense_adjacency(a, backward)))

    def summary_fwd(self, a: int) -> np.ndarray:
        """Paper's f^a: bool[n], bit i set iff node i has an outgoing a-edge."""
        e = self.edges_for_label(a)
        out = np.zeros(self.n_nodes, dtype=bool)
        out[e[:, 0]] = True
        return out

    def summary_bwd(self, a: int) -> np.ndarray:
        """Paper's b^a: bool[n], bit i set iff node i has an incoming a-edge."""
        e = self.edges_for_label(a)
        out = np.zeros(self.n_nodes, dtype=bool)
        out[e[:, 1]] = True
        return out


def _csr(src: np.ndarray, dst: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, src + 1, 1)
    np.cumsum(ptr, out=ptr)
    return ptr, dst.astype(np.int32)


def subgraph_triples(g: Graph, triple_mask: np.ndarray) -> Graph:
    """Graph restricted to the triples selected by ``triple_mask``."""
    return Graph(
        n_nodes=g.n_nodes,
        n_labels=g.n_labels,
        triples=g.triples[triple_mask],
        node_names=g.node_names,
        label_names=g.label_names,
    )


# --------------------------------------------------------------------- #
# deltas between snapshots (incremental maintenance; DESIGN.md Sect. 8)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """The difference between two consecutive graph snapshots.

    A mutation source (``repro.db.GraphDB``) records one ``GraphDelta`` per
    version bump; the engine composes them to decide whether a superseded
    plan is *resumable* (dictionary and node axis unchanged — operands can
    be patched in place and the old fixpoint warm-starts the new solve) or
    *cold* (shape change: rebuild from scratch).  Triples are int id rows,
    valid in both snapshots whenever :attr:`shape_stable` holds (ids are
    stable across mutations; deletes never drop names).
    """

    inserted: np.ndarray  # (K, 3) int32 (src, label, dst) rows added
    deleted: np.ndarray  # (K, 3) int32 rows removed
    nodes_before: int
    nodes_after: int
    labels_before: int
    labels_after: int

    @property
    def shape_stable(self) -> bool:
        """True iff the dictionary did not grow: no new nodes or labels.

        Shape-stable deltas keep every compiled operand shape (chi width,
        dense/packed adjacency) and every name -> id mapping valid, which is
        the precondition for patching a plan instead of rebuilding it.
        """
        return (
            self.nodes_after == self.nodes_before
            and self.labels_after == self.labels_before
        )

    @property
    def has_insertions(self) -> bool:
        """True iff the delta adds edges (the fixpoint may *grow*)."""
        return len(self.inserted) > 0

    @property
    def n_changes(self) -> int:
        """Total number of edge insertions + deletions."""
        return len(self.inserted) + len(self.deleted)

    def touched_labels(self) -> set[int]:
        """Label ids with at least one inserted or deleted edge."""
        out: set[int] = set()
        if len(self.inserted):
            out.update(int(x) for x in np.unique(self.inserted[:, 1]))
        if len(self.deleted):
            out.update(int(x) for x in np.unique(self.deleted[:, 1]))
        return out

    def inserted_labels(self) -> set[int]:
        """Label ids with at least one *inserted* edge (these destabilize
        dependent SOI rows; deletions alone never do)."""
        if not len(self.inserted):
            return set()
        return {int(x) for x in np.unique(self.inserted[:, 1])}

    def compose(self, later: "GraphDelta") -> "GraphDelta":
        """The delta of applying ``self`` then ``later`` (cancelling an
        insert against a later delete of the same triple and vice versa)."""
        ins = {tuple(r) for r in self.inserted.tolist()}
        dele = {tuple(r) for r in self.deleted.tolist()}
        for r in later.inserted.tolist():
            t = tuple(r)
            if t in dele:
                dele.discard(t)
            else:
                ins.add(t)
        for r in later.deleted.tolist():
            t = tuple(r)
            if t in ins:
                ins.discard(t)
            else:
                dele.add(t)
        as_rows = lambda s: (
            np.asarray(sorted(s), dtype=np.int32).reshape(-1, 3)
        )
        return GraphDelta(
            inserted=as_rows(ins),
            deleted=as_rows(dele),
            nodes_before=self.nodes_before,
            nodes_after=later.nodes_after,
            labels_before=self.labels_before,
            labels_after=later.labels_after,
        )
