"""HHK-style simulation algorithm (Henzinger, Henzinger, Kopke 1995),
adapted to edge-labeled graphs and to *dual* simulation, per the paper's
Sect. 3.3 complexity discussion ("specific data complexity hypothesis").

The classic algorithm maintains, per pattern node v (and here per incident
label/direction), a *remove set*: data nodes that have an a-edge but whose
a-neighbours no longer intersect sim(v).  Processing a nonempty remove set
shrinks the simulators of v's pattern neighbours.  We run the machinery on
forward and backward edges simultaneously, which is what "executing HHK two
times" amounts to for dual simulation.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def dual_simulation_hhk(pattern: Graph, db: Graph) -> tuple[np.ndarray, int]:
    """Largest dual simulation via labeled-HHK remove sets.

    Returns (S bool[|V1|, |V2|], number of remove-set pops).
    """
    n1, n2 = pattern.n_nodes, db.n_nodes
    labels = sorted(set(int(a) for a in pattern.triples[:, 1]))

    # dense boolean adjacency per (label, dir) — fine at reference scale
    F = {a: db.dense_adjacency(a) for a in labels}
    B = {a: db.dense_adjacency(a, backward=True) for a in labels}
    has_f = {a: F[a].any(axis=1) for a in labels}  # x has a-successor
    has_b = {a: B[a].any(axis=1) for a in labels}  # x has a-predecessor

    sim = np.ones((n1, n2), dtype=bool)
    p_out = [[] for _ in range(n1)]
    p_in = [[] for _ in range(n1)]
    for s, a, o in pattern.triples:
        p_out[s].append((int(a), int(o)))
        p_in[o].append((int(a), int(s)))

    # init: Eq.-13-equivalent sharpening (HHK's "prefilter")
    for v in range(n1):
        for a, _ in p_out[v]:
            sim[v] &= has_f[a]
        for a, _ in p_in[v]:
            sim[v] &= has_b[a]

    # remove_fwd[(v, a)] = {x : x has a-succ but none in sim(v)}
    def mk_remove_f(v, a):
        reach = F[a] @ sim[v]  # x -> count of a-successors in sim(v)
        return has_f[a] & ~(reach > 0)

    def mk_remove_b(v, a):
        reach = B[a] @ sim[v]
        return has_b[a] & ~(reach > 0)

    rem_f = {}
    rem_b = {}
    for v in range(n1):
        for a in {a for a, _ in p_out[v]} | {a for a, _ in p_in[v]}:
            rem_f[(v, a)] = mk_remove_f(v, a)
            rem_b[(v, a)] = mk_remove_b(v, a)

    pops = 0
    dirty = True
    while dirty:
        dirty = False
        for key in list(rem_f):
            v, a = key
            r = rem_f[key]
            if not r.any():
                continue
            pops += 1
            rem_f[key] = np.zeros(n2, dtype=bool)
            # u --a--> v in pattern: simulators of u must reach sim(v)
            for aa, u in p_in[v]:
                if aa != a:
                    continue
                newu = sim[u] & ~r
                if not np.array_equal(newu, sim[u]):
                    sim[u] = newu
                    _refresh(u, sim, p_out, p_in, rem_f, rem_b, mk_remove_f, mk_remove_b)
                    dirty = True
        for key in list(rem_b):
            v, a = key
            r = rem_b[key]
            if not r.any():
                continue
            pops += 1
            rem_b[key] = np.zeros(n2, dtype=bool)
            # v --a--> w in pattern: simulators of w must be reached from sim(v)
            for aa, w in p_out[v]:
                if aa != a:
                    continue
                neww = sim[w] & ~r
                if not np.array_equal(neww, sim[w]):
                    sim[w] = neww
                    _refresh(w, sim, p_out, p_in, rem_f, rem_b, mk_remove_f, mk_remove_b)
                    dirty = True
    return sim, pops


def _refresh(v, sim, p_out, p_in, rem_f, rem_b, mk_f, mk_b):
    """Recompute remove sets of a shrunk pattern node (simplified HHK: the
    original maintains them incrementally; recomputation keeps the same
    fixpoint and pass structure at higher constant cost)."""
    for a in {a for a, _ in p_out[v]} | {a for a, _ in p_in[v]}:
        rem_f[(v, a)] = mk_f(v, a)
        rem_b[(v, a)] = mk_b(v, a)
