"""In-framework SPARQL evaluator (hash joins) — the downstream "database
system" stand-in for the paper's Tables 4/5 experiments, and the match
oracle for the soundness property tests (Theorems 1/2).

Evaluates the paper's fragment S (+UNION) under the standard semantics of
Pérez et al.: BGP via selectivity-ordered hash joins, AND via compatible
inner join, OPTIONAL via compatible left-outer join, UNION via concatenation.
Unbound variables are the sentinel ``-1`` and are compatible with anything.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph
from .sparql import And, BGP, Const, Optional_, Query, Triple, Union_, Var


@dataclasses.dataclass
class Bindings:
    """A match table: column per variable, -1 = unbound."""

    cols: dict[str, np.ndarray]  # each (n_rows,) int64

    @property
    def n_rows(self) -> int:
        if not self.cols:
            return 1  # the empty mapping (one trivial match)
        return len(next(iter(self.cols.values())))

    @staticmethod
    def empty_match() -> "Bindings":
        return Bindings(cols={})

    @staticmethod
    def no_match(names: list[str]) -> "Bindings":
        return Bindings(cols={n: np.zeros(0, dtype=np.int64) for n in names})

    def dedup(self) -> "Bindings":
        if not self.cols:
            return self
        names = sorted(self.cols)
        stacked = np.stack([self.cols[n] for n in names], axis=1)
        uniq = np.unique(stacked, axis=0)
        return Bindings(cols={n: uniq[:, i] for i, n in enumerate(names)})


def evaluate(q: Query, g: Graph, *, join_order: str = "selectivity") -> Bindings:
    """``join_order``: 'selectivity' (RDFox-style, smallest table first) or
    'syntactic' (Virtuoso-default-like left-to-right) — the two downstream
    query-plan policies benchmarked in Tables 4/5."""
    return _eval(q, g, join_order).dedup()


def _eval(q: Query, g: Graph, jo: str = "selectivity") -> Bindings:
    if isinstance(q, BGP):
        return _eval_bgp(q, g, jo)
    if isinstance(q, And):
        return _join(_eval(q.left, g, jo), _eval(q.right, g, jo), outer=False)
    if isinstance(q, Optional_):
        return _join(_eval(q.left, g, jo), _eval(q.right, g, jo), outer=True)
    if isinstance(q, Union_):
        return _union(_eval(q.left, g, jo), _eval(q.right, g, jo))
    raise TypeError(q)


# --------------------------------------------------------------------- #
# BGP: selectivity-ordered joins over per-label edge lists
# --------------------------------------------------------------------- #
def _triple_table(t: Triple, g: Graph) -> Bindings:
    if g.label_names is not None and isinstance(t.p, str):
        la = g.label_index().get(t.p, -1)
    else:
        la = int(t.p) if int(t.p) < g.n_labels else -1
    if la < 0:
        names = [x.name for x in (t.s, t.o) if isinstance(x, Var)]
        return Bindings.no_match(names)
    e = g.edges_for_label(la).astype(np.int64)
    s, o = e[:, 0], e[:, 1]
    if isinstance(t.s, Const):
        sid = g.node_id(t.s.name) if g.node_names and t.s.name in g.node_names else -2
        keep = s == sid
        s, o = s[keep], o[keep]
    if isinstance(t.o, Const):
        oid = g.node_id(t.o.name) if g.node_names and t.o.name in g.node_names else -2
        keep = o == oid
        s, o = s[keep], o[keep]
    cols: dict[str, np.ndarray] = {}
    if isinstance(t.s, Var):
        cols[t.s.name] = s
    if isinstance(t.o, Var):
        if isinstance(t.s, Var) and t.o.name == t.s.name:
            keep = s == o
            cols[t.s.name] = s[keep]
        else:
            cols[t.o.name] = o
    if isinstance(t.s, Var) and isinstance(t.o, Var) and t.s.name == t.o.name:
        pass  # handled above
    elif not cols:
        # fully constant pattern: zero or one trivial match
        return Bindings.empty_match() if len(s) else Bindings.no_match([])
    return Bindings(cols=cols)


def _eval_bgp(q: BGP, g: Graph, jo: str = "selectivity") -> Bindings:
    if not q.triples:
        return Bindings.empty_match()
    tables = [_triple_table(t, g) for t in q.triples]
    if jo == "selectivity":
        order = np.argsort([t.n_rows for t in tables])
    else:
        order = np.arange(len(tables))
    acc = tables[order[0]]
    for i in order[1:]:
        acc = _join(acc, tables[i], outer=False)
    return acc


# --------------------------------------------------------------------- #
# compatible joins (NULL-aware)
# --------------------------------------------------------------------- #
def _join(t1: Bindings, t2: Bindings, *, outer: bool) -> Bindings:
    shared = sorted(set(t1.cols) & set(t2.cols))
    only1 = sorted(set(t1.cols) - set(t2.cols))
    only2 = sorted(set(t2.cols) - set(t1.cols))
    n1, n2 = t1.n_rows, t2.n_rows

    if not t2.cols:
        return t1 if t2.n_rows else (t1 if outer else Bindings.no_match(list(t1.cols)))
    if not t1.cols:
        if t1.n_rows == 0:
            return Bindings.no_match(sorted(set(t2.cols)))
        return t2 if (t2.n_rows or not outer) else t2

    nulls1 = any((t1.cols[c] == -1).any() for c in shared)
    nulls2 = any((t2.cols[c] == -1).any() for c in shared)

    if shared and not nulls1 and not nulls2:
        i1, i2 = _hash_join_indices(
            [t1.cols[c] for c in shared], [t2.cols[c] for c in shared]
        )
    elif shared:
        i1, i2 = _compat_join_indices(t1, t2, shared)
    else:
        i1 = np.repeat(np.arange(n1, dtype=np.int64), n2)
        i2 = np.tile(np.arange(n2, dtype=np.int64), n1)

    cols: dict[str, np.ndarray] = {}
    for c in only1:
        cols[c] = t1.cols[c][i1]
    for c in only2:
        cols[c] = t2.cols[c][i2]
    for c in shared:
        a, b = t1.cols[c][i1], t2.cols[c][i2]
        cols[c] = np.where(a == -1, b, a)

    if outer:
        matched = np.zeros(n1, dtype=bool)
        matched[i1] = True
        miss = np.flatnonzero(~matched)
        for c in list(cols):
            extra = (
                t1.cols[c][miss]
                if c in t1.cols
                else np.full(len(miss), -1, dtype=np.int64)
            )
            cols[c] = np.concatenate([cols[c], extra])
    return Bindings(cols=cols)


def _hash_join_indices(keys1: list[np.ndarray], keys2: list[np.ndarray]):
    k1 = np.stack(keys1, axis=1)
    k2 = np.stack(keys2, axis=1)
    both = np.concatenate([k1, k2], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    h1, h2 = inv[: len(k1)], inv[len(k1) :]
    order2 = np.argsort(h2, kind="stable")
    h2s = h2[order2]
    starts = np.searchsorted(h2s, h1, side="left")
    ends = np.searchsorted(h2s, h1, side="right")
    counts = ends - starts
    i1 = np.repeat(np.arange(len(k1), dtype=np.int64), counts)
    offs = np.concatenate([np.arange(c) for c in counts]) if len(counts) else np.zeros(0, np.int64)
    i2 = order2[np.repeat(starts, counts) + offs.astype(np.int64)] if len(i1) else np.zeros(0, np.int64)
    return i1, i2.astype(np.int64)


def _compat_join_indices(t1: Bindings, t2: Bindings, shared: list[str]):
    """NULL-compatible join: blockwise nested loop (rare path: only after
    OPTIONAL/UNION introduced unbound values in join columns)."""
    n1, n2 = t1.n_rows, t2.n_rows
    i1s, i2s = [], []
    a = np.stack([t1.cols[c] for c in shared], axis=1)  # [n1, k]
    b = np.stack([t2.cols[c] for c in shared], axis=1)  # [n2, k]
    block = max(1, int(2_000_000 // max(n2, 1)))
    for s in range(0, n1, block):
        ab = a[s : s + block][:, None, :]  # [b, 1, k]
        ok = ((ab == b[None]) | (ab == -1) | (b[None] == -1)).all(axis=2)
        ii, jj = np.nonzero(ok)
        i1s.append(ii + s)
        i2s.append(jj)
    return (
        np.concatenate(i1s) if i1s else np.zeros(0, np.int64),
        np.concatenate(i2s) if i2s else np.zeros(0, np.int64),
    )


def _union(t1: Bindings, t2: Bindings) -> Bindings:
    names = sorted(set(t1.cols) | set(t2.cols))
    cols = {}
    for c in names:
        a = t1.cols.get(c, np.full(t1.n_rows if t1.cols else 0, -1, np.int64))
        b = t2.cols.get(c, np.full(t2.n_rows if t2.cols else 0, -1, np.int64))
        cols[c] = np.concatenate([a, b])
    return Bindings(cols=cols)


# --------------------------------------------------------------------- #
# required triples (Table 3 column)
# --------------------------------------------------------------------- #
def required_triples(q: Query, g: Graph, matches: Bindings) -> int:
    """Number of distinct database triples participating in some match."""
    used: set[tuple[int, int, int]] = set()

    def walk(qq: Query):
        if isinstance(qq, BGP):
            for t in qq.triples:
                if g.label_names is not None and isinstance(t.p, str):
                    la = g.label_index().get(t.p)
                    if la is None:
                        continue
                else:
                    la = int(t.p)
                sv = (
                    matches.cols.get(t.s.name)
                    if isinstance(t.s, Var)
                    else None
                )
                ov = (
                    matches.cols.get(t.o.name)
                    if isinstance(t.o, Var)
                    else None
                )
                n = matches.n_rows if matches.cols else 0
                if sv is None:
                    sid = g.node_id(t.s.name) if isinstance(t.s, Const) and g.node_names and t.s.name in g.node_names else -2
                    sv = np.full(n, sid, dtype=np.int64)
                if ov is None:
                    oid = g.node_id(t.o.name) if isinstance(t.o, Const) and g.node_names and t.o.name in g.node_names else -2
                    ov = np.full(n, oid, dtype=np.int64)
                ok = (sv >= 0) & (ov >= 0)
                for s, o in zip(sv[ok], ov[ok]):
                    used.add((int(s), la, int(o)))
        else:
            walk(qq.left)
            walk(qq.right)

    walk(q)
    if not used:
        return 0
    # count only triples that actually exist in the DB
    trip = {(int(s), int(p), int(o)) for s, p, o in g.triples}
    return len(used & trip)
