"""Ma et al. (2014) dual-simulation algorithm — the paper's Table-2 rival.

The "single passive strategy" (paper Sect. 1/3): start from the full
candidate relation and repeatedly re-check the *definition* (Def. 2) for
every pattern node / candidate pair, removing violating pairs, until a full
pass makes no change.  Candidate tests walk adjacency lists per pair, which
is what gives the naive O(|V2|^3) behaviour the SOI formulation avoids
in practice (fewer, cheaper iterations).

Implemented in numpy with per-pair CSR scans to stay faithful to the
original evaluation strategy (vectorizing the inner test would silently turn
it into our algorithm).
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def dual_simulation_ma(pattern: Graph, db: Graph) -> tuple[np.ndarray, int]:
    """Largest dual simulation via Ma et al.'s refinement.

    Returns (S bool[|V1|, |V2|], number of full passes).
    """
    n1, n2 = pattern.n_nodes, db.n_nodes
    sim = np.ones((n1, n2), dtype=bool)

    # pre-index pattern edges per node
    p_out = [[] for _ in range(n1)]  # (label, w)
    p_in = [[] for _ in range(n1)]  # (label, u)
    for s, a, o in pattern.triples:
        p_out[s].append((a, o))
        p_in[o].append((a, s))

    passes = 0
    changed = True
    while changed:
        changed = False
        passes += 1
        for v in range(n1):
            for x in np.flatnonzero(sim[v]):
                ok = True
                # Def. 2(i): every outgoing pattern edge must be matched.
                for a, w in p_out[v]:
                    succ = db.fwd(a, int(x))
                    if len(succ) == 0 or not sim[w, succ].any():
                        ok = False
                        break
                if ok:
                    # Def. 2(ii): every incoming pattern edge must be matched.
                    for a, u in p_in[v]:
                        pred = db.bwd(a, int(x))
                        if len(pred) == 0 or not sim[u, pred].any():
                            ok = False
                            break
                if not ok:
                    sim[v, x] = False
                    changed = True
    return sim, passes
