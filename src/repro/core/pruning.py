"""Per-query database pruning (paper Sect. 5, Tables 3-5).

A triple ``(s, a, o)`` of the database *survives* pruning iff some pattern
edge ``(v, a, w)`` of the query's SOI has ``chi[v][s] and chi[w][o]``; all
other triples are irrelevant for any match (Theorems 1/2) and can be dropped
before handing the query to a downstream join processor.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import Graph, subgraph_triples
from .soi import SOI


@dataclasses.dataclass
class PruneStats:
    n_triples: int
    n_after: int
    fraction_pruned: float
    per_edge_survivors: list[int]


def prune_triples(
    soi: SOI, chi: np.ndarray, g: Graph
) -> tuple[np.ndarray, PruneStats]:
    """Boolean survivor mask over ``g.triples`` plus stats."""
    mask = np.zeros(g.n_edges, dtype=bool)
    per_edge = []
    label_of = g.triples[:, 1]
    s_of = g.triples[:, 0]
    o_of = g.triples[:, 2]
    for v, a, w in soi.pattern_edges:
        if isinstance(a, str):
            la = g.label_index().get(a) if g.label_names is not None else None
            if la is None:
                per_edge.append(0)
                continue
        else:
            la = int(a)
        sel = label_of == la
        hit = sel & chi[v][s_of] & chi[w][o_of]
        per_edge.append(int(hit.sum()))
        mask |= hit
    n_after = int(mask.sum())
    return mask, PruneStats(
        n_triples=g.n_edges,
        n_after=n_after,
        fraction_pruned=1.0 - n_after / max(g.n_edges, 1),
        per_edge_survivors=per_edge,
    )


def pruned_graph(soi: SOI, chi: np.ndarray, g: Graph) -> tuple[Graph, PruneStats]:
    mask, stats = prune_triples(soi, chi, g)
    return subgraph_triples(g, mask), stats
