"""System-of-inequalities (SOI) construction — paper Sect. 3.2 + Sect. 4.

For every pattern edge ``(v, a, w)`` the SOI contains (Eq. 11)::

    w  <=  v ×b F_a        (forward inequality)
    v  <=  w ×b B_a        (backward inequality)

plus per-variable initialization (Eq. 12 / sharper Eq. 13 summaries) and, for
OPTIONAL / non-well-designed AND combinations, plain copy inequalities
``v_opt <= v_mand`` (Eq. 14/15, Lemmas 4/5) produced by the optional-renaming
machinery with the paper's *syntactically closest* rule (Sect. 4.4).

The builder is recursive over the query AST; UNION is split away beforehand
(:func:`repro.core.sparql.union_split`).  Exposure model:

* ``external_mand[name]`` — the unique mandatory representative variable.
* ``external_opt[name]``  — optional occurrence variables not yet linked to a
  mandatory occurrence.  When a mandatory occurrence appears at an enclosing
  operator, each of these receives ``opt <= mand`` and stops being exposed,
  which reproduces the paper's chains ``z_R3 <= z_R2 <= z``.
* constants get private singleton variables per BGP — never merged, so an
  unsatisfied optional branch can never empty a mandatory constant.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import sparql
from .graph import Graph
from .sparql import And, BGP, Const, Optional_, Query, Var

FWD, BWD = 0, 1


@dataclasses.dataclass
class SOI:
    """A built (but not yet graph-compiled) system of inequalities."""

    base: list[str]  # internal var id -> original query variable name
    is_const: list[str | None]  # internal var id -> constant name or None
    edge_ineqs: list[tuple[int, int, str, int]]  # (lhs, rhs, label, dir)
    copy_ineqs: list[tuple[int, int]]  # lhs <= rhs
    pattern_edges: list[tuple[int, str, int]]  # (v, label, w) — for pruning
    external_mand: dict[str, int]
    external_opt: dict[str, list[int]]

    @property
    def n_vars(self) -> int:
        return len(self.base)

    def var_groups(self) -> dict[str, list[int]]:
        """Original variable name -> every internal id carrying it."""
        groups: dict[str, list[int]] = {}
        for i, b in enumerate(self.base):
            if self.is_const[i] is None:
                groups.setdefault(b, []).append(i)
        return groups


# --------------------------------------------------------------------- #
# recursive construction
# --------------------------------------------------------------------- #
def build_soi(q: Query) -> SOI:
    if not sparql.is_union_free(q):
        raise ValueError("run sparql.union_split first; build_soi is union-free")
    return _build(q)


def _build(q: Query) -> SOI:
    if isinstance(q, BGP):
        return _build_bgp(q)
    if isinstance(q, And):
        return _combine(_build(q.left), _build(q.right), optional=False)
    if isinstance(q, Optional_):
        return _combine(_build(q.left), _build(q.right), optional=True)
    raise TypeError(q)


def _build_bgp(q: BGP) -> SOI:
    base: list[str] = []
    is_const: list[str | None] = []
    ids: dict[str, int] = {}

    def vid(term) -> int:
        key = f"?{term.name}" if isinstance(term, Var) else f"<{term.name}>"
        if key not in ids:
            ids[key] = len(base)
            base.append(term.name)
            is_const.append(term.name if isinstance(term, Const) else None)
        return ids[key]

    edge_ineqs, pattern_edges = [], []
    for t in q.triples:
        v, w = vid(t.s), vid(t.o)
        pattern_edges.append((v, t.p, w))
        edge_ineqs.append((w, v, t.p, FWD))  # w <= v ×b F_a
        edge_ineqs.append((v, w, t.p, BWD))  # v <= w ×b B_a
    mand = {
        t.name
        for tr in q.triples
        for t in (tr.s, tr.o)
        if isinstance(t, Var)
    }
    return SOI(
        base=base,
        is_const=is_const,
        edge_ineqs=edge_ineqs,
        copy_ineqs=[],
        pattern_edges=pattern_edges,
        external_mand={n: ids[f"?{n}"] for n in mand},
        external_opt={},
    )


def _combine(e1: SOI, e2: SOI, *, optional: bool) -> SOI:
    """AND (Lemmas 3/5) or OPTIONAL (Lemma 4 + Sect. 4.4) combination."""
    off = e1.n_vars
    base = e1.base + e2.base
    is_const = e1.is_const + e2.is_const
    edge_ineqs = e1.edge_ineqs + [
        (l + off, r + off, a, d) for (l, r, a, d) in e2.edge_ineqs
    ]
    copy_ineqs = e1.copy_ineqs + [(l + off, r + off) for (l, r) in e2.copy_ineqs]
    pattern_edges = e1.pattern_edges + [
        (v + off, a, w + off) for (v, a, w) in e2.pattern_edges
    ]
    m2 = {n: i + off for n, i in e2.external_mand.items()}
    o2 = {n: [i + off for i in ids] for n, ids in e2.external_opt.items()}

    mand_out: dict[str, int] = {}
    opt_out: dict[str, list[int]] = {}
    merges: list[tuple[int, int]] = []  # (keep, drop)

    names = (
        set(e1.external_mand) | set(e1.external_opt) | set(m2) | set(o2)
    )
    for n in names:
        ma, mb = e1.external_mand.get(n), m2.get(n)
        oa = list(e1.external_opt.get(n, []))
        ob = list(o2.get(n, []))
        if optional:
            # OPTIONAL(q1, q2): result mandatory = mand(q1).  Any occurrence
            # of n in q2 (mandatory-in-q2 or unlinked-optional) is optional
            # w.r.t. the result.
            occ2 = ([mb] if mb is not None else []) + ob
            if ma is not None:
                # Lemma 4: rename q2's occurrence(s), add  v_Q2 <= v.
                copy_ineqs.extend((i, ma) for i in occ2)
                mand_out[n] = ma
                if oa:
                    opt_out[n] = oa
            else:
                # optional-in-both (Sect. 4.4): independent, no links.
                occ = oa + occ2
                if occ:
                    opt_out[n] = occ
        else:
            # AND(q1, q2), Lemmas 3/5.
            if ma is not None and mb is not None:
                merges.append((ma, mb))  # shared mandatory: identical variable
                mand_out[n] = ma
            elif ma is not None:
                copy_ineqs.extend((i, ma) for i in ob)  # rho_2: opt <= mand
                mand_out[n] = ma
            elif mb is not None:
                copy_ineqs.extend((i, mb) for i in oa)  # rho_1
                mand_out[n] = mb
            else:
                occ = oa + ob
                if occ:
                    opt_out[n] = occ

    soi = SOI(
        base=base,
        is_const=is_const,
        edge_ineqs=edge_ineqs,
        copy_ineqs=copy_ineqs,
        pattern_edges=pattern_edges,
        external_mand=mand_out,
        external_opt=opt_out,
    )
    # Apply merges sequentially, translating each pair through the id
    # compaction of the previous merges (stale ids would otherwise merge
    # the WRONG variables — e.g. a surrogate instead of its mandatory
    # original; caught by the Thm.-2 soundness property test).
    trans = {i: i for i in range(soi.n_vars)}
    for keep, drop in merges:
        k, d = trans[keep], trans[drop]
        if k == d:
            continue
        soi, remap = _merge_vars(soi, k, d)
        trans = {o: remap[c] for o, c in trans.items()}
    return soi


def _merge_vars(soi: SOI, keep: int, drop: int) -> tuple[SOI, dict]:
    """Identify variable ``drop`` with ``keep`` and compact ids.
    Returns (new_soi, remap old-id -> new-id)."""
    remap = {}
    j = 0
    for i in range(soi.n_vars):
        if i == drop:
            continue
        remap[i] = j
        j += 1
    remap[drop] = remap[keep]
    f = lambda i: remap[i]
    base = [b for i, b in enumerate(soi.base) if i != drop]
    is_const = [c for i, c in enumerate(soi.is_const) if i != drop]
    return SOI(
        base=base,
        is_const=is_const,
        edge_ineqs=[(f(l), f(r), a, d) for (l, r, a, d) in soi.edge_ineqs],
        copy_ineqs=sorted({(f(l), f(r)) for (l, r) in soi.copy_ineqs if f(l) != f(r)}),
        pattern_edges=[(f(v), a, f(w)) for (v, a, w) in soi.pattern_edges],
        external_mand={n: f(i) for n, i in soi.external_mand.items()},
        external_opt={n: [f(i) for i in ids] for n, ids in soi.external_opt.items()},
    ), remap


# --------------------------------------------------------------------- #
# compile against a concrete graph database
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class CompiledSOI:
    """SOI lowered to dense index arrays against one graph's label table.

    ``mats`` enumerates the distinct (label_id, direction) adjacency
    operators the SOI needs; ``ineq_mat[i]`` indexes into it.  ``init`` is
    the Eq.-13 initialization (label-summary intersections + constant
    singletons).  Inequalities whose label is absent from the database force
    the LHS variable to the empty set (handled via ``init``).
    """

    soi: SOI
    n_vars: int
    n_nodes: int
    mats: list[tuple[int, int]]  # (label_id, FWD/BWD)
    ineq_lhs: np.ndarray  # (I,) int32
    ineq_rhs: np.ndarray  # (I,) int32
    ineq_mat: np.ndarray  # (I,) int32 -> index into mats
    copy_lhs: np.ndarray  # (C,) int32
    copy_rhs: np.ndarray  # (C,) int32
    init: np.ndarray  # (n_vars, n_nodes) bool


def compile_soi(
    soi: SOI,
    g: Graph,
    node_index: dict[str, int] | None = None,
    label_index: dict[str, int] | None = None,
) -> CompiledSOI:
    """Lower ``soi`` against ``g``.

    ``node_index`` / ``label_index`` map names -> ids; callers that already
    hold them (the engine does) pass them down so constants and labels
    resolve in O(1) instead of an O(n) ``list.index`` scan each.  Falls back
    to the graph's own cached indexes otherwise.
    """
    assert g.label_names is not None or all(
        isinstance(a, int) for (_, _, a, _) in soi.edge_ineqs
    ), "graph must carry label names (or SOI labels must be int ids)"
    if label_index is None and g.label_names is not None:
        label_index = g.label_index()

    def lid(a) -> int | None:
        if isinstance(a, int):
            return a if a < g.n_labels else None
        return label_index.get(a)  # None = label absent from the database

    n = g.n_nodes
    init = np.ones((soi.n_vars, n), dtype=bool)

    # Eq. 13: intersect per-variable with forward/backward summaries.
    dead = np.zeros(soi.n_vars, dtype=bool)
    for v, a, w in soi.pattern_edges:
        la = lid(a)
        if la is None:
            dead[v] = dead[w] = True  # no a-edges at all -> no simulators
            continue
        init[v] &= g.summary_fwd(la)
        init[w] &= g.summary_bwd(la)
    init[dead] = False

    # constants: singleton sets.
    if node_index is None and any(c is not None for c in soi.is_const):
        node_index = g.node_index() if g.node_names is not None else {}
    for i, c in enumerate(soi.is_const):
        if c is None:
            continue
        row = np.zeros(n, dtype=bool)
        nid = node_index.get(c)
        if nid is not None:
            row[nid] = init[i][nid]
        init[i] = row

    mats: list[tuple[int, int]] = []
    mat_index: dict[tuple[int, int], int] = {}
    lhs, rhs, mat = [], [], []
    for l, r, a, d in soi.edge_ineqs:
        la = lid(a)
        if la is None:
            continue  # already zeroed via init
        key = (la, d)
        if key not in mat_index:
            mat_index[key] = len(mats)
            mats.append(key)
        lhs.append(l)
        rhs.append(r)
        mat.append(mat_index[key])

    cl = [l for (l, _) in soi.copy_ineqs]
    cr = [r for (_, r) in soi.copy_ineqs]
    return CompiledSOI(
        soi=soi,
        n_vars=soi.n_vars,
        n_nodes=n,
        mats=mats,
        ineq_lhs=np.asarray(lhs, dtype=np.int32),
        ineq_rhs=np.asarray(rhs, dtype=np.int32),
        ineq_mat=np.asarray(mat, dtype=np.int32),
        copy_lhs=np.asarray(cl, dtype=np.int32),
        copy_rhs=np.asarray(cr, dtype=np.int32),
        init=init,
    )


def collect(soi: SOI, chi: np.ndarray) -> dict[str, np.ndarray]:
    """Per original query variable, the union of all its internal rows.

    Renamed optional surrogates are unified with their originals (paper
    Sect. 4.3/4.4 "interpreted as if all renamed variables are unified").
    """
    out: dict[str, np.ndarray] = {}
    for name, ids in soi.var_groups().items():
        out[name] = np.logical_or.reduce(chi[ids], axis=0)
    return out
