"""SPARQL query fragment from the paper (Sect. 4): the language S.

    Q ::= BGP | Q AND Q | Q OPTIONAL Q | Q UNION Q

Triple patterns are ``(s, p, o)`` where ``s``/``o`` are :class:`Var` or
:class:`Const` and ``p`` is a predicate label.  ``UNION`` is removed before
SOI construction by the DNF-style rewriting of Pérez et al. (Prop. 3.8 in the
paper); ``mand()`` computes mandatory-variable sets for the optional-renaming
machinery of Sect. 4.3/4.4.

A tiny text parser is provided for queries written like::

    SELECT WHERE {
      { ?director directed ?movie . ?director worked_with ?coworker }
    }

with ``{..} AND {..}``, ``{..} OPTIONAL {..}``, ``{..} UNION {..}`` at any
nesting depth.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Union as TUnion


@dataclasses.dataclass(frozen=True)
class Var:
    name: str

    def __repr__(self) -> str:
        return f"?{self.name}"


@dataclasses.dataclass(frozen=True)
class Const:
    """A database constant (IRI or literal), referenced by name."""

    name: str

    def __repr__(self) -> str:
        return f"<{self.name}>"


Term = TUnion[Var, Const]


@dataclasses.dataclass(frozen=True)
class Triple:
    s: Term
    p: str
    o: Term


@dataclasses.dataclass(frozen=True)
class BGP:
    triples: tuple[Triple, ...]


@dataclasses.dataclass(frozen=True)
class And:
    left: "Query"
    right: "Query"


@dataclasses.dataclass(frozen=True)
class Optional_:
    left: "Query"
    right: "Query"


@dataclasses.dataclass(frozen=True)
class Union_:
    left: "Query"
    right: "Query"


Query = TUnion[BGP, And, Optional_, Union_]


# --------------------------------------------------------------------- #
# variable analysis (paper Sect. 4.3)
# --------------------------------------------------------------------- #
def vars_of(q: Query) -> set[str]:
    if isinstance(q, BGP):
        out: set[str] = set()
        for t in q.triples:
            for term in (t.s, t.o):
                if isinstance(term, Var):
                    out.add(term.name)
        return out
    return vars_of(q.left) | vars_of(q.right)


def mand(q: Query) -> set[str]:
    """Mandatory variables: mand(BGP)=vars, mand(AND)=∪, mand(OPT)=mand(left)."""
    if isinstance(q, BGP):
        return vars_of(q)
    if isinstance(q, And):
        return mand(q.left) | mand(q.right)
    if isinstance(q, Optional_):
        return mand(q.left)
    if isinstance(q, Union_):
        # union-free rewriting happens first; for analysis use intersection
        # (a variable is certainly bound only if bound in every branch).
        return mand(q.left) & mand(q.right)
    raise TypeError(q)


def labels_of(q: Query) -> set[str]:
    if isinstance(q, BGP):
        return {t.p for t in q.triples}
    return labels_of(q.left) | labels_of(q.right)


def is_union_free(q: Query) -> bool:
    if isinstance(q, BGP):
        return True
    if isinstance(q, Union_):
        return False
    return is_union_free(q.left) and is_union_free(q.right)


# --------------------------------------------------------------------- #
# UNION normal form (Prop. 3.8 of Pérez et al., as cited by the paper)
# --------------------------------------------------------------------- #
def union_split(q: Query) -> list[Query]:
    """Rewrite ``q`` into a list of union-free queries whose result union
    equals (for AND/left-OPTIONAL distribution) or over-approximates (for
    UNION nested in the optional side) the original result set.  Soundness of
    the dual-simulation pruning only needs the over-approximation direction,
    see DESIGN.md Sect. 3."""
    if isinstance(q, BGP):
        return [q]
    if isinstance(q, Union_):
        return union_split(q.left) + union_split(q.right)
    lefts = union_split(q.left)
    rights = union_split(q.right)
    ctor = And if isinstance(q, And) else Optional_
    return [ctor(l, r) for l in lefts for r in rights]


# --------------------------------------------------------------------- #
# parser
# --------------------------------------------------------------------- #
_TOKEN = re.compile(
    r"\s*(?:(?P<lbrace>\{)|(?P<rbrace>\})|(?P<dot>\.)"
    r"|(?P<kw>(?:AND|OPTIONAL|UNION|SELECT|WHERE)\b)"  # \b: ANDERSON is a name
    r"|(?P<var>\?[A-Za-z_][A-Za-z0-9_]*)"
    r"|(?P<name>[A-Za-z0-9_:/#\-\.]+))"
)


def _line_col(text: str, pos: int) -> tuple[int, int]:
    """1-based (line, column) of character offset ``pos`` in ``text``."""
    line = text.count("\n", 0, pos) + 1
    col = pos - (text.rfind("\n", 0, pos) + 1) + 1
    return line, col


def _err(text: str, pos: int, msg: str) -> SyntaxError:
    line, col = _line_col(text, pos)
    return SyntaxError(f"{msg} at line {line}, column {col}")


def parse(text: str) -> Query:
    """Parse the small query language described in the module docstring.

    Malformed input raises :class:`SyntaxError` with the 1-based line and
    column of the offending token; empty groups ``{}`` are rejected (a
    vacuous ``BGP(())`` matches everything, which is never what a typo
    meant).
    """
    toks: list[tuple[str, str, int]] = []  # (kind, value, char offset)
    pos = 0
    end = len(text.rstrip())
    while pos < end:
        m = _TOKEN.match(text, pos)
        if not m:
            at = pos + len(text[pos:]) - len(text[pos:].lstrip())
            raise _err(text, at, f"bad token at {text[at:at+30]!r}")
        pos = m.end()
        kind = m.lastgroup
        val = m.group(kind)
        if kind == "kw" and val in ("SELECT", "WHERE"):
            continue
        toks.append((kind, val, m.start(kind)))

    if not toks:
        raise _err(text, 0, "empty query")

    def peek():
        return toks[0] if toks else (None, None, end)

    def pop(expect=None):
        if not toks:
            raise _err(text, end, "unexpected end of query")
        kind, val, at = toks.pop(0)
        if expect and kind != expect:
            raise _err(text, at, f"expected {expect}, got {kind} {val!r}")
        return kind, val

    def parse_group() -> Query:
        _, _, open_at = toks[0] if toks else (None, None, end)
        pop("lbrace")
        if peek()[0] == "rbrace":
            raise _err(text, open_at, "empty group '{}'")
        if peek()[0] == "lbrace":  # nested composite
            q = parse_expr()
            pop("rbrace")
            return q
        triples = []
        while peek()[0] != "rbrace":
            s = parse_term()
            _, p = pop("name")
            o = parse_term()
            triples.append(Triple(s, p, o))
            if peek()[0] == "dot":
                pop("dot")
        pop("rbrace")
        return BGP(tuple(triples))

    def parse_term() -> Term:
        if not toks:
            raise _err(text, end, "unexpected end of query")
        kind, val, at = toks.pop(0)
        if kind == "var":
            return Var(val[1:])
        if kind == "name":
            return Const(val)
        raise _err(text, at, f"expected term, got {kind} {val!r}")

    def parse_expr() -> Query:
        left = parse_group()
        while peek()[0] == "kw":
            _, op = pop("kw")
            right = parse_group()
            left = {"AND": And, "OPTIONAL": Optional_, "UNION": Union_}[op](
                left, right
            )
        return left

    q = parse_expr()
    if toks:
        raise _err(text, toks[0][2], f"trailing tokens: {toks[0][1]!r}")
    return q


# --------------------------------------------------------------------- #
# pretty-printer (inverse of parse)
# --------------------------------------------------------------------- #
def format_term(t: Term) -> str:
    return f"?{t.name}" if isinstance(t, Var) else t.name


def format_query(q: Query) -> str:
    """Serialize a query so that ``parse(format_query(q)) == q``.

    The guarantee holds for ASTs whose constant / predicate names match the
    parser's ``name`` token class (``[A-Za-z0-9_:/#.-]+``) and whose BGPs are
    non-empty — i.e. everything the parser or the :mod:`repro.db.builder`
    can produce.
    """
    if isinstance(q, BGP):
        if not q.triples:
            raise ValueError("cannot format an empty BGP (parse rejects {})")
        body = " . ".join(
            f"{format_term(t.s)} {t.p} {format_term(t.o)}" for t in q.triples
        )
        return "{ " + body + " }"
    op = {And: "AND", Optional_: "OPTIONAL", Union_: "UNION"}[type(q)]
    return "{ " + f"{format_query(q.left)} {op} {format_query(q.right)}" + " }"


def bgp_of_triples(*spo: tuple[str, str, str]) -> BGP:
    """Convenience: strings starting with '?' are variables, else constants."""

    def term(x: str) -> Term:
        return Var(x[1:]) if x.startswith("?") else Const(x)

    return BGP(tuple(Triple(term(s), p, term(o)) for s, p, o in spo))
