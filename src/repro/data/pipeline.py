"""Deterministic, shardable host data pipeline.

Determinism contract (fault tolerance): batch ``i`` of epoch ``e`` is a pure
function of ``(seed, e, i, host_shard)`` — a replacement host replays its
shard exactly after restart; no inter-host coordination needed beyond the
step counter in the checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    host_index: int
    host_count: int


def _perm(seed: int, epoch: int, n: int) -> np.ndarray:
    return np.random.default_rng((seed, epoch)).permutation(n)


def token_batches(
    corpus: np.ndarray,  # [N] int32 token stream
    *,
    batch: int,
    seq: int,
    seed: int,
    shard: ShardSpec,
    start_step: int = 0,
) -> Iterator[dict]:
    """Next-token LM batches: deterministic sequence of (tokens, labels)."""
    n_seqs = (len(corpus) - 1) // seq
    per_host = batch // shard.host_count
    assert per_host * shard.host_count == batch, "batch % hosts != 0"
    step = start_step
    while True:
        epoch = (step * batch) // max(n_seqs, 1)
        perm = _perm(seed, epoch, n_seqs)
        base = (step * batch) % max(n_seqs, 1)
        idx = perm[(base + np.arange(batch)) % n_seqs]
        idx = idx[shard.host_index * per_host : (shard.host_index + 1) * per_host]
        toks = np.stack([corpus[i * seq : i * seq + seq] for i in idx])
        lbls = np.stack([corpus[i * seq + 1 : i * seq + seq + 1] for i in idx])
        yield {"tokens": toks.astype(np.int32), "labels": lbls.astype(np.int32)}
        step += 1


def synthetic_corpus(vocab: int, n_tokens: int, seed: int = 0) -> np.ndarray:
    """Zipfian synthetic token stream (offline-friendly LM data)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, vocab + 1)
    p /= p.sum()
    return rng.choice(vocab, size=n_tokens, p=p).astype(np.int32)


def recsys_batches(
    *,
    batch: int,
    n_dense: int,
    vocab_sizes: tuple[int, ...],
    seed: int,
    shard: ShardSpec,
    start_step: int = 0,
) -> Iterator[dict]:
    per_host = batch // shard.host_count
    step = start_step
    vocabs = np.asarray(vocab_sizes)
    while True:
        rng = np.random.default_rng((seed, step, shard.host_index))
        dense = rng.normal(size=(per_host, n_dense)).astype(np.float32)
        sparse = (rng.random((per_host, len(vocabs))) * vocabs).astype(np.int32)
        logits = dense[:, 0] + 0.1 * (sparse[:, 0] % 7 - 3)
        labels = (logits + rng.normal(size=per_host) > 0).astype(np.float32)
        yield {"dense": dense, "sparse": sparse, "labels": labels}
        step += 1
