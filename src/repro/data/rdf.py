"""N-Triples-style RDF reader/writer with dictionary encoding.

Two ingest paths:

* :func:`load` — convenience wrapper over ``Graph.from_triples``; builds a
  Python list of string tuples first, fine for test fixtures.
* :func:`load_stream` — chunked streaming ingest for the DBpedia/LUBM-scale
  workload (ISSUE 8): triples are dictionary-encoded straight into int32
  chunk buffers as lines are read, so peak memory is the name dictionaries
  plus one ``(chunk_triples, 3) int32`` buffer — never a tuple-per-triple
  Python list (~25x smaller transient footprint at 10^6+ edges).

:func:`dump_stream` is the writing mirror: serialize an *iterator* of
string triples without materializing a Graph.
"""
from __future__ import annotations

import re
from typing import Iterable, Iterator

import numpy as np

from repro.core.graph import Graph

_LINE = re.compile(
    r"^\s*(<[^>]+>|\S+)\s+(<[^>]+>|\S+)\s+(<[^>]+>|\"[^\"]*\"\S*|\S+)\s*\.?\s*$"
)


def _strip(term: str) -> str:
    if term.startswith("<") and term.endswith(">"):
        return term[1:-1]
    return term


def iter_triples(lines: Iterable[str]) -> Iterator[tuple[str, str, str]]:
    for line in lines:
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"bad triple line: {line[:80]!r}")
        yield _strip(m.group(1)), _strip(m.group(2)), _strip(m.group(3))


def load(path: str) -> Graph:
    with open(path) as f:
        return Graph.from_triples(iter_triples(f))


def load_stream(path: str, chunk_triples: int = 1 << 20) -> Graph:
    """Streaming dictionary-encoding ingest of an N-Triples file.

    Equivalent to :func:`load` (same ids: first-seen order), but encodes
    each parsed line directly into an int32 chunk buffer instead of
    accumulating Python tuples, so arbitrarily large files ingest with
    O(dictionary + chunk) transient memory.
    """
    if chunk_triples < 1:
        raise ValueError("chunk_triples must be >= 1")
    nodes: dict[str, int] = {}
    labels: dict[str, int] = {}
    chunks: list[np.ndarray] = []
    buf = np.empty((chunk_triples, 3), np.int32)
    k = 0
    with open(path) as f:
        for s, p, o in iter_triples(f):
            buf[k, 0] = nodes.setdefault(s, len(nodes))
            buf[k, 1] = labels.setdefault(p, len(labels))
            buf[k, 2] = nodes.setdefault(o, len(nodes))
            k += 1
            if k == chunk_triples:
                chunks.append(buf)
                buf = np.empty((chunk_triples, 3), np.int32)
                k = 0
    chunks.append(buf[:k])
    arr = np.concatenate(chunks) if len(chunks) > 1 else chunks[0].copy()
    return Graph(
        n_nodes=len(nodes),
        n_labels=len(labels),
        triples=arr,
        node_names=list(nodes),
        label_names=list(labels),
    )


def dump(g: Graph, path: str) -> None:
    assert g.node_names is not None and g.label_names is not None
    with open(path, "w") as f:
        for s, p, o in g.triples:
            f.write(
                f"<{g.node_names[s]}> <{g.label_names[p]}> <{g.node_names[o]}> .\n"
            )


def dump_stream(
    triples: Iterable[tuple[str, str, str]], path: str
) -> int:
    """Write an iterator of string triples as N-Triples; returns the count.

    The workload generator side of :func:`load_stream`: neither end ever
    holds the full triple set as Python objects.
    """
    count = 0
    with open(path, "w") as f:
        for s, p, o in triples:
            f.write(f"<{s}> <{p}> <{o}> .\n")
            count += 1
    return count
