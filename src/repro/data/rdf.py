"""N-Triples-style RDF reader/writer with dictionary encoding."""
from __future__ import annotations

import re
from typing import Iterable, Iterator

from repro.core.graph import Graph

_LINE = re.compile(
    r"^\s*(<[^>]+>|\S+)\s+(<[^>]+>|\S+)\s+(<[^>]+>|\"[^\"]*\"\S*|\S+)\s*\.?\s*$"
)


def _strip(term: str) -> str:
    if term.startswith("<") and term.endswith(">"):
        return term[1:-1]
    return term


def iter_triples(lines: Iterable[str]) -> Iterator[tuple[str, str, str]]:
    for line in lines:
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"bad triple line: {line[:80]!r}")
        yield _strip(m.group(1)), _strip(m.group(2)), _strip(m.group(3))


def load(path: str) -> Graph:
    with open(path) as f:
        return Graph.from_triples(iter_triples(f))


def dump(g: Graph, path: str) -> None:
    assert g.node_names is not None and g.label_names is not None
    with open(path, "w") as f:
        for s, p, o in g.triples:
            f.write(
                f"<{g.node_names[s]}> <{g.label_names[p]}> <{g.node_names[o]}> .\n"
            )
