"""Synthetic graph databases and query workloads.

* :func:`lubm_like` — a scaled-down LUBM generator (universities,
  departments, professors, students, publications) with the benchmark's
  characteristic low label diversity (~18 predicates over a dense instance
  graph), which is exactly the regime where the paper's L0/L1 iteration
  behaviour shows (Sect. 5.3).
* :func:`lubm_stream` — the same schema as a one-triple-at-a-time
  generator with O(department) live state, feeding the streaming RDF
  ingest at node counts where the dense [n, n] tier cannot exist
  (ISSUE 8).
* :func:`dbpedia_like` — heterogeneous labels with Zipfian selectivity,
  mimicking DBpedia's high-selectivity predicates.
* :func:`random_graph` / :func:`random_pattern` — property-test fodder.
* Query builders for the paper's L0/L1 shapes (cyclic, low-selectivity).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph
from repro.core.sparql import Optional_, Query, bgp_of_triples

LUBM_PREDICATES = [
    "type", "memberOf", "subOrganizationOf", "undergraduateDegreeFrom",
    "worksFor", "advisor", "publicationAuthor", "teacherOf",
    "takesCourse", "headOf", "degreeFrom", "mastersDegreeFrom",
    "doctoralDegreeFrom", "researchInterest", "emailAddress", "telephone",
    "name", "teachingAssistantOf",
]


def lubm_like(
    n_universities: int = 3,
    depts_per_uni: int = 4,
    profs_per_dept: int = 5,
    students_per_dept: int = 20,
    pubs_per_prof: int = 3,
    seed: int = 0,
) -> Graph:
    rng = np.random.default_rng(seed)
    triples: list[tuple[str, str, str]] = []
    unis, depts, profs, students, pubs = [], [], [], [], []
    for u in range(n_universities):
        uni = f"Univ{u}"
        unis.append(uni)
        for d in range(depts_per_uni):
            dept = f"Dept{u}_{d}"
            depts.append(dept)
            triples.append((dept, "subOrganizationOf", uni))
            dept_profs = []
            for p in range(profs_per_dept):
                prof = f"Prof{u}_{d}_{p}"
                profs.append(prof)
                dept_profs.append(prof)
                triples.append((prof, "worksFor", dept))
                triples.append(
                    (prof, "degreeFrom", unis[rng.integers(0, len(unis))])
                )
                for k in range(pubs_per_prof):
                    pub = f"Pub{u}_{d}_{p}_{k}"
                    pubs.append(pub)
                    triples.append((pub, "publicationAuthor", prof))
            for s in range(students_per_dept):
                st = f"Student{u}_{d}_{s}"
                students.append(st)
                triples.append((st, "memberOf", dept))
                adv = dept_profs[rng.integers(0, len(dept_profs))]
                triples.append((st, "advisor", adv))
                triples.append(
                    (st, "undergraduateDegreeFrom", unis[rng.integers(0, len(unis))])
                )
                # some students co-author with their advisor's publications
                if rng.random() < 0.4 and pubs:
                    triples.append(
                        (pubs[rng.integers(0, len(pubs))], "publicationAuthor", st)
                    )
    return Graph.from_triples(triples)


def lubm_stream(
    n_universities: int,
    depts_per_uni: int = 4,
    profs_per_dept: int = 5,
    students_per_dept: int = 20,
    pubs_per_prof: int = 3,
    seed: int = 0,
):
    """LUBM-shaped triples as a *generator* — the RDF-scale workload source
    (ISSUE 8).

    Same entity schema and predicate mix as :func:`lubm_like`, but yields
    ``(s, p, o)`` string triples one at a time with O(department) live
    state: degree edges target a uniform university id (names are
    deterministic, no list needed) and student co-authorship picks a
    department-local publication.  Pipe into
    :func:`repro.data.rdf.dump_stream` / :func:`~repro.data.rdf.load_stream`
    to ingest node counts where the dense [n, n] tier cannot exist without
    ever materializing a tuple-per-triple list.
    """
    rng = np.random.default_rng(seed)
    for u in range(n_universities):
        uni = f"Univ{u}"
        for d in range(depts_per_uni):
            dept = f"Dept{u}_{d}"
            yield dept, "subOrganizationOf", uni
            dept_pubs: list[str] = []
            for p in range(profs_per_dept):
                prof = f"Prof{u}_{d}_{p}"
                yield prof, "worksFor", dept
                deg = f"Univ{rng.integers(0, n_universities)}"
                yield prof, "degreeFrom", deg
                for k in range(pubs_per_prof):
                    pub = f"Pub{u}_{d}_{p}_{k}"
                    dept_pubs.append(pub)
                    yield pub, "publicationAuthor", prof
            for s in range(students_per_dept):
                st = f"Student{u}_{d}_{s}"
                yield st, "memberOf", dept
                adv = f"Prof{u}_{d}_{rng.integers(0, profs_per_dept)}"
                yield st, "advisor", adv
                deg = f"Univ{rng.integers(0, n_universities)}"
                yield st, "undergraduateDegreeFrom", deg
                if rng.random() < 0.4 and dept_pubs:
                    pub = dept_pubs[rng.integers(0, len(dept_pubs))]
                    yield pub, "publicationAuthor", st


def dbpedia_like(
    n_nodes: int = 2000, n_labels: int = 40, n_edges: int = 10_000, seed: int = 0
) -> Graph:
    """Zipfian label selectivity: few huge predicates, long tail of rare."""
    rng = np.random.default_rng(seed)
    zipf = 1.0 / np.arange(1, n_labels + 1)
    zipf /= zipf.sum()
    labels = rng.choice(n_labels, size=n_edges, p=zipf)
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = rng.integers(0, n_nodes, size=n_edges)
    triples = np.stack([src, labels, dst], axis=1)
    g = Graph.from_arrays(n_nodes, n_labels, triples)
    g.node_names = [f"n{i}" for i in range(n_nodes)]
    g.label_names = [f"p{i}" for i in range(n_labels)]
    return g


def random_graph(
    n_nodes: int, n_labels: int, n_edges: int, seed: int = 0
) -> Graph:
    rng = np.random.default_rng(seed)
    triples = np.stack(
        [
            rng.integers(0, n_nodes, size=n_edges),
            rng.integers(0, n_labels, size=n_edges),
            rng.integers(0, n_nodes, size=n_edges),
        ],
        axis=1,
    )
    g = Graph.from_arrays(n_nodes, n_labels, triples)
    g.node_names = [f"n{i}" for i in range(n_nodes)]
    g.label_names = [f"p{i}" for i in range(n_labels)]
    return g


def random_pattern(
    n_vars: int, n_labels: int, n_edges: int, seed: int = 0
) -> Graph:
    """A random connected-ish pattern graph (for graph-graph dual sim)."""
    rng = np.random.default_rng(seed)
    edges = []
    for i in range(n_edges):
        if i < n_vars - 1:
            s, o = i, i + 1  # spanning chain keeps it connected
        else:
            s, o = rng.integers(0, n_vars, size=2)
        edges.append((s, rng.integers(0, n_labels), o))
    return Graph.from_arrays(n_vars, n_labels, np.asarray(edges))


# --------------------------------------------------------------------- #
# paper-shaped queries
# --------------------------------------------------------------------- #
def lubm_l0_like() -> Query:
    """Cyclic low-selectivity triangle (the paper's L0 regime: >30 sweeps)."""
    return bgp_of_triples(
        ("?x", "memberOf", "?y"),
        ("?y", "subOrganizationOf", "?z"),
        ("?x", "undergraduateDegreeFrom", "?z"),
    )


def lubm_l1_like() -> Query:
    """The paper's L1: publication with two authors, one student member of a
    department of the university the student got their degree from."""
    return bgp_of_triples(
        ("?pub", "publicationAuthor", "?student"),
        ("?pub", "publicationAuthor", "?prof"),
        ("?student", "memberOf", "?dept"),
        ("?prof", "worksFor", "?dept"),
        ("?dept", "subOrganizationOf", "?univ"),
        ("?student", "undergraduateDegreeFrom", "?univ"),
    )


def optional_query() -> Query:
    """An OPTIONAL-heavy query in the style of Atre's benchmark set."""
    core = bgp_of_triples(("?s", "memberOf", "?d"), ("?d", "subOrganizationOf", "?u"))
    opt1 = bgp_of_triples(("?s", "advisor", "?a"))
    opt2 = bgp_of_triples(("?p", "publicationAuthor", "?s"))
    return Optional_(Optional_(core, opt1), opt2)
