"""`repro.db` — the session-oriented public API of the dual-simulation
database (DESIGN.md Sect. 6).

The paper positions dual simulation as a *database* primitive: a sound
over-approximation for the full SPARQL fragment S (Pérez et al.'s algebra,
paper Sect. 4) fast enough to sit in front of a real query processor.  This
package is the database-shaped surface over the PR-1 engine internals::

    from repro.db import GraphDB, Q

    db = GraphDB.from_triples(triples)
    db.insert([("Dept9", "subOrganizationOf", "Univ0")])   # versioned

    rs = db.query(Q.triple("?d", "subOrganizationOf", "Univ0")
                   .triple("?s", "memberOf", "?d"))
    rs.bindings("s")               # node names, lazily materialized
    list(rs.survivor_triples(limit=10))

    with db.session(max_delay_ms=5) as s:        # cross-request batching
        futs = [s.submit(q) for q in queries]
        rows = [f.result() for f in futs]

Layers (one module each):

* :class:`GraphDB` — mutable handle, snapshot semantics, monotone version
  counter folded into the plan-cache fingerprint (precise invalidation),
  bounded per-version delta log driving incremental plan maintenance:
  shape-stable mutations patch superseded plans in place and warm-resume
  their fixpoints instead of rebuilding (DESIGN.md Sect. 8).
* :class:`Session` / :class:`ResultFuture` — deadline/size admission over
  the engine's microbatcher.
* :class:`Q` — fluent builder for the Sect.-4 algebra; round-trips through
  :func:`repro.core.sparql.format_query` / ``parse``.
* :class:`ResultSet` — lazy named bindings, survivor-triple pagination,
  honest per-request timing.

`repro.engine` remains the internal executor; importing its ``ExecResult``
still works but emits a :class:`DeprecationWarning`.
"""
from .builder import Q
from .graphdb import GraphDB
from .results import ResultSet
from .session import ResultFuture, Session

__all__ = ["GraphDB", "Q", "ResultFuture", "ResultSet", "Session"]
