"""Fluent query builder for the paper's fragment S (DESIGN.md 6.3).

Programmatic callers used to string-format query text and re-parse it; the
builder constructs :mod:`repro.core.sparql` ASTs directly, with the same
grammar the paper gives (Sect. 4)::

    Q ::= BGP | Q AND Q | Q OPTIONAL Q | Q UNION Q

Usage — terms starting with ``?`` are variables, everything else constants::

    q = (Q.triple("?d", "memberOf", "?u")
          .triple("?s", "advisor", "?d")          # extends the same BGP
          .and_(Q.triple("?u", "subOrganizationOf", "Univ0"))
          .optional("{ ?s publicationAuthor ?p }")  # text mixes in fine
          .union(("?s", "headOf", "?d")))           # so do bare triples
    q.build()    # -> core.sparql Query AST
    q.sparql()   # -> text that parse() round-trips to an equal AST

Builders are immutable: every call returns a new ``Q``, so prefixes can be
shared and specialized.  ``sparql()`` goes through
:func:`repro.core.sparql.format_query`, whose output is guaranteed to
``parse`` back to the identical AST (the builder only accepts predicate /
constant names in the parser's token class, keeping that guarantee tight).
"""
from __future__ import annotations

import re
from typing import Callable

from repro.core import sparql
from repro.core.sparql import (
    BGP,
    And,
    Const,
    Optional_,
    Query,
    Term,
    Triple,
    Union_,
    Var,
    format_query,
)

# the parser's `name` / `var` token classes: accepting only these keeps
# builder -> format_query -> parse a guaranteed identity
_NAME = re.compile(r"[A-Za-z0-9_:/#\-\.]+\Z")
_VAR = re.compile(r"\?[A-Za-z_][A-Za-z0-9_]*\Z")
# names the tokenizer would lex as a keyword instead of a name (its kw
# alternative wins at a word boundary, e.g. "AND", "WHERE", "AND:x")
_KEYWORD = re.compile(r"(?:AND|OPTIONAL|UNION|SELECT|WHERE)\b")


def _valid_name(x: str) -> bool:
    return bool(_NAME.match(x)) and not _KEYWORD.match(x)


def _term(x: str | Term) -> Term:
    if isinstance(x, (Var, Const)):
        return x
    if not isinstance(x, str):
        raise TypeError(f"term must be str or Var/Const, got {type(x).__name__}")
    if x.startswith("?"):
        if not _VAR.match(x):
            raise ValueError(f"invalid variable name {x!r}")
        return Var(x[1:])
    if not _valid_name(x):
        raise ValueError(f"invalid constant name {x!r} (not a parser token)")
    return Const(x)


def _label(p: str) -> str:
    if not isinstance(p, str) or not _valid_name(p):
        raise ValueError(f"invalid predicate label {p!r} (not a parser token)")
    return p


class _StartOrChain:
    """Descriptor so ``Q.triple(...)`` starts a builder and
    ``q.triple(...)`` extends one — the class itself is the empty builder."""

    def __init__(self, fn: Callable):
        self.fn = fn
        self.__doc__ = fn.__doc__

    def __get__(self, obj, cls):
        target = obj if obj is not None else cls()
        return lambda *args, **kwargs: self.fn(target, *args, **kwargs)


class Q:
    """Immutable fluent builder over the Sect.-4 query algebra."""

    __slots__ = ("_q",)

    def __init__(self, query: Query | None = None):
        self._q = query

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    def _triple(self, s: str | Term, p: str, o: str | Term) -> "Q":
        """Start a BGP (``Q.triple(...)``) or append to one (``q.triple(...)``).

        Appending to a composite (AND/OPTIONAL/UNION root) is ambiguous —
        use ``.and_(...)`` there instead.
        """
        t = Triple(_term(s), _label(p), _term(o))
        if self._q is None:
            return Q(BGP((t,)))
        if isinstance(self._q, BGP):
            return Q(BGP(self._q.triples + (t,)))
        raise TypeError(
            "cannot .triple() onto a composite query; wrap the new pattern "
            "in .and_(Q.triple(...)) / .optional(...) / .union(...)"
        )

    triple = _StartOrChain(_triple)

    @classmethod
    def bgp(cls, *spo: tuple[str, str, str]) -> "Q":
        """Build a whole BGP at once from (s, p, o) string triples."""
        q = cls()
        for s, p, o in spo:
            q = q.triple(s, p, o)
        return q

    @classmethod
    def parse(cls, text: str) -> "Q":
        """Wrap parsed query text in a builder."""
        return cls(sparql.parse(text))

    # ------------------------------------------------------------------ #
    # the three binary operators
    # ------------------------------------------------------------------ #
    def and_(self, other) -> "Q":
        """``self AND other`` (Pérez et al. algebra; paper Sect. 4)."""
        return Q(And(self.build(), _coerce(other)))

    def optional(self, other) -> "Q":
        """``self OPTIONAL other``."""
        return Q(Optional_(self.build(), _coerce(other)))

    def union(self, other) -> "Q":
        """``self UNION other`` (split away before SOI construction)."""
        return Q(Union_(self.build(), _coerce(other)))

    # ------------------------------------------------------------------ #
    # output
    # ------------------------------------------------------------------ #
    def build(self) -> Query:
        """The finished :mod:`repro.core.sparql` AST."""
        if self._q is None:
            raise ValueError("empty builder: add at least one triple")
        return self._q

    def sparql(self) -> str:
        """Query text; ``parse(q.sparql()) == q.build()`` always holds."""
        return format_query(self.build())

    def __eq__(self, other) -> bool:
        return isinstance(other, Q) and self._q == other._q

    def __hash__(self) -> int:
        return hash(self._q)

    def __repr__(self) -> str:
        return f"Q({self.sparql()})" if self._q is not None else "Q(<empty>)"


def _coerce(other) -> Query:
    """Accept a Q, an AST, query text, or a bare (s, p, o) triple."""
    if isinstance(other, Q):
        return other.build()
    if isinstance(other, (BGP, And, Optional_, Union_)):
        return other
    if isinstance(other, str):
        return sparql.parse(other)
    if (
        isinstance(other, tuple)
        and len(other) == 3
        and all(isinstance(x, (str, Var, Const)) for x in other)
    ):
        s, p, o = other
        return BGP((Triple(_term(s), _label(p), _term(o)),))
    raise TypeError(
        f"cannot build a query operand from {type(other).__name__}: "
        "pass a Q, a parsed Query, query text, or an (s, p, o) triple"
    )
