"""Mutable graph-database handle with snapshot semantics and versioned
plan invalidation (paper Sect. 2 database model; DESIGN.md Sect. 6.1).

The paper treats the database ``G = (V, Sigma, E)`` as a static input; a
database *system* (Angles et al., *Foundations of Modern Query Languages
for Graph Databases*) additionally needs updates and a stable handle the
query surface hangs off.  :class:`GraphDB` is that handle:

* **Snapshot semantics** — the underlying :class:`~repro.core.graph.Graph`
  is never mutated in place.  ``insert``/``delete`` build a *new* triples
  array; anything holding a previous ``snapshot()`` (a result set, an
  in-flight plan) keeps a consistent view.
* **Versioned fingerprints** — a monotone version counter is folded into
  the plan-cache fingerprint (``{content-hash}+v{version}``), so a mutation
  precisely invalidates stale compiled plans: same-template plans rebuild
  lazily on next use, adjacency device arrays for old snapshots are
  dropped, and the cache metrics expose exact invalidation counts
  (:meth:`repro.engine.engine.Engine.refresh`).
* **Set semantics** — ``E`` is a set of labeled edges: inserting a triple
  that already exists, or deleting one that does not, is a no-op and does
  not bump the version (so it invalidates nothing).

The executor behind the handle is the PR-1 :class:`repro.engine.Engine`
(template canonicalization -> LRU plan cache -> microbatching); ``GraphDB``
owns exactly one, shared by every :class:`~repro.db.session.Session`, so
all sessions hit one warm plan cache.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Iterable, Sequence

import numpy as np

from repro.core.graph import Graph, GraphDelta
from repro.core.sparql import Query
from repro.engine.batcher import DEFAULT_BUCKETS
from repro.engine.engine import Engine, EngineMetrics, graph_fingerprint

from .results import ResultSet

StrTriple = tuple[str, str, str]

# Per-version deltas kept for incremental plan maintenance; once a stale
# plan falls further behind than this, `delta_since` reports the history as
# truncated and the engine rebuilds cold (DESIGN.md Sect. 8.1).
DELTA_LOG_LIMIT = 64


def _empty_graph() -> Graph:
    return Graph(
        n_nodes=0,
        n_labels=0,
        triples=np.zeros((0, 3), dtype=np.int32),
        node_names=[],
        label_names=[],
    )


class GraphDB:
    """A mutable database handle over immutable :class:`Graph` snapshots.

    Contracts the rest of the system builds on:

    * **Snapshot pinning** — :meth:`query` / :meth:`execute_many` / session
      flushes each pin exactly one snapshot for their whole call; a
      concurrent mutation never makes one batch mix two graph versions.
      Returned :class:`~repro.db.results.ResultSet` objects keep reading
      through the snapshot they were computed against.
    * **Set semantics** — duplicate inserts and missing deletes are no-ops
      that bump nothing and invalidate nothing.
    * **Versioned invalidation** — every effective mutation bumps
      :attr:`version` (folded into :attr:`fingerprint`), records a
      :class:`~repro.core.graph.GraphDelta` in a bounded delta log, and
      lets :meth:`repro.engine.engine.Engine.refresh` classify superseded
      plans as *resumable* (shape-stable delta: operands patched in place,
      previous fixpoint warm-starts the next solve) or *cold* (dictionary
      grew: full rebuild).  ``incremental=False`` disables resumption.
    * **Stable ids** — existing node/label ids never change; deletes keep
      names in the dictionary, inserts append.
    """

    def __init__(
        self,
        graph: Graph | None = None,
        *,
        engine: str = "auto",
        cache_capacity: int = 64,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        backend: str | None = None,
        mesh=None,
        n_blocks: int | None = None,
        incremental: bool = True,
    ):
        """``engine`` picks the fixpoint engine ("auto" = cost-based):
        dense / packed / packed_fused / sparse / jacobi_packed /
        partitioned.  ``mesh`` is
        a ``jax.sharding.Mesh`` (see :func:`repro.distributed.ctx.node_mesh`)
        the partitioned engine shards chi's node axis over; with a mesh of
        >= 2 devices, engine="auto" selects "partitioned" once the graph
        outgrows single-shard budgets.  ``n_blocks`` overrides the number of
        destination blocks (default: one per mesh device).  ``incremental``
        enables warm-resume plan maintenance across mutations (DESIGN.md
        Sect. 8); disable it to force every superseded plan cold."""
        if graph is None:
            graph = _empty_graph()
        if graph.node_names is None or graph.label_names is None:
            raise ValueError(
                "GraphDB needs a graph with node_names/label_names; "
                "build it with Graph.from_triples or assign names first"
            )
        self._graph = graph
        self.version = 0
        self._base_fp = graph_fingerprint(graph)
        self._node_index = {n: i for i, n in enumerate(graph.node_names)}
        self._label_index = {n: i for i, n in enumerate(graph.label_names)}
        # lazily built by _edges(); insert/delete mutate it in place
        self._edge_set: set[tuple[int, int, int]] | None = None  # guarded-by: _lock
        # (version, delta that produced it) — consumed by Engine.refresh()
        self._delta_log: deque[tuple[int, GraphDelta]] = deque(  # guarded-by: _lock
            maxlen=DELTA_LOG_LIMIT
        )
        self._lock = threading.RLock()
        self._engine = Engine(
            self,
            engine=engine,
            cache_capacity=cache_capacity,
            buckets=buckets,
            backend=backend,
            mesh=mesh,
            n_blocks=n_blocks,
            incremental=incremental,
        )

    @classmethod
    def from_triples(cls, triples: Iterable[StrTriple], **kwargs) -> "GraphDB":
        """Build a database from (subject, predicate, object) string triples."""
        return cls(Graph.from_triples(triples), **kwargs)

    # ------------------------------------------------------------------ #
    # the contract Engine.refresh() reads (duck-typed source)
    # ------------------------------------------------------------------ #
    @property
    def graph(self) -> Graph:
        """The current immutable snapshot."""
        return self._graph

    @property
    def fingerprint(self) -> str:
        """Plan-cache fingerprint: content hash of the seed snapshot with
        the monotone version counter folded in."""
        return f"{self._base_fp}+v{self.version}"

    @property
    def node_index(self) -> dict[str, int]:
        """Live node name -> id map (ids are stable across mutations)."""
        return self._node_index

    @property
    def label_index(self) -> dict[str, int]:
        """Live label name -> id map (ids are stable across mutations)."""
        return self._label_index

    # ------------------------------------------------------------------ #
    # convenience views
    # ------------------------------------------------------------------ #
    @property
    def n_nodes(self) -> int:
        """Node count of the current snapshot."""
        return self._graph.n_nodes

    @property
    def n_triples(self) -> int:
        """Triple count of the current snapshot."""
        return self._graph.n_edges

    def snapshot(self) -> Graph:
        """Alias of :attr:`graph`, for callers that want to pin a version."""
        return self._graph

    def __contains__(self, triple: StrTriple) -> bool:
        s, p, o = triple
        ids = (
            self._node_index.get(s),
            self._label_index.get(p),
            self._node_index.get(o),
        )
        if None in ids:
            return False
        # RL3: _edges() lazily builds and caches _edge_set; unlocked it
        # races insert/delete mutating the same set (the lock is re-entrant)
        with self._lock:
            return ids in self._edges()

    def __len__(self) -> int:
        return self.n_triples

    def __repr__(self) -> str:
        return (
            f"GraphDB({self.n_triples} triples, {self.n_nodes} nodes, "
            f"v{self.version})"
        )

    # ------------------------------------------------------------------ #
    # mutation
    # ------------------------------------------------------------------ #
    def _edges(self) -> set[tuple[int, int, int]]:  # requires-lock: _lock
        if self._edge_set is None:
            self._edge_set = {tuple(row) for row in self._graph.triples.tolist()}
        return self._edge_set

    @staticmethod
    def _validated(triples: Iterable[StrTriple]) -> list[StrTriple]:
        """Materialize and type-check up front, so the mutation loops below
        cannot fail halfway and leave the live indexes out of sync with the
        committed snapshot."""
        out = []
        for i, t in enumerate(triples):
            if not (
                isinstance(t, tuple)
                and len(t) == 3
                and all(isinstance(x, str) for x in t)
            ):
                raise TypeError(
                    f"triple #{i} must be a (str, str, str) tuple, got {t!r}"
                )
            out.append(t)
        return out

    def insert(self, triples: Iterable[StrTriple]) -> int:
        """Insert string triples; unseen nodes/labels extend the dictionary.

        Returns the number of triples actually added (set semantics:
        already-present triples do not count and alone do not mutate).
        Bumps :attr:`version` — and thereby invalidates stale plans —
        only when something was added.
        """
        with self._lock:
            triples = self._validated(triples)
            edges = self._edges()
            node_names = list(self._graph.node_names)
            label_names = list(self._graph.label_names)
            added: list[tuple[int, int, int]] = []
            for s, p, o in triples:
                si = self._node_index.get(s)
                if si is None:
                    si = self._node_index[s] = len(node_names)
                    node_names.append(s)
                pi = self._label_index.get(p)
                if pi is None:
                    pi = self._label_index[p] = len(label_names)
                    label_names.append(p)
                oi = self._node_index.get(o)
                if oi is None:
                    oi = self._node_index[o] = len(node_names)
                    node_names.append(o)
                row = (si, pi, oi)
                if row not in edges:
                    edges.add(row)
                    added.append(row)
            if not added:
                # a duplicate triple cannot introduce new names, so the
                # dictionary is untouched too: nothing to commit
                return 0
            rows = np.asarray(added, dtype=np.int32).reshape(-1, 3)
            self._commit(
                Graph(
                    n_nodes=len(node_names),
                    n_labels=len(label_names),
                    triples=np.vstack([self._graph.triples, rows]),
                    node_names=node_names,
                    label_names=label_names,
                ),
                GraphDelta(
                    inserted=rows,
                    deleted=np.zeros((0, 3), np.int32),
                    nodes_before=self._graph.n_nodes,
                    nodes_after=len(node_names),
                    labels_before=self._graph.n_labels,
                    labels_after=len(label_names),
                ),
            )
            return len(added)

    def delete(self, triples: Iterable[StrTriple]) -> int:
        """Delete string triples; names never seen are ignored.

        Nodes and labels stay in the dictionary (ids are stable across
        deletes).  Returns the number of triples actually removed; the
        version bumps only when that is non-zero.
        """
        with self._lock:
            triples = self._validated(triples)
            edges = self._edges()
            doomed: set[tuple[int, int, int]] = set()
            for s, p, o in triples:
                row = (
                    self._node_index.get(s),
                    self._label_index.get(p),
                    self._node_index.get(o),
                )
                if None not in row and row in edges:
                    doomed.add(row)  # type: ignore[arg-type]
            if not doomed:
                return 0
            keep = np.asarray(
                [tuple(r) not in doomed for r in self._graph.triples.tolist()],
                dtype=bool,
            )
            self._edge_set = edges - doomed
            n, la = self._graph.n_nodes, self._graph.n_labels
            self._commit(
                Graph(
                    n_nodes=n,
                    n_labels=la,
                    triples=self._graph.triples[keep],
                    node_names=self._graph.node_names,
                    label_names=self._graph.label_names,
                ),
                GraphDelta(
                    inserted=np.zeros((0, 3), np.int32),
                    deleted=np.asarray(sorted(doomed), np.int32).reshape(-1, 3),
                    nodes_before=n,
                    nodes_after=n,
                    labels_before=la,
                    labels_after=la,
                ),
            )
            return len(doomed)

    def _commit(self, graph: Graph, delta: GraphDelta) -> None:  # requires-lock: _lock
        self._graph = graph
        self.version += 1
        self._delta_log.append((self.version, delta))

    def delta_since(self, version: int) -> GraphDelta | None:
        """The composed :class:`GraphDelta` from ``version`` to now.

        Returns ``None`` when the bounded delta log no longer reaches back
        to ``version`` (or the version is unknown) — the caller must then
        treat anything pinned to that version as cold.  Inserts cancelled
        by later deletes (and vice versa) drop out of the composition.
        """
        with self._lock:
            entries = [d for v, d in self._delta_log if v > version]
            if len(entries) != self.version - version or not entries:
                return None  # log truncated before `version` (or no change)
            out = entries[0]
            for d in entries[1:]:
                out = out.compose(d)
            return out

    # ------------------------------------------------------------------ #
    # querying
    # ------------------------------------------------------------------ #
    def session(self, **kwargs) -> "Session":
        """Open a :class:`~repro.db.session.Session` over this database."""
        from .session import Session

        return Session(self, **kwargs)

    def query(self, query) -> ResultSet:
        """One-shot convenience: execute a single query synchronously.

        ``query`` may be text, a parsed :class:`Query`, or a
        :class:`~repro.db.builder.Q` builder.  For request streams, use
        :meth:`session` — it microbatches same-template requests.
        """
        with self._lock:
            raw = self._engine.execute(self._coerce(query))
            return ResultSet(raw, self._engine.db)

    def execute_many(self, queries) -> list[ResultSet]:
        """Synchronously execute a request list with microbatching."""
        with self._lock:
            raws = self._engine.execute_many(
                [self._coerce(q) for q in queries]
            )
            snap = self._engine.db
            return [ResultSet(r, snap) for r in raws]

    def _execute_prepared(self, prepared) -> list[ResultSet]:
        """Session flush path: requests already split by Engine.prepare."""
        with self._lock:
            raws = self._engine.execute_prepared(prepared)
            snap = self._engine.db
            return [ResultSet(r, snap) for r in raws]

    @staticmethod
    def _coerce(query) -> str | Query:
        build = getattr(query, "build", None)  # Q builder without an import
        return build() if callable(build) else query

    def metrics(self) -> EngineMetrics:
        """Serving counters: cache hits/misses, invalidation classes
        (cold vs resumable vs resumed), microbatches, per-stage seconds.

        The copy is a single lock-protected snapshot
        (:meth:`repro.engine.engine.Engine.stats`), safe to read from any
        thread while sessions and the serving loop are in flight.
        """
        return self._engine.stats()

    def stats(self) -> EngineMetrics:
        """Alias of :meth:`metrics` (the engine-level name)."""
        return self._engine.stats()
