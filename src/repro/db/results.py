"""Lazy result sets over survivor masks (paper Sect. 5; DESIGN.md 6.4).

The engine's raw outcome is numeric: a boolean survivor mask over the
database triples (Theorems 1/2 pruning) and per-variable candidate node
masks.  :class:`ResultSet` is the *public* view of that outcome: bindings
materialize to node **names** on first access (via the snapshot's
dictionary) and are cached, survivor triples iterate and paginate without
ever materializing the full name list, and timing/provenance is honest
per-request — ``timings["total"]`` is this request's fair share of its
microbatch, ``timings["batch_total"]`` the whole microbatch wall time.

A ``ResultSet`` pins the :class:`~repro.core.graph.Graph` snapshot it was
computed against, so results stay self-consistent across subsequent
``GraphDB.insert``/``delete`` calls (snapshot semantics).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.core.graph import Graph
from repro.core.pruning import PruneStats
from repro.engine.engine import ExecResult

StrTriple = tuple[str, str, str]


class ResultSet:
    """Lazy, named, paginated view of one request's pruning outcome."""

    def __init__(self, raw: ExecResult, snapshot: Graph):
        self._raw = raw
        self._snapshot = snapshot
        self._name_cache: dict[str, list[str]] = {}
        self._survivor_ids: np.ndarray | None = None

    # ------------------------------------------------------------------ #
    # provenance / stats passthrough
    # ------------------------------------------------------------------ #
    @property
    def snapshot(self) -> Graph:
        """The graph snapshot this result was computed against."""
        return self._snapshot

    @property
    def stats(self) -> PruneStats:
        """Pruning statistics (triple counts before/after, per-edge splits)."""
        return self._raw.stats

    @property
    def sweeps(self) -> int:
        """Fixpoint sweeps the solve took (warm resumes take far fewer)."""
        return self._raw.sweeps

    @property
    def engine(self) -> str:
        """Fixpoint engine(s) that served this request."""
        return self._raw.engine

    @property
    def cache_hit(self) -> bool:
        """True iff every plan this request needed was already cached."""
        return self._raw.cache_hit

    @property
    def batch(self) -> int:
        """Microbatch bucket the request rode in."""
        return self._raw.batch

    @property
    def template_keys(self) -> tuple[str, ...]:
        """Plan-cache template keys (one per union-free part)."""
        return self._raw.template_keys

    @property
    def timings(self) -> dict[str, float]:
        """Per-stage seconds; ``total`` is this request's fair share of
        ``batch_total`` (the whole microbatch wall time)."""
        return self._raw.timings

    @property
    def survivor_mask(self) -> np.ndarray:
        """Raw bool mask over ``snapshot.triples`` (the Sect.-5 output)."""
        return self._raw.survivors

    def raw(self) -> ExecResult:
        """The internal engine record (compat escape hatch, not API)."""
        return self._raw

    # ------------------------------------------------------------------ #
    # bindings: node names, lazily materialized per variable
    # ------------------------------------------------------------------ #
    @property
    def variables(self) -> tuple[str, ...]:
        """The query's variable names, sorted."""
        return tuple(sorted(self._raw.bindings))

    def binding_mask(self, var: str) -> np.ndarray:
        """bool[n_nodes] candidate mask for ``var`` (no materialization)."""
        return self._raw.bindings[var]

    def bindings(self, var: str) -> list[str]:
        """Candidate node *names* for ``var``; computed once, then cached."""
        if var not in self._name_cache:
            names = self._snapshot.node_names
            ids = np.flatnonzero(self._raw.bindings[var])
            self._name_cache[var] = [names[i] for i in ids]
        return self._name_cache[var]

    def binding_count(self, var: str) -> int:
        """Candidate count for ``var`` without materializing names."""
        return int(self._raw.bindings[var].sum())

    # ------------------------------------------------------------------ #
    # survivor triples: iteration + pagination
    # ------------------------------------------------------------------ #
    def _ids(self) -> np.ndarray:
        if self._survivor_ids is None:
            self._survivor_ids = np.flatnonzero(self._raw.survivors)
        return self._survivor_ids

    def __len__(self) -> int:
        """Number of surviving triples."""
        return int(self._ids().shape[0])

    def survivor_triples(
        self, offset: int = 0, limit: int | None = None
    ) -> Iterator[StrTriple]:
        """Yield surviving ``(subject, predicate, object)`` name triples.

        ``offset``/``limit`` paginate over the survivor set in database
        order; only the requested page is ever materialized to names.
        """
        ids = self._ids()
        stop = len(ids) if limit is None else min(len(ids), offset + limit)
        nodes = self._snapshot.node_names
        labels = self._snapshot.label_names
        rows = self._snapshot.triples
        for i in ids[offset:stop]:
            s, p, o = rows[i]
            yield (nodes[s], labels[p], nodes[o])

    def page(self, offset: int = 0, limit: int = 50) -> list[StrTriple]:
        """One pagination page of :meth:`survivor_triples`, as a list."""
        return list(self.survivor_triples(offset=offset, limit=limit))

    def __iter__(self) -> Iterator[StrTriple]:
        return self.survivor_triples()

    def __repr__(self) -> str:
        t = self._raw.timings.get("total", 0.0)
        return (
            f"ResultSet({len(self)}/{self.stats.n_triples} triples survive, "
            f"engine={self.engine}, cache_hit={self.cache_hit}, "
            f"total={t*1e3:.2f}ms)"
        )
