"""Sessions: deadline/size-batched request admission over the microbatcher
(DESIGN.md 6.2; ROADMAP "async request queues" seam).

A :class:`Session` turns the engine's list-at-a-time ``execute_many`` into
a submit/flush surface: ``submit(query)`` returns a :class:`ResultFuture`
immediately, and pending requests are released to the engine as one
microbatched flush when the *admission policy* fires:

* **bucket cap** — as soon as any one template accumulates
  ``max_pending`` requests (default: the engine's largest microbatch
  bucket), waiting longer cannot improve batching, so the session flushes.
  N concurrent same-template submits therefore cost at most
  ``ceil(N / max_bucket)`` fixpoint solves.
* **deadline** — the first pending submit arms a ``max_delay_ms`` deadline;
  a submit arriving at or past it flushes everything (the late arrival
  rides along).  ``max_delay_ms=0`` degenerates to synchronous execution.
* **explicit** — ``flush()``, ``future.result()`` on an unresolved future,
  or leaving the ``with`` block.

By default the API is synchronous-cooperative: deadlines are checked at
submit and result boundaries, so behaviour is fully deterministic for
tests and single-threaded servers.  With ``auto_flush=True`` a background
flusher thread makes ``max_delay_ms`` a *real* timer: the deadline fires
even if no further submit or result call ever arrives (the serving-loop
regime, DESIGN.md Sect. 10).  Session state is lock-protected either way,
so submits and flushes may come from concurrent threads.  All sessions of
one :class:`~repro.db.graphdb.GraphDB` share its engine, so they share one
warm plan cache; the database lock serializes flushes from concurrent
threads.

A flush isolates failures per request: if a batched execution raises, the
batch re-runs request-by-request and only the offending request's future
carries the exception — sibling futures still resolve with their results.
"""
from __future__ import annotations

import threading
import time

from repro.core.sparql import Query
from repro.engine.template import TemplateInstance

from .results import ResultSet


class ResultFuture:
    """Handle for one submitted request; resolves when its batch flushes."""

    __slots__ = ("_session", "_result", "_error")

    def __init__(self, session: "Session"):
        self._session = session
        self._result: ResultSet | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        """True once the request's batch has flushed and resolved it."""
        return self._result is not None or self._error is not None

    def result(self) -> ResultSet:
        """The request's :class:`ResultSet`, flushing the session if needed.

        Raises the request's *own* execution exception if it failed —
        sibling requests of the same flush are unaffected.
        """
        if not self.done():
            self._session.flush()
        if self._error is not None:
            raise self._error
        if self._result is None:
            # only reachable when an exception tore down the session's
            # `with` block and dropped its pending work unresolved
            raise RuntimeError(
                "request was dropped: its session exited on an exception "
                "before flushing"
            )
        return self._result

    def _resolve(self, rs: ResultSet) -> None:
        self._result = rs

    def _reject(self, exc: BaseException) -> None:
        self._error = exc


class _BackgroundFlusher(threading.Thread):
    """Daemon timer that fires a session's ``max_delay_ms`` for real.

    Sleeps on a condition variable until the session's armed deadline (or
    until notified of a new, earlier one); past the deadline it calls
    ``flush()``, which resolves every pending future.  Execution errors
    cannot escape the flush (per-request isolation), so the thread only
    dies on shutdown.
    """

    def __init__(self, session: "Session"):
        super().__init__(name="session-flusher", daemon=True)
        self._session = session
        self.cv = threading.Condition()
        self._stop = False  # guarded-by: cv
        # The flusher's own copy of the armed deadline, handed over by
        # poke().  RL3: reading session._deadline here would cross into
        # state guarded by the *session* lock while holding only the cv —
        # and taking the session lock under the cv would invert submit's
        # `_lock -> cv` acquisition order (deadlock).
        self._armed: float | None = None  # guarded-by: cv

    def run(self) -> None:
        while True:
            with self.cv:
                if self._stop:
                    return
                wait = (
                    None if self._armed is None
                    else self._armed - time.monotonic()
                )
                if wait is None or wait > 0:
                    self.cv.wait(timeout=wait)
                    continue
                self._armed = None  # consumed: re-armed by the next poke()
            # deadline passed: flush outside the cv (flush takes the
            # session lock; submit holds it while notifying)
            self._session.flush()

    def stop(self) -> None:
        """Unblock and terminate the timer thread."""
        with self.cv:
            self._stop = True
            self.cv.notify()

    def poke(self, deadline: float) -> None:
        """Hand over a freshly armed deadline (called by submit)."""
        with self.cv:
            self._armed = deadline
            self.cv.notify()


class Session:
    """Submit/flush request surface over one :class:`GraphDB`."""

    def __init__(
        self,
        db,
        *,
        max_delay_ms: float = 5.0,
        max_pending: int | None = None,
        auto_flush: bool = False,
    ):
        if max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        self._db = db
        self._engine = db._engine
        self.max_delay_ms = max_delay_ms
        self.max_pending = (
            max_pending if max_pending is not None else max(self._engine.buckets)
        )
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self._pending: list[  # guarded-by: _lock
            tuple[ResultFuture, tuple[Query, TemplateInstance | None]]
        ] = []
        # per template key: the *unique* constant tuples pending.  Duplicate
        # submits share one instance slot in the microbatch (the batcher
        # dedups before chunking), so only unique tuples count toward the
        # bucket cap — N identical submits never force an early flush.
        self._group_consts: dict[str, set[tuple[str, ...]]] = {}  # guarded-by: _lock
        self._deadline: float | None = None  # guarded-by: _lock
        self._closed = False  # guarded-by: _lock
        self.submitted = 0  # guarded-by: _lock
        self.flushes = 0  # guarded-by: _lock
        self._lock = threading.RLock()
        self._flusher = _BackgroundFlusher(self) if auto_flush else None
        if self._flusher is not None:
            self._flusher.start()

    # ------------------------------------------------------------------ #
    @property
    def pending(self) -> int:
        """Requests submitted but not yet released to the engine."""
        with self._lock:  # RL3: submits/flushes mutate the list concurrently
            return len(self._pending)

    def submit(self, query) -> ResultFuture:
        """Queue one request; returns a future resolved at the next flush.

        ``query`` may be text, a parsed :class:`Query`, or a
        :class:`~repro.db.builder.Q` builder.  Parsing happens here so
        syntax errors surface at the submit site, not inside a later flush.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("session is closed")
            # prepare (parse + union_split + canonicalize) exactly once: the
            # admission counter needs the template key here, and the flush
            # hands the prepared pair straight to Engine.execute_prepared
            q, inst = self._engine.prepare(self._db._coerce(query))
            fut = ResultFuture(self)
            self._pending.append((fut, (q, inst)))
            self.submitted += 1

            # admission policy ------------------------------------------ #
            now = time.monotonic()
            if self._deadline is None:
                self._deadline = now + self.max_delay_ms / 1e3
                if self._flusher is not None:
                    self._flusher.poke(self._deadline)  # hand the deadline over
            if inst is not None:
                # same template key => same microbatch; unique constant
                # tuples count toward its cap (duplicates ride a slot)
                seen = self._group_consts.setdefault(inst.template.key, set())
                seen.add(inst.constants)
                if len(seen) >= self.max_pending:
                    self.flush()
                    return fut
            if now >= self._deadline:
                self.flush()
            return fut

    def flush(self) -> int:
        """Release all pending requests as one microbatched engine call.

        Resolves every pending future; returns how many were resolved.
        Failures are isolated per request: if the batched execution
        raises, the batch re-runs one request at a time so only the
        offending request's future is rejected with the exception, and its
        siblings still resolve with results (regression: a poisoned
        request used to leave the whole flush unresolved).
        """
        with self._lock:
            if not self._pending:
                self._deadline = None
                return 0
            pending, self._pending = self._pending, []
            self._group_consts.clear()
            self._deadline = None
            try:
                results = self._db._execute_prepared(
                    [prep for _, prep in pending]
                )
            except Exception:
                # isolate the poisoned request: siblings get their results,
                # the offender's future carries its own exception
                for fut, prep in pending:  # rl4: track=fut
                    try:
                        fut._resolve(self._db._execute_prepared([prep])[0])
                    except Exception as exc:
                        fut._reject(exc)
            else:
                for (fut, _), rs in zip(pending, results):  # rl4: track=fut
                    fut._resolve(rs)
            self.flushes += 1
            return len(pending)

    def close(self) -> None:
        """Flush outstanding work and reject further submits."""
        with self._lock:
            if self._closed:
                return
            self.flush()
            self._closed = True
        if self._flusher is not None:
            self._flusher.stop()

    # ------------------------------------------------------------------ #
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            # an exception unwound the block: drop pending work unresolved
            # rather than masking the error with a flush that may also fail
            with self._lock:
                self._pending.clear()
                self._group_consts.clear()
                self._deadline = None
                self._closed = True
            if self._flusher is not None:
                self._flusher.stop()

    def __repr__(self) -> str:
        with self._lock:  # RL3: one consistent snapshot of the counters
            n_pending, submitted, flushes = (
                len(self._pending), self.submitted, self.flushes,
            )
        return (
            f"Session(pending={n_pending}, submitted={submitted}, "
            f"flushes={flushes}, max_delay_ms={self.max_delay_ms}, "
            f"max_pending={self.max_pending})"
        )
