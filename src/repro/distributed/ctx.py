"""Logical-axis sharding context and mesh construction.

Model code annotates tensors with *logical* axes (``batch``, ``vocab``,
``expert``, ...); the launcher activates a mapping to physical mesh axes
around tracing (``with logical_axis_rules(mesh): jit(...).lower(...)``).
Outside the context every annotation is a no-op, so the same model code runs
unsharded on CPU tests and fully sharded in the production dry-run.

:func:`node_mesh` builds the 1-D device mesh the partitioned dual-simulation
engine shards chi's node axis over (DESIGN.md Sect. 7);
:func:`force_host_device_count` simulates a multi-device host for CPU tests
and benchmarks.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

_STATE = threading.local()

NODE_AXIS = "nodes"  # mesh axis name chi's node dimension shards over


def force_host_device_count(n: int) -> None:
    """Ask XLA to split the host CPU into ``n`` simulated devices.

    Only effective when called BEFORE the first JAX computation initializes
    the backend (XLA reads ``XLA_FLAGS`` at client construction); a no-op
    if the flag is already set, so exported ``XLA_FLAGS`` wins.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )


def node_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over the local devices for the partitioned fixpoint engine.

    The single axis (:data:`NODE_AXIS`) carries chi's node dimension; edge
    blocks are placed block-major along it so segment reductions stay
    device-local and the only cross-shard traffic is the packed frontier
    broadcast (one ``n/8``-byte collective per sweep).
    """
    devices = jax.devices()
    n = n_devices if n_devices is not None else len(devices)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} (or call "
            "force_host_device_count before the first JAX computation)"
        )
    return Mesh(np.asarray(devices[:n]), (NODE_AXIS,))

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "expert_cap": ("data",),
    "moe_tokens": ("pod", "data", "model"),  # flat (token, k) dispatch dim
    "embed_fsdp": ("data",),
    "kv_seq": ("data",),
    "nodes": ("data", "model"),
    "edges": ("pod", "data", "model"),
}


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, {**DEFAULT_RULES, **(rules or {})})
    try:
        yield
    finally:
        _STATE.ctx = prev


def group_count(name: str, dim: int) -> int:
    """Number of shard groups the logical axis ``name`` would split ``dim``
    into under the active rules (1 outside a rule context).  Used by the MoE
    layer to block its dispatch into shard-local groups."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return 1
    mesh, rules = ctx
    axes = tuple(a for a in rules.get(name, ()) if a in mesh.shape)
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size and dim % size == 0:
            return size
        axes = axes[1:]
    return 1


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """Apply with_sharding_constraint if a rule context is active.

    ``logical`` has one entry per dim: a logical axis name or None.
    Mesh axes that are absent or do not divide the dim are dropped.
    """
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = []
    for dim, name in zip(x.shape, logical):
        if name is None:
            spec.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.shape)
        while axes:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size and dim % size == 0:
                break
            axes = axes[1:]
        spec.append(axes if axes else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
