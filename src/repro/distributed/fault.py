"""Fault tolerance: heartbeats, straggler detection, restart-from-checkpoint.

On a 1000+ node cluster the failure model is: hosts vanish (preemption,
hardware), hosts slow down (thermal, network), and whole pods partition.
The framework's answer, mirrored here at single-process scale so it is
testable on CPU:

* ``Heartbeat``         — per-host monotonic step/time reports.
* ``StragglerMonitor``  — flags hosts whose step latency exceeds
  ``threshold x median`` over a sliding window; the launcher responds by
  excluding the host and re-sharding (elastic scale-down) at the next
  checkpoint boundary.
* ``RestartPolicy``     — drives run loops: every exception rolls back to
  the last committed checkpoint, with capped exponential backoff and a
  budget of restarts (same contract a cluster-level supervisor implements).

Deterministic data order (``repro.data.pipeline``) + committed checkpoints
make replacement-host replay exact.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable


@dataclasses.dataclass
class Heartbeat:
    host: str
    step: int
    t: float


class StragglerMonitor:
    def __init__(self, window: int = 16, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._lat: dict[str, deque] = defaultdict(lambda: deque(maxlen=window))
        self._last: dict[str, Heartbeat] = {}

    def report(self, hb: Heartbeat) -> None:
        prev = self._last.get(hb.host)
        if prev is not None and hb.step > prev.step:
            self._lat[hb.host].append((hb.t - prev.t) / (hb.step - prev.step))
        self._last[hb.host] = hb

    def median_latency(self) -> float | None:
        all_lat = sorted(
            sum(d, start=0.0) / len(d) for d in self._lat.values() if d
        )
        if not all_lat:
            return None
        return all_lat[len(all_lat) // 2]

    def stragglers(self) -> list[str]:
        med = self.median_latency()
        if med is None or med <= 0:
            return []
        out = []
        for host, d in self._lat.items():
            if d and (sum(d) / len(d)) > self.threshold * med:
                out.append(host)
        return sorted(out)

    def forget(self, host: str) -> None:
        """Drop a host's history (a replaced/rebuilt host starts fresh)."""
        self._lat.pop(host, None)
        self._last.pop(host, None)

    def dead(self, now: float, timeout: float) -> list[str]:
        return sorted(
            h for h, hb in self._last.items() if now - hb.t > timeout
        )


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    backoff_cap_s: float = 60.0

    def run(
        self,
        body: Callable[[int], None],
        *,
        on_restart: Callable[[int, BaseException], None] | None = None,
        sleep: Callable[[float], None] = time.sleep,
    ) -> int:
        """Run ``body(restart_idx)`` with restart-on-exception semantics.
        Returns the number of restarts consumed."""
        restarts = 0
        while True:
            try:
                body(restarts)
                return restarts
            except KeyboardInterrupt:
                raise
            except BaseException as e:  # noqa: BLE001 — supervisor semantics
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if on_restart is not None:
                    on_restart(restarts, e)
                sleep(min(self.backoff_s * 2 ** (restarts - 1), self.backoff_cap_s))
