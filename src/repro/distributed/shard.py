"""Sharding rule tables: param/input PartitionSpecs per model family.

Strategy (DESIGN.md Sect. 4):

* LMs — FSDP over ``data`` (params' d_model-ish dim) x TP over ``model``
  (heads / ffn columns / vocab); MoE experts over ``model`` when the expert
  count divides (EP), else expert-internal d_ff over ``model`` (TP).
  Batch over ``(pod, data)``.
* GNNs — edge arrays fully sharded over ``(pod, data, model)``; node arrays
  sharded over ``data`` (replicated over ``model``) so segment reductions
  land locally after an all-gather of features.
* RecSys — the embedding table row-sharded over every axis (it IS the
  memory); dense trunk replicated, batch over ``(pod, data)``.

Every spec passes through :func:`safe_spec`, which drops mesh axes that do
not divide the dimension — so one rule table serves every (config x mesh)
combination without divisibility crashes.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def safe_spec(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop axes that don't divide their dimension (replicate instead)."""
    out = []
    for i, dim in enumerate(shape):
        axes = spec[i] if i < len(spec) else None
        if axes is None:
            out.append(None)
            continue
        if dim % axis_size(mesh, axes) == 0 and dim > 0:
            out.append(axes)
        else:
            # try a prefix of the axis tuple before giving up
            if isinstance(axes, tuple):
                kept = None
                for j in range(len(axes) - 1, 0, -1):
                    if dim % axis_size(mesh, axes[:j]) == 0:
                        kept = axes[:j]
                        break
                out.append(kept)
            else:
                out.append(None)
    return P(*out)


def shard_by_rules(
    tree: Any, mesh: Mesh, rules: list[tuple[str, P]]
) -> Any:
    """Tree of NamedShardings: first rule whose regex matches the param path."""

    def one(path, leaf):
        pstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        shape = np.shape(leaf)
        for pat, spec in rules:
            if re.search(pat, pstr):
                return NamedSharding(mesh, safe_spec(shape, spec, mesh))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, tree)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """All batch-parallel axes present in the mesh (pod first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard the batch dim over (pod, data) with divisibility fallback."""
    axes = data_axes(mesh)
    while axes and batch % axis_size(mesh, axes) != 0:
        axes = axes[1:]
    return P(axes if axes else None)


def all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


# --------------------------------------------------------------------- #
# LM rules
# --------------------------------------------------------------------- #
def lm_param_rules(cfg, mesh: Mesh | None = None) -> list[tuple[str, P]]:
    rules = [
        # NB: anchored — "embed" must not shadow "unembed".
        # unembed: keep d_model replicated so the CE contraction needs no
        # full-vocab all-reduce; logits are born vocab-sharded
        # (EXPERIMENTS §Perf, qwen3 train iteration 2).
        (r"^unembed", P(None, "model")),
        (r"^embed", P("model", "data")),
        (r"ln_f|ln1|ln2|q_norm|k_norm", P()),
        (r"attn/wq", P(None, "data", "model")),
        (r"attn/wk|attn/wv", P(None, "data", None)),
        (r"attn/wo", P(None, "model", "data")),
        (r"mlp/w_gate|mlp/w_up", P(None, "data", "model")),
        (r"mlp/w_down", P(None, "model", "data")),
        (r"moe/router", P(None, "data", None)),
    ]
    if cfg.moe is not None:
        model_size = mesh.shape["model"] if mesh is not None else 1
        if model_size > 1 and cfg.moe.n_experts % model_size == 0:
            # EP: experts across 'model'
            rules += [
                (r"moe/w_gate|moe/w_up", P(None, "model", "data", None)),
                (r"moe/w_down", P(None, "model", None, "data")),
            ]
        else:
            # TP fallback: expert-internal d_ff across 'model'
            rules += [
                (r"moe/w_gate|moe/w_up", P(None, None, "data", "model")),
                (r"moe/w_down", P(None, None, "model", "data")),
            ]
    return rules


def lm_input_specs(mesh: Mesh, batch: int) -> dict[str, P]:
    bs = batch_spec(mesh, batch)
    return {"tokens": bs, "labels": bs}


def lm_cache_spec(mesh: Mesh, cfg, batch: int, seq: int) -> dict[str, P]:
    """KV cache [L, B, S, kv, hd]: batch over (pod,data) when divisible,
    else the cache sequence dim (flash-decoding-style split)."""
    baxes = data_axes(mesh)
    if batch % axis_size(mesh, baxes) == 0 and batch > 1:
        # batch over (pod, data); cache sequence over 'model'
        # (flash-decoding-style split of the KV read).
        kv = P(None, baxes, "model", None, None)
        pos = P(baxes)
    else:
        kv = P(None, None, ("data", "model"), None, None)
        pos = P()
    return {"k": kv, "v": kv, "pos": pos}


# --------------------------------------------------------------------- #
# GNN rules
# --------------------------------------------------------------------- #
def gnn_param_rules(cfg) -> list[tuple[str, P]]:
    return [(r".*", P())]  # GNN trunks are tiny: replicate params


def gnn_input_specs(mesh: Mesh) -> dict[str, P]:
    eaxes = all_axes(mesh)
    naxes = tuple(a for a in ("data", "model") if a in mesh.shape)
    return {
        "feat": P(naxes, None),
        "edges": P(eaxes, None),
        "edge_mask": P(eaxes),
        "labels": P(naxes),
        "node_graph": P(naxes),
        "positions": P(naxes, None),
    }


# --------------------------------------------------------------------- #
# RecSys rules
# --------------------------------------------------------------------- #
def recsys_param_rules(cfg) -> list[tuple[str, P]]:
    return [
        (r"table", P(("data", "model"), None)),
        (r"mlp/\d+/w", P(None, "model")),
        (r".*", P()),
    ]


def recsys_input_specs(mesh: Mesh, batch: int) -> dict[str, P]:
    bs = batch_spec(mesh, batch)
    return {
        "dense": P(*bs, None),
        "sparse": P(*bs, None),
        "labels": bs,
        "candidates": P(("data", "model"), None),
    }


# --------------------------------------------------------------------- #
# dual-simulation (paper workload) rules
# --------------------------------------------------------------------- #
def dualsim_sparse_specs(mesh: Mesh) -> dict[str, P]:
    """Sparse engine: edges fully sharded; chi columns over the non-pod
    axes (the chi working set is the HBM hot spot at DB scale)."""
    eaxes = all_axes(mesh)
    chi_axes = tuple(a for a in ("data", "model") if a in mesh.shape)
    return {
        "init": P(None, chi_axes),
        "edge_src": P(eaxes),
        "edge_dst": P(eaxes),
        "mat_rhs": P(),
        "mat_table": P(),
        "copy_rhs": P(),
        "var_copy": P(),
    }


def dualsim_dense_specs(mesh: Mesh) -> dict[str, P]:
    """Dense/MXU engine: adjacency 2-D sharded (rows x cols)."""
    return {
        "init": P(None, "model"),
        "adj_dense": P(None, "data", "model"),
        "adj_packed": P(None, "data", "model"),
        "mat_rhs": P(),
        "mat_table": P(),
        "copy_rhs": P(),
        "var_copy": P(),
    }
