"""`repro.engine` — the production query-engine subsystem (DESIGN.md Sect. 5).

Ties the whole pipeline together — ``sparql`` → ``union_split`` → ``soi`` →
``dualsim`` → ``pruning`` → ``join`` — behind one facade::

    from repro.engine import Engine
    eng = Engine(db)                      # cost model picks the fixpoint engine
    res = eng.execute("{ ?d subOrganizationOf Univ3 . ?s memberOf ?d }")
    res.survivors, res.bindings, res.timings, res.cache_hit

The key mechanism is *parameterized plan caching*: constants are abstracted
out of a parsed query into a canonical template (:mod:`template`), the
template is compiled once into a :class:`~repro.engine.plan.CompiledPlan`
whose jitted fixpoint takes the per-request constant rows as an *input*
(:mod:`plan`), and subsequent requests with the same shape rebind constants
with zero SOI recompilation and zero jit retraces (:mod:`cache`).  Groups of
same-template requests are solved as one disjoint-union SOI, padded to
bucketed batch sizes so traces are reused (:mod:`batcher`), and the fixpoint
engine (dense / packed / sparse / jacobi_packed / partitioned) is chosen per
plan by a communication-aware cost model (:mod:`cost`) instead of a
hard-coded flag.  ``Engine(db, mesh=...)`` shards the partitioned engine's
chi over a device mesh (DESIGN.md Sect. 7).
"""
import warnings

from .batcher import BatchLayout, MicroBatcher, batch_layout, batched_soi, bucket_for
from .cache import CacheStats, PlanCache
from .cost import (
    CostEstimate,
    CostModel,
    HAND_TUNED,
    ResumeDecision,
    choose_engine,
    estimate_costs,
    resume_decision,
)
from .engine import Engine, EngineMetrics
from .machine import MachineSpec, default_spec, machine_fingerprint
from .plan import CompiledPlan, PlanMetrics
from .template import (
    SLOT_PREFIX,
    QueryTemplate,
    TemplateInstance,
    canonicalize,
    template_key,
)

def __getattr__(name: str):
    """Deprecation shim: `repro.db.ResultSet` is the public result type now;
    the raw ``ExecResult`` record remains reachable for old callers but
    warns."""
    if name == "ExecResult":
        warnings.warn(
            "importing ExecResult from repro.engine is deprecated; use the "
            "repro.db public API (Session/GraphDB return repro.db.ResultSet)",
            DeprecationWarning,
            stacklevel=2,
        )
        from .engine import ExecResult

        return ExecResult
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BatchLayout",
    "CacheStats",
    "CompiledPlan",
    "CostEstimate",
    "CostModel",
    "Engine",
    "EngineMetrics",
    "ExecResult",
    "HAND_TUNED",
    "MachineSpec",
    "MicroBatcher",
    "PlanCache",
    "PlanMetrics",
    "QueryTemplate",
    "ResumeDecision",
    "SLOT_PREFIX",
    "TemplateInstance",
    "batch_layout",
    "batched_soi",
    "bucket_for",
    "canonicalize",
    "choose_engine",
    "default_spec",
    "estimate_costs",
    "machine_fingerprint",
    "resume_decision",
    "template_key",
]
