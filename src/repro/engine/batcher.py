"""Microbatching: disjoint-union SOIs and template-keyed request queues
(DESIGN.md 5.4).

``batched_soi`` (moved here from ``launch/serve.py``) forms the disjoint
union of per-request SOIs — variables get per-instance copies, so one
fixpoint solves the whole batch; instances never interact because no
inequality crosses an instance boundary.  Variables are renamed with a
*per-instance index* suffix (``{base}#{i}``), so instance boundaries are
reconstructible for result demux: :func:`batch_layout` records the variable
offset of every instance.

``MicroBatcher`` groups pending requests by template key and pads each group
to a bucketed batch size (1, 2, 4, ...), so a handful of compiled plans —
one per (template, bucket) — serve any request mix with zero retraces.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

from repro.core.soi import SOI

from .template import TemplateInstance

DEFAULT_BUCKETS = (1, 2, 4, 8, 16)


def batched_soi(parts: Sequence[SOI]) -> SOI:
    """Disjoint union of per-request SOIs (no shared variables).

    Instance ``i``'s variables are renamed ``{base}#{i}`` and occupy the
    contiguous id block ``[offsets[i], offsets[i] + parts[i].n_vars)`` — see
    :func:`batch_layout` for the demux view.
    """
    return batch_layout(parts).soi


@dataclasses.dataclass
class BatchLayout:
    """A batched SOI plus the per-instance demux information."""

    soi: SOI
    parts: list[SOI]
    offsets: list[int]  # instance i -> first internal var id

    def chi_slice(self, i: int) -> slice:
        """Row slice of the batched chi belonging to instance ``i``."""
        return slice(self.offsets[i], self.offsets[i] + self.parts[i].n_vars)


def batch_layout(parts: Iterable[SOI]) -> BatchLayout:
    """Disjoint-union SOI plus per-instance offsets for result demux."""
    parts = list(parts)
    base: list[str] = []
    is_const: list[str | None] = []
    edge, copy, pe = [], [], []
    offsets = []
    for i, s in enumerate(parts):
        off = len(base)
        offsets.append(off)
        base += [f"{b}#{i}" for b in s.base]
        is_const += s.is_const
        edge += [(l + off, r + off, a, d) for (l, r, a, d) in s.edge_ineqs]
        copy += [(l + off, r + off) for (l, r) in s.copy_ineqs]
        pe += [(v + off, a, w + off) for (v, a, w) in s.pattern_edges]
    union = SOI(
        base=base, is_const=is_const, edge_ineqs=edge, copy_ineqs=copy,
        pattern_edges=pe, external_mand={}, external_opt={},
    )
    return BatchLayout(soi=union, parts=parts, offsets=offsets)


def bucket_for(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n (largest bucket caps the microbatch size)."""
    for b in buckets:
        if b >= n:
            return b
    return max(buckets)


@dataclasses.dataclass
class Microbatch:
    """A group of same-template requests to be solved as one fixpoint."""

    template_key: str
    requests: list[tuple[int, TemplateInstance]]  # (caller index, instance)
    bucket: int


class MicroBatcher:
    """Queue requests, then drain them as template-grouped microbatches.

    Grouping is by template key: requests that share a plan (same query
    shape) batch together regardless of their constants.  Each group is
    chunked at the largest bucket and padded up to the smallest bucket that
    fits, so the set of (template, bucket) plans stays small.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        self._queues: dict[str, list[tuple[int, TemplateInstance]]] = {}

    def add(self, index: int, instance: TemplateInstance) -> None:
        """Queue one request under its template key for the next drain."""
        self._queues.setdefault(instance.template.key, []).append(
            (index, instance)
        )

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def drain(self) -> Iterator[Microbatch]:
        """Yield microbatches (FIFO within a template) and empty the queues.

        Requests are deduplicated by constant tuple *before* chunking:
        duplicate submits share one instance slot at execution, so they must
        not consume chunk capacity — 20 identical submits at cap 16 are ONE
        solve, not two.  Chunks hold up to ``max(buckets)`` unique tuples
        (FIFO by first occurrence) and the bucket is sized for that unique
        count, naming the (template, bucket) plan the executor will use.
        """
        cap = max(self.buckets)
        for key, queue in self._queues.items():
            groups: dict[tuple[str, ...], list[tuple[int, TemplateInstance]]] = {}
            for idx, inst in queue:
                groups.setdefault(inst.constants, []).append((idx, inst))
            uniq = list(groups.values())
            for s in range(0, len(uniq), cap):
                chunk = uniq[s : s + cap]
                yield Microbatch(
                    template_key=key,
                    requests=[r for grp in chunk for r in grp],
                    bucket=bucket_for(len(chunk), self.buckets),
                )
        self._queues.clear()
