"""LRU plan cache with hit/miss/eviction counters (DESIGN.md 5.2).

Keys are ``(template key, graph fingerprint, bucket, engine override)``
tuples built by the facade; values are :class:`~repro.engine.plan.
CompiledPlan` objects.  The counters are the observable the zero-recompile
acceptance test asserts on: a warm rebind must increment ``hits`` and leave
``misses`` (= plan builds = SOI compilations) unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Hashable, TypeVar

V = TypeVar("V")


@dataclasses.dataclass
class CacheStats:
    """Point-in-time cache counters (the zero-recompile observables)."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    capacity: int = 0
    invalidations: int = 0  # entries dropped by invalidate(), not LRU pressure

    @property
    def hit_rate(self) -> float:
        """Hits over total lookups (0.0 before any lookup)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class BoundedDict(OrderedDict):
    """Dict with LRU eviction past ``capacity`` — the adjacency cache.

    Evicting an entry only loses *sharing*: plans already holding the array
    keep it alive through their operands, so eviction is always safe.
    """

    def __init__(self, capacity: int = 16):
        super().__init__()
        self.capacity = capacity

    def __getitem__(self, key):
        value = super().__getitem__(key)
        self.move_to_end(key)
        return value

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        while len(self) > self.capacity:
            # not popitem(): its value fetch re-enters our __getitem__ after
            # the link is gone and move_to_end would raise
            del self[next(iter(self))]


class PlanCache:
    """A plain LRU: most-recently-used plans survive, counters are public."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("plan cache capacity must be >= 1")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self._entries: OrderedDict[Hashable, V] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get_or_build(self, key: Hashable, builder: Callable[[], V]) -> V:
        """Return the cached value for ``key``, building (and possibly
        evicting the LRU entry) on miss."""
        if key in self._entries:
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        value = builder()
        self._entries[key] = value
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return value

    def pop_matching(
        self, match: Callable[[Hashable], bool]
    ) -> list[tuple[Hashable, V]]:
        """Remove and return the entries whose key satisfies ``match``.

        Unlike :meth:`invalidate` this does NOT count toward
        ``invalidations``: it is the *reclassification* path — the engine
        moves superseded-but-resumable plans into its staging area instead
        of dropping them (DESIGN.md Sect. 8.3).
        """
        keys = [k for k in self._entries if match(k)]
        return [(k, self._entries.pop(k)) for k in keys]

    def invalidate(self, stale: Callable[[Hashable], bool]) -> int:
        """Drop exactly the entries whose key satisfies ``stale``.

        This is the precise (non-flush) invalidation path used on graph
        mutation: only plans bound to fingerprints outside the version
        history are removed, everything else keeps its LRU position.
        Returns the number of entries dropped (also accumulated in
        ``invalidations``).
        """
        keys = [k for k in self._entries if stale(k)]
        for k in keys:
            del self._entries[k]
        self.invalidations += len(keys)
        return len(keys)

    def clear(self) -> None:
        """Drop every entry (counters keep accumulating)."""
        self._entries.clear()

    def stats(self) -> CacheStats:
        """A :class:`CacheStats` snapshot of the counters."""
        return CacheStats(
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            size=len(self._entries),
            capacity=self.capacity,
            invalidations=self.invalidations,
        )
