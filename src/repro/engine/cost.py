"""Cost-based fixpoint-engine selection (DESIGN.md 5.3 / 7.2).

Replaces the hard-coded ``--engine`` flag: given the database statistics and
the compiled SOI, estimate the per-sweep work of each batched engine in
:mod:`repro.core.dualsim` and pick the cheapest *feasible* one.  All engines
compute the same greatest fixpoint, so the choice is purely a performance
decision — which is what makes a closed-form model safe: a wrong pick is
slow, never incorrect.

Per-sweep model (arbitrary units; V = SOI variables, n = nodes, M = distinct
(label, direction) operators, E = total edges touched by the SOI's
operators, W = devices in the mesh):

* ``dense``  — M boolean matmuls: ``V * n * n * M`` elements at matmul
  efficiency ``C_DENSE`` (MXU/BLAS amortization).  Infeasible when the
  stacked ``bool[M, n, n]`` adjacency exceeds ``DENSE_MAX_BYTES``.
* ``packed`` — the Pallas bitmm path: 32 bits per word cuts element count by
  32x, but on the CPU backend the kernel runs in interpret mode, which the
  model charges a large penalty (packed is an accelerator engine).
* ``packed_fused`` — the end-to-end bit-packed engine (DESIGN.md Sect. 9):
  same word count as ``packed`` at roughly half the per-word cost (the
  unpack → gather → AND chain between product and update is fused away, so
  chi never inflates 8x in HBM), and on CPU it lowers to the word-wise XLA
  path instead of kernel emulation — far cheaper than interpreted
  ``packed`` though still behind ``sparse`` on most CPU-sized graphs.
* ``sparse`` / ``jacobi_packed`` — the segmented-OR sweep (ISSUE 8),
  priced from BYTES MOVED: per sweep the engine streams ``E * (8 + V)``
  bytes of edge ids + gathered frontier messages, and ``3 * M * V * n/8``
  bytes of packed ``y`` words through the per-variable AND (write + read +
  chi fold).  Always feasible on one device.  Under Gauss–Seidel every
  operator re-gathers the freshly-updated packed chi, so on a mesh it pays
  M packed-chi collectives (``M * V * n/8`` bytes) per sweep;
  ``jacobi_packed`` reads ONE bit-packed broadcast per sweep but pays a
  ~2x sweep-count inflation (Jacobi vs Gauss–Seidel, measured in
  ``configs/dualsim_base.py``).
* ``partitioned`` — jacobi_packed with destination-partitioned edge blocks:
  compute divides across the W shards, cross-shard traffic stays the one
  packed broadcast.  Needs a mesh (infeasible at W = 1, where it only adds
  block-padding overhead over jacobi_packed).

Communication terms enter only when ``n_devices > 1`` — on a single device
there is no collective traffic and the model must reduce to the PR-1
single-shard model exactly.

Feasibility is a HARD gate, not a preference: any engine whose *build*
path materializes an ``[n, n]`` plane — dense itself, and the packed tier,
whose ``graph.packed_adjacency`` packs through a transient dense build —
is refused outright once ``n * n`` exceeds the byte budget
(``graph.DENSE_ADJ_MAX_BYTES``).  Before ISSUE 8 the model only priced the
*resident* operand bytes, so it could select an engine whose operands then
OOMed at build time.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.graph import DENSE_ADJ_MAX_BYTES, Graph
from repro.core.soi import CompiledSOI

ENGINES = (
    "dense", "packed", "packed_fused", "sparse", "jacobi_packed",
    "partitioned",
)

# model constants (relative cost per element)
C_DENSE = 1.0 / 8.0  # matmul elements amortize on MXU/BLAS
C_PACKED = 2.0  # per uint32 word, compiled Pallas
C_PACKED_INTERPRET = 256.0  # per word under interpret mode (CPU backend)
C_PACKED_FUSED = 1.0  # per word, fused kernel: no unpack/gather chain
C_PACKED_FUSED_CPU = 24.0  # per word, word-wise XLA lowering (no kernel)
PACKED_LAUNCH = 65536.0  # per-operator kernel launch overhead
C_SPARSE = 4.0  # per edge message (admission envelope only, see below)
C_APPLY = 0.5  # per chi element per operator (admission envelope only)
C_SEGOR_BYTE = 1.0  # per byte moved through the segmented-OR sweep
C_COMM = 8.0  # per byte of cross-shard collective traffic
JACOBI_SWEEP_FACTOR = 2.0  # Jacobi needs ~2x the sweeps of Gauss–Seidel
DENSE_MAX_BYTES = 2 << 30  # stacked bool[M, n, n] adjacency budget
PACKED_MAX_BYTES = 2 << 30
# any single [n, n] plane past this cannot be BUILT (graph.dense_adjacency
# raises MemoryError) — shared with the data layer so the model's hard gate
# and the constructor's guard can never disagree
DENSE_TIER_MAX_BYTES = DENSE_ADJ_MAX_BYTES


def dense_tier_feasible(n: int) -> bool:
    """Whether any ``[n, n]`` operand plane may be materialized at all.

    Gates dense AND both packed engines: ``graph.packed_adjacency`` packs
    through a transient dense ``[n, n]`` build, so the packed tier is just
    as impossible past the budget even though its *resident* operand is 32x
    smaller.
    """
    return n * n <= DENSE_TIER_MAX_BYTES


def segor_sweep_cost(v: int, n: int, m: int, e: int) -> float:
    """Bytes-moved model of one segmented-OR Gauss–Seidel sweep.

    ``E * (8 + V)`` bytes of edge ids (src + dst int32) and int8 frontier
    messages, plus ``3 * M * V * n/8`` bytes of packed ``y`` words (written
    by the segmented OR, read by the per-variable AND, folded into chi).
    """
    return C_SEGOR_BYTE * (e * (8.0 + v) + 3.0 * m * v * (n / 8.0))


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Chosen engine plus the full per-engine cost breakdown."""

    engine: str
    costs: dict[str, float]  # per-sweep model cost; float('inf') = infeasible
    reason: str


def _soi_stats(g: Graph, c: CompiledSOI) -> tuple[int, int, int]:
    """(V, M, E_total) for a compiled SOI against ``g``."""
    hist = g.label_histogram()
    e_total = int(sum(hist[la] for la, _ in c.mats))
    return c.n_vars, len(c.mats), e_total


def estimate_costs(
    g: Graph,
    c: CompiledSOI,
    *,
    backend: str | None = None,
    n_devices: int = 1,
) -> dict[str, float]:
    """Per-sweep model cost of every engine (``inf`` when infeasible).

    ``n_devices`` is the mesh size the sharded engines would run on: it
    divides the partitioned engine's compute and switches the communication
    terms on (single-device runs have no collective traffic).
    """
    backend = backend or jax.default_backend()
    v, m, e = _soi_stats(g, c)
    n = g.n_nodes
    n_words = (n + 31) // 32
    multi = n_devices > 1

    costs: dict[str, float] = {}
    # hard gate first: past the [n, n] budget no dense-layout engine can
    # even BUILD its operands (m > 0 — an operator-free SOI builds nothing)
    tier_ok = m == 0 or dense_tier_feasible(n)
    dense_bytes = m * n * n
    costs["dense"] = (
        float("inf")
        if not tier_ok or dense_bytes > DENSE_MAX_BYTES
        else v * n * n * m * C_DENSE
    )
    packed_bytes = m * n * n_words * 4
    c_packed = C_PACKED_INTERPRET if backend == "cpu" else C_PACKED
    costs["packed"] = (
        float("inf")
        if not tier_ok or packed_bytes > PACKED_MAX_BYTES
        else v * n * n_words * m * c_packed + m * PACKED_LAUNCH
    )
    c_fused = C_PACKED_FUSED_CPU if backend == "cpu" else C_PACKED_FUSED
    costs["packed_fused"] = (
        float("inf")
        if not tier_ok or packed_bytes > PACKED_MAX_BYTES
        else v * n * n_words * m * c_fused + m * PACKED_LAUNCH
    )
    sweep = segor_sweep_cost(v, n, m, e)
    # Gauss–Seidel re-gathers the packed chi per operator: M packed-chi
    # collectives (n/8 bytes each) per sweep
    sparse_comm = m * v * (n / 8.0) * C_COMM if multi else 0.0
    costs["sparse"] = sweep + sparse_comm
    # Jacobi: ONE n/8-byte packed broadcast serves all M operators per sweep,
    # at ~2x the sweep count
    bcast_comm = v * (n / 8.0) * C_COMM if multi else 0.0
    costs["jacobi_packed"] = JACOBI_SWEEP_FACTOR * (sweep + bcast_comm)
    costs["partitioned"] = (
        JACOBI_SWEEP_FACTOR * (sweep / n_devices + bcast_comm)
        if multi
        else float("inf")  # no mesh: pure overhead over jacobi_packed
    )
    return costs


def admission_estimate(g: Graph, q) -> float:
    """Admission-control price of a parsed query (DESIGN.md Sect. 10.2).

    The serving loop must price a request *before* compiling anything —
    admission is the cheap path — so this estimates the always-feasible
    sparse engine's solve cost from the query text alone plus the graph's
    label histogram: ``DEFAULT_SWEEPS * (V*E*C_SPARSE + V*n*M*C_APPLY)``
    with V = distinct variables, M = 2x distinct labels (each label may
    induce a forward and a backward operator in the SOI), and E the total
    edges under the query's labels.  Labels absent from the graph
    contribute no edges (such queries prune to empty almost immediately,
    which the low price reflects).  Deliberately an *envelope*, not the
    per-engine model: all the gate needs is a monotone handle on "how much
    worse than the median template is this request".
    """
    from repro.core import sparql

    def walk(node):
        if isinstance(node, sparql.BGP):
            return list(node.triples)
        return walk(node.left) + walk(node.right)

    triples = walk(q)
    v = len(sparql.vars_of(q))
    labels = {t.p for t in triples}
    m = 2 * len(labels)
    hist = g.label_histogram()
    label_index = g.label_index() if g.label_names is not None else {}
    e = sum(int(hist[label_index[name]])
            for name in labels if name in label_index)
    return DEFAULT_SWEEPS * (v * e * C_SPARSE + v * g.n_nodes * m * C_APPLY)


# resume-vs-cold model constants (DESIGN.md Sect. 8.3).  A cold rebuild
# pays SOI build + compile + operand upload + a fresh jit trace — the trace
# dominates by orders of magnitude on the serving path (the PR-1 cold/warm
# bench), which is why TRACE_COST towers over the per-sweep terms.
TRACE_COST = 5e7  # fresh jit trace + lowering of a plan's fixpoint
PATCH_COST_PER_EDGE = 16.0  # host-side rebuild of touched operators
RESUME_SWEEP_RATE = 50.0  # extra-sweep inflation per fractional delta
DEFAULT_SWEEPS = 8.0  # sweep prior when the plan never executed
RESUME_MAX_DELTA_FRACTION = 0.25  # past this, the old chi is mostly reseeded


@dataclasses.dataclass(frozen=True)
class ResumeDecision:
    """Outcome of the resume-vs-cold classification for one stale plan."""

    resume: bool
    est_resume: float  # model cost of patch + warm-started sweeps
    est_cold: float  # model cost of rebuild + cold sweeps
    reason: str


def resume_decision(
    g: Graph,
    c: CompiledSOI,
    *,
    engine: str,
    delta_edges: int,
    last_sweeps: int | None = None,
    backend: str | None = None,
    n_devices: int = 1,
) -> ResumeDecision:
    """Should a superseded (shape-stable) plan warm-resume or rebuild cold?

    Expected sweeps scale with the delta size: a warm start from the old
    fixpoint re-runs roughly ``1 + S_cold * min(1, rate * delta/E)`` sweeps
    (deletions propagate locally; insertions re-seed the destabilized
    closure), whereas a cold rebuild pays the full sweep count *plus* the
    trace.  Past :data:`RESUME_MAX_DELTA_FRACTION` of the edges changing,
    the old chi is mostly re-seeded anyway and the patch bookkeeping stops
    paying for itself — rebuild cold.  Either choice is correct (the
    resumed fixpoint is asserted identical); this is purely a latency call.
    """
    costs = estimate_costs(g, c, backend=backend, n_devices=n_devices)
    per_sweep = costs[engine]
    if per_sweep == float("inf"):
        # the plan exists and runs with this engine, whatever the model's
        # feasibility gate says (e.g. partitioned pinned on one device);
        # price its sweeps with the always-finite sparse estimate instead
        per_sweep = costs["sparse"]
    _, _, e = _soi_stats(g, c)
    frac = delta_edges / max(e, 1)
    s_cold = float(last_sweeps) if last_sweeps else DEFAULT_SWEEPS
    s_resume = 1.0 + s_cold * min(1.0, RESUME_SWEEP_RATE * frac)
    est_cold = TRACE_COST + s_cold * per_sweep
    est_resume = PATCH_COST_PER_EDGE * delta_edges + s_resume * per_sweep
    resume = frac <= RESUME_MAX_DELTA_FRACTION and est_resume < est_cold
    reason = (
        f"{'resume' if resume else 'cold'}: delta {delta_edges}/{e} edges "
        f"({frac:.2%}), est resume {est_resume:.3g} vs cold {est_cold:.3g} "
        f"({engine}, ~{s_cold:.0f} sweeps cold / {s_resume:.1f} resumed)"
    )
    return ResumeDecision(
        resume=resume, est_resume=est_resume, est_cold=est_cold, reason=reason
    )


def choose_engine(
    g: Graph,
    c: CompiledSOI,
    *,
    backend: str | None = None,
    n_devices: int = 1,
    allow: tuple[str, ...] = ENGINES,
) -> CostEstimate:
    """Pick the cheapest feasible engine for this (SOI, graph, mesh) triple."""
    costs = estimate_costs(g, c, backend=backend, n_devices=n_devices)
    feasible = {k: v for k, v in costs.items() if k in allow and v != float("inf")}
    if not feasible:  # sparse is always feasible unless excluded by `allow`
        raise ValueError(f"no feasible engine among {allow}")
    best = min(feasible, key=feasible.get)
    v, m, e = _soi_stats(g, c)
    reason = (
        f"{best}: cost {feasible[best]:.3g} over "
        f"{{V={v}, n={g.n_nodes}, M={m}, E={e}, W={n_devices}}} "
        f"(candidates: "
        + ", ".join(f"{k}={costs[k]:.3g}" for k in costs)
        + ")"
    )
    return CostEstimate(engine=best, costs=costs, reason=reason)
