"""Cost-based fixpoint-engine selection (DESIGN.md 5.3 / 7.2 / 13).

Replaces the hard-coded ``--engine`` flag: given the database statistics and
the compiled SOI, estimate the per-sweep work of each batched engine in
:mod:`repro.core.dualsim` and pick the cheapest *feasible* one.  All engines
compute the same greatest fixpoint, so the choice is purely a performance
decision — which is what makes a closed-form model safe: a wrong pick is
slow, never incorrect.

Per-sweep model (V = SOI variables, n = nodes, M = distinct
(label, direction) operators, E = total edges touched by the SOI's
operators, W = devices in the mesh):

* ``dense``  — M boolean matmuls: ``V * n * n * M`` elements at matmul
  efficiency ``c_dense`` (MXU/BLAS amortization).  Infeasible when the
  stacked ``bool[M, n, n]`` adjacency exceeds ``DENSE_MAX_BYTES``.
* ``packed`` — the Pallas bitmm path: 32 bits per word cuts element count by
  32x, but on the CPU backend the kernel runs in interpret mode, which the
  model charges via ``c_packed_interpret`` (packed is an accelerator
  engine); per operator it also pays a kernel-launch overhead.
* ``packed_fused`` — the end-to-end bit-packed engine (DESIGN.md Sect. 9):
  same word count as ``packed`` at a lower per-word cost (the
  unpack → gather → AND chain between product and update is fused away, so
  chi never inflates 8x in HBM), and on CPU it lowers to the word-wise XLA
  path instead of kernel emulation — far cheaper than interpreted
  ``packed`` though still behind ``sparse`` on most CPU-sized graphs.
* ``sparse`` / ``jacobi_packed`` — the segmented-OR sweep (ISSUE 8),
  priced from BYTES MOVED: per sweep the engine streams ``E * (8 + V)``
  bytes of edge ids + gathered frontier messages, and ``3 * M * V * n/8``
  bytes of packed ``y`` words through the per-variable AND (write + read +
  chi fold), plus M per-operator dispatch overheads.  Always feasible on
  one device.  Under Gauss–Seidel every operator re-gathers the
  freshly-updated packed chi, so on a mesh it pays M packed-chi collectives
  (``M * V * n/8`` bytes) per sweep; ``jacobi_packed`` reads ONE bit-packed
  broadcast per sweep but pays a ~2x sweep-count inflation (Jacobi vs
  Gauss–Seidel, measured in ``configs/dualsim_base.py``).
* ``partitioned`` — jacobi_packed with destination-partitioned edge blocks:
  compute divides across the W shards, cross-shard traffic stays the one
  packed broadcast.  Needs a mesh (infeasible at W = 1, where it only adds
  block-padding overhead over jacobi_packed).

Communication terms enter only when ``n_devices > 1`` — on a single device
there is no collective traffic and the model must reduce to the PR-1
single-shard model exactly.

**Units and calibration (ISSUE 9).**  Every constant lives in a
:class:`CostModel`.  :data:`HAND_TUNED` carries the original folklore
constants in arbitrary units — one developer machine baked into numbers —
and remains the documented fallback.  When a measured
:class:`~repro.engine.machine.MachineSpec` is available (passed explicitly,
or discovered via :func:`repro.engine.machine.default_spec`),
:meth:`CostModel.from_spec` derives every constant from the machine's
probed ceilings instead, and the model's unit becomes *seconds*: each
engine's formula is its bytes-moved/ops count divided by the measured
throughput, plus measured per-call overheads.  No engine-selection path
reads a hand-tuned constant once a spec is present.

Feasibility is a HARD gate, not a preference: any engine whose *build*
path materializes an ``[n, n]`` plane — dense itself, and the packed tier,
whose ``graph.packed_adjacency`` packs through a transient dense build —
is refused outright once ``n * n`` exceeds the byte budget
(``graph.DENSE_ADJ_MAX_BYTES``).  The gate depends only on graph shape,
never on calibration: no spec, however distorted, can un-refuse an engine
that cannot build its operands.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.graph import DENSE_ADJ_MAX_BYTES, Graph
from repro.core.soi import CompiledSOI

from . import machine as machine_mod
from .machine import MachineSpec

ENGINES = (
    "dense", "packed", "packed_fused", "sparse", "jacobi_packed",
    "partitioned",
)

# hand-tuned model constants (relative cost per element, arbitrary units) —
# the documented fallback when no MachineSpec exists.  Kept as module-level
# names because DESIGN.md and the seed benches reference them; every model
# consumer goes through a CostModel instead of reading these directly.
C_DENSE = 1.0 / 8.0  # matmul elements amortize on MXU/BLAS
C_PACKED = 2.0  # per uint32 word, compiled Pallas
C_PACKED_INTERPRET = 256.0  # per word under interpret mode (CPU backend)
C_PACKED_FUSED = 1.0  # per word, fused kernel: no unpack/gather chain
C_PACKED_FUSED_CPU = 24.0  # per word, word-wise XLA lowering (no kernel)
PACKED_LAUNCH = 65536.0  # per-operator kernel launch overhead
C_SPARSE = 4.0  # per edge message (admission envelope only, see below)
C_APPLY = 0.5  # per chi element per operator (admission envelope only)
C_SEGOR_BYTE = 1.0  # per byte moved through the segmented-OR sweep
C_COMM = 8.0  # per byte of cross-shard collective traffic
JACOBI_SWEEP_FACTOR = 2.0  # Jacobi needs ~2x the sweeps of Gauss–Seidel
DENSE_MAX_BYTES = 2 << 30  # stacked bool[M, n, n] adjacency budget
PACKED_MAX_BYTES = 2 << 30
# any single [n, n] plane past this cannot be BUILT (graph.dense_adjacency
# raises MemoryError) — shared with the data layer so the model's hard gate
# and the constructor's guard can never disagree
DENSE_TIER_MAX_BYTES = DENSE_ADJ_MAX_BYTES

# resume-vs-cold model constants (DESIGN.md Sect. 8.3).  A cold rebuild
# pays SOI build + compile + operand upload + a fresh jit trace — the trace
# dominates by orders of magnitude on the serving path (the PR-1 cold/warm
# bench), which is why TRACE_COST towers over the per-sweep terms.
TRACE_COST = 5e7  # fresh jit trace + lowering of a plan's fixpoint
PATCH_COST_PER_EDGE = 16.0  # host-side rebuild of touched operators
RESUME_SWEEP_RATE = 50.0  # extra-sweep inflation per fractional delta
DEFAULT_SWEEPS = 8.0  # sweep prior when the plan never executed
RESUME_MAX_DELTA_FRACTION = 0.25  # past this, the old chi is mostly reseeded


def dense_tier_feasible(n: int) -> bool:
    """Whether any ``[n, n]`` operand plane may be materialized at all.

    Gates dense AND both packed engines: ``graph.packed_adjacency`` packs
    through a transient dense ``[n, n]`` build, so the packed tier is just
    as impossible past the budget even though its *resident* operand is 32x
    smaller.
    """
    return n * n <= DENSE_TIER_MAX_BYTES


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Every constant the engine/resume/admission models read, as one unit.

    Two provenances: :data:`HAND_TUNED` (arbitrary units, the seed's
    folklore constants) and :meth:`from_spec` (seconds, derived from a
    probed :class:`~repro.engine.machine.MachineSpec`).  The formulas in
    :func:`estimate_costs` etc. are provenance-agnostic — only the
    constants change — so the calibrated model reduces to the hand-tuned
    one structurally (same terms, same single-device reduction).
    """

    c_dense: float  # per dense boolean-matmul element
    c_packed: float  # per uint32 word, compiled kernel path
    c_packed_interpret: float  # per word, interpret-mode kernel (CPU)
    c_packed_fused: float  # per word, fused kernel path
    c_packed_fused_cpu: float  # per word, word-wise XLA lowering
    packed_launch: float  # per-operator launch overhead, packed engine
    fused_launch: float  # per-operator launch overhead, fused engine
    c_sparse: float  # per edge message (admission envelope)
    c_apply: float  # per chi element per operator (admission envelope)
    c_segor_byte: float  # per byte through the segmented-OR sweep
    c_comm: float  # per byte of cross-shard collective traffic
    c_dispatch: float  # per-operator per-sweep fixed overhead (XLA dispatch)
    trace_cost: float  # fresh jit trace + lowering of a plan's fixpoint
    patch_cost_per_edge: float  # host-side rebuild of touched operators
    source: str  # "hand-tuned" or the spec fingerprint
    unit: str  # "arb" (hand-tuned) or "s" (calibrated)

    @classmethod
    def from_spec(cls, spec: MachineSpec) -> "CostModel":
        """Derive every constant from a machine's probed ceilings (seconds).

        Derivations (DESIGN.md Sect. 13.2):

        * ``c_segor_byte = 1 / stream_bytes_per_s`` — the segmented-OR
          sweep is a pure streaming workload; its byte count divided by
          sustained bandwidth is its time.
        * ``c_dense = 1 / dense_elems_per_s`` — measured boolean-matmul
          element throughput (f32 MXU/BLAS path, as the engine runs it).
        * packed/fused per-word costs are reciprocals of the measured
          ``bitmm_apply`` word throughputs.  The probe measures the
          *shipping* lowering for the spec's backend (interpret-mode kernel
          on CPU, compiled kernel on accelerators) plus the word-wise XLA
          lowering; the constant for the lowering the spec's backend does
          not ship falls back to the XLA measurement — the closest probed
          proxy — and is only read under a backend/spec mismatch.
        * launches: the packed engine pays the measured kernel-path
          overhead per operator; the fused engine pays the same on
          accelerators but only an XLA dispatch on CPU (its words lowering
          launches no kernel).
        * ``c_sparse = 12 / stream`` (two int32 ids + a gathered message
          word share per edge) and ``c_apply = 0.375 / stream`` (three
          packed-plane passes = 3/8 byte per chi element per operator) keep
          the admission envelope's shape while pricing it in seconds;
          ``c_dispatch`` adds the measured per-op overhead the hand-tuned
          envelope ignored (zero there), which is what dominates
          millisecond-scale serving solves.
        * ``c_comm`` is the probed collective reciprocal; below 2 devices
          collectives are unprobed and fall back to ``4 / stream``
          (collectives move bytes a small factor slower than local streams).
        * ``trace_cost`` is the measured trace+compile of a representative
          packed fixpoint; ``patch_cost_per_edge = 64 / stream`` is the
          host-side operand-rebuild envelope (~64 bytes touched per edge).
        """
        stream = spec.stream_bytes_per_s
        cpu = spec.backend == "cpu"
        shipping = 1.0 / spec.packed_words_per_s
        xla = 1.0 / spec.packed_words_per_s_xla
        fused = 1.0 / spec.fused_words_per_s
        return cls(
            c_dense=1.0 / spec.dense_elems_per_s,
            c_packed=xla if cpu else shipping,
            c_packed_interpret=shipping if cpu else xla,
            c_packed_fused=fused,
            c_packed_fused_cpu=fused if cpu else xla,
            packed_launch=spec.kernel_launch_s,
            fused_launch=spec.dispatch_s if cpu else spec.kernel_launch_s,
            c_sparse=12.0 / stream,
            c_apply=0.375 / stream,
            c_segor_byte=1.0 / stream,
            c_comm=(
                1.0 / spec.collective_bytes_per_s
                if spec.collective_bytes_per_s
                else 4.0 / stream
            ),
            c_dispatch=spec.dispatch_s,
            trace_cost=spec.trace_s,
            patch_cost_per_edge=64.0 / stream,
            source=spec.fingerprint,
            unit="s",
        )


HAND_TUNED = CostModel(
    c_dense=C_DENSE,
    c_packed=C_PACKED,
    c_packed_interpret=C_PACKED_INTERPRET,
    c_packed_fused=C_PACKED_FUSED,
    c_packed_fused_cpu=C_PACKED_FUSED_CPU,
    packed_launch=PACKED_LAUNCH,
    fused_launch=PACKED_LAUNCH,
    c_sparse=C_SPARSE,
    c_apply=C_APPLY,
    c_segor_byte=C_SEGOR_BYTE,
    c_comm=C_COMM,
    c_dispatch=0.0,  # the arb-unit envelope never priced per-op overhead
    trace_cost=TRACE_COST,
    patch_cost_per_edge=PATCH_COST_PER_EDGE,
    source="hand-tuned",
    unit="arb",
)


def resolve_model(
    spec: MachineSpec | None = None,
    model: CostModel | None = None,
    backend: str | None = None,
) -> CostModel:
    """The model a cost query should price with.

    Precedence: an explicit ``model``; an explicit ``spec``; the machine's
    persisted spec (:func:`repro.engine.machine.default_spec`, governed by
    ``REPRO_MACHINE_SPEC``); the hand-tuned fallback.  This is THE spot the
    acceptance gate cares about: with a spec present, every constant the
    selection reads is spec-derived.
    """
    if model is not None:
        return model
    if spec is None:
        spec = machine_mod.default_spec(backend)
    return CostModel.from_spec(spec) if spec is not None else HAND_TUNED


def segor_sweep_cost(
    v: int, n: int, m: int, e: int, model: CostModel = HAND_TUNED
) -> float:
    """Bytes-moved model of one segmented-OR Gauss–Seidel sweep.

    ``E * (8 + V)`` bytes of edge ids (src + dst int32) and int8 frontier
    messages, plus ``3 * M * V * n/8`` bytes of packed ``y`` words (written
    by the segmented OR, read by the per-variable AND, folded into chi),
    plus M per-operator dispatch overheads (zero in the hand-tuned model).
    """
    return (
        model.c_segor_byte * (e * (8.0 + v) + 3.0 * m * v * (n / 8.0))
        + m * model.c_dispatch
    )


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Chosen engine plus the full per-engine cost breakdown."""

    engine: str
    costs: dict[str, float]  # per-sweep model cost; float('inf') = infeasible
    reason: str


def _soi_stats(g: Graph, c: CompiledSOI) -> tuple[int, int, int]:
    """(V, M, E_total) for a compiled SOI against ``g``."""
    hist = g.label_histogram()
    e_total = int(sum(hist[la] for la, _ in c.mats))
    return c.n_vars, len(c.mats), e_total


def estimate_costs(
    g: Graph,
    c: CompiledSOI,
    *,
    backend: str | None = None,
    n_devices: int = 1,
    spec: MachineSpec | None = None,
    model: CostModel | None = None,
) -> dict[str, float]:
    """Per-sweep model cost of every engine (``inf`` when infeasible).

    ``n_devices`` is the mesh size the sharded engines would run on: it
    divides the partitioned engine's compute and switches the communication
    terms on (single-device runs have no collective traffic).  ``spec`` /
    ``model`` select the calibration (see :func:`resolve_model`); without
    either, the machine's persisted spec applies, then the hand-tuned
    fallback.
    """
    backend = backend or jax.default_backend()
    mdl = resolve_model(spec, model, backend)
    v, m, e = _soi_stats(g, c)
    n = g.n_nodes
    n_words = (n + 31) // 32
    multi = n_devices > 1

    costs: dict[str, float] = {}
    # hard gate first: past the [n, n] budget no dense-layout engine can
    # even BUILD its operands (m > 0 — an operator-free SOI builds nothing)
    tier_ok = m == 0 or dense_tier_feasible(n)
    dense_bytes = m * n * n
    costs["dense"] = (
        float("inf")
        if not tier_ok or dense_bytes > DENSE_MAX_BYTES
        else v * n * n * m * mdl.c_dense
    )
    packed_bytes = m * n * n_words * 4
    c_packed = mdl.c_packed_interpret if backend == "cpu" else mdl.c_packed
    costs["packed"] = (
        float("inf")
        if not tier_ok or packed_bytes > PACKED_MAX_BYTES
        else v * n * n_words * m * c_packed + m * mdl.packed_launch
    )
    c_fused = mdl.c_packed_fused_cpu if backend == "cpu" else mdl.c_packed_fused
    costs["packed_fused"] = (
        float("inf")
        if not tier_ok or packed_bytes > PACKED_MAX_BYTES
        else v * n * n_words * m * c_fused + m * mdl.fused_launch
    )
    sweep = segor_sweep_cost(v, n, m, e, mdl)
    # Gauss–Seidel re-gathers the packed chi per operator: M packed-chi
    # collectives (n/8 bytes each) per sweep
    sparse_comm = m * v * (n / 8.0) * mdl.c_comm if multi else 0.0
    costs["sparse"] = sweep + sparse_comm
    # Jacobi: ONE n/8-byte packed broadcast serves all M operators per sweep,
    # at ~2x the sweep count
    bcast_comm = v * (n / 8.0) * mdl.c_comm if multi else 0.0
    costs["jacobi_packed"] = JACOBI_SWEEP_FACTOR * (sweep + bcast_comm)
    costs["partitioned"] = (
        JACOBI_SWEEP_FACTOR * (sweep / n_devices + bcast_comm)
        if multi
        else float("inf")  # no mesh: pure overhead over jacobi_packed
    )
    return costs


def admission_estimate(
    g: Graph,
    q,
    *,
    spec: MachineSpec | None = None,
    model: CostModel | None = None,
) -> float:
    """Admission-control price of a parsed query (DESIGN.md Sect. 10.2).

    The serving loop must price a request *before* compiling anything —
    admission is the cheap path — so this estimates the always-feasible
    sparse engine's solve cost from the query text alone plus the graph's
    label histogram: ``DEFAULT_SWEEPS * (M*c_dispatch + V*E*c_sparse +
    V*n*M*c_apply)`` with V = distinct variables, M = 2x distinct labels
    (each label may induce a forward and a backward operator in the SOI),
    and E the total edges under the query's labels.  Labels absent from the
    graph contribute no edges (such queries prune to empty almost
    immediately, which the low price reflects).  Deliberately an
    *envelope*, not the per-engine model: all the gate needs is a monotone
    handle on "how much worse than the median template is this request".
    With a :class:`~repro.engine.machine.MachineSpec` the envelope is
    priced in seconds — per-op dispatch plus streamed bytes over measured
    bandwidth — and ``tests/test_serve.py`` asserts it stays within a
    bounded ratio of the measured per-batch solve time.
    """
    from repro.core import sparql

    mdl = resolve_model(spec, model)

    def walk(node):
        if isinstance(node, sparql.BGP):
            return list(node.triples)
        return walk(node.left) + walk(node.right)

    triples = walk(q)
    v = len(sparql.vars_of(q))
    labels = {t.p for t in triples}
    m = 2 * len(labels)
    hist = g.label_histogram()
    label_index = g.label_index() if g.label_names is not None else {}
    e = sum(int(hist[label_index[name]])
            for name in labels if name in label_index)
    return DEFAULT_SWEEPS * (
        m * mdl.c_dispatch
        + v * e * mdl.c_sparse
        + v * g.n_nodes * m * mdl.c_apply
    )


@dataclasses.dataclass(frozen=True)
class ResumeDecision:
    """Outcome of the resume-vs-cold classification for one stale plan."""

    resume: bool
    est_resume: float  # model cost of patch + warm-started sweeps
    est_cold: float  # model cost of rebuild + cold sweeps
    reason: str


def resume_decision(
    g: Graph,
    c: CompiledSOI,
    *,
    engine: str,
    delta_edges: int,
    last_sweeps: int | None = None,
    backend: str | None = None,
    n_devices: int = 1,
    spec: MachineSpec | None = None,
    model: CostModel | None = None,
) -> ResumeDecision:
    """Should a superseded (shape-stable) plan warm-resume or rebuild cold?

    Expected sweeps scale with the delta size: a warm start from the old
    fixpoint re-runs roughly ``1 + S_cold * min(1, rate * delta/E)`` sweeps
    (deletions propagate locally; insertions re-seed the destabilized
    closure), whereas a cold rebuild pays the full sweep count *plus* the
    trace.  Past :data:`RESUME_MAX_DELTA_FRACTION` of the edges changing,
    the old chi is mostly re-seeded anyway and the patch bookkeeping stops
    paying for itself — rebuild cold.  Either choice is correct (the
    resumed fixpoint is asserted identical); this is purely a latency call.
    """
    mdl = resolve_model(spec, model, backend)
    costs = estimate_costs(
        g, c, backend=backend, n_devices=n_devices, model=mdl
    )
    per_sweep = costs[engine]
    if per_sweep == float("inf"):
        # the plan exists and runs with this engine, whatever the model's
        # feasibility gate says (e.g. partitioned pinned on one device);
        # price its sweeps with the always-finite sparse estimate instead
        per_sweep = costs["sparse"]
    _, _, e = _soi_stats(g, c)
    frac = delta_edges / max(e, 1)
    s_cold = float(last_sweeps) if last_sweeps else DEFAULT_SWEEPS
    s_resume = 1.0 + s_cold * min(1.0, RESUME_SWEEP_RATE * frac)
    est_cold = mdl.trace_cost + s_cold * per_sweep
    est_resume = mdl.patch_cost_per_edge * delta_edges + s_resume * per_sweep
    resume = frac <= RESUME_MAX_DELTA_FRACTION and est_resume < est_cold
    reason = (
        f"{'resume' if resume else 'cold'}: delta {delta_edges}/{e} edges "
        f"({frac:.2%}), est resume {est_resume:.3g} vs cold {est_cold:.3g} "
        f"({engine}, ~{s_cold:.0f} sweeps cold / {s_resume:.1f} resumed)"
    )
    return ResumeDecision(
        resume=resume, est_resume=est_resume, est_cold=est_cold, reason=reason
    )


def choose_engine(
    g: Graph,
    c: CompiledSOI,
    *,
    backend: str | None = None,
    n_devices: int = 1,
    allow: tuple[str, ...] = ENGINES,
    spec: MachineSpec | None = None,
    model: CostModel | None = None,
) -> CostEstimate:
    """Pick the cheapest feasible engine for this (SOI, graph, mesh) triple."""
    mdl = resolve_model(spec, model, backend)
    costs = estimate_costs(
        g, c, backend=backend, n_devices=n_devices, model=mdl
    )
    feasible = {k: v for k, v in costs.items() if k in allow and v != float("inf")}
    if not feasible:  # sparse is always feasible unless excluded by `allow`
        raise ValueError(f"no feasible engine among {allow}")
    best = min(feasible, key=feasible.get)
    v, m, e = _soi_stats(g, c)
    reason = (
        f"{best}: cost {feasible[best]:.3g}{mdl.unit} over "
        f"{{V={v}, n={g.n_nodes}, M={m}, E={e}, W={n_devices}}} "
        f"[{mdl.source}] (candidates: "
        + ", ".join(f"{k}={costs[k]:.3g}" for k in costs)
        + ")"
    )
    return CostEstimate(engine=best, costs=costs, reason=reason)
