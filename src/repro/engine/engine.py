"""The query-engine facade (DESIGN.md 5.5).

``Engine(db)`` owns everything a serving process needs: the parsed-query →
template canonicalizer, the LRU plan cache keyed by (template, graph
fingerprint, batch bucket), the cost model that picks a fixpoint engine per
plan, and the microbatcher that groups same-template requests into one
disjoint-union solve.  ``execute`` handles one request end-to-end (UNION
queries run one plan per union-free part and union the results);
``execute_many`` batches a request list through the microbatcher.

Results carry the survivor triple mask (Sect. 5 pruning), per-variable
candidate bindings under the query's own variable names, per-stage timings,
and the cache/batch provenance — enough for a caller to assert the warm
path did no recompilation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Sequence

import numpy as np

from repro.core import pruning, soi as soi_mod, sparql
from repro.core.graph import Graph
from repro.core.sparql import Query

from . import cost as cost_mod, machine as machine_mod
from .batcher import DEFAULT_BUCKETS, MicroBatcher, bucket_for
from .cache import BoundedDict, CacheStats, PlanCache
from .plan import CompiledPlan
from .template import TemplateInstance, canonicalize


@dataclasses.dataclass
class ExecResult:
    """Outcome of one request."""

    survivors: np.ndarray  # bool mask over db.triples (Sect. 5 pruning)
    stats: pruning.PruneStats
    bindings: dict[str, np.ndarray]  # query var -> candidate node mask
    sweeps: int
    engine: str  # fixpoint engine(s) used
    template_keys: tuple[str, ...]
    cache_hit: bool  # every plan this request needed was cached
    batch: int  # microbatch bucket the request rode in
    timings: dict[str, float]  # per-stage seconds


@dataclasses.dataclass
class EngineMetrics:
    """Cumulative serving counters, split by the invalidation taxonomy.

    On a mutation, a superseded plan is either *cold-invalidated*
    (``cache.invalidations``: dictionary/shape change, or no delta log —
    full rebuild on next use) or *reclassified resumable*
    (``plans_resumable``: staged with its delta; on next use it is patched
    in place and warm-started — ``plans_resumed``).  ``resumes_declined``
    counts staged plans that went cold after all: the cost model judged
    the delta too large, a later dictionary-changing mutation discarded
    the staging area, or the bounded staging evicted them — so
    ``plans_resumable == plans_resumed + resumes_declined + |staged|``.  ``warm_resume_solves`` counts solves
    that actually started from a previous fixpoint, and
    ``adj_rebuilds_saved`` counts adjacency uploads avoided because the
    delta touched none of an entry's labels (DESIGN.md Sect. 8).
    """

    requests: int
    microbatches: int  # == fixpoint solves: one disjoint-union solve each
    engine_counts: dict[str, int]
    cache: CacheStats
    stage_seconds: dict[str, float]
    invalidation_events: int = 0  # refreshes that adopted a mutated snapshot
    adj_invalidations: int = 0  # adjacency entries dropped on those refreshes
    plans_resumable: int = 0  # stale plans reclassified resumable (staged)
    plans_resumed: int = 0  # staged plans actually patched + reused
    resumes_declined: int = 0  # staged plans the cost model sent cold
    warm_resume_solves: int = 0  # fixpoint solves warm-started from old chi
    adj_rebuilds_saved: int = 0  # adjacency kept because its labels were untouched

    @property
    def plan_builds(self) -> int:
        """Plans built from scratch (cache misses minus in-place resumes)."""
        return self.cache.misses - self.plans_resumed

    @property
    def plan_invalidations(self) -> int:
        """Cold invalidations: superseded plans dropped outright."""
        return self.cache.invalidations


def graph_fingerprint(g: Graph) -> str:
    """Content hash binding cached plans to one database state.

    The name dictionaries are part of the state: two snapshots with
    identical int arrays but different ``node_names``/``label_names``
    encodings are *different* databases (constants resolve to different
    ids), so they must not share plans.
    """
    h = hashlib.blake2b(digest_size=12)
    h.update(np.ascontiguousarray(g.triples).tobytes())
    h.update(f"{g.n_nodes}/{g.n_labels}".encode())
    for names in (g.node_names, g.label_names):
        # length-prefix each list so the node/label boundary is unambiguous
        # (['a','bc']/['d'] must not collide with ['a','b']/['cd'])
        if names is None:
            h.update(b"\x00")
        else:
            h.update(f"{len(names)}\x1e".encode())
            h.update("\x1f".join(names).encode())
            h.update(b"\x1e")
    return h.hexdigest()


class Engine:
    """Facade over template → plan-cache → microbatch → fixpoint → prune."""

    def __init__(
        self,
        db,
        *,
        engine: str = "auto",
        cache_capacity: int = 64,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        backend: str | None = None,
        mesh=None,
        n_blocks: int | None = None,
        incremental: bool = True,
        spec: machine_mod.MachineSpec | None = None,
    ):
        """Build the facade over ``db`` (a Graph or a mutable GraphDB source).

        ``incremental`` enables warm-resume maintenance of superseded plans
        across shape-stable mutations (DESIGN.md Sect. 8); with it off,
        every mutation invalidates cold, as before.

        ``spec`` pins the machine calibration every cost decision (engine
        auto-selection, resume-vs-cold, serving admission) prices with;
        ``None`` resolves the machine's persisted spec via
        :func:`repro.engine.machine.default_spec` (hand-tuned fallback when
        absent or disabled — DESIGN.md Sect. 13).
        """
        # ``db`` is either an immutable core Graph or a mutable source with
        # (graph, version, fingerprint, node_index) — i.e. repro.db.GraphDB.
        # Duck-typed so this module never imports the layer above it.
        self._source = db if hasattr(db, "graph") and hasattr(db, "version") else None
        self.db: Graph = self._source.graph if self._source is not None else db
        self.engine_pref = engine
        self.buckets = tuple(sorted(buckets))
        self.backend = backend
        # resolved once so introspection (`eng.spec`) shows the calibration
        # actually in force; None means the hand-tuned fallback model
        self.spec = (
            spec if spec is not None else machine_mod.default_spec(backend)
        )
        # mesh: a jax.sharding.Mesh (see repro.distributed.ctx.node_mesh).
        # Plans shard chi's node axis across it and the cost model sees its
        # size, so engine="auto" can pick "partitioned" once the graph
        # outgrows single-shard budgets.  n_blocks defaults to the mesh size
        # (one destination block per device).
        self.mesh = mesh
        self.n_devices = int(mesh.devices.size) if mesh is not None else 1
        # without a mesh the partitioned engine still runs (single-device,
        # block-structured); 4 blocks keeps the layout non-degenerate
        self.n_blocks = (
            n_blocks
            if n_blocks is not None
            else (self.n_devices if mesh is not None else 4)
        )
        # a mesh-shape token in the plan key: an Engine's mesh is fixed, but
        # cache keys must stay unambiguous if a cache is ever shared/dumped
        self._mesh_key = (
            (tuple(mesh.axis_names), tuple(mesh.devices.shape))
            if mesh is not None
            else None
        )
        self.cache = PlanCache(cache_capacity)
        # (engine, mats) -> device adjacency, shared across plans; bounded so
        # a churning template mix cannot pin unbounded device memory
        self._adj_cache = BoundedDict(capacity=16)
        if self._source is not None:
            self.fingerprint = self._source.fingerprint
            self._version = self._source.version
            self._node_index = self._source.node_index
        else:
            self.fingerprint = graph_fingerprint(self.db)
            self._version = None
            self._node_index = (
                self.db.node_index() if self.db.node_names is not None else {}
            )
        self._prev_db: Graph = self.db  # adjacency retention window
        self.incremental = incremental
        # superseded-but-resumable plans: (template key, bucket, engine,
        # n_blocks, mesh) -> (plan, composed delta from its snapshot to now)
        self._resumable: dict = {}
        # one lock for every serving counter below: updates that belong to
        # one event (a microbatch's count + its engine tally) commit
        # atomically, and stats() copies under the same lock, so a reader
        # thread can never observe a torn snapshot (DESIGN.md 10.5)
        self._stats_lock = threading.Lock()
        self._requests = 0  # guarded-by: _stats_lock
        self._microbatches = 0  # guarded-by: _stats_lock
        self._invalidation_events = 0  # guarded-by: _stats_lock
        self._adj_invalidations = 0  # guarded-by: _stats_lock
        self._plans_resumable = 0  # guarded-by: _stats_lock
        self._plans_resumed = 0  # guarded-by: _stats_lock
        self._resumes_declined = 0  # guarded-by: _stats_lock
        self._warm_solves = 0  # guarded-by: _stats_lock
        self._adj_rebuilds_saved = 0  # guarded-by: _stats_lock
        self._engine_counts: dict[str, int] = {}  # guarded-by: _stats_lock
        self._stage_seconds: dict[str, float] = {}  # guarded-by: _stats_lock
        # fault-injection hook (repro.faults.BoundFaults); None disarms the
        # site at the cost of one attribute read per prepared batch
        self.faults = None

    # ------------------------------------------------------------------ #
    # versioned invalidation (repro.db.GraphDB mutations)
    # ------------------------------------------------------------------ #
    def refresh(self) -> int:
        """Adopt the source database's current snapshot if it has mutated.

        Called on every execute/plan access; a no-op unless the source's
        monotone version counter moved.  Invalidation is *precise*, not a
        flush, and since ISSUE 4 it is also *classified* (DESIGN.md 8.3):

        * **resumable** — the source's delta log covers the gap and the
          delta is shape-stable (no new nodes/labels).  Plans keyed at the
          superseded fingerprint are moved into a staging area together
          with the delta; on next use they are patched in place and their
          last fixpoint warm-starts the solve.  Plans staged by an earlier
          refresh compose their delta forward.  Adjacency entries whose
          operator labels the delta does not touch are bit-identical in the
          new snapshot, so they are re-keyed instead of rebuilt (counted in
          ``adj_rebuilds_saved``).
        * **cold** — dictionary/shape change, or no usable delta.  Plans
          keyed outside the {current, previous} fingerprint window are
          dropped and counted in ``cache.invalidations`` (the previous
          window survives so results in flight keep their plans); staged
          resumables are discarded; adjacency from graphs outside the
          window is dropped (it can never hit again — the adjacency cache
          matches on graph identity).

        Returns the number of plans cold-invalidated by this call.
        """
        if self._source is None or self._source.version == self._version:
            return 0
        prev_fp, prev_db, prev_version = self.fingerprint, self.db, self._version
        version = self._source.version
        self.db = self._source.graph
        self.fingerprint = self._source.fingerprint
        self._node_index = self._source.node_index
        delta = None
        if self.incremental:
            delta_since = getattr(self._source, "delta_since", None)
            if delta_since is not None:
                delta = delta_since(prev_version)
        if self._source.version != version:
            # the source mutated between reading the snapshot and the delta
            # (an unlocked direct Engine): the pair may be torn, so fall
            # back to cold — patching with a mismatched delta could mix two
            # graph versions inside one plan's operands.  self._version
            # stays at the first read, so the next refresh re-adopts.
            delta = None
        self._version = version
        resumable = delta is not None and delta.shape_stable

        staged = declined = adj_saved = adj_dropped = 0
        if resumable:
            # earlier-staged plans ride forward under the composed delta
            self._resumable = {
                k: (plan, d.compose(delta))
                for k, (plan, d) in self._resumable.items()
            }
            moved = self.cache.pop_matching(lambda key: key[1] == prev_fp)
            for key, plan in moved:
                self._resumable[(key[0], *key[2:])] = (plan, delta)
            staged = len(moved)
            # bounded staging: never pin more superseded plans (device
            # operands + chi memos) than the live cache could hold — the
            # oldest staged entries go cold, counted as declined resumes
            while len(self._resumable) > self.cache.capacity:
                self._resumable.pop(next(iter(self._resumable)))
                declined += 1
        else:
            # staged plans cannot survive a dictionary/shape change (or a
            # truncated delta log): they go cold, counted as declined
            declined = len(self._resumable)
            self._resumable.clear()

        keep_fp = {self.fingerprint, prev_fp}
        dropped = self.cache.invalidate(lambda key: key[1] not in keep_fp)
        touched = delta.touched_labels() if resumable else None
        for k, (g_stored, adj) in list(self._adj_cache.items()):
            if g_stored is self.db:
                continue
            if g_stored is prev_db:
                if resumable and not ({la for la, _ in k[1]} & touched):
                    # untouched labels: the arrays are bit-identical in the
                    # new snapshot — re-key instead of rebuilding later
                    self._adj_cache[k] = (self.db, adj)
                    adj_saved += 1
                continue  # retention window: in-flight plans share these
            del self._adj_cache[k]
            adj_dropped += 1
        self._prev_db = prev_db
        # RL3: the whole refresh commits as one atomic stats event — a
        # stats() reader on another thread sees all of it or none of it
        with self._stats_lock:
            self._plans_resumable += staged
            self._resumes_declined += declined
            self._adj_rebuilds_saved += adj_saved
            self._adj_invalidations += adj_dropped
            self._invalidation_events += 1
        return dropped

    # ------------------------------------------------------------------ #
    # plan access
    # ------------------------------------------------------------------ #
    def plan_for(
        self, instance_or_template, bucket: int = 1, *, _refresh: bool = True
    ) -> tuple[CompiledPlan, bool]:
        """Fetch (or build) the plan for a template at one batch bucket.

        Returns ``(plan, cache_hit)``.  ``_refresh=False`` is the internal
        mid-batch path: the snapshot was already pinned at the batch
        boundary and must not move under in-flight requests.
        """
        if _refresh:
            self.refresh()
        template = (
            instance_or_template.template
            if isinstance(instance_or_template, TemplateInstance)
            else instance_or_template
        )
        key = (
            template.key, self.fingerprint, bucket, self.engine_pref,
            self.n_blocks, self._mesh_key,
        )
        hit = key in self.cache
        plan = self.cache.get_or_build(
            key, lambda: self._build_or_resume(template, bucket, key)
        )
        return plan, hit

    def _build_or_resume(self, template, bucket: int, key) -> CompiledPlan:
        """Miss path: patch a staged resumable plan, or build from scratch.

        A staged plan resumes when the cost model expects the patch + warm
        sweeps to undercut a rebuild (:func:`repro.engine.cost.
        resume_decision`); either way the outcome is re-keyed under the
        current fingerprint by the caller's ``get_or_build``.
        """
        staged = self._resumable.pop((key[0], *key[2:]), None)
        if staged is not None:
            plan, delta = staged
            decision = cost_mod.resume_decision(
                self.db,
                plan.csoi,
                engine=plan.engine,
                delta_edges=delta.n_changes,
                last_sweeps=plan.last_sweeps,
                backend=self.backend,
                n_devices=self.n_devices,
                spec=self.spec,
            )
            if decision.resume:
                try:
                    plan.patch_graph(
                        self.db, delta, self._node_index, self._adj_cache
                    )
                except ValueError:
                    with self._stats_lock:
                        self._resumes_declined += 1  # not actually patchable
                else:
                    with self._stats_lock:
                        self._plans_resumed += 1
                    return plan
            else:
                with self._stats_lock:
                    self._resumes_declined += 1
        return CompiledPlan(
            template,
            self.db,
            engine=self.engine_pref,
            batch=bucket,
            node_index=self._node_index,
            backend=self.backend,
            adj_cache=self._adj_cache,
            mesh=self.mesh,
            n_blocks=self.n_blocks,
            spec=self.spec,
            # chi memoization only pays off when the graph can mutate: a
            # plan over a plain immutable Graph never stages warm starts
            incremental=self.incremental and self._source is not None,
        )

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def execute(self, query: str | Query) -> ExecResult:
        """Run one query end-to-end (parse → plans → solve → prune)."""
        self.refresh()
        return self._execute_pinned(query)

    def _execute_pinned(self, query: str | Query) -> ExecResult:
        """``execute`` against the already-adopted snapshot (no refresh):
        the mid-batch path of :meth:`execute_prepared`, where every request
        of one call must see one graph version even if the source mutates
        concurrently."""
        t0 = time.perf_counter()
        q, t_parse = self._parse(query)
        parts = sparql.union_split(q)
        partials = []
        for part in parts:
            inst = canonicalize(part)
            partials.append(self._solve_microbatch([(0, inst)])[0][1])
        res = _merge_union(partials, self.db)
        res.timings["parse"] = t_parse
        res.timings["total"] = time.perf_counter() - t0
        res.timings["batch_total"] = res.timings["total"]  # batch of one
        with self._stats_lock:
            self._requests += 1
        self._bump_stage("parse", t_parse)
        return res

    def prepare(self, query: str | Query) -> tuple[Query, TemplateInstance | None]:
        """Parse + canonicalize a request once, ahead of execution.

        Returns ``(query, instance)`` where ``instance`` is the canonical
        template instance for union-free requests and ``None`` for UNION
        requests (which need cross-part merging and run unbatched).  The
        result is graph-independent, so it stays valid across mutations —
        sessions prepare at submit time (they need the template key for
        admission anyway) and hand the prepared pairs to
        :meth:`execute_prepared` at flush, paying canonicalization once.
        """
        q, t_parse = self._parse(query)
        self._bump_stage("parse", t_parse)
        parts = sparql.union_split(q)
        return q, canonicalize(parts[0]) if len(parts) == 1 else None

    def execute_many(self, queries: Sequence[str | Query]) -> list[ExecResult]:
        """Run a request list, microbatching same-template requests."""
        return self.execute_prepared([self.prepare(q) for q in queries])

    def execute_prepared(
        self, prepared: Sequence[tuple[Query, TemplateInstance | None]]
    ) -> list[ExecResult]:
        """Run requests already split by :meth:`prepare`.

        The snapshot is pinned ONCE here: every request of the call —
        microbatched and multipart (UNION) alike — executes against the
        same graph version, even when the source database mutates while
        the batch is in flight.
        """
        if self.faults is not None:
            # deterministic injection site (DESIGN.md 14.1): a poisoned
            # request raises here, on every replica it is retried on
            self.faults.on_execute_prepared(list(prepared))
        self.refresh()
        results: list[ExecResult | None] = [None] * len(prepared)
        batcher = MicroBatcher(self.buckets)
        multipart: list[tuple[int, Query]] = []
        for idx, (q, inst) in enumerate(prepared):
            if inst is not None:
                batcher.add(idx, inst)
            else:
                # UNION requests need cross-part merging; run them unbatched
                multipart.append((idx, q))
        for mb in batcher.drain():
            t_mb = time.perf_counter()
            solved = self._solve_microbatch(mb.requests, bucket=mb.bucket)
            dt = time.perf_counter() - t_mb
            # honest attribution: the microbatch wall time is a *batch*
            # property; a request's own "total" is its fair share of it
            share = dt / len(mb.requests)
            for idx, res in solved:
                res.timings["batch_total"] = dt
                res.timings["total"] = share
                results[idx] = res
        for idx, q in multipart:
            # NOT self.execute(): that would refresh() mid-batch and let one
            # execute_many call mix two graph versions under mutation
            results[idx] = self._execute_pinned(q)
        with self._stats_lock:
            self._requests += len(prepared) - len(multipart)  # _execute_pinned counted the rest
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------ #
    def _parse(self, query: str | Query) -> tuple[Query, float]:
        t = time.perf_counter()
        q = sparql.parse(query) if isinstance(query, str) else query
        return q, time.perf_counter() - t

    def _solve_microbatch(
        self,
        requests: list[tuple[int, TemplateInstance]],
        bucket: int | None = None,
    ) -> list[tuple[int, ExecResult]]:
        """Solve same-template requests as one padded disjoint-union batch."""
        # requests with identical constants share one instance slot
        by_consts: dict[tuple[str, ...], list[tuple[int, TemplateInstance]]] = {}
        for idx, inst in requests:
            by_consts.setdefault(inst.constants, []).append((idx, inst))
        uniq = list(by_consts)
        if bucket is None:
            bucket = bucket_for(len(uniq), self.buckets)
        bindings = uniq + [uniq[-1]] * (bucket - len(uniq))  # pad: repeat last

        t = time.perf_counter()
        # snapshot already pinned by the caller (execute/execute_prepared)
        plan, hit = self.plan_for(requests[0][1].template, bucket, _refresh=False)
        t_plan = time.perf_counter() - t

        t = time.perf_counter()
        warm_before = plan.metrics.warm_resumes
        chi, sweeps = plan.execute(bindings)
        t_solve = time.perf_counter() - t
        with self._stats_lock:
            # one atomic commit per microbatch event, so every stats()
            # snapshot satisfies sum(engine_counts) == microbatches
            self._warm_solves += plan.metrics.warm_resumes - warm_before
            self._microbatches += 1
            self._engine_counts[plan.engine] = (
                self._engine_counts.get(plan.engine, 0) + 1
            )
        self._bump_stage("plan", t_plan)
        self._bump_stage("solve", t_solve)

        out: list[tuple[int, ExecResult]] = []
        for i, consts in enumerate(uniq):
            t = time.perf_counter()
            chi_i = chi[plan.layout.chi_slice(i)]
            mask, stats = pruning.prune_triples(plan.base_soi, chi_i, self.db)
            canon_rows = soi_mod.collect(plan.base_soi, chi_i)
            t_prune = time.perf_counter() - t
            self._bump_stage("prune", t_prune)
            for idx, inst in by_consts[consts]:
                out.append(
                    (
                        idx,
                        ExecResult(
                            survivors=mask,
                            stats=stats,
                            bindings=inst.rename_bindings(canon_rows),
                            sweeps=sweeps,
                            engine=plan.engine,
                            template_keys=(plan.template.key,),
                            cache_hit=hit,
                            batch=bucket,
                            timings={
                                "plan": t_plan,
                                "solve": t_solve,
                                "prune": t_prune,
                            },
                        ),
                    )
                )
        return out

    def _bump_stage(self, stage: str, seconds: float) -> None:
        with self._stats_lock:
            self._stage_seconds[stage] = (
                self._stage_seconds.get(stage, 0.0) + seconds
            )

    # ------------------------------------------------------------------ #
    def stats(self) -> EngineMetrics:
        """A *consistent* point-in-time snapshot of the serving counters.

        The whole copy happens under the counters' lock, so concurrent
        sessions and the serving loop can read mid-flight without torn
        values: in every snapshot ``sum(engine_counts.values()) ==
        microbatches``, and the dict copies never race their writers
        (asserted under a multithreaded hammer in ``tests/test_serve.py``).
        """
        with self._stats_lock:
            return EngineMetrics(
                requests=self._requests,
                microbatches=self._microbatches,
                engine_counts=dict(self._engine_counts),
                cache=self.cache.stats(),
                stage_seconds=dict(self._stage_seconds),
                invalidation_events=self._invalidation_events,
                adj_invalidations=self._adj_invalidations,
                plans_resumable=self._plans_resumable,
                plans_resumed=self._plans_resumed,
                resumes_declined=self._resumes_declined,
                warm_resume_solves=self._warm_solves,
                adj_rebuilds_saved=self._adj_rebuilds_saved,
            )

    def metrics(self) -> EngineMetrics:
        """Alias of :meth:`stats` (the original name, kept for callers)."""
        return self.stats()


def _merge_union(partials: list[ExecResult], db: Graph) -> ExecResult:
    """Union the per-part results of a UNION query (single part: identity)."""
    if len(partials) == 1:
        return partials[0]
    mask = np.zeros(db.n_edges, dtype=bool)
    bindings: dict[str, np.ndarray] = {}
    per_edge: list[int] = []
    sweeps = 0
    timings: dict[str, float] = {}
    for p in partials:
        mask |= p.survivors
        sweeps += p.sweeps
        per_edge += p.stats.per_edge_survivors
        for var, row in p.bindings.items():
            bindings[var] = bindings.get(var, np.zeros(db.n_nodes, bool)) | row
        for k, v in p.timings.items():
            timings[k] = timings.get(k, 0.0) + v
    n_after = int(mask.sum())
    stats = pruning.PruneStats(
        n_triples=db.n_edges,
        n_after=n_after,
        fraction_pruned=1.0 - n_after / max(db.n_edges, 1),
        per_edge_survivors=per_edge,
    )
    return ExecResult(
        survivors=mask,
        stats=stats,
        bindings=bindings,
        sweeps=sweeps,
        engine=",".join(sorted({p.engine for p in partials})),
        template_keys=tuple(k for p in partials for k in p.template_keys),
        cache_hit=all(p.cache_hit for p in partials),
        batch=max(p.batch for p in partials),
        timings=timings,
    )
