"""The query-engine facade (DESIGN.md 5.5).

``Engine(db)`` owns everything a serving process needs: the parsed-query →
template canonicalizer, the LRU plan cache keyed by (template, graph
fingerprint, batch bucket), the cost model that picks a fixpoint engine per
plan, and the microbatcher that groups same-template requests into one
disjoint-union solve.  ``execute`` handles one request end-to-end (UNION
queries run one plan per union-free part and union the results);
``execute_many`` batches a request list through the microbatcher.

Results carry the survivor triple mask (Sect. 5 pruning), per-variable
candidate bindings under the query's own variable names, per-stage timings,
and the cache/batch provenance — enough for a caller to assert the warm
path did no recompilation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Sequence

import numpy as np

from repro.core import pruning, soi as soi_mod, sparql
from repro.core.graph import Graph
from repro.core.sparql import Query

from .batcher import DEFAULT_BUCKETS, MicroBatcher, bucket_for
from .cache import BoundedDict, CacheStats, PlanCache
from .plan import CompiledPlan
from .template import TemplateInstance, canonicalize


@dataclasses.dataclass
class ExecResult:
    """Outcome of one request."""

    survivors: np.ndarray  # bool mask over db.triples (Sect. 5 pruning)
    stats: pruning.PruneStats
    bindings: dict[str, np.ndarray]  # query var -> candidate node mask
    sweeps: int
    engine: str  # fixpoint engine(s) used
    template_keys: tuple[str, ...]
    cache_hit: bool  # every plan this request needed was cached
    batch: int  # microbatch bucket the request rode in
    timings: dict[str, float]  # per-stage seconds


@dataclasses.dataclass
class EngineMetrics:
    requests: int
    microbatches: int  # == fixpoint solves: one disjoint-union solve each
    engine_counts: dict[str, int]
    cache: CacheStats
    stage_seconds: dict[str, float]
    invalidation_events: int = 0  # refreshes that adopted a mutated snapshot
    adj_invalidations: int = 0  # adjacency entries dropped on those refreshes

    @property
    def plan_builds(self) -> int:
        # every cache miss builds exactly one plan; single source of truth
        return self.cache.misses

    @property
    def plan_invalidations(self) -> int:
        return self.cache.invalidations


def graph_fingerprint(g: Graph) -> str:
    """Content hash binding cached plans to one database state.

    The name dictionaries are part of the state: two snapshots with
    identical int arrays but different ``node_names``/``label_names``
    encodings are *different* databases (constants resolve to different
    ids), so they must not share plans.
    """
    h = hashlib.blake2b(digest_size=12)
    h.update(np.ascontiguousarray(g.triples).tobytes())
    h.update(f"{g.n_nodes}/{g.n_labels}".encode())
    for names in (g.node_names, g.label_names):
        # length-prefix each list so the node/label boundary is unambiguous
        # (['a','bc']/['d'] must not collide with ['a','b']/['cd'])
        if names is None:
            h.update(b"\x00")
        else:
            h.update(f"{len(names)}\x1e".encode())
            h.update("\x1f".join(names).encode())
            h.update(b"\x1e")
    return h.hexdigest()


class Engine:
    """Facade over template → plan-cache → microbatch → fixpoint → prune."""

    def __init__(
        self,
        db,
        *,
        engine: str = "auto",
        cache_capacity: int = 64,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        backend: str | None = None,
        mesh=None,
        n_blocks: int | None = None,
    ):
        # ``db`` is either an immutable core Graph or a mutable source with
        # (graph, version, fingerprint, node_index) — i.e. repro.db.GraphDB.
        # Duck-typed so this module never imports the layer above it.
        self._source = db if hasattr(db, "graph") and hasattr(db, "version") else None
        self.db: Graph = self._source.graph if self._source is not None else db
        self.engine_pref = engine
        self.buckets = tuple(sorted(buckets))
        self.backend = backend
        # mesh: a jax.sharding.Mesh (see repro.distributed.ctx.node_mesh).
        # Plans shard chi's node axis across it and the cost model sees its
        # size, so engine="auto" can pick "partitioned" once the graph
        # outgrows single-shard budgets.  n_blocks defaults to the mesh size
        # (one destination block per device).
        self.mesh = mesh
        self.n_devices = int(mesh.devices.size) if mesh is not None else 1
        # without a mesh the partitioned engine still runs (single-device,
        # block-structured); 4 blocks keeps the layout non-degenerate
        self.n_blocks = (
            n_blocks
            if n_blocks is not None
            else (self.n_devices if mesh is not None else 4)
        )
        # a mesh-shape token in the plan key: an Engine's mesh is fixed, but
        # cache keys must stay unambiguous if a cache is ever shared/dumped
        self._mesh_key = (
            (tuple(mesh.axis_names), tuple(mesh.devices.shape))
            if mesh is not None
            else None
        )
        self.cache = PlanCache(cache_capacity)
        # (engine, mats) -> device adjacency, shared across plans; bounded so
        # a churning template mix cannot pin unbounded device memory
        self._adj_cache = BoundedDict(capacity=16)
        if self._source is not None:
            self.fingerprint = self._source.fingerprint
            self._version = self._source.version
            self._node_index = self._source.node_index
        else:
            self.fingerprint = graph_fingerprint(self.db)
            self._version = None
            self._node_index = (
                self.db.node_index() if self.db.node_names is not None else {}
            )
        self._prev_db: Graph = self.db  # adjacency retention window
        self._requests = 0
        self._microbatches = 0
        self._invalidation_events = 0
        self._adj_invalidations = 0
        self._engine_counts: dict[str, int] = {}
        self._stage_seconds: dict[str, float] = {}

    # ------------------------------------------------------------------ #
    # versioned invalidation (repro.db.GraphDB mutations)
    # ------------------------------------------------------------------ #
    def refresh(self) -> int:
        """Adopt the source database's current snapshot if it has mutated.

        Called on every execute/plan access; a no-op unless the source's
        monotone version counter moved.  Invalidation is *precise*, not a
        flush: plans keyed by the engine's current or immediately-previous
        fingerprint survive (history <= 1 version, so results in flight keep
        their plans), anything older is dropped and counted in
        ``cache.invalidations``.  Adjacency entries built from graphs outside
        that window are dropped too — they can never hit again because the
        adjacency cache matches on graph identity.

        Returns the number of plans invalidated by this call.
        """
        if self._source is None or self._source.version == self._version:
            return 0
        prev_fp, prev_db = self.fingerprint, self.db
        self.db = self._source.graph
        self.fingerprint = self._source.fingerprint
        self._version = self._source.version
        self._node_index = self._source.node_index
        keep_fp = {self.fingerprint, prev_fp}
        dropped = self.cache.invalidate(lambda key: key[1] not in keep_fp)
        for k, (g_stored, _) in list(self._adj_cache.items()):
            if g_stored is not self.db and g_stored is not prev_db:
                del self._adj_cache[k]
                self._adj_invalidations += 1
        self._prev_db = prev_db
        self._invalidation_events += 1
        return dropped

    # ------------------------------------------------------------------ #
    # plan access
    # ------------------------------------------------------------------ #
    def plan_for(
        self, instance_or_template, bucket: int = 1, *, _refresh: bool = True
    ) -> tuple[CompiledPlan, bool]:
        """Fetch (or build) the plan for a template at one batch bucket.

        Returns ``(plan, cache_hit)``.  ``_refresh=False`` is the internal
        mid-batch path: the snapshot was already pinned at the batch
        boundary and must not move under in-flight requests.
        """
        if _refresh:
            self.refresh()
        template = (
            instance_or_template.template
            if isinstance(instance_or_template, TemplateInstance)
            else instance_or_template
        )
        key = (
            template.key, self.fingerprint, bucket, self.engine_pref,
            self.n_blocks, self._mesh_key,
        )
        hit = key in self.cache
        plan = self.cache.get_or_build(
            key,
            lambda: CompiledPlan(
                template,
                self.db,
                engine=self.engine_pref,
                batch=bucket,
                node_index=self._node_index,
                backend=self.backend,
                adj_cache=self._adj_cache,
                mesh=self.mesh,
                n_blocks=self.n_blocks,
            ),
        )
        return plan, hit

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def execute(self, query: str | Query) -> ExecResult:
        """Run one query end-to-end (parse → plans → solve → prune)."""
        self.refresh()
        return self._execute_pinned(query)

    def _execute_pinned(self, query: str | Query) -> ExecResult:
        """``execute`` against the already-adopted snapshot (no refresh):
        the mid-batch path of :meth:`execute_prepared`, where every request
        of one call must see one graph version even if the source mutates
        concurrently."""
        t0 = time.perf_counter()
        q, t_parse = self._parse(query)
        parts = sparql.union_split(q)
        partials = []
        for part in parts:
            inst = canonicalize(part)
            partials.append(self._solve_microbatch([(0, inst)])[0][1])
        res = _merge_union(partials, self.db)
        res.timings["parse"] = t_parse
        res.timings["total"] = time.perf_counter() - t0
        res.timings["batch_total"] = res.timings["total"]  # batch of one
        self._requests += 1
        self._bump_stage("parse", t_parse)
        return res

    def prepare(self, query: str | Query) -> tuple[Query, TemplateInstance | None]:
        """Parse + canonicalize a request once, ahead of execution.

        Returns ``(query, instance)`` where ``instance`` is the canonical
        template instance for union-free requests and ``None`` for UNION
        requests (which need cross-part merging and run unbatched).  The
        result is graph-independent, so it stays valid across mutations —
        sessions prepare at submit time (they need the template key for
        admission anyway) and hand the prepared pairs to
        :meth:`execute_prepared` at flush, paying canonicalization once.
        """
        q, t_parse = self._parse(query)
        self._bump_stage("parse", t_parse)
        parts = sparql.union_split(q)
        return q, canonicalize(parts[0]) if len(parts) == 1 else None

    def execute_many(self, queries: Sequence[str | Query]) -> list[ExecResult]:
        """Run a request list, microbatching same-template requests."""
        return self.execute_prepared([self.prepare(q) for q in queries])

    def execute_prepared(
        self, prepared: Sequence[tuple[Query, TemplateInstance | None]]
    ) -> list[ExecResult]:
        """Run requests already split by :meth:`prepare`.

        The snapshot is pinned ONCE here: every request of the call —
        microbatched and multipart (UNION) alike — executes against the
        same graph version, even when the source database mutates while
        the batch is in flight.
        """
        self.refresh()
        results: list[ExecResult | None] = [None] * len(prepared)
        batcher = MicroBatcher(self.buckets)
        multipart: list[tuple[int, Query]] = []
        for idx, (q, inst) in enumerate(prepared):
            if inst is not None:
                batcher.add(idx, inst)
            else:
                # UNION requests need cross-part merging; run them unbatched
                multipart.append((idx, q))
        for mb in batcher.drain():
            t_mb = time.perf_counter()
            solved = self._solve_microbatch(mb.requests, bucket=mb.bucket)
            dt = time.perf_counter() - t_mb
            # honest attribution: the microbatch wall time is a *batch*
            # property; a request's own "total" is its fair share of it
            share = dt / len(mb.requests)
            for idx, res in solved:
                res.timings["batch_total"] = dt
                res.timings["total"] = share
                results[idx] = res
        for idx, q in multipart:
            # NOT self.execute(): that would refresh() mid-batch and let one
            # execute_many call mix two graph versions under mutation
            results[idx] = self._execute_pinned(q)
        self._requests += len(prepared) - len(multipart)  # _execute_pinned counted the rest
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------ #
    def _parse(self, query: str | Query) -> tuple[Query, float]:
        t = time.perf_counter()
        q = sparql.parse(query) if isinstance(query, str) else query
        return q, time.perf_counter() - t

    def _solve_microbatch(
        self,
        requests: list[tuple[int, TemplateInstance]],
        bucket: int | None = None,
    ) -> list[tuple[int, ExecResult]]:
        """Solve same-template requests as one padded disjoint-union batch."""
        # requests with identical constants share one instance slot
        by_consts: dict[tuple[str, ...], list[tuple[int, TemplateInstance]]] = {}
        for idx, inst in requests:
            by_consts.setdefault(inst.constants, []).append((idx, inst))
        uniq = list(by_consts)
        if bucket is None:
            bucket = bucket_for(len(uniq), self.buckets)
        bindings = uniq + [uniq[-1]] * (bucket - len(uniq))  # pad: repeat last

        t = time.perf_counter()
        # snapshot already pinned by the caller (execute/execute_prepared)
        plan, hit = self.plan_for(requests[0][1].template, bucket, _refresh=False)
        t_plan = time.perf_counter() - t

        t = time.perf_counter()
        chi, sweeps = plan.execute(bindings)
        t_solve = time.perf_counter() - t

        self._microbatches += 1
        self._engine_counts[plan.engine] = (
            self._engine_counts.get(plan.engine, 0) + 1
        )
        self._bump_stage("plan", t_plan)
        self._bump_stage("solve", t_solve)

        out: list[tuple[int, ExecResult]] = []
        for i, consts in enumerate(uniq):
            t = time.perf_counter()
            chi_i = chi[plan.layout.chi_slice(i)]
            mask, stats = pruning.prune_triples(plan.base_soi, chi_i, self.db)
            canon_rows = soi_mod.collect(plan.base_soi, chi_i)
            t_prune = time.perf_counter() - t
            self._bump_stage("prune", t_prune)
            for idx, inst in by_consts[consts]:
                out.append(
                    (
                        idx,
                        ExecResult(
                            survivors=mask,
                            stats=stats,
                            bindings=inst.rename_bindings(canon_rows),
                            sweeps=sweeps,
                            engine=plan.engine,
                            template_keys=(plan.template.key,),
                            cache_hit=hit,
                            batch=bucket,
                            timings={
                                "plan": t_plan,
                                "solve": t_solve,
                                "prune": t_prune,
                            },
                        ),
                    )
                )
        return out

    def _bump_stage(self, stage: str, seconds: float) -> None:
        self._stage_seconds[stage] = self._stage_seconds.get(stage, 0.0) + seconds

    # ------------------------------------------------------------------ #
    def metrics(self) -> EngineMetrics:
        return EngineMetrics(
            requests=self._requests,
            microbatches=self._microbatches,
            engine_counts=dict(self._engine_counts),
            cache=self.cache.stats(),
            stage_seconds=dict(self._stage_seconds),
            invalidation_events=self._invalidation_events,
            adj_invalidations=self._adj_invalidations,
        )


def _merge_union(partials: list[ExecResult], db: Graph) -> ExecResult:
    """Union the per-part results of a UNION query (single part: identity)."""
    if len(partials) == 1:
        return partials[0]
    mask = np.zeros(db.n_edges, dtype=bool)
    bindings: dict[str, np.ndarray] = {}
    per_edge: list[int] = []
    sweeps = 0
    timings: dict[str, float] = {}
    for p in partials:
        mask |= p.survivors
        sweeps += p.sweeps
        per_edge += p.stats.per_edge_survivors
        for var, row in p.bindings.items():
            bindings[var] = bindings.get(var, np.zeros(db.n_nodes, bool)) | row
        for k, v in p.timings.items():
            timings[k] = timings.get(k, 0.0) + v
    n_after = int(mask.sum())
    stats = pruning.PruneStats(
        n_triples=db.n_edges,
        n_after=n_after,
        fraction_pruned=1.0 - n_after / max(db.n_edges, 1),
        per_edge_survivors=per_edge,
    )
    return ExecResult(
        survivors=mask,
        stats=stats,
        bindings=bindings,
        sweeps=sweeps,
        engine=",".join(sorted({p.engine for p in partials})),
        template_keys=tuple(k for p in partials for k in p.template_keys),
        cache_hit=all(p.cache_hit for p in partials),
        batch=max(p.batch for p in partials),
        timings=timings,
    )
