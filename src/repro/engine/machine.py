"""Machine capability specs for the calibrated cost model (DESIGN.md 13).

A :class:`MachineSpec` is the output of the ERT-style probe in
``benchmarks/roofline.py``: a handful of measured machine ceilings —
sustained streaming bandwidth, packed bit-op throughput under the shipping
and the word-wise XLA lowerings, dense boolean-matmul efficiency, per-call
kernel-launch and XLA-dispatch overheads, the jit trace+compile latency,
and (on a mesh) per-byte collective cost.  :func:`repro.engine.cost.
CostModel.from_spec` turns those ceilings into the per-engine cost
constants, replacing the hand-tuned defaults that encode one developer
machine.

Specs are persisted as versioned JSON under ``results/machine/`` keyed by a
:func:`machine_fingerprint` (backend + device kind + host shape), so CI
runners and dev machines each calibrate against their own measurements and
the perf gate (``tools/perfgate``) never compares trajectories across
machines.

Resolution order for :func:`default_spec` (what the cost model consults
when no spec is passed explicitly):

* ``REPRO_MACHINE_SPEC=off`` (or ``0``/``none``) — calibration disabled;
  the hand-tuned model is used.  The test suite pins this for determinism.
* ``REPRO_MACHINE_SPEC=<path>`` — load exactly that spec file.
* unset — look up ``results/machine/<fingerprint>.json`` for the current
  machine; hand-tuned fallback when absent.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import tempfile

SPEC_VERSION = 1
ENV_VAR = "REPRO_MACHINE_SPEC"
SPEC_DIR = os.path.normpath(
    os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "machine"
    )
)


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Measured machine ceilings, the probe's persisted output.

    Rates are per second of sustained throughput (best over repeats);
    overheads and the trace latency are seconds per call.  ``fast`` records
    whether the probe ran its reduced CI sweep (fewer sizes/repeats) —
    fast specs are still valid calibration, just noisier.
    """

    backend: str  # jax backend the probe ran on ("cpu", "tpu", ...)
    device_kind: str  # jax device kind string (e.g. "cpu", "TPU v4")
    fingerprint: str  # machine_fingerprint() at probe time
    n_devices: int  # visible device count at probe time
    stream_bytes_per_s: float  # sustained streaming bandwidth (uint32 traffic)
    dense_elems_per_s: float  # dense f32-matmul boolean-product elements/s
    packed_words_per_s: float  # bitmm_apply words/s, shipping lowering
    packed_words_per_s_xla: float  # bitmm_apply words/s, word-wise XLA lowering
    fused_words_per_s: float  # fused-path words/s, shipping lowering
    kernel_launch_s: float  # per-call overhead of the shipping kernel path
    dispatch_s: float  # per-call overhead of a compiled XLA op
    trace_s: float  # jit trace+compile of a representative packed fixpoint
    collective_bytes_per_s: float | None = None  # None below 2 devices
    probed_at: str = ""  # ISO timestamp (informational only)
    fast: bool = False  # reduced --fast sweep
    version: int = SPEC_VERSION

    def to_json(self) -> dict:
        """Plain-dict form for persistence (round-trips via ``load_spec``)."""
        return dataclasses.asdict(self)


def machine_fingerprint(backend: str | None = None) -> str:
    """Stable id of (backend, device kind, host shape) for spec keying.

    Includes the CPU architecture, core count, device count, and a short
    hostname hash so a CI runner never inherits (or pollutes) a dev
    machine's calibration or perf-gate history: an unseen fingerprint
    bootstraps a fresh trajectory instead of cross-comparing.
    """
    import jax

    backend = backend or jax.default_backend()
    devices = jax.devices(backend)
    kind = devices[0].device_kind if devices else "unknown"
    node = hashlib.blake2b(
        platform.node().encode(), digest_size=4
    ).hexdigest()
    raw = "__".join(
        str(p)
        for p in (
            backend, kind.replace(" ", "-"), platform.machine(),
            os.cpu_count(), len(devices), node,
        )
    )
    return raw.replace("/", "-")


def spec_path(fingerprint: str) -> str:
    """Where a spec with this fingerprint persists under ``results/machine/``."""
    return os.path.join(SPEC_DIR, f"{fingerprint}.json")


def save_spec(spec: MachineSpec, path: str | None = None) -> str:
    """Persist ``spec`` as JSON (atomic rename) and return the path."""
    path = path or spec_path(spec.fingerprint)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(path) or ".", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(spec.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    clear_spec_cache()
    return path


def load_spec(path: str) -> MachineSpec:
    """Load a persisted spec, tolerating fields added by later versions."""
    with open(path) as f:
        raw = json.load(f)
    fields = {f.name for f in dataclasses.fields(MachineSpec)}
    return MachineSpec(**{k: v for k, v in raw.items() if k in fields})


_cache: dict[tuple[str | None, str | None], MachineSpec | None] = {}


def clear_spec_cache() -> None:
    """Drop memoized :func:`default_spec` results (tests, fresh probes)."""
    _cache.clear()


def default_spec(backend: str | None = None) -> MachineSpec | None:
    """The spec the cost model should use when none is passed explicitly.

    Honors ``REPRO_MACHINE_SPEC`` (see module docstring); memoized per
    (env value, backend) so the per-plan cost of consulting it is a dict
    lookup, not disk I/O.
    """
    env = os.environ.get(ENV_VAR)
    key = (env, backend)
    if key in _cache:
        return _cache[key]
    spec: MachineSpec | None
    if env is not None and env.strip().lower() in ("off", "0", "none", ""):
        spec = None
    elif env is not None:
        spec = load_spec(env)
    else:
        path = spec_path(machine_fingerprint(backend))
        spec = load_spec(path) if os.path.exists(path) else None
    _cache[key] = spec
    return spec
