"""Compiled, constant-rebindable execution plans (DESIGN.md 5.2).

A :class:`CompiledPlan` is everything about a query that does not depend on
the constants: the (batched) SOI built from the template, its compilation
against one graph's label table, the engine-specific device operands with
static shapes, and a jitted fixpoint.  The per-request constants enter as an
*input* — a ``bool[K, n]`` stack of one-hot rows scattered into the Eq.-13
init inside the traced function — so rebinding a template to new constants
re-runs the same trace: zero SOI recompilation, zero jit retraces.

Slot handling: the template SOI marks constants as ``$slot{k}`` (see
:mod:`repro.engine.template`).  For compilation we strip those markers so
:func:`repro.core.soi.compile_soi` gives slot rows the full structural
(Eq.-13 summary) init of a variable; binding then ANDs in the one-hot row,
which reproduces exactly what ``compile_soi`` does for a literal constant
(singleton intersected with the summaries; all-zero when the constant is not
in the database).  One slot may map to *several* internal variables — the
SOI builder gives constants a private singleton variable per BGP — so the
scatter index list carries one entry per (instance, slot variable).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, dualsim, soi as soi_mod
from repro.core.graph import Graph, GraphDelta

from . import cost as cost_mod
from .batcher import BatchLayout, batch_layout
from .cache import BoundedDict
from .template import QueryTemplate, slot_index


def _shard_partitioned_operands(
    ops: dualsim.Operands, mesh: jax.sharding.Mesh, chi_spec
) -> dualsim.Operands:
    """Place partitioned operands on the mesh: edge blocks [W, Eb] shard
    block-major along the mesh (block w lives where chi block w lives, so
    every segment reduction is device-local), init shards like chi.  A
    device_put onto the sharding an array already has is a no-op, so cached
    edge blocks are not re-copied across plans."""
    block = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(mesh.axis_names, None)
    )
    put = lambda xs: tuple(jax.device_put(x, block) for x in xs)
    # init_packed stays replicated: its word axis (n/32) need not divide the
    # mesh (device_put rejects uneven sharding), it is read once at loop
    # start, and the loop state constraint distributes chi from there
    replicated = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()
    )
    return dataclasses.replace(
        ops,
        init=jax.device_put(ops.init, chi_spec),
        init_packed=jax.device_put(ops.init_packed, replicated),
        edge_src_b=put(ops.edge_src_b),
        edge_dst_b=put(ops.edge_dst_b),
    )


@dataclasses.dataclass
class PlanMetrics:
    """Observable counters for the zero-recompile acceptance test."""

    traces: int = 0  # times the jitted fixpoint was (re)traced
    executions: int = 0  # times it was called
    build_seconds: float = 0.0  # host-side SOI build + compile + operands
    patches: int = 0  # shape-stable graph deltas adopted in place
    warm_resumes: int = 0  # executions warm-started from a previous chi


class CompiledPlan:
    """One (template, graph, bucket) entry of the plan cache."""

    def __init__(
        self,
        template: QueryTemplate,
        db: Graph,
        *,
        engine: str = "auto",
        batch: int = 1,
        node_index: dict[str, int] | None = None,
        backend: str | None = None,
        adj_cache: dict | None = None,
        mesh: jax.sharding.Mesh | None = None,
        n_blocks: int | None = None,
        incremental: bool = True,
        spec=None,
    ):
        """Compile ``template`` against ``db`` at batch size ``batch``.

        ``spec`` is the :class:`repro.engine.machine.MachineSpec` the
        ``engine="auto"`` selection prices with (``None``: the persisted
        machine spec, then the hand-tuned fallback — DESIGN.md Sect. 13).
        """
        t0 = time.perf_counter()
        backend = backend or jax.default_backend()
        self.template = template
        self.batch = batch
        self.n_nodes = db.n_nodes
        self.mesh = mesh
        self.incremental = incremental
        n_devices = int(mesh.devices.size) if mesh is not None else 1
        self._n_devices = n_devices
        self.n_blocks = n_blocks if n_blocks is not None else max(n_devices, 1)
        # chi is [V, n]: shard the node axis across every mesh axis; the
        # V axis (variables) stays replicated — it is tiny and irregular
        self.chi_spec = (
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(None, mesh.axis_names)
            )
            if mesh is not None
            else None
        )
        if node_index is None:
            node_index = db.node_index() if db.node_names is not None else {}
        self._node_index = node_index

        base = soi_mod.build_soi(template.query)
        self.base_soi = base
        self.layout: BatchLayout = batch_layout([base] * batch)
        union = self.layout.soi

        # strip slot markers so compile_soi inits slot rows like variables
        stripped = dataclasses.replace(
            union,
            is_const=[
                None if (c is not None and slot_index(c) is not None) else c
                for c in union.is_const
            ],
        )
        self._stripped = stripped  # kept for shape-stable recompiles (patch)
        self.csoi = soi_mod.compile_soi(stripped, db, node_index=node_index)

        # (instance, slot variable) scatter order; row j of const_rows lands
        # in init row scatter_ids[j] and carries constants[slot_of[j]]
        per_part = [
            (vid, slot_index(c))
            for vid, c in enumerate(base.is_const)
            if c is not None and slot_index(c) is not None
        ]
        self._scatter_ids = np.asarray(
            [
                self.layout.offsets[i] + vid
                for i in range(batch)
                for vid, _ in per_part
            ],
            dtype=np.int32,
        )
        self._scatter_slot = [k for _ in range(batch) for _, k in per_part]
        self._scatter_instance = [
            i for i in range(batch) for _ in per_part
        ]

        self.cost: cost_mod.CostEstimate | None = None
        if engine == "auto":
            self.cost = cost_mod.choose_engine(
                db, self.csoi, backend=backend, n_devices=n_devices, spec=spec
            )
            engine = self.cost.engine
        self.engine = engine

        if engine == "dense":
            self.operands = dualsim.make_dense_operands(self.csoi, db, adj_cache)
            solver = dualsim.solve_dense
        elif engine == "packed":
            self.operands = dualsim.make_packed_operands(self.csoi, db, adj_cache)
            # compiled Pallas kernel on accelerators; interpret only on CPU
            # (the cost model prices the two regimes very differently)
            solver = functools.partial(
                dualsim.solve_packed, interpret=(backend == "cpu")
            )
        elif engine == "packed_fused":
            self.operands = dualsim.make_packed_operands(self.csoi, db, adj_cache)
            # fused Pallas kernel on accelerators; on CPU the word-wise XLA
            # lowering (kernel emulation would cost ~9x — DESIGN.md Sect. 9).
            # Resolved here, not via impl=None, because plans honor an
            # Engine-level ``backend`` override rather than the process
            # default the solver's auto-detection would consult.
            solver = functools.partial(
                dualsim.solve_packed_fused,
                impl=("words" if backend == "cpu" else "kernel"),
            )
        elif engine in ("sparse", "jacobi_packed"):
            # both sparse modes run the segmented-OR sweep over bit-packed
            # chi (ISSUE 8).  The lowering is resolved here like
            # packed_fused's: blocked Pallas kernel on accelerators, the
            # word-wise XLA path on CPU — plans honor an Engine-level
            # ``backend`` override rather than the process default the
            # solver's auto-detection would consult.
            self.operands = dualsim.make_sparse_operands(self.csoi, db, adj_cache)
            solver = functools.partial(
                dualsim.solve_sparse,
                mode=("jacobi_packed" if engine == "jacobi_packed" else "gs"),
                impl=("words" if backend == "cpu" else "kernel"),
                chi_spec=self.chi_spec,
            )
        elif engine == "partitioned":
            self.operands = dualsim.make_partitioned_operands(
                self.csoi, db, self.n_blocks, adj_cache
            )
            if mesh is not None and self.n_blocks % n_devices == 0:
                self.operands = _shard_partitioned_operands(
                    self.operands, mesh, self.chi_spec
                )
            solver = functools.partial(
                dualsim.solve_partitioned, chi_spec=self.chi_spec
            )
        else:
            raise ValueError(f"unknown engine {engine!r}")

        self._adj_cache = adj_cache
        # incremental maintenance state (DESIGN.md Sect. 8): the last solved
        # chi per constant tuple (bit-packed, 8x smaller than bool), and
        # re-seeded warm starts staged by patch_graph for the next
        # execution of the same constants
        self._chi_memo: BoundedDict = BoundedDict(capacity=4)
        self._warm: dict = {}
        self.last_sweeps: int | None = None
        # engines whose while_loop state is bit-packed take constants and
        # warm starts as uint32 words; bool chi never touches the device.
        # Since ISSUE 8 that is every edge-list engine — sparse included.
        self._packed_chi = engine in ("packed_fused", "sparse",
                                      "jacobi_packed", "partitioned")

        self.metrics = PlanMetrics()
        scatter = jnp.asarray(self._scatter_ids)
        n_nodes = self.n_nodes

        def _run(ops: dualsim.Operands, const_rows: jax.Array, chi0: jax.Array):
            # executes at trace time only: the counter observes retraces.
            # chi0 is the warm-start upper bound; the cold path passes the
            # init itself, making the AND below an identity — one trace
            # serves both regimes.
            self.metrics.traces += 1
            init = ops.init_packed if self._packed_chi else ops.init
            if const_rows.shape[0]:
                if const_rows.shape[-1] != init.shape[-1]:
                    # partitioned layout: init is block-padded past n_nodes
                    # (zero pad words/columns are dead either way)
                    const_rows = jnp.pad(
                        const_rows,
                        ((0, 0), (0, init.shape[-1] - const_rows.shape[-1])),
                    )
                init = init.at[scatter].set(init[scatter] & const_rows)
            init = init & chi0
            if self._packed_chi:
                ops = dataclasses.replace(ops, init_packed=init)
            else:
                ops = dataclasses.replace(ops, init=init)
            chi, sweeps = solver(ops)
            return chi[:, :n_nodes], sweeps

        self._run = jax.jit(_run)
        self.metrics.build_seconds = time.perf_counter() - t0

    # ------------------------------------------------------------------ #
    @property
    def n_slot_rows(self) -> int:
        """Init rows the per-request constants scatter into."""
        return len(self._scatter_ids)

    def const_rows(self, bindings: Sequence[tuple[str, ...]]) -> np.ndarray:
        """One-hot ``bool[K, n]`` rows for a batch of constant tuples.

        ``bindings[i]`` is instance i's slot->constant assignment; a constant
        missing from the database yields an all-zero row (forces that
        instance's component empty, same as ``compile_soi``).
        """
        if len(bindings) != self.batch:
            raise ValueError(
                f"plan is compiled for batch={self.batch}, "
                f"got {len(bindings)} binding tuples"
            )
        rows = np.zeros((self.n_slot_rows, self.n_nodes), dtype=bool)
        for j, (i, k) in enumerate(
            zip(self._scatter_instance, self._scatter_slot)
        ):
            if k >= len(bindings[i]):
                raise ValueError(
                    f"instance {i} binds {len(bindings[i])} constants, "
                    f"template needs {self.template.n_slots}"
                )
            node = self._node_index.get(bindings[i][k])
            # the index may be a live dict shared with a mutating source;
            # a name minted after this plan's snapshot has an id past our
            # node axis and (correctly) binds to the empty set here
            if node is not None and node < self.n_nodes:
                rows[j, node] = True
        return rows

    def execute(
        self, bindings: Sequence[tuple[str, ...]]
    ) -> tuple[np.ndarray, int]:
        """Solve the fixpoint for one batch of constant tuples.

        Returns ``(chi, sweeps)`` with ``chi`` of shape
        ``[batch * n_vars, n_nodes]``; use ``self.layout.chi_slice(i)`` to
        demux instance i.  When :meth:`patch_graph` staged a re-seeded warm
        start for exactly these constants, the solve resumes from it
        instead of the Eq.-13 init (same fixpoint, far fewer sweeps).
        """
        rows = self.const_rows(bindings)
        if self._packed_chi:
            # packed engines take everything as uint32 words: constants,
            # init, warm starts — 8x less host->device traffic per request
            rows = bitops.pack_np(rows)
        rows = jnp.asarray(rows)
        key = tuple(bindings)
        warm = self._warm.pop(key, None)
        cold_identity = (
            self.operands.init_packed if self._packed_chi else self.operands.init
        )
        if warm is None:
            chi0 = cold_identity  # cold: AND with init is an identity
        else:
            width = cold_identity.shape[-1]
            if warm.shape[-1] != width:  # partitioned block padding
                warm = np.pad(warm, ((0, 0), (0, width - warm.shape[-1])))
            chi0 = jnp.asarray(warm)
            self.metrics.warm_resumes += 1
        chi, sweeps = self._run(self.operands, rows, chi0)
        self.metrics.executions += 1
        chi, sweeps = np.asarray(chi), int(sweeps)
        self.last_sweeps = sweeps
        if self.incremental:
            # bit-packed: 8x smaller than the bool chi it warm-starts, and
            # for the packed-chi engines it feeds straight back into the
            # solver with no unpack round trip (DESIGN.md Sect. 9)
            self._chi_memo[key] = bitops.pack_np(chi)
        return chi, sweeps

    def patch_graph(
        self,
        db: Graph,
        delta: GraphDelta,
        node_index: dict[str, int] | None = None,
        adj_cache: dict | None = None,
    ) -> None:
        """Adopt a shape-stable mutated snapshot without a rebuild.

        The template SOI, batch layout, and jitted fixpoint all survive;
        only the graph-dependent pieces move: the compiled SOI's Eq.-13
        init is recomputed, touched adjacency operators are patched in
        place (:func:`repro.core.dualsim.patch_operands` — untouched
        operators and therefore operand *shapes* carry over, so the
        existing trace keeps serving), and every memoized fixpoint becomes
        a staged warm start with the delta's destabilized rows re-seeded
        to ⊤ (DESIGN.md Sect. 8.2).
        """
        if not delta.shape_stable or db.n_nodes != self.n_nodes:
            raise ValueError("patch_graph needs a shape-stable delta")
        if node_index is not None:
            self._node_index = node_index
        old_mats = self.csoi.mats
        self.csoi = soi_mod.compile_soi(
            self._stripped, db, node_index=self._node_index
        )
        if self.csoi.mats != old_mats:  # dictionary change slipped through
            raise ValueError("operator list moved; delta is not resumable")
        cache = adj_cache if adj_cache is not None else self._adj_cache
        self.operands = dualsim.patch_operands(
            self.operands,
            self.csoi,
            db,
            delta.touched_labels(),
            n_blocks=self.n_blocks,
            adj_cache=cache,
        )
        if (
            self.engine == "partitioned"
            and self.mesh is not None
            and self.n_blocks % self._n_devices == 0
        ):
            self.operands = _shard_partitioned_operands(
                self.operands, self.mesh, self.chi_spec
            )
        grow = dualsim.destabilized_rows(self.csoi, delta.inserted_labels())
        self._warm = {}
        if self._packed_chi:
            # stay packed: destabilized rows re-seed to the all-ones mask
            # (trailing pad bits zero), the memo words go back verbatim
            ones = bitops.ones_mask(self.n_nodes)
            for key, packed in self._chi_memo.items():
                chi0 = packed.copy()
                chi0[grow] = ones
                self._warm[key] = chi0
        else:
            for key, packed in self._chi_memo.items():
                chi0 = bitops.unpack_np(packed, self.n_nodes)
                chi0[grow] = True
                self._warm[key] = chi0
        # superseded fixpoints are warm seeds now, not current results
        self._chi_memo.clear()
        self.metrics.patches += 1
