"""Query templates: constants abstracted into binding slots (DESIGN.md 5.1).

A *template* is a union-free query with every variable renamed to ``v0, v1,
...`` (first-occurrence order) and every constant replaced by a slot marker
``$slot0, $slot1, ...`` (also first-occurrence order; repeated occurrences of
the same constant map to the same slot, preserving the equality the query
expresses).  Two queries that differ only in variable names and constant
values therefore canonicalize to the *same* template key and share one
compiled plan — "same shape, different constants" is a cache hit.

The per-request remainder is a :class:`TemplateInstance`: the slot → constant
assignment plus the canonical-variable → original-name map used to label
results on the way out.
"""
from __future__ import annotations

import dataclasses

from repro.core import sparql
from repro.core.sparql import BGP, Const, Query, Triple, Var

SLOT_PREFIX = "$slot"


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    """A canonical union-free query shape; ``key`` is the plan-cache key."""

    key: str
    query: Query  # canonical AST: Var("v{j}"), Const("$slot{k}")
    n_slots: int
    n_vars: int

    def __hash__(self) -> int:  # Query holds tuples of frozen dataclasses
        return hash(self.key)


@dataclasses.dataclass(frozen=True)
class TemplateInstance:
    """One request: a template plus its constant bindings."""

    template: QueryTemplate
    constants: tuple[str, ...]  # slot k -> constant name
    var_names: tuple[str, ...]  # canonical var j ("v{j}") -> original name

    def rename_bindings(self, rows: dict) -> dict:
        """Map canonical-variable result rows back to the query's names."""
        out = {}
        for name, row in rows.items():
            if name.startswith("v") and name[1:].isdigit():
                j = int(name[1:])
                if j < len(self.var_names):
                    out[self.var_names[j]] = row
                    continue
            out[name] = row
        return out


def slot_index(name: str) -> int | None:
    """Slot number of a ``$slot{k}`` constant name, else None."""
    if name.startswith(SLOT_PREFIX) and name[len(SLOT_PREFIX):].isdigit():
        return int(name[len(SLOT_PREFIX):])
    return None


def canonicalize(q: Query) -> TemplateInstance:
    """Abstract a union-free query into (template, constants, var names)."""
    if not sparql.is_union_free(q):
        raise ValueError("run sparql.union_split first; templates are union-free")
    vmap: dict[str, str] = {}
    cmap: dict[str, str] = {}

    def term(t):
        if isinstance(t, Var):
            if t.name not in vmap:
                vmap[t.name] = f"v{len(vmap)}"
            return Var(vmap[t.name])
        if t.name not in cmap:
            cmap[t.name] = f"{SLOT_PREFIX}{len(cmap)}"
        return Const(cmap[t.name])

    def walk(qq: Query) -> Query:
        if isinstance(qq, BGP):
            return BGP(tuple(Triple(term(t.s), t.p, term(t.o)) for t in qq.triples))
        return type(qq)(walk(qq.left), walk(qq.right))

    cq = walk(q)
    tmpl = QueryTemplate(
        key=template_key(cq), query=cq, n_slots=len(cmap), n_vars=len(vmap)
    )
    # invert the first-occurrence maps back to positional tuples
    var_names = tuple(sorted(vmap, key=lambda orig: int(vmap[orig][1:])))
    constants = tuple(
        sorted(cmap, key=lambda orig: int(cmap[orig][len(SLOT_PREFIX):]))
    )
    return TemplateInstance(template=tmpl, constants=constants, var_names=var_names)


def template_key(q: Query) -> str:
    """Deterministic serialization of a canonical AST (labels included —
    different predicates need different adjacency operands, hence plans)."""
    if isinstance(q, BGP):
        trs = " . ".join(f"{t.s!r} {t.p} {t.o!r}" for t in q.triples)
        return "{" + trs + "}"
    op = type(q).__name__.rstrip("_").upper()
    return f"({template_key(q.left)} {op} {template_key(q.right)})"
