"""Deterministic fault injection for the serving path (DESIGN.md Sect. 14).

Every failure mode the serving plane claims to survive — a replica that
crashes mid-run, a chronic straggler, a poisoned query, an executor that
rejects work, a refresh that raises — is expressible as a seeded
:class:`FaultPlan` so chaos runs are reproducible tests, not war stories.
Hooks thread through ``ReplicaRouter``, ``Engine.execute_prepared`` and
``AsyncServer`` as zero-cost no-ops when no plan is armed.
"""

from .plan import (
    BoundFaults,
    FaultPlan,
    InjectedCrash,
    InjectedFault,
    InjectedPoison,
    InjectedRefreshFailure,
    InjectedReject,
)

__all__ = [
    "BoundFaults",
    "FaultPlan",
    "InjectedCrash",
    "InjectedFault",
    "InjectedPoison",
    "InjectedRefreshFailure",
    "InjectedReject",
]
