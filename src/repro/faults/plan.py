"""Seeded, thread-safe fault plans driving the serving chaos tests.

A :class:`FaultPlan` is a declarative schedule of injections:

- ``crash_replica(name, at_batch=k)`` — the k-th batch started on that
  replica (counted from :meth:`arm`) raises :class:`InjectedCrash`, and the
  replica stays crashed (every later batch fails fast) until
  :meth:`heal` is called — which is exactly what a rebuild does.
- ``slow_replica(name, factor=f, extra_s=s)`` — a chronic straggler: each
  batch on that replica is stretched to ``f``× its measured service time
  plus ``s`` seconds of absolute delay.
- ``poison_matching(marker)`` — any prepared request whose constants or
  query text contain ``marker`` raises :class:`InjectedPoison` from inside
  ``Engine.execute_prepared`` (on *every* replica: poison travels with the
  request, not the host).
- ``reject_dispatch(at_dispatch=k, count=c)`` — dispatches ``k..k+c-1``
  (counted from :meth:`arm`) raise :class:`InjectedReject` before the batch
  reaches the executor, simulating a rejected/shut-down pool.
- ``fail_refresh(name, times=t)`` — the next ``t`` fence refreshes of that
  replica raise :class:`InjectedRefreshFailure`.

Plans start disarmed; every hook is a no-op until :meth:`arm` runs, so a
server can be constructed (and warmed) with the plan attached and the fault
clock starts only when the measured phase does.  All state is guarded by a
single internal lock; hook cost while disarmed is one attribute read.
"""

from __future__ import annotations

import threading
import time
from typing import Any


class InjectedFault(RuntimeError):
    """Base class for all injected failures (never raised by real code)."""


class InjectedCrash(InjectedFault):
    """The routed replica crashed: the whole batch attempt is lost."""


class InjectedPoison(InjectedFault):
    """A poisoned request: fails deterministically on every replica."""


class InjectedReject(InjectedFault):
    """The executor rejected the batch before any replica ran it."""


class InjectedRefreshFailure(InjectedFault):
    """A replica's ``refresh()`` failed during a fence."""


class FaultPlan:
    """A seeded schedule of failures injected into the serving path."""

    def __init__(self, seed: int = 0) -> None:
        """Create an empty, disarmed plan (``seed`` is recorded for reports)."""
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._armed = False
        self._crash_at: dict[str, int] = {}  # guarded-by: self._lock
        self._crashed: set[str] = set()  # guarded-by: self._lock
        self._slow: dict[str, tuple[float, float]] = {}  # guarded-by: self._lock
        self._poison_markers: list[str] = []  # guarded-by: self._lock
        self._reject_window: tuple[int, int] | None = None  # guarded-by: self._lock
        self._refresh_failures: dict[str, int] = {}  # guarded-by: self._lock
        self._batch_seq: dict[str, int] = {}  # guarded-by: self._lock
        self._dispatch_seq = 0  # guarded-by: self._lock
        self._counts: dict[str, Any] = {}  # guarded-by: self._lock
        self._crash_fired: dict[str, dict[str, float]] = {}  # guarded-by: self._lock

    # -- schedule builders -------------------------------------------------

    def crash_replica(self, name: str, at_batch: int = 1) -> "FaultPlan":
        """Crash ``name`` on its ``at_batch``-th armed batch; stays down until healed."""
        with self._lock:
            self._crash_at[name] = max(1, int(at_batch))
        return self

    def slow_replica(
        self, name: str, factor: float = 1.0, extra_s: float = 0.0
    ) -> "FaultPlan":
        """Stretch each batch on ``name`` to ``factor``× service + ``extra_s`` seconds."""
        with self._lock:
            self._slow[name] = (max(1.0, float(factor)), max(0.0, float(extra_s)))
        return self

    def poison_matching(self, marker: str) -> "FaultPlan":
        """Poison every request whose constants or text contain ``marker``."""
        with self._lock:
            self._poison_markers.append(str(marker))
        return self

    def reject_dispatch(self, at_dispatch: int = 1, count: int = 1) -> "FaultPlan":
        """Reject dispatches ``at_dispatch .. at_dispatch + count - 1`` (armed count)."""
        with self._lock:
            lo = max(1, int(at_dispatch))
            self._reject_window = (lo, lo + max(1, int(count)))
        return self

    def fail_refresh(self, name: str, times: int = 1) -> "FaultPlan":
        """Make the next ``times`` fence refreshes of ``name`` raise."""
        with self._lock:
            self._refresh_failures[name] = max(1, int(times))
        return self

    # -- lifecycle ---------------------------------------------------------

    def arm(self) -> "FaultPlan":
        """Start the fault clock: reset sequence counters and enable hooks."""
        with self._lock:
            self._batch_seq.clear()
            self._dispatch_seq = 0
            self._armed = True
        return self

    def disarm(self) -> "FaultPlan":
        """Stop injecting (schedule and counters are preserved)."""
        with self._lock:
            self._armed = False
        return self

    def heal(self, name: str) -> None:
        """Clear the crashed state of ``name`` (called by replica rebuild)."""
        with self._lock:
            self._crashed.discard(name)
            self._crash_at.pop(name, None)

    # -- injection hooks ---------------------------------------------------

    def on_batch_start(self, replica: str) -> None:
        """Raise :class:`InjectedCrash` if ``replica`` is (or just became) crashed."""
        with self._lock:
            if not self._armed:
                return
            if replica in self._crashed:
                self._bump("crash")
                raise InjectedCrash(f"replica {replica} is crashed (injected)")
            at = self._crash_at.get(replica)
            if at is None:
                return
            n = self._batch_seq.get(replica, 0) + 1
            self._batch_seq[replica] = n
            if n >= at:
                self._crashed.add(replica)
                self._crash_fired[replica] = {"batch": float(n), "t": time.monotonic()}
                self._bump("crash")
                raise InjectedCrash(
                    f"replica {replica} crashed at armed batch {n} (injected)"
                )

    def solve_penalty(self, replica: str, measured_s: float) -> float:
        """Extra seconds to sleep after a batch on ``replica`` (0.0 when clean)."""
        with self._lock:
            if not self._armed:
                return 0.0
            cfg = self._slow.get(replica)
            if cfg is None:
                return 0.0
            factor, extra = cfg
            penalty = (factor - 1.0) * max(0.0, measured_s) + extra
            if penalty > 0.0:
                self._counts["slow_s"] = self._counts.get("slow_s", 0.0) + penalty
            return penalty

    def on_execute_prepared(self, prepared: list) -> None:
        """Raise :class:`InjectedPoison` if any prepared request matches a marker."""
        with self._lock:
            if not self._armed or not self._poison_markers:
                return
            markers = tuple(self._poison_markers)
        for item in prepared:
            if self.matches_poison(item):
                with self._lock:
                    self._bump("poison")
                raise InjectedPoison(
                    f"poisoned request (markers={markers!r}): {item!r}"
                )

    def on_dispatch(self) -> None:
        """Raise :class:`InjectedReject` if this armed dispatch is scheduled to fail."""
        with self._lock:
            if not self._armed or self._reject_window is None:
                return
            self._dispatch_seq += 1
            lo, hi = self._reject_window
            if lo <= self._dispatch_seq < hi:
                self._bump("reject")
                raise InjectedReject(
                    f"dispatch {self._dispatch_seq} rejected (injected)"
                )

    def on_refresh(self, replica: str) -> None:
        """Raise :class:`InjectedRefreshFailure` if a refresh failure is pending."""
        with self._lock:
            if not self._armed:
                return
            left = self._refresh_failures.get(replica, 0)
            if left > 0:
                self._refresh_failures[replica] = left - 1
                self._bump("refresh")
                raise InjectedRefreshFailure(
                    f"refresh of replica {replica} failed (injected)"
                )

    # -- introspection -----------------------------------------------------

    def matches_poison(self, item: Any) -> bool:
        """True when a prepared ``(query, instance)`` pair matches a poison marker."""
        with self._lock:
            markers = tuple(self._poison_markers)
        if not markers:
            return False
        try:
            _q, inst = item
        except (TypeError, ValueError):
            _q, inst = item, None
        consts = getattr(inst, "constants", None)
        hay = " ".join(str(c) for c in consts) if consts else repr(_q)
        return any(m in hay for m in markers)

    def bind(self, replica: str) -> "BoundFaults":
        """Return the per-replica hook object installed as ``Engine.faults``."""
        return BoundFaults(self, replica)

    def counts(self) -> dict[str, Any]:
        """Snapshot of fired-injection counters (crash/poison/reject/refresh/slow_s)."""
        with self._lock:
            return dict(self._counts)

    def crash_fired(self, replica: str) -> dict[str, float] | None:
        """When (armed batch no. + monotonic time) ``replica`` crashed, if it did."""
        with self._lock:
            rec = self._crash_fired.get(replica)
            return dict(rec) if rec is not None else None

    # requires-lock: _lock
    def _bump(self, key: str) -> None:
        self._counts[key] = self._counts.get(key, 0) + 1


class BoundFaults:
    """A plan bound to one replica name — the ``Engine.faults`` hook surface."""

    __slots__ = ("plan", "replica")

    def __init__(self, plan: FaultPlan, replica: str) -> None:
        """Bind ``plan``'s request-level hooks to ``replica``."""
        self.plan = plan
        self.replica = replica

    def on_execute_prepared(self, prepared: list) -> None:
        """Engine-side hook: poison check over a prepared batch."""
        self.plan.on_execute_prepared(prepared)
