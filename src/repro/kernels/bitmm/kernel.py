"""Pallas TPU kernel: bit-packed boolean vector-batch x matrix product.

Computes ``out[q, jw] = OR_{i : x[q,i]=1} A[i, jw]`` over ``uint32`` words,
i.e. the paper's ``×b`` with the adjacency matrix resident in HBM/VMEM at
**1 bit per edge** (64x denser than bf16, 32x than int8).  The OR-AND
semiring runs on the VPU: a masked select of packed rows followed by an
OR-reduction over the contraction block.

Tiling: grid = (J, I) with the contraction dimension I innermost so each
``out`` tile is revisited sequentially and OR-accumulated in VMEM.

    x block   (V,  BI)   at (0, i)      — the query-variable frontier bits
    A block   (BI, BJW)  at (i, j)      — packed adjacency tile
    out block (V,  BJW)  at (0, j)      — packed result tile (accumulated)

VMEM per step = V*BI*4 + BI*BJW*4 + V*BJW*4 bytes plus the [V, BI, BJW]
select intermediate in VREGs; defaults (V<=8, BI=256, BJW=128) stay well
under the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitmm_kernel(x_ref, a_ref, o_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [V, BI] uint32 (0/1 flags)
    a = a_ref[...]  # [BI, BJW] uint32 packed words
    # rows of A where the frontier bit is set, OR-reduced over the block.
    masked = jnp.where(
        (x != 0)[:, :, None], a[None, :, :], jnp.uint32(0)
    )  # [V, BI, BJW]
    acc = jax.lax.reduce(
        masked, jnp.uint32(0), jax.lax.bitwise_or, (1,)
    )  # [V, BJW]
    o_ref[...] = jnp.bitwise_or(o_ref[...], acc)


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_jw", "interpret")
)
def bitmm_packed(
    x_flags: jax.Array,  # uint32 [V, n] 0/1 per node
    a_packed: jax.Array,  # uint32 [n, nw]
    *,
    block_i: int = 256,
    block_jw: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Packed boolean product; returns uint32 [V, nw]."""
    v, n = x_flags.shape
    n_a, nw = a_packed.shape
    assert n == n_a, (x_flags.shape, a_packed.shape)

    # pad every dimension to its block multiple (zeros are OR-identities)
    vp = -(-v // 8) * 8
    np_ = -(-n // block_i) * block_i
    nwp = -(-nw // block_jw) * block_jw
    x_p = jnp.zeros((vp, np_), jnp.uint32).at[:v, :n].set(x_flags)
    a_p = jnp.zeros((np_, nwp), jnp.uint32).at[:n, :nw].set(a_packed)

    grid = (nwp // block_jw, np_ // block_i)
    out = pl.pallas_call(
        _bitmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((vp, block_i), lambda j, i: (0, i)),
            pl.BlockSpec((block_i, block_jw), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((vp, block_jw), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((vp, nwp), jnp.uint32),
        interpret=interpret,
    )(x_p, a_p)
    return out[:v, :nw]
