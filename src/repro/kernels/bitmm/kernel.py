"""Pallas TPU kernel: bit-packed boolean vector-batch x matrix product.

Computes ``out[q, jw] = OR_{i : x[q,i]=1} A[i, jw]`` over ``uint32`` words,
i.e. the paper's ``×b`` with the adjacency matrix resident in HBM/VMEM at
**1 bit per edge** (64x denser than bf16, 32x than int8).  The OR-AND
semiring runs on the VPU: a masked select of packed rows followed by an
OR-reduction over the contraction block.

Tiling: grid = (J, I) with the contraction dimension I innermost so each
``out`` tile is revisited sequentially and OR-accumulated in VMEM.

    x block   (V,  BI)   at (0, i)      — the query-variable frontier bits
    A block   (BI, BJW)  at (i, j)      — packed adjacency tile
    out block (V,  BJW)  at (0, j)      — packed result tile (accumulated)

VMEM per step = V*BI*4 + BI*BJW*4 + V*BJW*4 bytes plus the [V, BI, BJW]
select intermediate in VREGs; defaults (V<=8, BI=256, BJW=128) stay well
under the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitmm_kernel(x_ref, a_ref, o_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [V, BI] uint32 (0/1 flags)
    a = a_ref[...]  # [BI, BJW] uint32 packed words
    # rows of A where the frontier bit is set, OR-reduced over the block.
    masked = jnp.where(
        (x != 0)[:, :, None], a[None, :, :], jnp.uint32(0)
    )  # [V, BI, BJW]
    acc = jax.lax.reduce(
        masked, jnp.uint32(0), jax.lax.bitwise_or, (1,)
    )  # [V, BJW]
    o_ref[...] = jnp.bitwise_or(o_ref[...], acc)


def _bitmm_apply_kernel(xc_ref, a_ref, f_ref, xe_ref, o_ref, chg_ref):
    """Fused sweep step: packed product, AND-combine, changed accumulation.

    Grid (J, I), I innermost.  ``o_ref`` doubles as the y accumulator: for
    i < I-1 it holds the partial packed product; the last contraction step
    turns it into the updated chi tile in place and ORs the changed words
    into ``chg_ref`` — one revisited output tile, no scratch buffer.
    """
    j, i = pl.program_id(0), pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when((j == 0) & (i == 0))
    def _init_changed():
        chg_ref[...] = jnp.zeros_like(chg_ref)

    @pl.when(i == 0)
    def _init_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    xw = xc_ref[...]  # [V, BIW] packed chi words of the contraction block
    a = a_ref[...]  # [1, BIW, 32, BJW] packed adjacency tile, word-split rows
    # frontier bits of the block, extracted word-wise on the VPU (bit s of
    # word w is contraction row 32*w + s — matching a's host-side reshape)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (xw[:, :, None] >> shifts) & jnp.uint32(1)  # [V, BIW, 32]
    masked = jnp.where(
        (bits != 0)[..., None], a, jnp.uint32(0)
    )  # [V, BIW, 32, BJW]
    acc = jax.lax.reduce(masked, jnp.uint32(0), jax.lax.bitwise_or, (1, 2))
    o_ref[...] = jnp.bitwise_or(o_ref[...], acc)

    @pl.when(i == ni - 1)
    def _combine():
        y = o_ref[...]  # [V, BJW] finished packed product chi ×b A
        f = f_ref[...]  # [V, V] lhs-rhs inequality flags
        # chi[l] &= AND_{r: f[l,r]} y[r]  ==  chi[l] &= ~OR_{r: f[l,r]} ~y[r]
        viol = jnp.where(
            (f != 0)[:, :, None], jnp.bitwise_not(y)[None, :, :], jnp.uint32(0)
        )  # [V(lhs), V(rhs), BJW]
        bad = jax.lax.reduce(viol, jnp.uint32(0), jax.lax.bitwise_or, (1,))
        old = xe_ref[...]  # [V, BJW] chi tile being updated
        new = jnp.bitwise_and(old, jnp.bitwise_not(bad))
        o_ref[...] = new
        delta = jax.lax.reduce(
            jnp.bitwise_xor(new, old), jnp.uint32(0), jax.lax.bitwise_or, (0, 1)
        )
        chg_ref[...] = jnp.bitwise_or(
            chg_ref[...], jnp.full((1, 1), delta, jnp.uint32)
        )


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_jw", "interpret")
)
def bitmm_apply_packed(
    chi_packed: jax.Array,  # uint32 [V, nw] packed chi rows
    a_packed: jax.Array,  # uint32 [n, nw] packed adjacency
    lhs_flags: jax.Array,  # uint32 [V, V] 0/1; [l, r] set iff ineq chi[l] <= chi[r] xb A
    *,
    block_i: int = 256,
    block_jw: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One fused operator application on bit-packed chi.

    Computes ``y = chi ×b A`` and ``chi'[l] = chi[l] & AND_{r: F[l,r]} y[r]``
    in a single Pallas grid; returns ``(chi', changed)`` with ``changed`` a
    uint32 scalar that is nonzero iff any chi word moved.  Everything stays
    packed: HBM traffic is 1 bit per node end-to-end, and the former
    bitmm → unpack → gather → ``jnp.all`` → AND chain is one kernel launch.
    """
    assert block_i % 32 == 0, block_i
    v, nw = chi_packed.shape
    n, nw_a = a_packed.shape
    assert nw_a == nw, (chi_packed.shape, a_packed.shape)
    assert lhs_flags.shape == (v, v), (lhs_flags.shape, v)

    vp = -(-v // 8) * 8
    np_ = -(-n // block_i) * block_i
    nwp = -(-nw // block_jw) * block_jw
    biw = block_i // 32
    # chi plays two roles: contraction input (its bits select A rows, so its
    # word axis pads to np_/32) and elementwise input (tiles like the
    # output, padding to nwp).  Zero padding is the OR/AND identity in both.
    xc = jnp.zeros((vp, np_ // 32), jnp.uint32).at[:v, :nw].set(chi_packed)
    xe = jnp.zeros((vp, nwp), jnp.uint32).at[:v, :nw].set(chi_packed)
    a_p = jnp.zeros((np_, nwp), jnp.uint32).at[:n, :nw].set(a_packed)
    # row 32*w + s of block b lands at [b, w, s, :]: the kernel's bit
    # extraction indexes words, never reshapes inside the kernel
    a4 = a_p.reshape(np_ // block_i, biw, 32, nwp)
    f_p = jnp.zeros((vp, vp), jnp.uint32).at[:v, :v].set(lhs_flags)

    grid = (nwp // block_jw, np_ // block_i)
    chi_new, changed = pl.pallas_call(
        _bitmm_apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((vp, biw), lambda j, i: (0, i)),
            pl.BlockSpec((1, biw, 32, block_jw), lambda j, i: (i, 0, 0, j)),
            pl.BlockSpec((vp, vp), lambda j, i: (0, 0)),
            pl.BlockSpec((vp, block_jw), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((vp, block_jw), lambda j, i: (0, j)),
            pl.BlockSpec((1, 1), lambda j, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((vp, nwp), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(xc, a4, f_p, xe)
    return chi_new[:v, :nw], changed[0, 0]


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_jw", "interpret")
)
def bitmm_packed(
    x_flags: jax.Array,  # uint32 [V, n] 0/1 per node
    a_packed: jax.Array,  # uint32 [n, nw]
    *,
    block_i: int = 256,
    block_jw: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Packed boolean product; returns uint32 [V, nw]."""
    v, n = x_flags.shape
    n_a, nw = a_packed.shape
    assert n == n_a, (x_flags.shape, a_packed.shape)

    # pad every dimension to its block multiple (zeros are OR-identities)
    vp = -(-v // 8) * 8
    np_ = -(-n // block_i) * block_i
    nwp = -(-nw // block_jw) * block_jw
    x_p = jnp.zeros((vp, np_), jnp.uint32).at[:v, :n].set(x_flags)
    a_p = jnp.zeros((np_, nwp), jnp.uint32).at[:n, :nw].set(a_packed)

    grid = (nwp // block_jw, np_ // block_i)
    out = pl.pallas_call(
        _bitmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((vp, block_i), lambda j, i: (0, i)),
            pl.BlockSpec((block_i, block_jw), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((vp, block_jw), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((vp, nwp), jnp.uint32),
        interpret=interpret,
    )(x_p, a_p)
    return out[:v, :nw]
