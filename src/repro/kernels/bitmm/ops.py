"""jit'd public wrappers for the bitmm kernel.

``bitmm`` is the drop-in boolean product used by
:func:`repro.core.dualsim.solve_packed`: boolean frontier in, boolean rows
out, packed adjacency in between.  On CPU we run the Pallas kernel in
interpret mode; on TPU the same call compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops
from . import kernel as _kernel
from . import ref as _ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref"))
def bitmm(
    x: jax.Array,  # bool [V, n]
    a_packed: jax.Array,  # uint32 [n, nw]
    *,
    interpret: bool = False,
    use_ref: bool = False,
) -> jax.Array:
    """Returns bool [V, n_cols] where n_cols = n (square adjacency)."""
    n = x.shape[-1]
    if use_ref:
        return _ref.bitmm_ref(x, a_packed, n)
    flags = x.astype(jnp.uint32)
    out_packed = _kernel.bitmm_packed(flags, a_packed, interpret=interpret)
    return bitops.unpack(out_packed, n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmm_packed(
    x_packed: jax.Array,  # uint32 [V, nw] packed frontier
    a_packed: jax.Array,  # uint32 [n, nw]
    n: int | None = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Fully packed variant: packed frontier in, packed result out."""
    nn = a_packed.shape[0]
    flags = bitops.unpack(x_packed, nn).astype(jnp.uint32)
    return _kernel.bitmm_packed(flags, a_packed, interpret=interpret)
