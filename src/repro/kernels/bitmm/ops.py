"""jit'd public wrappers for the bitmm kernels.

``bitmm`` is the drop-in boolean product used by
:func:`repro.core.dualsim.solve_packed`: boolean frontier in, boolean rows
out, packed adjacency in between.  ``bitmm_apply`` is the fused sweep step
of :func:`repro.core.dualsim.solve_packed_fused`: packed chi in, packed chi
out, product + AND-combine + changed detection in one launch.

``interpret=None`` (the default) auto-detects the backend: on CPU the
Pallas kernel runs in interpret mode, on accelerators it compiles — direct
callers no longer silently interpret on TPU or crash on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops
from . import kernel as _kernel
from . import ref as _ref


def _resolve_interpret(interpret: bool | None) -> bool:
    """Backend auto-detection: interpret the kernel only off-accelerator."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref"))
def bitmm(
    x: jax.Array,  # bool [V, n]
    a_packed: jax.Array,  # uint32 [n, nw]
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Returns bool [V, n_cols] where n_cols = n (square adjacency)."""
    n = x.shape[-1]
    if use_ref:
        return _ref.bitmm_ref(x, a_packed, n)
    flags = x.astype(jnp.uint32)
    out_packed = _kernel.bitmm_packed(
        flags, a_packed, interpret=_resolve_interpret(interpret)
    )
    return bitops.unpack(out_packed, n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmm_packed(
    x_packed: jax.Array,  # uint32 [V, nw] packed frontier
    a_packed: jax.Array,  # uint32 [n, nw]
    n: int | None = None,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Fully packed variant: packed frontier in, packed result out."""
    nn = a_packed.shape[0]
    flags = bitops.unpack(x_packed, nn).astype(jnp.uint32)
    return _kernel.bitmm_packed(
        flags, a_packed, interpret=_resolve_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref"))
def bitmm_apply(
    chi_packed: jax.Array,  # uint32 [V, nw] packed chi
    a_packed: jax.Array,  # uint32 [n, nw] packed adjacency of one operator
    lhs_flags: jax.Array,  # uint32 [V, V] inequality flags for that operator
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused operator application on packed chi (see ``bitmm_apply_packed``).

    Returns ``(chi', changed)``: the AND-updated packed chi and a uint32
    scalar, nonzero iff any word moved.  ``use_ref`` swaps in the pure-jnp
    oracle (:func:`..ref.bitmm_apply_ref`) — the same fixpoint step, useful
    both for parity tests and as the XLA lowering where no accelerator is
    present.
    """
    if use_ref:
        n = a_packed.shape[0]
        return _ref.bitmm_apply_ref(chi_packed, a_packed, lhs_flags, n)
    return _kernel.bitmm_apply_packed(
        chi_packed, a_packed, lhs_flags,
        interpret=_resolve_interpret(interpret),
    )
