"""Pure-jnp oracle for the bit-packed boolean matrix product.

``bitmm(x, A)[q, j] = OR_i ( x[q, i] AND A[i, j] )`` — the paper's ``×b``
(footnote 2), with ``A`` stored bit-packed as ``uint32[n, ceil(n_cols/32)]``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitops


def bitmm_ref(x, a_packed, n_cols: int):
    """x: bool[V, n]; a_packed: uint32[n, nw]; returns bool[V, n_cols]."""
    a = bitops.unpack(a_packed, n_cols)  # bool [n, n_cols]
    y = jnp.einsum(
        "vn,nk->vk",
        x.astype(jnp.float32),
        a.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return y > 0


def bitmm_packed_ref(x, a_packed, n_cols: int):
    """Same, but returns the packed uint32 result."""
    return bitops.pack(bitmm_ref(x, a_packed, n_cols))
