"""Pure-jnp oracle for the bit-packed boolean matrix product.

``bitmm(x, A)[q, j] = OR_i ( x[q, i] AND A[i, j] )`` — the paper's ``×b``
(footnote 2), with ``A`` stored bit-packed as ``uint32[n, ceil(n_cols/32)]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops


def bitmm_ref(x, a_packed, n_cols: int):
    """x: bool[V, n]; a_packed: uint32[n, nw]; returns bool[V, n_cols]."""
    a = bitops.unpack(a_packed, n_cols)  # bool [n, n_cols]
    y = jnp.einsum(
        "vn,nk->vk",
        x.astype(jnp.float32),
        a.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return y > 0


def bitmm_packed_ref(x, a_packed, n_cols: int):
    """Same, but returns the packed uint32 result."""
    return bitops.pack(bitmm_ref(x, a_packed, n_cols))


def bitmm_apply_ref(chi_packed, a_packed, lhs_flags, n_cols: int):
    """Oracle for the fused sweep step :func:`..kernel.bitmm_apply_packed`.

    ``chi'[l] = chi[l] & AND_{r: lhs_flags[l, r]} (chi ×b A)[r]``, evaluated
    in plain boolean space; returns ``(chi'_packed, changed)`` with
    ``changed`` nonzero iff any word moved.
    """
    n = a_packed.shape[0]
    chi = bitops.unpack(chi_packed, n)
    y = bitmm_ref(chi, a_packed, n_cols)  # bool [V, n_cols]
    # bad[l, c] = OR_{r: F[l,r]} ~y[r, c]  (float einsum, like bitmm_ref)
    bad = jnp.einsum(
        "lr,rc->lc",
        (lhs_flags != 0).astype(jnp.float32),
        (~y).astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) > 0
    new = jnp.logical_and(chi[:, : y.shape[1]], ~bad)
    new_packed = bitops.pack(new)
    changed = jnp.any(new_packed != chi_packed[:, : new_packed.shape[1]])
    return new_packed, changed.astype(jnp.uint32)


def bitmm_apply_words(chi_packed, a_packed, lhs_flags):
    """Word-wise XLA lowering of the fused sweep step (no Pallas).

    Same contract as :func:`bitmm_apply_ref` but every reduction runs over
    packed ``uint32`` words — the product is a masked OR-reduce over the
    frontier bits, the combine a masked OR-reduce over ``~y`` rows.  This is
    the serving path where no accelerator is present: measured ~9x faster
    than interpreting the Pallas kernel on CPU, bit-identical results.
    """
    n = a_packed.shape[0]
    zero = jnp.uint32(0)
    bits = bitops.unpack(chi_packed, n)  # bool [V, n]
    y = jax.lax.reduce(
        jnp.where(bits[:, :, None], a_packed[None, :, :], zero),
        zero, jax.lax.bitwise_or, (1,),
    )  # uint32 [V, nw] packed product
    viol = jnp.where(
        (lhs_flags != 0)[:, :, None], jnp.bitwise_not(y)[None, :, :], zero
    )
    bad = jax.lax.reduce(viol, zero, jax.lax.bitwise_or, (1,))
    new = jnp.bitwise_and(chi_packed, jnp.bitwise_not(bad))
    changed = jnp.any(new != chi_packed)
    return new, changed.astype(jnp.uint32)
