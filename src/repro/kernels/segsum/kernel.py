"""Pallas TPU kernel: windowed segment-sum over sorted segment ids.

The scatter hot spot of the GNN zoo and the sparse dual-simulation engine:
``out[s] += sum_{i: seg[i]=s} vals[i]`` with ``seg`` sorted.  The TPU has no
scatter unit, so the reduce is reformulated as a one-hot matmul per edge
block — the MXU does the scatter (kernel_taxonomy §GNN, GE-SpMM style).

Tiling: grid over edge blocks.  A host-precomputed, scalar-prefetched map
``win[i]`` gives the segment-window block each edge block writes
(``BlockSpec`` index map reads it), valid because sorted ids make windows
monotone non-decreasing; the host layout guarantees each edge block touches
at most one window (`prepare`: blocks are split at window boundaries).
Revisited windows accumulate in VMEM; first visit initializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def prepare(
    vals: np.ndarray, seg_ids: np.ndarray, num_segments: int,
    block_e: int = 256, block_n: int = 256,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side layout: split/pad edge blocks so each touches ONE segment
    window of ``block_n``.  Returns (vals_p, seg_p, win, n_pad).
    Padding rows carry segment id = window_start (sums zeros — vals are 0).
    """
    e = len(seg_ids)
    order = np.argsort(seg_ids, kind="stable")
    seg_s, vals_s = seg_ids[order], vals[order]
    blocks_v, blocks_s, win = [], [], []
    i = 0
    while i < e:
        w = int(seg_s[i]) // block_n
        j = i
        while j < e and j - i < block_e and int(seg_s[j]) // block_n == w:
            j += 1
        bs = np.full(block_e, w * block_n, np.int32)
        bv = np.zeros((block_e,) + vals.shape[1:], vals.dtype)
        bs[: j - i] = seg_s[i:j]
        bv[: j - i] = vals_s[i:j]
        blocks_s.append(bs)
        blocks_v.append(bv)
        win.append(w)
        i = j
    n_pad = -(-num_segments // block_n) * block_n
    n_win = n_pad // block_n
    # every output window must be visited at least once (unvisited pallas
    # output blocks are undefined): insert zero blocks for uncovered windows
    covered = set(win)
    merged_v, merged_s, merged_w = [], [], []
    k = 0
    for w in range(n_win):
        if w in covered:
            while k < len(win) and win[k] == w:
                merged_v.append(blocks_v[k]); merged_s.append(blocks_s[k])
                merged_w.append(w); k += 1
        else:
            merged_v.append(np.zeros((block_e,) + vals.shape[1:], vals.dtype))
            merged_s.append(np.full(block_e, w * block_n, np.int32))
            merged_w.append(w)
    blocks_v, blocks_s, win = merged_v, merged_s, merged_w
    return (
        np.concatenate(blocks_v).reshape(len(win), block_e, *vals.shape[1:]),
        np.stack(blocks_s),
        np.asarray(win, np.int32),
        n_pad,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_n", "interpret")
)
def segsum_blocks(
    vals_b: jax.Array,  # [G, BE, D]
    seg_b: jax.Array,  # [G, BE] absolute sorted ids
    win: jax.Array,  # [G] window block per edge block
    *,
    num_segments: int,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    g, be, d = vals_b.shape
    n_pad = -(-num_segments // block_n) * block_n
    dp = -(-d // 128) * 128
    vals_p = jnp.zeros((g, be, dp), vals_b.dtype).at[:, :, :d].set(vals_b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i, win: (i, 0)),
            pl.BlockSpec((1, be, dp), lambda i, win: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, dp), lambda i, win: (win[i], 0)),
    )

    def kern(win_ref, seg_ref, val_ref, out_ref):
        i = pl.program_id(0)

        @pl.when((i == 0) | (win_ref[i] != win_ref[jnp.maximum(i - 1, 0)]))
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        base = win_ref[i] * block_n
        local = seg_ref[0] - base  # [BE]
        onehot = (
            local[None, :] == jax.lax.iota(jnp.int32, block_n)[:, None]
        ).astype(val_ref.dtype)
        out_ref[...] += jnp.dot(
            onehot, val_ref[0], preferred_element_type=out_ref.dtype
        )

    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, dp), vals_b.dtype),
        interpret=interpret,
    )(win, seg_b, vals_p)
    return out[:num_segments, :d]
