"""Pallas TPU kernels: windowed segment-sum and segmented-OR over sorted ids.

The scatter hot spot of the GNN zoo and the sparse dual-simulation engine:
``out[s] += sum_{i: seg[i]=s} vals[i]`` with ``seg`` sorted.  The TPU has no
scatter unit, so the reduce is reformulated as a one-hot matmul per edge
block — the MXU does the scatter (kernel_taxonomy §GNN, GE-SpMM style).

Tiling: grid over edge blocks.  A host-precomputed, scalar-prefetched map
``win[i]`` gives the segment-window block each edge block writes
(``BlockSpec`` index map reads it), valid because sorted ids make windows
monotone non-decreasing; the host layout guarantees each edge block touches
at most one window (`prepare`: blocks are split at window boundaries).
Revisited windows accumulate in VMEM; first visit initializes.

``segor_blocks`` generalizes the same layout to the segmented OR the
edge-list dual-simulation engines run every sweep (DESIGN.md Sect. 12):
edges are blocked by destination *word* window, each block one-hot-matmuls
its gathered frontier bits into per-destination counts, and an exact f32
two-matmul bit-pack turns the ``block_n`` destination rows of a window into
``block_n / 32`` output words — OR-accumulated in VMEM, so ``y`` leaves the
kernel already packed ``uint32`` and the engines never touch an ``[n]``-wide
bool plane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def prepare(
    vals: np.ndarray, seg_ids: np.ndarray, num_segments: int,
    block_e: int = 256, block_n: int = 256,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side layout: split/pad edge blocks so each touches ONE segment
    window of ``block_n``.  Returns (vals_p, seg_p, win, n_pad).
    Padding rows carry segment id = window_start (sums zeros — vals are 0).
    """
    e = len(seg_ids)
    order = np.argsort(seg_ids, kind="stable")
    seg_s, vals_s = seg_ids[order], vals[order]
    blocks_v, blocks_s, win = [], [], []
    i = 0
    while i < e:
        w = int(seg_s[i]) // block_n
        j = i
        while j < e and j - i < block_e and int(seg_s[j]) // block_n == w:
            j += 1
        bs = np.full(block_e, w * block_n, np.int32)
        bv = np.zeros((block_e,) + vals.shape[1:], vals.dtype)
        bs[: j - i] = seg_s[i:j]
        bv[: j - i] = vals_s[i:j]
        blocks_s.append(bs)
        blocks_v.append(bv)
        win.append(w)
        i = j
    n_pad = -(-num_segments // block_n) * block_n
    n_win = n_pad // block_n
    # every output window must be visited at least once (unvisited pallas
    # output blocks are undefined): insert zero blocks for uncovered windows
    covered = set(win)
    merged_v, merged_s, merged_w = [], [], []
    k = 0
    for w in range(n_win):
        if w in covered:
            while k < len(win) and win[k] == w:
                merged_v.append(blocks_v[k]); merged_s.append(blocks_s[k])
                merged_w.append(w); k += 1
        else:
            merged_v.append(np.zeros((block_e,) + vals.shape[1:], vals.dtype))
            merged_s.append(np.full(block_e, w * block_n, np.int32))
            merged_w.append(w)
    blocks_v, blocks_s, win = merged_v, merged_s, merged_w
    return (
        np.concatenate(blocks_v).reshape(len(win), block_e, *vals.shape[1:]),
        np.stack(blocks_s),
        np.asarray(win, np.int32),
        n_pad,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_n", "interpret")
)
def segsum_blocks(
    vals_b: jax.Array,  # [G, BE, D]
    seg_b: jax.Array,  # [G, BE] absolute sorted ids
    win: jax.Array,  # [G] window block per edge block
    *,
    num_segments: int,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    g, be, d = vals_b.shape
    n_pad = -(-num_segments // block_n) * block_n
    dp = -(-d // 128) * 128
    vals_p = jnp.zeros((g, be, dp), vals_b.dtype).at[:, :, :d].set(vals_b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i, win: (i, 0)),
            pl.BlockSpec((1, be, dp), lambda i, win: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, dp), lambda i, win: (win[i], 0)),
    )

    def kern(win_ref, seg_ref, val_ref, out_ref):
        i = pl.program_id(0)

        @pl.when((i == 0) | (win_ref[i] != win_ref[jnp.maximum(i - 1, 0)]))
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        base = win_ref[i] * block_n
        local = seg_ref[0] - base  # [BE]
        onehot = (
            local[None, :] == jax.lax.iota(jnp.int32, block_n)[:, None]
        ).astype(val_ref.dtype)
        out_ref[...] += jnp.dot(
            onehot, val_ref[0], preferred_element_type=out_ref.dtype
        )

    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, dp), vals_b.dtype),
        interpret=interpret,
    )(win, seg_b, vals_p)
    return out[:num_segments, :d]


# Edge-block counts are rounded up to this multiple so modest edge churn
# under ``patch_operands`` lands in existing pad blocks instead of changing
# the blocked-layout shapes (zero retraces on warm resume, DESIGN.md 12).
SEG_G_PAD = 8


def prepare_segor(
    seg_ids: np.ndarray, num_segments: int,
    block_e: int = 256, block_n: int = 256, min_g: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side blocked layout for the segmented-OR kernel.

    Sorts edges by destination id, splits them into blocks of ``block_e``
    that each touch one destination window of ``block_n`` ids, and returns
    ``(idx_b, seg_b, win, n_pad)``: ``idx_b [G, BE]`` int32 gather indices
    into the original edge axis, ``seg_b [G, BE]`` absolute destination
    ids, ``win [G]`` the window each block writes, and the padded node
    count ``n_pad``.

    Pad entries carry gather index 0 and the sentinel id ``n_pad`` — the
    sentinel lies outside every window (its one-hot column is all-zero) and
    is ``>= num_segments`` (a segment reduce drops it), so a pad row can
    never turn on a bit regardless of what index 0 gathers.  Callers must
    pass RAW destination ids (< num_segments): an EDGE_PAD-style pad id of
    ``n`` would alias bit ``n`` whenever ``n`` falls inside a live window.
    """
    if block_n % 32:
        raise ValueError("block_n must be a multiple of 32")
    seg_ids = np.asarray(seg_ids, np.int32)
    e = len(seg_ids)
    order = np.argsort(seg_ids, kind="stable").astype(np.int32)
    seg_s = seg_ids[order]
    if e and int(seg_s[-1]) >= num_segments:
        raise ValueError(
            "seg_ids must be < num_segments (pass raw, unpadded edges)"
        )
    n_pad = max(-(-num_segments // block_n), 1) * block_n
    n_win = n_pad // block_n
    blocks_i, blocks_s, win = [], [], []
    i = 0
    while i < e:
        w = int(seg_s[i]) // block_n
        j = i
        while j < e and j - i < block_e and int(seg_s[j]) // block_n == w:
            j += 1
        bi = np.zeros(block_e, np.int32)
        bs = np.full(block_e, n_pad, np.int32)
        bi[: j - i] = order[i:j]
        bs[: j - i] = seg_s[i:j]
        blocks_i.append(bi)
        blocks_s.append(bs)
        win.append(w)
        i = j
    # every output window must be visited at least once (unvisited pallas
    # output blocks are undefined): insert all-pad blocks where uncovered
    covered = set(win)
    merged_i, merged_s, merged_w = [], [], []
    k = 0
    for w in range(n_win):
        if w in covered:
            while k < len(win) and win[k] == w:
                merged_i.append(blocks_i[k])
                merged_s.append(blocks_s[k])
                merged_w.append(w)
                k += 1
        else:
            merged_i.append(np.zeros(block_e, np.int32))
            merged_s.append(np.full(block_e, n_pad, np.int32))
            merged_w.append(w)
    g = -(-max(len(merged_w), min_g, 1) // SEG_G_PAD) * SEG_G_PAD
    while len(merged_w) < g:  # trailing pad blocks keep win monotone
        merged_i.append(np.zeros(block_e, np.int32))
        merged_s.append(np.full(block_e, n_pad, np.int32))
        merged_w.append(n_win - 1)
    return (
        np.stack(merged_i),
        np.stack(merged_s),
        np.asarray(merged_w, np.int32),
        n_pad,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_n", "interpret")
)
def segor_blocks(
    vals_b: jax.Array,  # [G, BE, V] 0/1 frontier bits per blocked edge
    seg_b: jax.Array,  # [G, BE] absolute destination ids (pads = n_pad)
    win: jax.Array,  # [G] destination-word window per edge block
    *,
    num_segments: int,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Segmented OR over the ``prepare_segor`` layout -> ``uint32 [V, nw]``.

    Per block: one-hot matmul scatters the 0/1 frontier bits into
    per-destination counts, then an exact f32 two-matmul bit-pack (16 low +
    16 high bit planes; every partial sum < 2**16 is exactly representable)
    collapses the ``block_n`` destination rows to ``block_n / 32`` words,
    OR-accumulated into the revisited VMEM output window.  VMEM per step:
    one ``[block_n, VP]`` f32 counts tile + the ``[block_n/32, VP]`` uint32
    output window — ~¼ MB at the defaults, far under the ~16 MB budget.
    """
    g, be, v = vals_b.shape
    n_pad = max(-(-num_segments // block_n), 1) * block_n
    block_w = block_n // 32
    nw = -(-num_segments // 32)
    vp = -(-v // 128) * 128
    vals_p = (
        jnp.zeros((g, be, vp), jnp.float32)
        .at[:, :, :v]
        .set(vals_b.astype(jnp.float32))
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, be), lambda i, win: (i, 0)),
            pl.BlockSpec((1, be, vp), lambda i, win: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, vp), lambda i, win: (win[i], 0)),
    )

    def kern(win_ref, seg_ref, val_ref, out_ref):
        i = pl.program_id(0)

        @pl.when((i == 0) | (win_ref[i] != win_ref[jnp.maximum(i - 1, 0)]))
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        base = win_ref[i] * block_n
        local = seg_ref[0] - base  # [BE]; pad sentinels land >= block_n
        onehot = (
            local[None, :]
            == jax.lax.broadcasted_iota(jnp.int32, (block_n, be), 0)
        ).astype(jnp.float32)
        counts = jnp.dot(
            onehot, val_ref[0], preferred_element_type=jnp.float32
        )  # [block_n, VP]
        bits = (counts > 0).astype(jnp.float32)
        # exact f32 bit-pack: words[w] = sum_s 2^s * bits[32w + s], split
        # into 16-bit halves so every weight and partial sum stays exact
        w_ids = jax.lax.broadcasted_iota(jnp.int32, (block_w, block_n), 0)
        j_ids = jax.lax.broadcasted_iota(jnp.int32, (block_w, block_n), 1)
        s = j_ids - w_ids * 32
        # integer shifts, not exp2: exp2 lowers through exp(x * ln 2) and
        # can return 32767.998 for 2^15, which truncates to the wrong word
        pow2 = jnp.int32(1) << jnp.clip(s % 16, 0, 15)
        lo_w = jnp.where(
            (s >= 0) & (s < 16), pow2.astype(jnp.float32), 0.0
        )
        hi_w = jnp.where(
            (s >= 16) & (s < 32), pow2.astype(jnp.float32), 0.0
        )
        lo = jnp.dot(lo_w, bits, preferred_element_type=jnp.float32)
        hi = jnp.dot(hi_w, bits, preferred_element_type=jnp.float32)
        words = lo.astype(jnp.uint32) | (
            hi.astype(jnp.uint32) << jnp.uint32(16)
        )
        out_ref[...] = out_ref[...] | words

    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad // 32, vp), jnp.uint32),
        interpret=interpret,
    )(win, seg_b, vals_p)
    return out[:nw, :v].T
