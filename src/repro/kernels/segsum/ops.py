"""Public wrappers: host-side prepare + kernel call in one step."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel as _kernel
from . import ref as _ref


def _resolve_interpret(interpret: bool | None) -> bool:
    """Default to interpret mode off-TPU so the kernels run everywhere."""
    if interpret is not None:
        return interpret
    return jax.default_backend() == "cpu"


def segsum(
    vals: np.ndarray,
    seg_ids: np.ndarray,
    num_segments: int,
    *,
    block_e: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    use_ref: bool = False,
):
    """Segment sum over (unsorted OK) segment ids via the windowed kernel.

    ``prepare`` sorts and blocks on the host (the data pipeline does this
    once per graph); the device kernel is gather-free and scatter-free.
    """
    if use_ref:
        order = np.argsort(seg_ids, kind="stable")
        return _ref.segsum_ref(
            jnp.asarray(vals[order]), jnp.asarray(seg_ids[order]), num_segments
        )
    vb, sb, win, _ = _kernel.prepare(
        vals, seg_ids, num_segments, block_e=block_e, block_n=block_n
    )
    return _kernel.segsum_blocks(
        jnp.asarray(vb), jnp.asarray(sb), jnp.asarray(win),
        num_segments=num_segments, block_n=block_n, interpret=interpret,
    )


def segor(
    bits: np.ndarray,
    seg_ids: np.ndarray,
    num_segments: int,
    *,
    block_e: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
    impl: str = "kernel",
):
    """Segmented OR of 0/1 ``bits [V, E]`` over destination ids, packed.

    Returns ``uint32 [V, ceil(num_segments / 32)]`` with trailing pad bits
    zero.  ``impl`` selects the Pallas kernel (``"kernel"``, interpret mode
    auto-enabled off-TPU), the word-wise XLA lowering (``"words"``), or the
    ``bitops.pack``-based oracle (``"ref"``).
    """
    bits = np.asarray(bits)
    seg_ids = np.asarray(seg_ids, np.int32)
    if impl == "ref":
        return _ref.segor_ref(jnp.asarray(bits), jnp.asarray(seg_ids),
                              num_segments)
    if impl == "words":
        return _ref.segor_words(jnp.asarray(bits), jnp.asarray(seg_ids),
                                num_segments)
    if impl != "kernel":
        raise ValueError(f"unknown segor impl: {impl!r}")
    idx_b, seg_b, win, _ = _kernel.prepare_segor(
        seg_ids, num_segments, block_e=block_e, block_n=block_n
    )
    if bits.shape[1]:
        vals_b = bits[:, idx_b].transpose(1, 2, 0)  # [G, BE, V]
    else:  # no edges: all-pad blocks, nothing to gather
        vals_b = np.zeros(idx_b.shape + (bits.shape[0],), bits.dtype)
    return _kernel.segor_blocks(
        jnp.asarray(vals_b), jnp.asarray(seg_b), jnp.asarray(win),
        num_segments=num_segments, block_n=block_n,
        interpret=_resolve_interpret(interpret),
    )
