"""Public wrapper: host-side prepare + kernel call in one step."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import kernel as _kernel
from . import ref as _ref


def segsum(
    vals: np.ndarray,
    seg_ids: np.ndarray,
    num_segments: int,
    *,
    block_e: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    use_ref: bool = False,
):
    """Segment sum over (unsorted OK) segment ids via the windowed kernel.

    ``prepare`` sorts and blocks on the host (the data pipeline does this
    once per graph); the device kernel is gather-free and scatter-free.
    """
    if use_ref:
        order = np.argsort(seg_ids, kind="stable")
        return _ref.segsum_ref(
            jnp.asarray(vals[order]), jnp.asarray(seg_ids[order]), num_segments
        )
    vb, sb, win, _ = _kernel.prepare(
        vals, seg_ids, num_segments, block_e=block_e, block_n=block_n
    )
    return _kernel.segsum_blocks(
        jnp.asarray(vb), jnp.asarray(sb), jnp.asarray(win),
        num_segments=num_segments, block_n=block_n, interpret=interpret,
    )
