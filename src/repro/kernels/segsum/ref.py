"""Oracles and XLA lowerings for the tiled segment kernels.

* :func:`segsum_ref` — ``jax.ops.segment_sum`` over sorted segment ids
  (oracle for the windowed segment-sum kernel).
* :func:`segor_ref` — einsum-free oracle for the segmented-OR primitive:
  per-segment OR of frontier bits, returned bit-packed.  Trusted path:
  ``segment_max`` + :func:`repro.core.bitops.pack`.
* :func:`segor_words` — word-wise XLA lowering of segmented OR for
  backends without a compiled Pallas path (the ``bitmm_apply_words``
  pattern from PR 5): the reduced 0/1 plane goes straight from the segment
  reduce into ``uint32`` words with shifts and an OR-reduce — no
  ``reduce_sum`` (the signature primitive of ``bitops.pack``) and no bool
  plane ever materializes, which is what lets the edge-list engines carry
  bit-packed chi through their whole ``while_loop`` (DESIGN.md Sect. 12).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops


def segsum_ref(vals, seg_ids, num_segments: int):
    """vals: [E, D]; seg_ids: [E] int32 sorted ascending; -> [N, D]."""
    return jax.ops.segment_sum(
        vals, seg_ids, num_segments=num_segments, indices_are_sorted=True
    )


def segor_ref(bits, seg_ids, num_segments: int):
    """Segmented OR, packed: ``out[v, :] = pack(OR_{e: seg[e]=s} bits[v, e])``.

    ``bits``: 0/1 int [V, E]; ``seg_ids``: int32 [E] (ids >= num_segments
    are dropped — the pad-row convention of every edge layout); returns
    ``uint32 [V, ceil(num_segments/32)]``.  Oracle only: packs through
    ``bitops.pack``, the trusted (but ``reduce_sum``-based) path.
    """
    y = jax.ops.segment_max(bits.T, seg_ids, num_segments=num_segments)
    return bitops.pack((jnp.maximum(y, 0) > 0).T)


def segor_words(bits, seg_ids, num_segments: int):
    """Word-wise XLA lowering of :func:`segor_ref` — same contract.

    The segment reduce lands in an int 0/1 plane which is packed by a
    shift + OR-reduce over 32-lane groups: no ``reduce_sum``, no bool
    plane, so the edge-list engines' while bodies stay clean under the
    ``tools.reprolint.dynamic`` audit.  Pad bits are structurally zero
    (the node axis is zero-padded up to a word multiple before packing).
    """
    v = bits.shape[0]
    y = jax.ops.segment_max(bits.T, seg_ids, num_segments=num_segments)
    y = jnp.maximum(y, 0).astype(jnp.uint32)  # [n, V] 0/1
    nw = bitops.packed_width(num_segments)
    pad = nw * bitops.WORD - num_segments
    if pad:
        y = jnp.concatenate([y, jnp.zeros((pad, v), y.dtype)])
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, bitops.WORD, 1), 1)
    grouped = y.reshape(nw, bitops.WORD, v) << shifts
    words = jax.lax.reduce(
        grouped, jnp.uint32(0), jax.lax.bitwise_or, (1,)
    )  # [nw, V]
    return words.T
