"""Oracle for the tiled segment-sum kernel: ``jax.ops.segment_sum`` over
sorted segment ids."""
from __future__ import annotations

import jax


def segsum_ref(vals, seg_ids, num_segments: int):
    """vals: [E, D]; seg_ids: [E] int32 sorted ascending; -> [N, D]."""
    return jax.ops.segment_sum(
        vals, seg_ids, num_segments=num_segments, indices_are_sorted=True
    )
