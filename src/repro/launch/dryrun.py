import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation), prove it fits
(memory_analysis) and extract roofline terms (cost_analysis + HLO collective
bytes).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multipod
Results are appended to results/dryrun/<arch>__<cell>__<mesh>.json and
existing results are skipped unless --force.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro import configs
from repro.distributed.ctx import logical_axis_rules
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

# TPU v5e-like hardware constants (per task spec)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_COLL = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE = re.compile(r"(pred|u4|u8|u16|u32|u64|s4|s8|s16|s32|s64|bf16|f16|f32|f64|c64|c128)\[([0-9,]*)\]")

_BYTES = {
    "pred": 1, "u4": 0.5, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "s4": 0.5, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of every collective op in the compiled HLO,
    keyed by "<op>@<loop-depth>" where depth counts enclosing while bodies
    (from the op_name metadata trace path)."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        shapes, op = m.group(1), m.group(2)
        total = 0.0
        for dt, dims in _SHAPE.findall(shapes):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _BYTES[dt]
        depth = line.count("/while/body")
        key = f"{op}@{depth}"
        out[key] = out.get(key, 0.0) + total
    return out


def weighted_collective_bytes(
    coll: dict[str, float], trips: list[float]
) -> float:
    """Total collective bytes with per-loop-depth trip multipliers: an op at
    depth d executes prod(trips[:d]) times (deeper than the known schedule
    uses the full product)."""
    total = 0.0
    for key, b in coll.items():
        depth = int(key.rsplit("@", 1)[1])
        mult = 1.0
        for t in trips[: min(depth, len(trips))]:
            mult *= t
        total += b * mult
    return total


def run_cell(spec, cell, mesh, mesh_name: str) -> dict:
    state = spec.abstract_state(cell)
    inputs = spec.abstract_inputs(cell)
    state_sh = spec.state_shardings(mesh, cell)
    input_sh = spec.input_shardings(mesh, cell)
    step = spec.step(cell)
    n_chips = mesh.devices.size

    # train steps return (state, metrics): pin the state's output sharding
    # to its input sharding (params/opt round-trip); let metrics replicate.
    out_sh = None
    if getattr(cell, "kind", None) == "train":
        from jax.sharding import NamedSharding, PartitionSpec as P

        out_sh = (state_sh, NamedSharding(mesh, P()))

    t0 = time.time()
    with mesh, logical_axis_rules(mesh):
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, input_sh),
            **({"out_shardings": out_sh} if out_sh is not None else {}),
        )
        lowered = jitted.lower(state, inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # cost_analysis() runs on the SPMD-partitioned module, so flops/bytes
    # are PER-DEVICE; while/scan bodies are counted once, so multiply by
    # the spec's static trip factor (layer scan x microbatch scan x ...).
    # Collectives are weighted by their actual loop depth: step-level
    # all-reduces run once, layer-scan gathers run trips[0]*trips[1] times.
    trip = float(getattr(spec, "hlo_trip_factor", lambda c: 1.0)(cell))
    trips = getattr(spec, "trip_schedule", lambda c: [trip])(cell)
    flops = float(cost.get("flops", 0.0)) * trip
    bytes_acc = float(cost.get("bytes accessed", 0.0)) * trip
    coll_total = weighted_collective_bytes(coll, trips)
    per_chip = dict(flops=flops, bytes=bytes_acc, coll_bytes=coll_total)
    terms = dict(
        compute_s=per_chip["flops"] / PEAK_FLOPS,
        memory_s=per_chip["bytes"] / HBM_BW,
        collective_s=per_chip["coll_bytes"] / ICI_BW,
    )
    dominant = max(terms, key=terms.get)
    model_flops = spec.model_flops(cell)
    rec = dict(
        arch=spec.id,
        cell=cell.name,
        mesh=mesh_name,
        n_chips=int(n_chips),
        ok=True,
        t_lower_s=round(t_lower, 2),
        t_compile_s=round(t_compile, 2),
        bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0))
        + int(getattr(mem, "argument_size_in_bytes", 0))
        + int(getattr(mem, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        hlo_flops=flops,
        hlo_bytes=bytes_acc,
        collective_bytes=coll_total,
        collectives=coll,
        trip_factor=trip,
        per_chip=per_chip,
        roofline=terms,
        dominant=dominant,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / (flops * n_chips)) if flops else None,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--cell", default=None, help="single cell name")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    arch_ids = [args.arch] if args.arch else configs.ARCH_IDS
    meshes = {
        "pod": (lambda: make_production_mesh(multi_pod=False)),
        "multipod": (lambda: make_production_mesh(multi_pod=True)),
    }
    if args.mesh != "both":
        meshes = {args.mesh: meshes[args.mesh]}

    failures = 0
    for arch_id in arch_ids:
        spec = configs.get(arch_id)
        for cell_name, cell in spec.cells().items():
            if args.cell and cell_name != args.cell:
                continue
            reason = spec.skip_reason(cell_name)
            for mesh_name, mk in meshes.items():
                fn = os.path.join(
                    args.out, f"{arch_id}__{cell_name}__{mesh_name}.json"
                )
                if os.path.exists(fn) and not args.force:
                    print(f"[skip-cached] {arch_id} {cell_name} {mesh_name}")
                    continue
                if reason is not None:
                    rec = dict(arch=arch_id, cell=cell_name, mesh=mesh_name,
                               ok=True, skipped=reason)
                    with open(fn, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[skip] {arch_id} {cell_name}: {reason}")
                    continue
                print(f"[run ] {arch_id} {cell_name} {mesh_name} ...", flush=True)
                try:
                    rec = run_cell(spec, cell, mk(), mesh_name)
                    print(
                        f"       ok: {rec['bytes_per_device']/2**30:.2f} GiB/dev, "
                        f"compute {rec['roofline']['compute_s']:.3e}s "
                        f"memory {rec['roofline']['memory_s']:.3e}s "
                        f"coll {rec['roofline']['collective_s']:.3e}s "
                        f"-> {rec['dominant']}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = dict(arch=arch_id, cell=cell_name, mesh=mesh_name,
                               ok=False, error=repr(e),
                               traceback=traceback.format_exc()[-4000:])
                    print(f"       FAIL: {e!r}", flush=True)
                with open(fn, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
