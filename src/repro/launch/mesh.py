"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.  The single-pod mesh is
16x16 = 256 chips (data, model); the multi-pod mesh adds a leading pod axis:
2 x 16 x 16 = 512 chips (pod, data, model) with the pod axis crossing DCI.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_host_mesh() -> Mesh:
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))
