"""Batched dual-simulation query serving driver.

Serves a stream of constant-parameterized query-template instances: each
batch of Q instances is compiled as ONE disjoint-union SOI (variables get
per-instance copies, Eq.-13 inits carry the per-instance constants) and
solved in a single fixpoint — the production pattern for "same query, many
constants" workloads (DESIGN.md Sect. 4; the batch16_sparse dry-run cell).

    PYTHONPATH=src python -m repro.launch.serve --batch 8 --requests 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import dualsim, pruning, soi, sparql
from repro.data import synth


def batched_soi(parts: list[soi.SOI]) -> soi.SOI:
    """Disjoint union of per-request SOIs (no shared variables)."""
    base, is_const, edge, copy, pe = [], [], [], [], []
    for s in parts:
        off = len(base)
        base += [f"{b}#{len(base)}" for b in s.base]  # keep instances apart
        is_const += s.is_const
        edge += [(l + off, r + off, a, d) for (l, r, a, d) in s.edge_ineqs]
        copy += [(l + off, r + off) for (l, r) in s.copy_ineqs]
        pe += [(v + off, a, w + off) for (v, a, w) in s.pattern_edges]
    return soi.SOI(
        base=base, is_const=is_const, edge_ineqs=edge, copy_ineqs=copy,
        pattern_edges=pe, external_mand={}, external_opt={},
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--engine", default="sparse",
                    choices=["sparse", "dense", "packed"])
    args = ap.parse_args()

    db = synth.lubm_like(n_universities=8, seed=0)
    print(f"database: {db.n_edges} triples / {db.n_nodes} nodes")

    # query template: department members of a given university (?u = const)
    unis = [n for n in db.node_names if n.startswith("Univ")]
    rng = np.random.default_rng(0)
    requests = [unis[rng.integers(len(unis))] for _ in range(args.requests)]

    served = 0
    t_all = time.perf_counter()
    while served < len(requests):
        chunk = requests[served : served + args.batch]
        parts = [
            soi.build_soi(sparql.parse(
                f"{{ ?d subOrganizationOf {u} . ?s memberOf ?d }}"))
            for u in chunk
        ]
        union = batched_soi(parts)
        c = soi.compile_soi(union, db)
        t0 = time.perf_counter()
        chi, sweeps = dualsim.solve_compiled(c, db, engine=args.engine)
        dt = time.perf_counter() - t0
        _, stats = pruning.prune_triples(union, chi, db)
        print(f"batch of {len(chunk)}: {sweeps} sweeps, {dt*1e3:.1f} ms, "
              f"{stats.n_after}/{stats.n_triples} triples survive")
        served += len(chunk)
    total = time.perf_counter() - t_all
    print(f"served {served} requests in {total:.2f}s "
          f"({served/total:.1f} req/s incl. SOI build+compile)")


if __name__ == "__main__":
    main()
