"""Async dual-simulation query serving driver — on the `repro.serve` loop.

Drives a stream of constant-parameterized query-template instances through
:class:`repro.serve.AsyncServer` (DESIGN.md Sect. 10): requests from
``--tenants`` synthetic tenants are admitted into a bounded queue, batched
by the real flush timer, scheduled deficit-round-robin across tenants, and
executed on ``--replicas`` engine replicas over immutable snapshots.  The
query shape is compiled ONCE per (bucket, replica) into a cached plan;
every subsequent request rebinds constants as jitted-fixpoint *inputs*
(zero recompiles, zero retraces).  Requests that cannot be served in time
are shed with explicit outcomes instead of queueing without bound.

With ``--mutate``, the driver mutates mid-stream to show both invalidation
classes (DESIGN.md Sect. 8) flowing through the replica pool: a
shape-stable delete/re-insert churn whose superseded plans are patched in
place and warm-resumed, then a dictionary-growing insert whose plans
rebuild cold; the metrics lines split the counts accordingly.

With ``--engine partitioned --devices 8`` every replica's fixpoint shards
over 8 simulated host devices (DESIGN.md Sect. 7):

    PYTHONPATH=src python -m repro.launch.serve --requests 32 --mutate
    PYTHONPATH=src python -m repro.launch.serve --engine partitioned --devices 8

The synchronous session surface this driver used before PR 6 is still the
right tool for single-process embedding; ``examples/serve_queries.py``
keeps that tour.  Closed-loop vs open-loop measurement:
``benchmarks/serve_bench.py`` is the saturation benchmark over this loop.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import numpy as np

from repro.data import synth
from repro.db import GraphDB
from repro.distributed import ctx as dctx
from repro.engine.cost import ENGINES
from repro.serve import AsyncServer

QUERY = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


async def _serve(args, db: GraphDB, requests: list[str], churn) -> None:
    async with AsyncServer(
        db,
        replicas=args.replicas,
        max_queue=args.max_queue,
        max_batch=args.batch,
        max_delay_ms=args.max_delay_ms,
        default_deadline_ms=args.deadline_ms,
    ) as server:
        t_all = time.monotonic()
        futs = [
            server.submit(q, tenant=f"t{i % args.tenants}")
            for i, q in enumerate(requests)
        ]
        results = await asyncio.gather(*futs)

        if args.mutate:
            # shape-stable churn: delete + re-insert an existing triple —
            # superseded replica plans are *resumable* (patched in place,
            # the next solve warm-starts from the previous fixpoint)
            db.delete(churn)
            mid = await asyncio.gather(*[
                server.submit(q, tenant=f"t{i % args.tenants}")
                for i, q in enumerate(requests[: args.batch])
            ])
            db.insert(churn)
            # dictionary-growing insert: the classic *cold* invalidation
            db.insert([("DeptNew", "subOrganizationOf", "Univ0"),
                       ("StudentNew", "memberOf", "DeptNew")])
            await server.fence()  # every replica adopts the new epoch
            mid += await asyncio.gather(*[
                server.submit(q, tenant=f"t{i % args.tenants}")
                for i, q in enumerate(requests[: args.batch])
            ])
            results += mid
        total = time.monotonic() - t_all

        done = [r for r in results if r.ok]
        for i in range(0, len(done), args.batch):
            chunk = done[i:i + args.batch]
            r = chunk[0].result
            print(
                f"batch of {len(chunk)}: {r.sweeps} sweeps, "
                f"{chunk[0].service_ms:.1f} ms service "
                f"(replica {chunk[0].replica}), engine={r.engine}, "
                + ", ".join(f"{len(x.result)}/{x.result.stats.n_triples}"
                            for x in chunk[:4])
                + (" ... triples survive" if len(chunk) > 4
                   else " triples survive")
            )

        snap = server.metrics.snapshot()
        agg = server.router.aggregate()
        shed = snap.shed_total
        print(
            f"served {snap.completed}/{len(results)} requests in {total:.2f}s "
            f"({snap.completed / total:.1f} req/s closed-loop — open-loop "
            f"capacity: benchmarks/serve_bench.py), {shed} shed "
            f"{dict(snap.shed)}, queue peak {snap.queue_peak}, "
            f"p50 {snap.latency['p50_ms']:.1f} ms / "
            f"p99 {snap.latency['p99_ms']:.1f} ms"
        )
        print(
            f"tenants: "
            + ", ".join(f"{t}: {d['completed']}/{d['submitted']}"
                        for t, d in sorted(snap.per_tenant.items()))
            + f"; replicas: {agg['batches_per_replica']} batches"
        )
        print(
            f"plan cache: {agg['cache_hits']} hits / {agg['cache_misses']} "
            f"misses, {agg['plan_builds']} plans built, "
            f"{agg['plan_invalidations']} cold-invalidated (v{db.version}), "
            f"engines={agg['engine_counts']}"
        )
        if args.mutate:
            print(
                f"incremental maintenance: {agg['plans_resumable']} plans "
                f"reclassified resumable, {agg['plans_resumed']} patched + "
                f"resumed ({agg['warm_resume_solves']} warm-started solves, "
                f"{agg['resumes_declined']} declined), "
                f"{agg['adj_rebuilds_saved']} adjacency rebuilds saved"
            )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8,
                    help="max requests per dispatched microbatch")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-delay-ms", type=float, default=50.0,
                    help="flush timer: max wait for a partial batch")
    ap.add_argument("--replicas", type=int, default=2,
                    help="engine read replicas over the shared snapshots")
    ap.add_argument("--tenants", type=int, default=2,
                    help="synthetic tenants round-robined over the stream")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission bound: beyond this, requests shed")
    ap.add_argument("--deadline-ms", type=float, default=10_000.0,
                    help="per-request deadline (expired => shed, not run)")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", *ENGINES],
                    help="fixpoint engine; 'auto' = cost-based selection")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard over a mesh of this many (simulated host) "
                         "devices; 0 = no mesh")
    ap.add_argument("--mutate", action="store_true",
                    help="mutate mid-stream: a shape-stable delete/re-insert "
                         "churn (warm-resumed plans) plus a dictionary-"
                         "growing insert (cold invalidation)")
    args = ap.parse_args()

    mesh = None
    if args.devices > 1:
        # must run before the first JAX computation initializes the backend
        dctx.force_host_device_count(args.devices)
        mesh = dctx.node_mesh(args.devices)

    db = GraphDB(synth.lubm_like(n_universities=8, seed=0),
                 engine=args.engine, mesh=mesh)
    print(f"database: {db.n_triples} triples / {db.n_nodes} nodes"
          + (f", mesh of {args.devices} devices" if mesh is not None else ""))

    unis = [n for n in db.graph.node_names if n.startswith("Univ")]
    rng = np.random.default_rng(0)
    requests = [
        QUERY.format(uni=unis[rng.integers(len(unis))])
        for _ in range(args.requests)
    ]

    churn = None
    if args.mutate:
        g = db.graph
        row = g.triples[0]
        churn = [(g.node_names[row[0]], g.label_names[row[1]],
                  g.node_names[row[2]])]

    asyncio.run(_serve(args, db, requests, churn))


if __name__ == "__main__":
    main()
