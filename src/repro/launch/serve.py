"""Batched dual-simulation query serving driver — on the `repro.db` API.

Serves a stream of constant-parameterized query-template instances through
a :class:`repro.db.Session`: requests are submitted as futures and the
deadline/size admission policy releases them to the engine as microbatches
(DESIGN.md Sect. 6.2).  The query shape is compiled ONCE into a cached
plan per microbatch bucket; every subsequent request rebinds constants as
jitted-fixpoint *inputs* (zero recompiles, zero retraces).  With
``--mutate``, the driver also mutates mid-stream to show both invalidation
classes (DESIGN.md Sect. 8): a shape-stable delete/re-insert churn whose
superseded plans are patched in place and warm-resumed from their previous
fixpoint, then a dictionary-growing insert whose plans rebuild cold; the
metrics lines split the counts accordingly.

With ``--engine partitioned --devices 8`` the fixpoint shards over 8
simulated host devices (one destination block per device; cross-shard
traffic is one packed chi broadcast per sweep — DESIGN.md Sect. 7):

    PYTHONPATH=src python -m repro.launch.serve --requests 32 --mutate
    PYTHONPATH=src python -m repro.launch.serve --engine partitioned --devices 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data import synth
from repro.db import GraphDB
from repro.distributed import ctx as dctx
from repro.engine.cost import ENGINES

QUERY = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8,
                    help="session bucket cap (max pending per template)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-delay-ms", type=float, default=50.0)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", *ENGINES],
                    help="fixpoint engine; 'auto' = cost-based selection")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard over a mesh of this many (simulated host) "
                         "devices; 0 = no mesh")
    ap.add_argument("--mutate", action="store_true",
                    help="mutate mid-stream: a shape-stable delete/re-insert "
                         "churn (warm-resumed plans) plus a dictionary-"
                         "growing insert (cold invalidation)")
    args = ap.parse_args()

    mesh = None
    if args.devices > 1:
        # must run before the first JAX computation initializes the backend
        dctx.force_host_device_count(args.devices)
        mesh = dctx.node_mesh(args.devices)

    db = GraphDB(synth.lubm_like(n_universities=8, seed=0),
                 engine=args.engine, mesh=mesh)
    print(f"database: {db.n_triples} triples / {db.n_nodes} nodes"
          + (f", mesh of {args.devices} devices" if mesh is not None else ""))

    unis = [n for n in db.graph.node_names if n.startswith("Univ")]
    rng = np.random.default_rng(0)
    requests = [
        QUERY.format(uni=unis[rng.integers(len(unis))])
        for _ in range(args.requests)
    ]

    churn = None
    if args.mutate:
        g = db.graph
        row = g.triples[0]
        churn = [(g.node_names[row[0]], g.label_names[row[1]],
                  g.node_names[row[2]])]

    t_all = time.perf_counter()
    with db.session(max_delay_ms=args.max_delay_ms,
                    max_pending=args.batch) as session:
        futures = [session.submit(q) for q in requests]
        if args.mutate:
            # shape-stable churn: delete + re-insert an existing triple —
            # superseded plans are *resumable* (patched in place, next
            # solve warm-starts from the previous fixpoint)
            db.delete(churn)
            mid = [session.submit(qq) for qq in requests[: args.batch]]
            db.insert(churn)
            # dictionary-growing insert: the classic *cold* invalidation
            db.insert([("DeptNew", "subOrganizationOf", unis[0]),
                       ("StudentNew", "memberOf", "DeptNew")])
            futures += mid
        results = [f.result() for f in futures]
    total = time.perf_counter() - t_all

    for i in range(0, len(results), args.batch):
        chunk = results[i : i + args.batch]
        r = chunk[0]
        print(
            f"batch of {len(chunk)}: {r.sweeps} sweeps, "
            f"{r.timings['batch_total']*1e3:.1f} ms batch, engine={r.engine}, "
            + ", ".join(f"{len(x)}/{x.stats.n_triples}" for x in chunk[:4])
            + (" ... triples survive" if len(chunk) > 4 else " triples survive")
        )

    m = db.metrics()
    print(
        f"served {len(results)} requests in {total:.2f}s "
        f"({len(results)/total:.1f} req/s) over {session.flushes} flushes; "
        f"plan cache: {m.cache.hits} hits / {m.cache.misses} misses "
        f"({m.cache.hit_rate:.0%}), {m.plan_builds} plans built, "
        f"{m.plan_invalidations} cold-invalidated (v{db.version}), "
        f"engines={m.engine_counts}"
    )
    if args.mutate:
        print(
            f"incremental maintenance: {m.plans_resumable} plans "
            f"reclassified resumable, {m.plans_resumed} patched + resumed "
            f"({m.warm_resume_solves} warm-started solves, "
            f"{m.resumes_declined} declined), "
            f"{m.adj_rebuilds_saved} adjacency rebuilds saved"
        )


if __name__ == "__main__":
    main()
