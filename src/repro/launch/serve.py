"""Batched dual-simulation query serving driver — now on `repro.engine`.

Serves a stream of constant-parameterized query-template instances through
the :class:`repro.engine.Engine` facade: the query shape is compiled ONCE
into a cached plan (per microbatch bucket), every subsequent request rebinds
constants as jitted-fixpoint *inputs* (zero recompiles, zero retraces), and
each batch of instances is solved as one disjoint-union SOI
(DESIGN.md Sect. 5; the batch16_sparse dry-run cell).

    PYTHONPATH=src python -m repro.launch.serve --batch 8 --requests 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data import synth
from repro.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "sparse", "dense", "packed"],
                    help="fixpoint engine; 'auto' = cost-based selection")
    args = ap.parse_args()

    db = synth.lubm_like(n_universities=8, seed=0)
    print(f"database: {db.n_edges} triples / {db.n_nodes} nodes")

    eng = Engine(db, engine=args.engine)

    # query template: department members of a given university (?u = const)
    unis = [n for n in db.node_names if n.startswith("Univ")]
    rng = np.random.default_rng(0)
    requests = [
        f"{{ ?d subOrganizationOf {unis[rng.integers(len(unis))]} . "
        f"?s memberOf ?d }}"
        for _ in range(args.requests)
    ]

    served = 0
    t_all = time.perf_counter()
    while served < len(requests):
        chunk = requests[served : served + args.batch]
        t0 = time.perf_counter()
        results = eng.execute_many(chunk)
        dt = time.perf_counter() - t0
        r = results[0]
        print(
            f"batch of {len(chunk)}: {r.sweeps} sweeps, {dt*1e3:.1f} ms, "
            f"engine={r.engine}, "
            + ", ".join(f"{x.stats.n_after}/{x.stats.n_triples}" for x in results[:4])
            + (" ... triples survive" if len(results) > 4 else " triples survive")
        )
        served += len(chunk)
    total = time.perf_counter() - t_all

    m = eng.metrics()
    print(
        f"served {served} requests in {total:.2f}s ({served/total:.1f} req/s); "
        f"plan cache: {m.cache.hits} hits / {m.cache.misses} misses "
        f"({m.cache.hit_rate:.0%}), {m.plan_builds} plans built, "
        f"engines={m.engine_counts}"
    )


if __name__ == "__main__":
    main()
