"""End-to-end distributed training driver.

Wires together: config registry -> mesh -> sharded state -> deterministic
data pipeline -> microbatched train step -> async checkpointing -> restart
policy + straggler monitor.  On the CPU container it runs reduced configs on
a 1x1 mesh; on a real cluster the same driver runs the full configs on the
production mesh (``--mesh pod``).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.checkpoint import ckpt
from repro.data import pipeline
from repro.distributed import fault
from repro.models import steps as steps_mod
from repro.models import transformer as tr
from repro.optimizer import adamw


def build(arch: str, reduced: bool):
    spec = configs.get(arch)
    cfg = spec.reduced() if reduced else spec.cfg
    opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=10_000)
    step = steps_mod.make_train_step(
        lambda p, b: tr.loss_fn(cfg, p, b), opt_cfg, microbatches=1
    )
    return cfg, opt_cfg, jax.jit(step, donate_argnums=(0, 1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a node failure at this step (testing)")
    args = ap.parse_args()

    cfg, opt_cfg, step = build(args.arch, args.reduced)
    corpus = pipeline.synthetic_corpus(cfg.vocab, 2_000_000, seed=0)
    monitor = fault.StragglerMonitor()

    def run(restart_idx: int) -> None:
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw.init(params)
        start = 0
        try:
            (params, opt_state), start = ckpt.restore(
                args.ckpt_dir, (params, opt_state))
            print(f"[restore] resumed from step {start}")
        except FileNotFoundError:
            pass

        batches = pipeline.token_batches(
            corpus, batch=args.batch, seq=args.seq, seed=1,
            shard=pipeline.ShardSpec(0, 1), start_step=start,
        )
        pending = None
        for s in range(start, args.steps):
            if s == args.inject_failure_at and restart_idx == 0:
                raise RuntimeError("injected node failure")
            b = next(batches)
            t0 = time.perf_counter()
            params, opt_state, metrics = step(
                params, opt_state,
                {k: jax.numpy.asarray(v) for k, v in b.items()},
            )
            monitor.report(fault.Heartbeat("host0", s, time.time()))
            if s % 10 == 0:
                print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
            if (s + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = ckpt.save(
                    args.ckpt_dir, s + 1, (params, opt_state),
                    background=True)
        if pending is not None:
            pending.join()
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state))
        if monitor.stragglers():
            print("[warn] stragglers:", monitor.stragglers())
        print("done.")

    policy = fault.RestartPolicy(max_restarts=3, backoff_s=0.1)
    restarts = policy.run(
        run,
        on_restart=lambda i, e: print(f"[restart {i}] after {e!r}"))
    print(f"training completed with {restarts} restart(s)")


if __name__ == "__main__":
    main()
