"""GNN zoo: GatedGCN, GAT, PNA, SchNet — segment-op message passing.

JAX has no sparse SpMM beyond BCOO, so all message passing is implemented
over explicit edge-index arrays with ``jax.ops.segment_sum`` / ``segment_max``
(the scatter regime of kernel_taxonomy §GNN) — this IS the system, not a
stub.  The same gather→reduce machinery implements the sparse dual-simulation
engine in :mod:`repro.core.dualsim` (DESIGN.md Sect. 2).

Graph batch format (all four shapes lower to it):

* ``feat``      — [N, F] node features (or int atom types for molecules)
* ``edges``     — [E, 2] int32 (src, dst)
* ``edge_mask`` — [E] bool (padding for sampled subgraphs)
* ``labels``    — [N] int (node tasks) or [G] float (graph regression)
* ``node_graph``— [N] int32 graph id (batched small graphs), else zeros
* ``positions`` — [N, 3] float (SchNet; synthesized for non-geometric cells,
  see DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # gatedgcn | gat | pna | schnet
    n_layers: int
    d_hidden: int
    d_in: int
    n_out: int
    n_heads: int = 1
    task: str = "node_class"  # node_class | graph_reg
    # schnet
    n_rbf: int = 300
    cutoff: float = 10.0
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    remat: bool = True  # checkpoint each message-passing layer


def _layer(cfg: GNNConfig, fn):
    """Per-layer remat wrapper: edge-sized intermediates are recomputed in
    the backward pass instead of stored (O(E·d) x n_layers -> O(E·d))."""
    return jax.checkpoint(fn, prevent_cse=False) if cfg.remat else fn


# --------------------------------------------------------------------- #
# segment utilities
# --------------------------------------------------------------------- #
def seg_sum(vals, idx, n):
    return jax.ops.segment_sum(vals, idx, num_segments=n)


def seg_max(vals, idx, n):
    out = jax.ops.segment_max(vals, idx, num_segments=n)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def seg_min(vals, idx, n):
    out = jax.ops.segment_min(vals, idx, num_segments=n)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def seg_mean(vals, idx, n, deg=None):
    s = seg_sum(vals, idx, n)
    if deg is None:
        deg = seg_sum(jnp.ones((vals.shape[0], 1), vals.dtype), idx, n)
    return s / jnp.maximum(deg, 1.0)


def seg_softmax(logits, idx, n):
    """Softmax over segments (edge -> destination-node groups)."""
    m = jax.ops.segment_max(logits, idx, num_segments=n)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(logits - m[idx])
    z = seg_sum(e, idx, n)
    return e / jnp.maximum(z[idx], 1e-30)


def _dense(key, din, dout, pd):
    w = jax.random.normal(key, (din, dout), pd) / math.sqrt(din)
    return {"w": w, "b": jnp.zeros((dout,), pd)}


def _apply(p, x):
    return x @ p["w"] + p["b"]


def _ln(x, eps=1e-5):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps)


# --------------------------------------------------------------------- #
# GatedGCN  (Bresson & Laurent; arXiv:2003.00982 benchmark config)
# --------------------------------------------------------------------- #
def _init_gatedgcn(cfg: GNNConfig, rng):
    keys = jax.random.split(rng, cfg.n_layers * 5 + 3)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k = keys[i * 5 : i * 5 + 5]
        layers.append(
            {
                "A": _dense(k[0], d, d, cfg.param_dtype),
                "B": _dense(k[1], d, d, cfg.param_dtype),
                "C": _dense(k[2], d, d, cfg.param_dtype),
                "D": _dense(k[3], d, d, cfg.param_dtype),
                "E": _dense(k[4], d, d, cfg.param_dtype),
            }
        )
    return {
        "embed": _dense(keys[-3], cfg.d_in, d, cfg.param_dtype),
        "edge_embed": jnp.zeros((1, d), cfg.param_dtype),
        "layers": layers,
        "head": _dense(keys[-1], d, cfg.n_out, cfg.param_dtype),
    }


def _fwd_gatedgcn(cfg: GNNConfig, p, batch):
    n = batch["feat"].shape[0]
    src, dst = batch["edges"][:, 0], batch["edges"][:, 1]
    emask = batch["edge_mask"][:, None].astype(cfg.dtype)
    p = jax.tree.map(
        lambda x: x.astype(cfg.dtype) if x.dtype == jnp.float32 else x, p
    )
    h = constrain(_apply(p["embed"], batch["feat"].astype(cfg.dtype)), "nodes", None)
    e = constrain(
        jnp.broadcast_to(p["edge_embed"], (src.shape[0], cfg.d_hidden)),
        "edges", None,
    )

    def layer(lp, h, e):
        dh = constrain(_apply(lp["D"], h)[src], "edges", None)
        eh = constrain(_apply(lp["E"], h)[dst], "edges", None)
        e_new = constrain(
            e + jax.nn.relu(_ln(_apply(lp["C"], e) + dh + eh)), "edges", None
        )
        eta = constrain(jax.nn.sigmoid(e_new) * emask, "edges", None)
        denom = constrain(seg_sum(eta, dst, n) + 1e-6, "nodes", None)
        bh = constrain(_apply(lp["B"], h)[src], "edges", None)
        msg = constrain(seg_sum(eta * bh, dst, n), "nodes", None) / denom
        h = constrain(h + jax.nn.relu(_ln(_apply(lp["A"], h) + msg)), "nodes", None)
        return h, e_new

    step = _layer(cfg, layer)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *p["layers"])

    def body(carry, lp):
        h, e = step(lp, *carry)
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), stacked)
    return _readout(cfg, p, h, batch)


# --------------------------------------------------------------------- #
# GAT  (Velickovic et al.; Cora config: 2 layers, 8 heads x 8)
# --------------------------------------------------------------------- #
def _init_gat(cfg: GNNConfig, rng):
    keys = jax.random.split(rng, cfg.n_layers * 3 + 1)
    layers = []
    din = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        dout = cfg.n_out if last else cfg.d_hidden
        k = jax.random.split(keys[i], 3)
        layers.append(
            {
                "W": jax.random.normal(k[0], (din, heads, dout), cfg.param_dtype)
                / math.sqrt(din),
                "a_src": jax.random.normal(k[1], (heads, dout), cfg.param_dtype)
                / math.sqrt(dout),
                "a_dst": jax.random.normal(k[2], (heads, dout), cfg.param_dtype)
                / math.sqrt(dout),
            }
        )
        din = heads * dout
    return {"layers": layers}


def _fwd_gat(cfg: GNNConfig, p, batch):
    n = batch["feat"].shape[0]
    src, dst = batch["edges"][:, 0], batch["edges"][:, 1]
    emask = batch["edge_mask"].astype(cfg.dtype)
    h = constrain(batch["feat"].astype(cfg.dtype), "nodes", None)

    def layer(lp, h, last):
        hw = jnp.einsum("nf,fhd->nhd", h, lp["W"])  # [N, H, D]
        al = jnp.einsum("nhd,hd->nh", hw, lp["a_src"])
        ar = jnp.einsum("nhd,hd->nh", hw, lp["a_dst"])
        logits = jax.nn.leaky_relu(
            constrain(al[src], "edges", None) + constrain(ar[dst], "edges", None),
            0.2,
        )  # [E, H]
        logits = constrain(
            jnp.where(emask[:, None] > 0, logits, -1e30), "edges", None
        )
        alpha = constrain(
            seg_softmax(logits, dst, n) * emask[:, None], "edges", None
        )  # [E, H]
        hws = constrain(hw[src], "edges", None, None)
        out = constrain(
            seg_sum(alpha[:, :, None] * hws, dst, n), "nodes", None, None
        )  # [N, H, D]
        if last:
            return constrain(jnp.mean(out, axis=1), "nodes", None)
        return constrain(jax.nn.elu(out).reshape(n, -1), "nodes", None)

    for i, lp in enumerate(p["layers"]):
        last = i == len(p["layers"]) - 1
        h = _layer(cfg, functools.partial(layer, last=last))(lp, h)
    return _readout(cfg, p, h, batch, head=False)


# --------------------------------------------------------------------- #
# PNA  (Corso et al.; mean/max/min/std x identity/amplification/attenuation)
# --------------------------------------------------------------------- #
def _init_pna(cfg: GNNConfig, rng):
    keys = jax.random.split(rng, cfg.n_layers * 2 + 3)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k = jax.random.split(keys[i], 2)
        layers.append(
            {
                "pre": _dense(k[0], 2 * d, d, cfg.param_dtype),
                "post": _dense(k[1], 13 * d, d, cfg.param_dtype),
            }
        )
    return {
        "embed": _dense(keys[-2], cfg.d_in, d, cfg.param_dtype),
        "layers": layers,
        "head": _dense(keys[-1], d, cfg.n_out, cfg.param_dtype),
    }


def _fwd_pna(cfg: GNNConfig, p, batch, delta: float = 2.5):
    n = batch["feat"].shape[0]
    src, dst = batch["edges"][:, 0], batch["edges"][:, 1]
    emask = batch["edge_mask"][:, None].astype(cfg.dtype)
    h = constrain(_apply(p["embed"], batch["feat"].astype(cfg.dtype)), "nodes", None)
    deg = seg_sum(emask, dst, n)  # [N, 1]
    logd = jnp.log(deg + 1.0)

    def layer(lp, h):
        hs = constrain(h[src], "edges", None)
        hd = constrain(h[dst], "edges", None)
        msg = constrain(jax.nn.relu(
            _apply(lp["pre"], jnp.concatenate([hs, hd], axis=-1))
        ) * emask, "edges", None)  # [E, d]
        mean = constrain(seg_mean(msg, dst, n, deg), "nodes", None)
        mx = constrain(seg_max(msg, dst, n), "nodes", None)
        mn = constrain(seg_min(msg, dst, n), "nodes", None)
        var = constrain(seg_mean(msg * msg, dst, n, deg), "nodes", None) - mean * mean
        std = jnp.sqrt(jnp.maximum(var, 1e-6))
        aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)  # [N, 4d]
        amp = logd / delta
        att = delta / jnp.maximum(logd, 1e-2)
        scaled = constrain(
            jnp.concatenate([aggs, aggs * amp, aggs * att, h], axis=-1),
            "nodes", None,
        )
        return constrain(h + jax.nn.relu(_apply(lp["post"], scaled)), "nodes", None)

    step = _layer(cfg, layer)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *p["layers"])
    h, _ = jax.lax.scan(lambda hh, lp: (step(lp, hh), None), h, stacked)
    return _readout(cfg, p, h, batch)


# --------------------------------------------------------------------- #
# SchNet  (Schütt et al.; continuous-filter conv, rbf=300, cutoff=10)
# --------------------------------------------------------------------- #
def _init_schnet(cfg: GNNConfig, rng):
    keys = jax.random.split(rng, cfg.n_layers * 4 + 4)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k = jax.random.split(keys[i], 4)
        layers.append(
            {
                "filt1": _dense(k[0], cfg.n_rbf, d, cfg.param_dtype),
                "filt2": _dense(k[1], d, d, cfg.param_dtype),
                "lin1": _dense(k[2], d, d, cfg.param_dtype),
                "lin2": _dense(k[3], d, d, cfg.param_dtype),
            }
        )
    return {
        "embed": jax.random.normal(keys[-3], (100, d), cfg.param_dtype) * 0.1,
        "layers": layers,
        "out1": _dense(keys[-2], d, d // 2, cfg.param_dtype),
        "out2": _dense(keys[-1], d // 2, cfg.n_out, cfg.param_dtype),
    }


def _ssp(x):  # shifted softplus
    return jax.nn.softplus(x) - math.log(2.0)


def _fwd_schnet(cfg: GNNConfig, p, batch):
    n = batch["feat"].shape[0]
    src, dst = batch["edges"][:, 0], batch["edges"][:, 1]
    emask = batch["edge_mask"][:, None].astype(cfg.dtype)
    z = batch["feat"]
    if z.ndim == 2:  # dense features -> project to type logits
        z = jnp.argmax(z[:, :100], axis=-1) if z.shape[1] >= 2 else z[:, 0].astype(jnp.int32)
    h = p["embed"][jnp.clip(z, 0, 99)]  # [N, d]
    pos = batch["positions"].astype(cfg.dtype)
    dist = jnp.sqrt(
        jnp.sum((pos[src] - pos[dst]) ** 2, axis=-1, keepdims=True) + 1e-12
    )  # [E, 1]
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf, dtype=cfg.dtype)
    gamma = 10.0
    rbf = constrain(
        jnp.exp(-gamma * (dist - centers[None, :]) ** 2), "edges", None
    )  # [E, n_rbf]
    cos_cut = 0.5 * (jnp.cos(jnp.pi * jnp.minimum(dist, cfg.cutoff) / cfg.cutoff) + 1.0)

    def layer(lp, h):
        w = constrain(
            _ssp(_apply(lp["filt2"], _ssp(_apply(lp["filt1"], rbf)))) * cos_cut,
            "edges", None,
        )
        hs = constrain(_apply(lp["lin1"], h)[src], "edges", None)
        msg = constrain(hs * w * emask, "edges", None)
        agg = constrain(seg_sum(msg, dst, n), "nodes", None)
        return constrain(h + _apply(lp["lin2"], _ssp(agg)), "nodes", None)

    step = _layer(cfg, layer)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *p["layers"])
    h, _ = jax.lax.scan(lambda hh, lp: (step(lp, hh), None), h, stacked)
    h = _apply(p["out1"], h)
    h = _apply(p["out2"], _ssp(h))  # [N, n_out]
    if cfg.task == "graph_reg":
        g = batch["node_graph"]
        ng = batch["n_graphs"]
        return seg_sum(h, g, ng)  # per-molecule energy sum
    return h


# --------------------------------------------------------------------- #
# shared readout / dispatch
# --------------------------------------------------------------------- #
def _readout(cfg: GNNConfig, p, h, batch, head: bool = True):
    if head:
        h = _apply(p["head"], h)
    if cfg.task == "graph_reg":
        g = batch["node_graph"]
        ng = batch["n_graphs"]
        cnt = seg_sum(jnp.ones((h.shape[0], 1), h.dtype), g, ng)
        return seg_sum(h, g, ng) / jnp.maximum(cnt, 1.0)
    return h


_INIT = {
    "gatedgcn": _init_gatedgcn,
    "gat": _init_gat,
    "pna": _init_pna,
    "schnet": _init_schnet,
}
_FWD = {
    "gatedgcn": _fwd_gatedgcn,
    "gat": _fwd_gat,
    "pna": _fwd_pna,
    "schnet": _fwd_schnet,
}


def init_params(cfg: GNNConfig, rng: jax.Array) -> dict:
    return _INIT[cfg.arch](cfg, rng)


def forward(cfg: GNNConfig, params: dict, batch: dict) -> jax.Array:
    return _FWD[cfg.arch](cfg, params, batch)


def loss_fn(cfg: GNNConfig, params: dict, batch: dict) -> jax.Array:
    out = forward(cfg, params, batch)
    if cfg.task == "graph_reg":
        tgt = batch["labels"].astype(jnp.float32)
        return jnp.mean((out[..., 0].astype(jnp.float32) - tgt) ** 2)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
