"""DCN-v2 (Wang et al., arXiv:2008.13535): cross network + deep MLP over
huge sparse embedding tables.

JAX has no native ``EmbeddingBag`` — :func:`embedding_bag` implements it as
``jnp.take`` + ``jax.ops.segment_sum`` (sum/mean modes), which is a required
part of the system.  Single-valued categorical features use the nnz=1
specialization (a plain ``take``).  All 26 tables are concatenated into one
row-sharded [sum(vocab), d] matrix so the lookup shards over the whole mesh.

``retrieval_score`` handles the 1-vs-1M ``retrieval_cand`` cell as one
batched dot product (never a loop).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Criteo-style per-feature vocabulary sizes (26 categorical fields).
CRITEO_VOCABS = (
    1460, 583, 10_131_227, 2_202_608, 305, 24, 12_517, 633, 3, 93_145,
    5_683, 8_351_593, 3_194, 27, 14_992, 5_461_306, 10, 5_652, 2_173, 4,
    7_046_547, 18, 15, 286_181, 105, 142_572,
)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross: int = 3
    mlp: tuple[int, ...] = (1024, 1024, 512)
    vocab_sizes: tuple[int, ...] = CRITEO_VOCABS
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def total_vocab(self) -> int:
        return sum(self.vocab_sizes)

    @property
    def d_interact(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(
            np.int32
        )


# --------------------------------------------------------------------- #
# EmbeddingBag: take + segment_sum (JAX has no native equivalent)
# --------------------------------------------------------------------- #
def embedding_bag(
    table: jax.Array,  # [V, d]
    values: jax.Array,  # [nnz] int32 row ids
    segment_ids: jax.Array,  # [nnz] int32 bag ids (sorted or not)
    n_bags: int,
    mode: str = "sum",
) -> jax.Array:
    """Gather rows then segment-reduce per bag: the FBGEMM TBE primitive."""
    rows = jnp.take(table, values, axis=0)  # [nnz, d]
    agg = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones((values.shape[0], 1), rows.dtype),
            segment_ids,
            num_segments=n_bags,
        )
        agg = agg / jnp.maximum(cnt, 1.0)
    return agg


def init_params(cfg: RecsysConfig, rng: jax.Array) -> dict:
    pd = cfg.param_dtype
    keys = jax.random.split(rng, cfg.n_cross + len(cfg.mlp) + 3)
    d = cfg.d_interact
    cross = []
    for i in range(cfg.n_cross):
        k = jax.random.split(keys[i], 2)
        cross.append(
            {
                "w": jax.random.normal(k[0], (d, d), pd) / math.sqrt(d),
                "b": jnp.zeros((d,), pd),
            }
        )
    mlp = []
    din = d
    for j, width in enumerate(cfg.mlp):
        k = keys[cfg.n_cross + j]
        mlp.append(
            {
                "w": jax.random.normal(k, (din, width), pd) / math.sqrt(din),
                "b": jnp.zeros((width,), pd),
            }
        )
        din = width
    return {
        "table": jax.random.normal(keys[-2], (cfg.total_vocab, cfg.embed_dim), pd)
        * 0.01,
        "cross": cross,
        "mlp": mlp,
        "head": {
            "w": jax.random.normal(keys[-1], (din, 1), pd) / math.sqrt(din),
            "b": jnp.zeros((1,), pd),
        },
    }


def _trunk(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    """dense + embedded sparse -> cross stack -> deep MLP; returns [B, mlp[-1]]."""
    b = batch["dense"].shape[0]
    offs = jnp.asarray(cfg.offsets())
    idx = batch["sparse"] + offs[None, :]  # [B, 26] global rows
    if "bag_values" in batch:
        emb = embedding_bag(
            params["table"],
            batch["bag_values"],
            batch["bag_segments"],
            n_bags=b * cfg.n_sparse,
        ).reshape(b, cfg.n_sparse * cfg.embed_dim)
    else:
        emb = jnp.take(params["table"], idx.reshape(-1), axis=0).reshape(
            b, cfg.n_sparse * cfg.embed_dim
        )
    x0 = jnp.concatenate([batch["dense"].astype(cfg.dtype), emb.astype(cfg.dtype)], axis=-1)
    x = x0
    for cp in params["cross"]:  # x_{l+1} = x0 ⊙ (W x_l + b) + x_l
        x = x0 * (x @ cp["w"].astype(cfg.dtype) + cp["b"].astype(cfg.dtype)) + x
    for mp in params["mlp"]:
        x = jax.nn.relu(x @ mp["w"].astype(cfg.dtype) + mp["b"].astype(cfg.dtype))
    return x


def forward(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    """CTR logit [B]."""
    x = _trunk(cfg, params, batch)
    hp = params["head"]
    return (x @ hp["w"].astype(cfg.dtype) + hp["b"].astype(cfg.dtype))[:, 0]


def loss_fn(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    logit = forward(cfg, params, batch).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


def retrieval_score(
    cfg: RecsysConfig, params: dict, batch: dict
) -> tuple[jax.Array, jax.Array]:
    """Score one query against N candidates; returns (scores, top-100 idx).

    ``batch['candidates']`` is [N, mlp[-1]] precomputed item vectors; the
    query tower is the DCN trunk.  One [B, d] x [d, N] matmul.
    """
    q = _trunk(cfg, params, batch)  # [B, d]
    scores = q @ batch["candidates"].T.astype(cfg.dtype)  # [B, N]
    top = jax.lax.top_k(scores, 100)[1]
    return scores, top
