"""Neighbor sampler for the ``minibatch_lg`` GNN shape (fanout 15-10).

A real GraphSAGE-style layered sampler over a CSR index: per seed node,
sample up to ``fanout[0]`` in-neighbors, then ``fanout[1]`` per frontier
node.  Emits a *fixed-shape* padded local subgraph (jit-stable): local node
ids, local edge index, edge mask, and the seed labels.  Numpy-side — this is
the host data pipeline feeding the device step.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SampledBlock:
    node_ids: np.ndarray  # [Nmax] global ids (padded with 0)
    node_mask: np.ndarray  # [Nmax] bool
    edges: np.ndarray  # [Emax, 2] local (src, dst), padded with 0
    edge_mask: np.ndarray  # [Emax] bool
    n_seeds: int


def block_sizes(batch_nodes: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """Padded (Nmax, Emax) for a given batch size and fanout."""
    n, e, frontier = batch_nodes, 0, batch_nodes
    for f in fanout:
        e += frontier * f
        frontier *= f
        n += frontier
    return n, e


class NeighborSampler:
    def __init__(self, n_nodes: int, edges: np.ndarray, seed: int = 0):
        """edges: [E, 2] (src, dst); sampling walks dst -> in-neighbors."""
        order = np.argsort(edges[:, 1], kind="stable")
        self._src = edges[order, 0].astype(np.int64)
        dst = edges[order, 1]
        self._ptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(self._ptr, dst + 1, 1)
        np.cumsum(self._ptr, out=self._ptr)
        self._rng = np.random.default_rng(seed)
        self.n_nodes = n_nodes

    def sample(self, seeds: np.ndarray, fanout: tuple[int, ...]) -> SampledBlock:
        nmax, emax = block_sizes(len(seeds), fanout)
        local: dict[int, int] = {int(s): i for i, s in enumerate(seeds)}
        nodes = list(int(s) for s in seeds)
        e_src: list[int] = []
        e_dst: list[int] = []
        frontier = list(int(s) for s in seeds)
        for f in fanout:
            nxt: list[int] = []
            for u in frontier:
                lo, hi = self._ptr[u], self._ptr[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, int(deg))
                picks = self._rng.choice(int(deg), size=take, replace=False)
                for nb in self._src[lo + picks]:
                    nb = int(nb)
                    if nb not in local:
                        local[nb] = len(nodes)
                        nodes.append(nb)
                    nxt.append(nb)
                    e_src.append(local[nb])
                    e_dst.append(local[u])
            frontier = nxt

        node_ids = np.zeros(nmax, dtype=np.int64)
        node_ids[: len(nodes)] = nodes
        node_mask = np.zeros(nmax, dtype=bool)
        node_mask[: len(nodes)] = True
        edges = np.zeros((emax, 2), dtype=np.int32)
        edges[: len(e_src), 0] = e_src
        edges[: len(e_src), 1] = e_dst
        edge_mask = np.zeros(emax, dtype=bool)
        edge_mask[: len(e_src)] = True
        return SampledBlock(
            node_ids=node_ids,
            node_mask=node_mask,
            edges=edges,
            edge_mask=edge_mask,
            n_seeds=len(seeds),
        )
