"""train_step / serve_step factories — one uniform signature per family.

``make_train_step(loss, opt_cfg, microbatches=k)`` builds a jit-able

    step(params, opt_state, batch) -> (params, opt_state, metrics)

with gradient accumulation over ``k`` microbatches (lax.scan) so the live
activation set is the microbatch's, not the global batch's — the standard
memory/throughput dial at 1000-node scale.  Gradients accumulate in f32 with
the same sharding as the parameters (FSDP extends to the accumulator).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optimizer import adamw


def _split_batch(batch: dict, k: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        return x.reshape(k, b // k, *x.shape[1:])

    return jax.tree.map(f, batch)


def make_train_step(
    loss_fn: Callable[[dict, dict], jax.Array],
    opt_cfg: adamw.AdamWConfig,
    *,
    microbatches: int = 1,
) -> Callable:
    """loss_fn(params, microbatch) -> scalar."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    if microbatches == 1:
        return step

    def step_mb(params, opt_state, batch):
        mb = _split_batch(batch, microbatches)

        def body(carry, one):
            acc, tot = carry
            l, g = jax.value_and_grad(loss_fn)(params, one)
            acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g)
            return (acc, tot + l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, tot), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics["loss"] = tot / microbatches
        return params, opt_state, metrics

    return step_mb
