"""Decoder-only LM family: dense GQA transformers and MoE variants.

Covers the five assigned LM architectures (internlm2-1.8b, qwen3-8b, yi-6b,
olmoe-1b-7b, mixtral-8x7b): grouped-query attention, RoPE, optional QK-norm
(qwen3), optional sliding-window attention (mixtral), SwiGLU FFN, and top-k
token-choice MoE with capacity-based one-hot dispatch (GShard-style einsum
formulation, EP/TP-shardable).

Pure JAX: params are nested dicts, every op is jnp / lax; sharding is
attached externally via :mod:`repro.distributed.shard` rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    sliding_window: int | None = None
    moe: MoEConfig | None = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16  # activation dtype
    param_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def full_attention(self) -> bool:
        return self.sliding_window is None

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        d, h, kv, hd, f, v = (
            self.d_model,
            self.n_heads,
            self.n_kv_heads,
            self.head_dim,
            self.d_ff,
            self.vocab,
        )
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_expert + d * self.moe.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k experts only."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * (
            self.moe.n_experts * 3 * d * self.moe.d_expert
        )
        return dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_expert


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def init_params(cfg: LMConfig, rng: jax.Array) -> dict:
    d, h, kv, hd, f, v = (
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.head_dim,
        cfg.d_ff,
        cfg.vocab,
    )
    pd = cfg.param_dtype
    keys = jax.random.split(rng, cfg.n_layers + 2)

    def dense(key, shape, fan_in):
        return (jax.random.normal(key, shape, pd) / math.sqrt(fan_in)).astype(pd)

    layers = []
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[li], 10)
        layer = {
            "attn": {
                "wq": dense(k[0], (d, h * hd), d),
                "wk": dense(k[1], (d, kv * hd), d),
                "wv": dense(k[2], (d, kv * hd), d),
                "wo": dense(k[3], (h * hd, d), h * hd),
            },
            "ln1": jnp.ones((d,), pd),
            "ln2": jnp.ones((d,), pd),
        }
        if cfg.qk_norm:
            layer["attn"]["q_norm"] = jnp.ones((hd,), pd)
            layer["attn"]["k_norm"] = jnp.ones((hd,), pd)
        if cfg.moe:
            e, fe = cfg.moe.n_experts, cfg.moe.d_expert
            layer["moe"] = {
                "router": dense(k[4], (d, e), d),
                "w_gate": dense(k[5], (e, d, fe), d),
                "w_up": dense(k[6], (e, d, fe), d),
                "w_down": dense(k[7], (e, fe, d), fe),
            }
        else:
            layer["mlp"] = {
                "w_gate": dense(k[4], (d, f), d),
                "w_up": dense(k[5], (d, f), d),
                "w_down": dense(k[6], (f, d), f),
            }
        layers.append(layer)
    return {
        "embed": dense(keys[-2], (v, d), d),
        "unembed": dense(keys[-1], (d, v), d),
        "ln_f": jnp.ones((d,), pd),
        "layers": _stack_layers(layers),
    }


def _stack_layers(layers: list[dict]) -> dict:
    """Stack per-layer pytrees along a leading axis (scan-friendly)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# --------------------------------------------------------------------- #
# building blocks
# --------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def _mask_bias(
    q_pos: jax.Array, k_pos: jax.Array, window: int | None
) -> jax.Array:
    """[B, 1, 1, Sq, Sk] additive mask: causal (+ sliding window)."""
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return jnp.where(ok[:, None, None], 0.0, -1e30).astype(jnp.float32)


def _sdpa_block(q, k, v, bias, scale):
    """GQA block attention.  q: [B, cq, KV, G, hd]; k/v: [B, ck, KV, hd];
    bias: [B, 1, 1, cq, ck].  Returns (o [B,KV,G,cq,hd], m, l)."""
    logits = (
        jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32)
        * scale
        + bias
    )
    # clamp the running max above the mask value so fully-masked rows get
    # p = exp(-1e30 + 1e9) = 0 instead of exp(0) = 1.
    m = jnp.maximum(jnp.max(logits, axis=-1), -1e9)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), v)
    return o, m, l


def gqa_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, KV, hd]
    v: jax.Array,  # [B, Sk, KV, hd]
    q_pos: jax.Array,  # [B, Sq]
    k_pos: jax.Array,  # [B, Sk]
    *,
    window: int | None,
    chunk_q: int = 1024,
    chunk_k: int = 2048,
) -> jax.Array:
    """Flash-style chunked GQA attention (online softmax, O(S) memory).

    Falls back to a single unchunked block for short sequences.  Never
    materializes the repeated-KV tensor nor the full S x S logits.
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kvh, g, hd)

    if sq * sk <= chunk_q * chunk_k * 4:  # small: one block
        bias = _mask_bias(q_pos, k_pos, window)
        o, m, l = _sdpa_block(qg, k, v, bias, scale)
        o = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
        return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)

    nq = -(-sq // chunk_q)
    nk = -(-sk // chunk_k)
    sq_p, sk_p = nq * chunk_q, nk * chunk_k
    qg = jnp.pad(qg, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp_ = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    # padded k positions must never be attended: put them far in the future
    kpos_p = jnp.pad(k_pos, ((0, 0), (0, sk_p - sk)), constant_values=2**30)
    qpos_p = jnp.pad(q_pos, ((0, 0), (0, sq_p - sq)), constant_values=0)

    q_ch = qg.reshape(b, nq, chunk_q, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qp_ch = qpos_p.reshape(b, nq, chunk_q).transpose(1, 0, 2)
    k_ch = kp_.reshape(b, nk, chunk_k, kvh, hd).transpose(1, 0, 2, 3, 4)
    v_ch = vp_.reshape(b, nk, chunk_k, kvh, hd).transpose(1, 0, 2, 3, 4)
    kp_ch = kpos_p.reshape(b, nk, chunk_k).transpose(1, 0, 2)

    def per_q_chunk(carry, xs):
        qc, qpc = xs  # [B, cq, KV, G, hd], [B, cq]

        def per_k_chunk(state, ks):
            m, l, acc = state
            kc, vc, kpc = ks
            bias = _mask_bias(qpc, kpc, window)
            logits = (
                jnp.einsum(
                    "bqkgd,bskd->bkgqs", qc, kc,
                    preferred_element_type=jnp.float32,
                )
                * scale
                + bias
            )
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))  # m0 = -1e9 floor
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None].astype(acc.dtype) + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(qc.dtype), vc
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, chunk_q), -1e9, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, chunk_q, hd), qc.dtype)
        (m, l, acc), _ = jax.lax.scan(
            per_k_chunk, (m0, l0, a0), (k_ch, v_ch, kp_ch)
        )
        o = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return carry, o  # [B, KV, G, cq, hd]

    _, o_ch = jax.lax.scan(per_q_chunk, (), (q_ch, qp_ch))  # [nq, B,KV,G,cq,hd]
    o = o_ch.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * chunk_q, h, hd)
    return o[:, :sq]


def attention(
    cfg: LMConfig,
    p: dict,
    x: jax.Array,  # [B, Sq, d]
    positions: jax.Array,  # [B, Sq]
    k_cache: jax.Array | None = None,  # [B, Sk, kv, hd]
    v_cache: jax.Array | None = None,
    k_pos: jax.Array | None = None,  # [B, Sk]
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    b, sq, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(cfg.dtype)).reshape(b, sq, h, hd)
    q = constrain(q, "batch", None, "heads", None)
    k = (x @ p["wk"].astype(cfg.dtype)).reshape(b, sq, kv, hd)
    v = (x @ p["wv"].astype(cfg.dtype)).reshape(b, sq, kv, hd)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if k_cache is not None:
        k_full = jnp.concatenate([k_cache.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([v_cache.astype(v.dtype), v], axis=1)
        kp = jnp.concatenate([k_pos, positions], axis=1)
    else:
        k_full, v_full, kp = k, v, positions

    o = gqa_attention(
        q, k_full, v_full, positions, kp, window=cfg.sliding_window
    ).reshape(b, sq, h * hd)
    return o @ p["wo"].astype(cfg.dtype), (k_full, v_full)


def swiglu(p: dict, x: jax.Array, dtype) -> jax.Array:
    g = jax.nn.silu(x @ p["w_gate"].astype(dtype))
    u = x @ p["w_up"].astype(dtype)
    return (g * u) @ p["w_down"].astype(dtype)


def moe_block(
    cfg: LMConfig, p: dict, x: jax.Array, groups: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with *group-local* sort-based dispatch.

    The token stream is blocked into ``G`` shard-local groups (G = the batch
    sharding degree from the logical-axis context; 1 on CPU).  Within each
    group: sort (token, k) slots by expert, derive each slot's position in
    its expert from the sorted prefix, scatter into a [E, C_local, d] buffer
    (out-of-capacity slots dropped via ``mode='drop'``), run expert FFNs
    batched over [G, E], gather back and weight-combine.  Every tk-sized op
    is batched over G, so SPMD partitioning is trivially local — this is the
    per-device-capacity dispatch real MoE systems use (GShard/MegaBlocks),
    never the [T, E, C] one-hot.  Returns (output, aux_load_balance_loss).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    from repro.distributed.ctx import group_count

    g_ = groups if groups is not None else group_count("batch", t)
    tl = t // g_  # tokens per group
    tkl = tl * k
    xg = constrain(x.reshape(g_, tl, d), "batch", None, None)

    gate_logits = (xg @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)  # [G, tl, E]
    topw, topi = jax.lax.top_k(probs, k)  # [G, tl, K]
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    e_flat = topi.reshape(g_, tkl)
    w_flat = topw.reshape(g_, tkl).astype(cfg.dtype)
    tok_flat = jnp.broadcast_to(
        jnp.arange(tkl, dtype=jnp.int32) // k, (g_, tkl)
    )
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    e_s = jnp.take_along_axis(e_flat, order, -1)  # [G, TKl]
    tok_s = jnp.take_along_axis(tok_flat, order, -1)
    w_s = jnp.take_along_axis(w_flat, order, -1)

    counts = jax.vmap(lambda es: jnp.bincount(es, length=e))(e_s)  # [G, E]
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos = jnp.arange(tkl, dtype=jnp.int32)[None] - jnp.take_along_axis(
        starts, e_s, -1
    )  # slot within (group, expert)

    cap = max(int(math.ceil(tl * k / e * m.capacity_factor)), k)

    def dispatch(xv, ev, pv, tv):  # per group, all local
        return jnp.zeros((e, cap, d), cfg.dtype).at[ev, pv].add(
            xv[tv], mode="drop"
        )

    buf = jax.vmap(dispatch)(xg.astype(cfg.dtype), e_s, pos, tok_s)
    buf = constrain(buf, "batch", "expert", None, None)  # [G, E, C, d]

    gact = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(cfg.dtype))
    )
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(cfg.dtype))
    ye = constrain(
        jnp.einsum("gecf,efd->gecd", gact * u, p["w_down"].astype(cfg.dtype)),
        "batch", "expert", None, None,
    )

    def combine(yv, ev, pv, tv, wv):  # per group, all local
        vals = yv.at[ev, pv].get(mode="fill", fill_value=0)  # [TKl, d]
        return jnp.zeros((tl, d), cfg.dtype).at[tv].add(vals * wv[:, None])

    yt = jax.vmap(combine)(ye, e_s, pos, tok_s, w_s)  # [G, tl, d]
    yt = constrain(yt, "batch", None, None)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    frac = jnp.sum(counts, axis=0).astype(jnp.float32) / (g_ * tkl)
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * pmean)
    return yt.reshape(b, s, d), aux


# --------------------------------------------------------------------- #
# forward passes
# --------------------------------------------------------------------- #
def _layer_fwd(cfg: LMConfig, lp: dict, x, positions):
    a, _ = attention(cfg, lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), positions)
    x = x + a
    hin = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe:
        y, aux = moe_block(cfg, lp["moe"], hin)
    else:
        y, aux = swiglu(lp["mlp"], hin, cfg.dtype), jnp.float32(0)
    return x + y, aux


def forward(cfg: LMConfig, params: dict, tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced forward: tokens [B, S] -> (hidden [B, S, d], aux).

    The unembedding is applied separately (chunked, in the loss / serving
    head) so the full [B, S, V] logits tensor is never materialized.
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(
        params["embed"].astype(cfg.dtype)[tokens], "batch", None, None
    )

    layer_fn = _layer_fwd
    if cfg.remat:
        layer_fn = jax.checkpoint(
            _layer_fwd, static_argnums=(0,), prevent_cse=False
        )

    def scan_body(carry, lp):
        x, aux = carry
        x, a = layer_fn(cfg, lp, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.float32(0)), params["layers"])
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, aux / cfg.n_layers


def logits_of(cfg: LMConfig, params: dict, hidden: jax.Array) -> jax.Array:
    return hidden @ params["unembed"].astype(cfg.dtype)


def chunked_ce(
    cfg: LMConfig,
    params: dict,
    hidden: jax.Array,  # [B, S, d]
    labels: jax.Array,  # [B, S] (-1 = masked)
    chunk: int = 1024,
) -> jax.Array:
    """Cross entropy without materializing [B, S, V]: scan over sequence
    chunks; per chunk compute logits, logsumexp, and the target logit via a
    one-hot contraction (keeps the vocab axis sharded under TP)."""
    b, s, d = hidden.shape
    w = params["unembed"].astype(cfg.dtype)
    chunk = min(chunk, s)
    n = -(-s // chunk)
    pad = n * chunk - s
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    l = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    h = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    l = l.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        hc, lc = xs  # [B, c, d], [B, c]
        logits = constrain(
            (hc @ w).astype(jnp.float32), "batch", None, "vocab"
        )  # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = constrain(
            jax.nn.one_hot(jnp.maximum(lc, 0), cfg.vocab, dtype=jnp.float32),
            "batch", None, "vocab",
        )
        tgt = jnp.einsum("bcv,bcv->bc", logits, onehot)
        mask = (lc >= 0).astype(jnp.float32)
        return (tot + jnp.sum((lse - tgt) * mask), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (h, l))
    return tot / jnp.maximum(cnt, 1.0)


def prefill_step(
    cfg: LMConfig, params: dict, tokens: jax.Array
) -> tuple[jax.Array, dict]:
    """Serving prefill: build the KV cache, return last-position logits."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"].astype(cfg.dtype)[tokens]
    win = cfg.sliding_window

    def body(x, lp):
        a, (k, v) = attention(
            cfg, lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), positions
        )
        x = x + a
        hin = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            y, _ = moe_block(cfg, lp["moe"], hin)
        else:
            y = swiglu(lp["mlp"], hin, cfg.dtype)
        if win is not None:
            k, v = k[:, -win:], v[:, -win:]
        return x + y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    last_logits = x[:, -1] @ params["unembed"].astype(cfg.dtype)
    cache = {"k": ks, "v": vs, "pos": jnp.full((b,), s, jnp.int32)}
    return last_logits, cache


def init_kv_cache(cfg: LMConfig, batch: int, seq: int) -> dict:
    """Pre-filled KV cache stand-in for decode shapes.  For sliding-window
    attention the cache only ever holds the last ``window`` positions."""
    s = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (cfg.n_layers, batch, s, kv, hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.zeros((batch,), jnp.int32) + s,
    }


def decode_step(
    cfg: LMConfig, params: dict, cache: dict, tokens: jax.Array
) -> tuple[jax.Array, dict]:
    """One-token decode: tokens [B, 1]; rolling cache for SWA."""
    positions = cache["pos"][:, None]  # [B, 1]
    x = params["embed"].astype(cfg.dtype)[tokens]
    s_cache = cache["k"].shape[2]
    k_pos = positions - s_cache + jnp.arange(s_cache, dtype=jnp.int32)[None, :]

    def body(carry, inp):
        x = carry
        lp, kc, vc = inp
        a, (k_new, v_new) = attention(
            cfg,
            lp["attn"],
            rmsnorm(x, lp["ln1"], cfg.norm_eps),
            positions,
            k_cache=kc,
            v_cache=vc,
            k_pos=k_pos,
        )
        x = x + a
        hin = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            y, _ = moe_block(cfg, lp["moe"], hin)
        else:
            y = swiglu(lp["mlp"], hin, cfg.dtype)
        # roll the cache: drop oldest position, append the new one
        return x + y, (k_new[:, 1:], v_new[:, 1:])

    x, (k_c, v_c) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(cfg.dtype)
    new_cache = {"k": k_c, "v": v_c, "pos": cache["pos"] + 1}
    return logits, new_cache


def cast_params(cfg: LMConfig, params: dict) -> dict:
    """Cast f32 master params to the activation dtype ONCE, while still
    sharded — so every FSDP all-gather downstream moves bf16, not f32
    (EXPERIMENTS §Perf, qwen3 train iteration 1).  No-op for bf16 params."""
    return jax.tree.map(
        lambda x: x.astype(cfg.dtype) if x.dtype == jnp.float32 else x, params
    )


def loss_fn(cfg: LMConfig, params: dict, batch: dict) -> jax.Array:
    params = cast_params(cfg, params)
    hidden, aux = forward(cfg, params, batch["tokens"])
    loss = chunked_ce(cfg, params, hidden, batch["labels"])
    return loss + 0.01 * aux
