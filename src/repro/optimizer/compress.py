"""Gradient compression for DCI-bound (cross-pod) data parallelism.

Top-k sparsification with error feedback (memory): each step transmits only
the largest-|g| fraction of each gradient tensor; the residual is carried to
the next step.  On a (pod, data, model) mesh the compressed gradient is what
crosses the pod axis; within a pod the full gradient reduces over ICI.

This is a *pre-reduce* transform: ``compress`` -> (sparse grads as dense
masked tensors, new error memory).  XLA's all-reduce of a mostly-zero tensor
does not shrink bytes by itself, so the practical win comes from pairing
with int8 quantization (``quantize_int8``) which does shrink the wire format.
Both are exposed as composable hooks on the train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def topk_sparsify(grads, error, fraction: float = 0.01):
    """Keep the top-``fraction`` entries (by magnitude) of grad+error."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = jnp.abs(g).reshape(-1)
        k = max(1, int(flat.shape[0] * fraction))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(g) >= thresh
        kept = jnp.where(mask, g, 0.0)
        return kept, g - kept

    flat, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def quantize_int8(grads):
    """Blockwise symmetric int8 quantization; returns (q, scales)."""

    def one(g):
        g = g.astype(jnp.float32)
        s = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        return jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8), s

    flat, treedef = jax.tree.flatten(grads)
    qs = [one(g) for g in flat]
    return (
        treedef.unflatten([q for q, _ in qs]),
        treedef.unflatten([s for _, s in qs]),
    )


def dequantize_int8(q, scales):
    return jax.tree.map(
        lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales
    )
