"""`repro.serve` — the async serving subsystem (DESIGN.md Sect. 10).

Turns the synchronous ``repro.db`` surface into a traffic-shaped front
end: a bounded, admission-controlled request queue with explicit shed
outcomes, deficit-round-robin fairness across tenants sharing one warm
engine, replica routing over immutable snapshots, a real flush timer, and
streaming result delivery::

    from repro.serve import AsyncServer

    async with AsyncServer(db, replicas=2, max_queue=64) as server:
        futs = [server.submit(q, tenant="alice") for q in queries]
        results = await asyncio.gather(*futs)
        assert all(r.outcome in ("ok", "overloaded", "deadline", "cost",
                                 "error") for r in results)

The open-loop saturation benchmark over this loop lives in
``benchmarks/serve_bench.py`` (p50/p99 vs offered load -> the top-level
``BENCH_serve.json`` trajectory); the closed-loop numbers in
``benchmarks/engine_bench.py`` measure the engine underneath, not serving
capacity.
"""
from .fairness import DeficitRoundRobin
from .metrics import LatencyHistogram, MetricsSnapshot, ServeMetrics
from .router import Replica, ReplicaRouter
from .server import OUTCOMES, AsyncServer, ServeResult, stream_pages

__all__ = [
    "AsyncServer",
    "DeficitRoundRobin",
    "LatencyHistogram",
    "MetricsSnapshot",
    "OUTCOMES",
    "Replica",
    "ReplicaRouter",
    "ServeMetrics",
    "ServeResult",
    "stream_pages",
]
