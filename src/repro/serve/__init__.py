"""`repro.serve` — the async serving subsystem (DESIGN.md Sect. 10).

Turns the synchronous ``repro.db`` surface into a traffic-shaped front
end: a bounded, admission-controlled request queue with explicit shed
outcomes, deficit-round-robin fairness across tenants sharing one warm
engine, replica routing over immutable snapshots, a real flush timer, and
streaming result delivery::

    from repro.serve import AsyncServer

    async with AsyncServer(db, replicas=2, max_queue=64) as server:
        futs = [server.submit(q, tenant="alice") for q in queries]
        results = await asyncio.gather(*futs)
        assert all(r.outcome in ("ok", "overloaded", "deadline", "cost",
                                 "error", "timeout") for r in results)

Since ISSUE 10 the loop also carries the failure plane (DESIGN.md Sect.
14): per-replica health (healthy → suspect → quarantined → rebuilding)
with routing that skips quarantined members, deadline-budgeted retry and
optional hedging, a per-batch solve watchdog behind the explicit
``timeout`` outcome, and deterministic fault injection via
:mod:`repro.faults` — the chaos soak over all of it lives in
``benchmarks/chaos_bench.py`` (-> ``BENCH_chaos.json``).

The open-loop saturation benchmark over this loop lives in
``benchmarks/serve_bench.py`` (p50/p99 vs offered load -> the top-level
``BENCH_serve.json`` trajectory); the closed-loop numbers in
``benchmarks/engine_bench.py`` measure the engine underneath, not serving
capacity.
"""
from .fairness import DeficitRoundRobin
from .metrics import LatencyHistogram, MetricsSnapshot, ServeMetrics
from .router import (
    HEALTHY,
    QUARANTINED,
    REBUILDING,
    SUSPECT,
    NoHealthyReplica,
    Replica,
    ReplicaRouter,
)
from .server import OUTCOMES, AsyncServer, ServeResult, stream_pages

__all__ = [
    "AsyncServer",
    "DeficitRoundRobin",
    "HEALTHY",
    "LatencyHistogram",
    "MetricsSnapshot",
    "NoHealthyReplica",
    "OUTCOMES",
    "QUARANTINED",
    "REBUILDING",
    "Replica",
    "ReplicaRouter",
    "SUSPECT",
    "ServeMetrics",
    "ServeResult",
    "stream_pages",
]
