"""Per-tenant weighted fair scheduling: deficit round robin (DESIGN.md
Sect. 10.3).

All tenants of one :class:`~repro.serve.server.AsyncServer` share one warm
engine, so without a scheduler a template storm from one tenant would
occupy every dispatch slot and starve the rest — the classic head-of-line
problem admission control alone cannot fix (admission bounds the *total*
queue, not its composition).  Deficit round robin (Shreedhar & Varghese)
fixes it with O(1) work per dequeue: each backlogged tenant holds a
*deficit* counter topped up by ``quantum * weight`` once per round, and may
dequeue requests while their cost fits the deficit.  Over any backlogged
interval, tenant throughput converges to the weight ratio regardless of
arrival order or burst size.

The scheduler is deliberately loop-agnostic (no asyncio imports): it is
driven from the server's single dispatcher task, so it needs no locking of
its own, and unit tests exercise it synchronously.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Iterator


class DeficitRoundRobin:
    """Weighted deficit-round-robin queue over per-tenant FIFOs.

    ``quantum`` is the deficit top-up per visit for a weight-1.0 tenant, in
    the same unit as item cost (the server uses cost 1.0 per request, so
    quantum = requests per round).  ``weights`` maps tenant -> relative
    weight; unknown tenants default to 1.0.
    """

    def __init__(
        self,
        *,
        quantum: float = 1.0,
        weights: dict[str, float] | None = None,
    ):
        if quantum <= 0:
            raise ValueError("quantum must be > 0")
        self.quantum = quantum
        self.weights = dict(weights or {})
        self._queues: dict[str, deque[tuple[float, Any]]] = {}
        self._deficit: dict[str, float] = {}
        self._active: deque[str] = deque()  # backlogged tenants, round order

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        """Total queued items across all tenants."""
        return sum(len(q) for q in self._queues.values())

    @property
    def tenants(self) -> tuple[str, ...]:
        """Backlogged tenants in current round order."""
        return tuple(self._active)

    def heads(self) -> Iterator[Any]:
        """The head item of every backlogged tenant's FIFO.

        The server scans these for the oldest pending arrival to arm its
        flush timer — per-tenant FIFO order makes the heads sufficient.
        """
        for t in self._active:
            yield self._queues[t][0][1]

    def enqueue(self, tenant: str, item: Any, cost: float = 1.0) -> int:
        """Queue ``item`` for ``tenant``; returns the new total depth."""
        q = self._queues.setdefault(tenant, deque())
        if not q and tenant not in self._active:
            self._active.append(tenant)
            self._deficit.setdefault(tenant, 0.0)
        q.append((cost, item))
        return len(self)

    def take(self, budget: int) -> list[tuple[str, Any]]:
        """Dequeue up to ``budget`` items fairly across backlogged tenants.

        Visits tenants round-robin; each visit tops the tenant's deficit up
        by ``quantum * weight`` and drains head items while their cost fits.
        A tenant emptied mid-round leaves the active list with its deficit
        reset (an idle tenant must not bank credit — that is what makes the
        guarantee *fair* rather than merely work-conserving).
        """
        out: list[tuple[str, Any]] = []
        while len(out) < budget and self._active:
            tenant = self._active.popleft()
            q = self._queues[tenant]
            self._deficit[tenant] += self.quantum * self.weights.get(tenant, 1.0)
            while q and len(out) < budget and q[0][0] <= self._deficit[tenant]:
                cost, item = q.popleft()
                self._deficit[tenant] -= cost
                out.append((tenant, item))
            if q:
                self._active.append(tenant)  # still backlogged: next round
            else:
                self._deficit[tenant] = 0.0  # idle tenants bank nothing
        return out

    def drain(self) -> list[tuple[str, Any]]:
        """Dequeue everything (shutdown path), still in fair order."""
        out: list[tuple[str, Any]] = []
        while self._active:
            out.extend(self.take(max(len(self), 1)))
        return out

    def __repr__(self) -> str:
        depth = {t: len(q) for t, q in self._queues.items() if q}
        return f"DeficitRoundRobin(quantum={self.quantum}, backlog={depth})"
