"""Serving observability: lock-consistent counters + latency histograms
(DESIGN.md Sect. 10.5).

The serving loop is judged by its tail, not its mean: an open-loop
saturation sweep (``benchmarks/serve_bench.py``) needs p50/p99 queue and
end-to-end latency, shed counts *by cause*, and per-tenant throughput —
and it needs them as one *consistent* snapshot, because the dispatcher,
the replica pool, and the benchmark reader all touch the counters from
different threads.  Every mutation and the whole :meth:`ServeMetrics.
snapshot` copy therefore run under one lock; a reader can never observe
``completed`` incremented while its latency sample is still missing.

Latencies go into fixed geometric buckets (:class:`LatencyHistogram`)
rather than per-request lists, so a saturation run's memory cost is O(1)
in request count and quantiles are one pass over ~40 ints.
"""
from __future__ import annotations

import dataclasses
import threading

# Geometric bucket upper edges in seconds: 50us .. ~190s, x1.5 per step.
# Quantiles resolve to a bucket's upper edge, i.e. within +50% of the true
# value — plenty for p50/p99 on a log-scale latency axis.
_EDGES: tuple[float, ...] = tuple(50e-6 * 1.5**k for k in range(38))

SHED_CAUSES = ("overloaded", "cost", "deadline")


class LatencyHistogram:
    """Fixed-bucket geometric latency histogram with quantile readout."""

    __slots__ = ("counts", "n", "total")

    def __init__(self):
        self.counts = [0] * (len(_EDGES) + 1)  # +1: overflow bucket
        self.n = 0
        self.total = 0.0

    def add(self, seconds: float) -> None:
        """Record one latency sample (seconds)."""
        lo, hi = 0, len(_EDGES)
        while lo < hi:  # first bucket whose upper edge holds the sample
            mid = (lo + hi) // 2
            if seconds <= _EDGES[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.n += 1
        self.total += seconds

    def quantile(self, q: float) -> float:
        """Upper-edge estimate of the ``q`` quantile (0 when empty)."""
        if self.n == 0:
            return 0.0
        rank = max(1, int(q * self.n + 0.999999))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return _EDGES[i] if i < len(_EDGES) else float("inf")
        return _EDGES[-1]

    @property
    def mean(self) -> float:
        """Mean of the recorded samples (exact, not bucketed)."""
        return self.total / self.n if self.n else 0.0

    def summary(self) -> dict[str, float]:
        """``{n, mean_ms, p50_ms, p99_ms, max_bucket_ms}`` in milliseconds."""
        return {
            "n": self.n,
            "mean_ms": self.mean * 1e3,
            "p50_ms": self.quantile(0.50) * 1e3,
            "p99_ms": self.quantile(0.99) * 1e3,
        }


@dataclasses.dataclass
class MetricsSnapshot:
    """One consistent copy of the serving counters (plain data, no locks)."""

    submitted: int
    admitted: int
    completed: int
    errors: int
    shed: dict[str, int]  # cause -> count (SHED_CAUSES)
    queue_depth: int
    queue_peak: int
    per_tenant: dict[str, dict[str, int]]  # tenant -> submitted/completed/shed
    queue_wait: dict[str, float]  # LatencyHistogram.summary() of queue time
    latency: dict[str, float]  # summary() of end-to-end completed latency
    service: dict[str, float]  # summary() of per-batch service time
    # failure-plane counters (ISSUE 10); defaulted so older constructors
    # and serialized snapshots stay valid
    timeouts: int = 0  # requests resolved with the explicit timeout outcome
    retries: int = 0  # batch attempts re-dispatched to another replica
    hedges: int = 0  # speculative duplicate dispatches past the tracked p99
    watchdog_overruns: int = 0  # attempts abandoned by the solve watchdog

    @property
    def shed_total(self) -> int:
        """All shed requests, any cause."""
        return sum(self.shed.values())

    @property
    def shed_rate(self) -> float:
        """Fraction of submitted requests shed (0 when nothing submitted)."""
        return self.shed_total / self.submitted if self.submitted else 0.0

    @property
    def resolved(self) -> int:
        """Every request that reached a terminal outcome, any outcome."""
        return self.completed + self.shed_total + self.errors + self.timeouts


class ServeMetrics:
    """Thread-safe serving counters with a single-lock snapshot.

    Invariants every :meth:`snapshot` satisfies (asserted in tests):
    ``submitted == admitted + shed_total + errors_at_admission`` is folded
    into ``submitted >= admitted + shed_total`` and
    ``admitted >= completed + shed["deadline"]`` while requests are in
    flight; once the server has drained,
    ``submitted == completed + shed_total + errors + timeouts``
    (the :attr:`MetricsSnapshot.resolved` identity).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._submitted = 0  # guarded-by: _lock
        self._admitted = 0  # guarded-by: _lock
        self._completed = 0  # guarded-by: _lock
        self._errors = 0  # guarded-by: _lock
        self._shed = {cause: 0 for cause in SHED_CAUSES}  # guarded-by: _lock
        self._queue_depth = 0  # guarded-by: _lock
        self._queue_peak = 0  # guarded-by: _lock
        self._per_tenant: dict[str, dict[str, int]] = {}  # guarded-by: _lock
        self._queue_wait = LatencyHistogram()  # guarded-by: _lock
        self._latency = LatencyHistogram()  # guarded-by: _lock
        self._service = LatencyHistogram()  # guarded-by: _lock
        self._timeouts = 0  # guarded-by: _lock
        self._retries = 0  # guarded-by: _lock
        self._hedges = 0  # guarded-by: _lock
        self._watchdog_overruns = 0  # guarded-by: _lock

    # ------------------------------------------------------------------ #
    # requires-lock: _lock
    def _tenant(self, tenant: str) -> dict[str, int]:
        return self._per_tenant.setdefault(
            tenant, {"submitted": 0, "completed": 0, "shed": 0, "errors": 0}
        )

    def on_submit(self, tenant: str) -> None:
        """One request arrived at the admission gate."""
        with self._lock:
            self._submitted += 1
            self._tenant(tenant)["submitted"] += 1

    def on_admit(self, depth: int) -> None:
        """One request passed admission; ``depth`` is the new queue depth."""
        with self._lock:
            self._admitted += 1
            self._queue_depth = depth
            self._queue_peak = max(self._queue_peak, depth)

    def on_shed(self, tenant: str, cause: str, queue_s: float = 0.0) -> None:
        """One request shed (``cause`` in :data:`SHED_CAUSES`)."""
        with self._lock:
            self._shed[cause] += 1
            self._tenant(tenant)["shed"] += 1
            if queue_s > 0.0:  # deadline sheds waited in queue first
                self._queue_wait.add(queue_s)

    def on_complete(self, tenant: str, queue_s: float, total_s: float) -> None:
        """One admitted request finished with a result."""
        with self._lock:
            self._completed += 1
            self._tenant(tenant)["completed"] += 1
            self._queue_wait.add(queue_s)
            self._latency.add(total_s)

    def on_error(self, tenant: str) -> None:
        """One request failed with an exception (its own, not its batch's)."""
        with self._lock:
            self._errors += 1
            self._tenant(tenant)["errors"] += 1

    def on_timeout(self, tenant: str, queue_s: float = 0.0) -> None:
        """One admitted request exhausted its deadline across attempts."""
        with self._lock:
            self._timeouts += 1
            self._tenant(tenant)["timeouts"] = (
                self._tenant(tenant).get("timeouts", 0) + 1
            )
            if queue_s > 0.0:
                self._queue_wait.add(queue_s)

    def on_retry(self) -> None:
        """One batch attempt was re-dispatched to a different replica."""
        with self._lock:
            self._retries += 1

    def on_hedge(self) -> None:
        """One speculative hedge dispatch was issued."""
        with self._lock:
            self._hedges += 1

    def on_watchdog(self) -> None:
        """One routed attempt was abandoned by the solve watchdog."""
        with self._lock:
            self._watchdog_overruns += 1

    def service_quantile(self, q: float) -> float | None:
        """Per-batch service-time quantile in seconds (None with no samples)."""
        with self._lock:
            if self._service.n == 0:
                return None
            return self._service.quantile(q)

    def on_batch(self, service_s: float, depth: int) -> None:
        """One microbatch finished executing; ``depth`` is the queue now."""
        with self._lock:
            self._service.add(service_s)
            self._queue_depth = depth

    def set_queue_depth(self, depth: int) -> None:
        """Update the queue-depth gauge (and its high-water mark)."""
        with self._lock:
            self._queue_depth = depth
            self._queue_peak = max(self._queue_peak, depth)

    # ------------------------------------------------------------------ #
    def snapshot(self) -> MetricsSnapshot:
        """One consistent copy of every counter, under a single lock."""
        with self._lock:
            return MetricsSnapshot(
                submitted=self._submitted,
                admitted=self._admitted,
                completed=self._completed,
                errors=self._errors,
                shed=dict(self._shed),
                queue_depth=self._queue_depth,
                queue_peak=self._queue_peak,
                per_tenant={t: dict(d) for t, d in self._per_tenant.items()},
                queue_wait=self._queue_wait.summary(),
                latency=self._latency.summary(),
                service=self._service.summary(),
                timeouts=self._timeouts,
                retries=self._retries,
                hedges=self._hedges,
                watchdog_overruns=self._watchdog_overruns,
            )
