"""Replica routing: N read replicas over immutable snapshots (DESIGN.md
Sect. 10.4), with a per-replica health plane (DESIGN.md Sect. 14.2).

Read replicas are nearly free in this system: a :class:`~repro.db.graphdb.
GraphDB` hands out *immutable* graph snapshots, and plan-cache keys carry
the versioned fingerprint, so any number of :class:`~repro.engine.engine.
Engine` instances can serve the same database concurrently without
coordination — each owns its plan cache, its adjacency uploads, and its own
lock, and ``Engine.execute_prepared`` pins exactly one snapshot per batch.

What replicas add is *parallel service*: the solver path holds the GIL only
between XLA dispatches, so two replicas executing on a thread pool overlap
their fixpoint compute.  What they must not add is *torn reads*: a replica
adopting a mutation halfway through a batch.  Two mechanisms fence that:

* snapshot pinning — a batch refreshes at its start and never again, so
  every request in it sees one graph version (a mutation mid-batch lands in
  the *next* batch);
* mutation epochs — :meth:`ReplicaRouter.fence` refreshes every replica to
  the source's current version and returns that version; after a fence, no
  replica can serve a pre-mutation snapshot.

Routing itself is least-in-flight (ties broken round-robin) *weighted by
health*.  Raw least-in-flight has a failure-amplification bug: a replica
that fails fast drains its in-flight gauge fast, so the picker keeps
steering MORE traffic onto the broken member.  The router therefore keeps a
per-replica failure EWMA and a healthy → suspect → quarantined → rebuilding
state machine:

* attempt failures (the whole routed batch raised — a crash, not one bad
  request) and watchdog overruns mark a replica **suspect** and, after
  ``quarantine_after`` consecutive ones, **quarantined**;
* chronic stragglers are caught by the seed
  :class:`~repro.distributed.fault.StragglerMonitor`, fed with cumulative
  service-time heartbeats so its step latency *is* the mean per-batch
  service time — a replica whose mean exceeds ``threshold × median`` of the
  fleet is straggling regardless of traffic shape;
* suspects keep serving but *probed*: the score penalty would otherwise
  starve a suspect of traffic entirely, so its failure streak could never
  reach the quarantine threshold (and a recovered replica could never
  prove itself) — every ``probe_every``-th route deliberately canaries a
  live batch onto a suspect, bounding a broken member's traffic share at
  ``1/probe_every`` while keeping its health verdict moving;
* quarantined replicas are skipped by :meth:`route` and **rebuilt** in the
  background under the seed :class:`~repro.distributed.fault.RestartPolicy`:
  a fresh engine over the live ``GraphDB`` snapshot, refreshed to the
  current version, swapped in with a bumped *epoch* so late health reports
  from pre-rebuild attempts cannot poison the new engine (epoch-fenced
  re-admission).

Request-level faults (one poisoned query in a batch) are isolated per
request and do NOT count against the replica: poison travels with the
request and would fail anywhere.
"""
from __future__ import annotations

import threading
import time
from typing import Sequence

from repro.db.results import ResultSet
from repro.distributed.fault import Heartbeat, RestartPolicy, StragglerMonitor
from repro.engine.engine import Engine

#: Replica health states (DESIGN.md 14.2).
HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"
REBUILDING = "rebuilding"

#: States a replica can be routed to.
ROUTABLE = (HEALTHY, SUSPECT)


class NoHealthyReplica(RuntimeError):
    """Every replica is quarantined or rebuilding: nothing to route to."""


class Replica:
    """One read replica: a private engine, lock, gauges, and health state."""

    __slots__ = (
        "name", "engine", "lock", "in_flight", "batches",
        "state", "epoch", "error_score", "latency_ewma",
        "consecutive_failures", "consecutive_successes", "straggles",
        "service_clock", "hb_steps", "quarantines", "rebuilds", "last_error",
    )

    def __init__(self, name: str, engine: Engine):
        """Wrap ``engine`` as replica ``name`` in the healthy state."""
        self.name = name
        # `engine`/`lock` are swapped atomically by rebuild; users snapshot
        # both under the router lock and keep using their snapshot (a
        # pre-rebuild batch finishes on the old engine + old lock).
        self.engine = engine
        self.lock = threading.Lock()
        # Every gauge below belongs to the router's routing/health decision,
        # so all are guarded by the *router's* lock, not the engine lock.
        self.in_flight = 0  # guarded-by: self._route_lock
        self.batches = 0  # guarded-by: self._route_lock
        self.state = HEALTHY  # guarded-by: self._route_lock
        self.epoch = 0  # guarded-by: self._route_lock
        self.error_score = 0.0  # guarded-by: self._route_lock
        self.latency_ewma = None  # guarded-by: self._route_lock
        self.consecutive_failures = 0  # guarded-by: self._route_lock
        self.consecutive_successes = 0  # guarded-by: self._route_lock
        self.straggles = 0  # guarded-by: self._route_lock
        self.service_clock = 0.0  # guarded-by: self._route_lock
        self.hb_steps = 0  # guarded-by: self._route_lock
        self.quarantines = 0  # guarded-by: self._route_lock
        self.rebuilds = 0  # guarded-by: self._route_lock
        self.last_error = None  # guarded-by: self._route_lock


class ReplicaRouter:
    """Route prepared batches across N engine replicas of one database.

    Replicas inherit the database's engine configuration (engine
    preference, buckets, mesh, incremental maintenance) so a routed request
    behaves exactly like ``db.query`` modulo which plan cache warms up.
    When ``fault_plan`` is set, each replica engine gets the plan's bound
    request-level hooks and the router consults its replica-level hooks —
    all zero-cost no-ops while the plan is disarmed.
    """

    def __init__(
        self,
        db,
        n_replicas: int = 2,
        *,
        fault_plan=None,
        auto_rebuild: bool = True,
        suspect_after: int = 1,
        quarantine_after: int = 3,
        recover_after: int = 2,
        error_penalty: float = 4.0,
        suspect_penalty: float = 2.0,
        probe_every: int = 4,
        straggler_factor: float = 4.0,
        straggler_window: int = 8,
        rebuild_backoff_s: float = 0.05,
        max_rebuilds: int = 4,
    ):
        """Build ``n_replicas`` engines over ``db`` plus the health plane."""
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self._db = db
        self._faults = fault_plan
        self.replicas = [
            Replica(f"r{i}", self._make_engine()) for i in range(n_replicas)
        ]
        if fault_plan is not None:
            for rep in self.replicas:
                rep.engine.faults = fault_plan.bind(rep.name)
        self._auto_rebuild = auto_rebuild
        self._suspect_after = max(1, suspect_after)
        self._quarantine_after = max(1, quarantine_after)
        self._recover_after = max(1, recover_after)
        self._error_penalty = error_penalty
        self._suspect_penalty = suspect_penalty
        self._probe_every = max(2, probe_every)
        self._restart_policy = RestartPolicy(
            max_restarts=max_rebuilds,
            backoff_s=rebuild_backoff_s,
            backoff_cap_s=1.0,
        )
        self._route_lock = threading.Lock()
        self._rr = 0  # guarded-by: _route_lock (round-robin tiebreaker)
        # service-time heartbeats: step = completed batches, t = cumulative
        # service seconds, so monitor "step latency" == mean service time
        self._monitor = StragglerMonitor(  # guarded-by: self._route_lock
            window=straggler_window, threshold=straggler_factor
        )
        self._events = []  # guarded-by: self._route_lock
        self._fence_failures = 0  # guarded-by: self._route_lock
        self._last_fence_partial = ()  # guarded-by: self._route_lock
        self._rebuild_threads = []  # guarded-by: self._route_lock

    def _make_engine(self) -> Engine:
        """A fresh engine replicating the database's own configuration."""
        proto = self._db._engine
        return Engine(
            self._db,
            engine=proto.engine_pref,
            cache_capacity=proto.cache.capacity,
            buckets=proto.buckets,
            backend=proto.backend,
            mesh=proto.mesh,
            n_blocks=proto.n_blocks,
            incremental=proto.incremental,
        )

    def __len__(self) -> int:
        """Number of replicas (routable or not)."""
        return len(self.replicas)

    # ------------------------------------------------------------------ #
    # routing
    # ------------------------------------------------------------------ #
    def route(self, exclude: Sequence[str] = ()) -> Replica:
        """Pick the best routable replica and count the batch in flight.

        Score is ``(in_flight + 1) · relative_latency + error_penalty ·
        failure_EWMA`` (+ a constant for suspects): expected wait in
        fleet-typical batch units, so a straggler saturates at one or two
        outstanding batches instead of matching the fast replicas'
        in-flight *count*, and a fast-failing replica is *de*-prioritized
        even though its in-flight gauge drains quickly.  Relative latency
        only bites at a >= 3x EWMA ratio — smaller disparities are host
        noise and must tie so the rotation keeps alternating.  Every
        ``probe_every``-th route canaries an *idle* suspect instead:
        without probes the penalty starves a suspect of traffic, so it
        can neither accumulate the failures that quarantine it nor the
        successes that recover it; requiring ``in_flight == 0`` bounds
        probe traffic to the suspect's own service rate.  ``exclude``
        names replicas already tried for this batch (retry/hedge
        placement); if exclusion empties the candidate set it is ignored —
        a busy replica beats no replica.  Raises
        :class:`NoHealthyReplica` when every replica is quarantined or
        rebuilding.
        """
        with self._route_lock:
            self._rr += 1
            avail = [r for r in self.replicas if r.state in ROUTABLE]
            if not avail:
                raise NoHealthyReplica(
                    "all replicas quarantined or rebuilding"
                )
            cands = [r for r in avail if r.name not in exclude] or avail
            if self._rr % self._probe_every == 0:
                # canary only *idle* suspects: a probe behind a backlog
                # re-measures the backlog, not the replica, and gating on
                # in_flight == 0 bounds probe traffic to the suspect's own
                # service rate (a wedged suspect drains via the watchdog,
                # a fast-failing one instantly, so probes keep flowing)
                suspects = [
                    r for r in cands
                    if r.state == SUSPECT and r.in_flight == 0
                ]
                if suspects:
                    rep = suspects[0]
                    rep.in_flight += 1
                    return rep
            k = self._rr % len(cands)
            order = cands[k:] + cands[:k]
            rep = min(order, key=self._score_locked)
            rep.in_flight += 1
            return rep

    # requires-lock: _route_lock
    def _score_locked(self, r: Replica) -> float:
        # Least-expected-wait, in units of fleet-typical batches: a batch
        # behind a 10x straggler waits 10x longer than its in_flight count
        # suggests, so in_flight alone keeps stacking work (and executor
        # slots) behind the slow replica until its *count* matches the
        # fast one's.  Scale by service latency relative to the fleet's
        # fastest (dimensionless, so the error/suspect penalties keep
        # their batch-count scale).  Sub-3x ratios score 1.0 — they are
        # noise, not signal: healthy replicas differ by EWMA epsilon (a
        # 2.8 ms vs 3.0 ms ratio is never exactly 1.0), and a loaded
        # host shows 2x between *identical* replicas; under a strict
        # min() any such epsilon steers 100% of idle-time traffic to one
        # replica, and with a sequential client the starved replica's
        # stale EWMA never gets a correcting sample — the bias is
        # permanent.  Only order-of-magnitude disparities (an actual
        # straggler) steer; near-equals must tie exactly so the rotation
        # alternates.  Unknown latency scores as 1.0: a fresh replica is
        # not presumed slow.
        lats = [
            x.latency_ewma for x in self.replicas
            if x.latency_ewma is not None and x.latency_ewma > 0.0
        ]
        slowness = 1.0
        if lats and r.latency_ewma is not None and r.latency_ewma > 0.0:
            ratio = r.latency_ewma / max(min(lats), 1e-9)
            if ratio >= 3.0:
                slowness = round(ratio)
        score = (r.in_flight + 1.0) * slowness
        score += self._error_penalty * r.error_score
        if r.state == SUSPECT:
            score += self._suspect_penalty
        return score

    def release(self, rep: Replica) -> None:
        """Return a routed batch slot."""
        with self._route_lock:
            rep.in_flight -= 1
            rep.batches += 1

    # ------------------------------------------------------------------ #
    # execution
    # ------------------------------------------------------------------ #
    def execute_isolated(
        self, prepared: Sequence
    ) -> tuple[list[ResultSet | Exception], str]:
        """Route one prepared batch and execute it (route + execute_on)."""
        rep = self.route()
        return self.execute_on(rep, prepared)

    def execute_on(
        self, rep: Replica, prepared: Sequence
    ) -> tuple[list[ResultSet | Exception], str]:
        """Execute one prepared batch on an already-routed replica.

        Returns ``(outcomes, replica_name)`` where each outcome is either a
        :class:`ResultSet` or the exception *that request* raised.  The
        fast path executes the whole batch in one microbatched call; if it
        raises, the batch re-runs request-by-request so one poisoned
        request cannot take its siblings' results down with it (the same
        isolation contract as ``Session.flush``).

        An exception escaping this method is an *attempt* failure (the
        replica itself broke — e.g. an injected crash) and feeds the health
        plane; per-request outcome exceptions do not.  Health reports are
        epoch-fenced: a batch that started before a rebuild cannot mark the
        rebuilt engine.  The routed slot is always released.
        """
        with self._route_lock:
            eng = rep.engine
            lk = rep.lock
            epoch = rep.epoch
        t0 = time.monotonic()
        try:
            if self._faults is not None:
                self._faults.on_batch_start(rep.name)
            with lk:
                # the slow-fault penalty scales *solve* time only: clocking
                # it from before the lock would multiply each batch's wait
                # behind its predecessor's sleep — an exponential backlog
                # no real 10x-slower replica exhibits
                t_solve = time.monotonic()
                try:
                    raws = eng.execute_prepared(list(prepared))
                    snap = eng.db
                    out: list[ResultSet | Exception] = [
                        ResultSet(r, snap) for r in raws
                    ]
                except Exception:
                    out = []
                    for pr in prepared:
                        try:
                            raw = eng.execute_prepared([pr])[0]
                            out.append(ResultSet(raw, eng.db))
                        except Exception as exc:  # this request's own fault
                            out.append(exc)
                if self._faults is not None:
                    penalty = self._faults.solve_penalty(
                        rep.name, time.monotonic() - t_solve
                    )
                    if penalty > 0.0:
                        time.sleep(penalty)
        except BaseException as exc:
            self._observe(rep, epoch, time.monotonic() - t0, error=exc)
            raise
        else:
            self._observe(rep, epoch, time.monotonic() - t0, error=None)
            return out, rep.name
        finally:
            self.release(rep)

    def on_overrun(self, rep: Replica) -> None:
        """Record a watchdog overrun: the routed attempt was abandoned."""
        with self._route_lock:
            if rep.state in (QUARANTINED, REBUILDING):
                return
            self._note_failure_locked(rep, "solve watchdog overrun")

    # ------------------------------------------------------------------ #
    # health plane
    # ------------------------------------------------------------------ #
    def _observe(
        self, rep: Replica, epoch: int, dt: float, *, error
    ) -> None:
        """Feed one finished attempt into the health state machine."""
        with self._route_lock:
            if rep.epoch != epoch:
                return  # pre-rebuild attempt: not the new engine's record
            if rep.state in (QUARANTINED, REBUILDING):
                return
            if error is not None:
                self._note_failure_locked(rep, repr(error))
                return
            # success: latency + straggler bookkeeping (failures are often
            # artificially fast, so only successes move the latency view)
            rep.error_score *= 0.5
            rep.consecutive_failures = 0
            prev = rep.latency_ewma
            rep.latency_ewma = dt if prev is None else 0.8 * prev + 0.2 * dt
            rep.service_clock += dt
            rep.hb_steps += 1
            self._monitor.report(
                Heartbeat(rep.name, rep.hb_steps, rep.service_clock)
            )
            if rep.name in self._monitor.stragglers():
                rep.straggles += 1
                rep.consecutive_successes = 0
                if rep.straggles >= self._quarantine_after:
                    self._quarantine_locked(rep, "chronic straggler")
                elif (
                    rep.state == HEALTHY
                    and rep.straggles >= self._suspect_after
                ):
                    rep.state = SUSPECT
                    self._event_locked(rep, "suspect", "straggling")
            else:
                rep.straggles = 0
                rep.consecutive_successes += 1
                if rep.consecutive_successes >= self._recover_after:
                    # full recovery clears the penalty entirely — the EWMA
                    # is evidence for state transitions, not a permanent
                    # tax.  A lingering epsilon would deterministically
                    # lose every min() tie-break under light sequential
                    # load, starving this replica of the traffic that
                    # warms its plan cache (and of the successes that
                    # would ever decay the epsilon away).
                    rep.error_score = 0.0
                    if rep.state == SUSPECT:
                        rep.state = HEALTHY
                        self._event_locked(rep, "recovered", "")

    # requires-lock: _route_lock
    def _note_failure_locked(self, rep: Replica, reason: str) -> None:
        rep.error_score = 0.5 * rep.error_score + 0.5
        rep.consecutive_failures += 1
        rep.consecutive_successes = 0
        rep.last_error = reason
        if rep.consecutive_failures >= self._quarantine_after:
            self._quarantine_locked(rep, reason)
        elif rep.state == HEALTHY and (
            rep.consecutive_failures >= self._suspect_after
        ):
            rep.state = SUSPECT
            self._event_locked(rep, "suspect", reason)

    # requires-lock: _route_lock
    def _quarantine_locked(self, rep: Replica, reason: str) -> None:
        if rep.state in (QUARANTINED, REBUILDING):
            return
        others = [
            r for r in self.replicas
            if r is not rep and r.state in ROUTABLE
        ]
        if not others:
            # never quarantine the last routable replica: degraded service
            # beats no service (stays suspect, keeps its error penalty)
            rep.state = SUSPECT
            self._event_locked(rep, "quarantine_deferred", reason)
            return
        rep.state = QUARANTINED
        rep.quarantines += 1
        self._event_locked(rep, "quarantined", reason)
        if self._auto_rebuild:
            t = threading.Thread(
                target=self._rebuild, args=(rep,),
                name=f"rebuild-{rep.name}", daemon=True,
            )
            self._rebuild_threads.append(t)
            t.start()

    def _rebuild(self, rep: Replica) -> None:
        """Background rebuild of a quarantined replica (epoch-fenced swap).

        Runs under the seed :class:`RestartPolicy` (capped exponential
        backoff, bounded restarts).  A rebuild is the moral equivalent of a
        process restart, so the fault plan's crash state for this replica
        is healed first; the fresh engine is built from the live database,
        refreshed to its current version, then swapped in together with a
        NEW replica lock — the old lock may be held forever by a wedged
        abandoned attempt — and a bumped epoch so stale health reports are
        fenced out.
        """
        if self._faults is not None:
            self._faults.heal(rep.name)

        def body(_restart_idx: int) -> None:
            with self._route_lock:
                rep.state = REBUILDING
                self._event_locked(rep, "rebuilding", "")
            eng = self._make_engine()
            if self._faults is not None:
                eng.faults = self._faults.bind(rep.name)
            eng.refresh()
            with self._route_lock:
                rep.engine = eng
                rep.lock = threading.Lock()
                rep.epoch += 1
                rep.state = HEALTHY
                rep.rebuilds += 1
                rep.error_score = 0.0
                rep.latency_ewma = None
                rep.consecutive_failures = 0
                rep.consecutive_successes = 0
                rep.straggles = 0
                rep.service_clock = 0.0
                rep.hb_steps = 0
                self._monitor.forget(rep.name)
                self._event_locked(rep, "rebuilt", f"epoch {rep.epoch}")

        try:
            self._restart_policy.run(body, sleep=time.sleep)
        except BaseException as exc:  # noqa: BLE001 — supervisor semantics
            with self._route_lock:
                rep.state = QUARANTINED
                rep.last_error = f"rebuild failed: {exc!r}"
                self._event_locked(rep, "rebuild_failed", repr(exc))

    # requires-lock: _route_lock
    def _event_locked(self, rep: Replica, event: str, detail: str) -> None:
        self._events.append({
            "t": time.monotonic(),
            "replica": rep.name,
            "event": event,
            "detail": detail,
            "batches": rep.batches,
        })

    def health(self) -> list[dict]:
        """Per-replica health snapshot (state, scores, epochs, gauges)."""
        with self._route_lock:
            return [
                {
                    "name": r.name,
                    "state": r.state,
                    "epoch": r.epoch,
                    "in_flight": r.in_flight,
                    "batches": r.batches,
                    "error_score": round(r.error_score, 4),
                    "latency_ewma_ms": (
                        None if r.latency_ewma is None
                        else round(r.latency_ewma * 1e3, 3)
                    ),
                    "quarantines": r.quarantines,
                    "rebuilds": r.rebuilds,
                    "last_error": r.last_error,
                }
                for r in self.replicas
            ]

    def events(self) -> list[dict]:
        """Health transition log (suspect/quarantined/rebuilt/...)."""
        with self._route_lock:
            return [dict(e) for e in self._events]

    def wait_rebuilt(self, timeout: float = 5.0) -> bool:
        """Block until no replica is quarantined/rebuilding (True on success)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._route_lock:
                bad = [
                    r for r in self.replicas
                    if r.state in (QUARANTINED, REBUILDING)
                ]
            if not bad:
                return True
            time.sleep(0.01)
        return False

    # ------------------------------------------------------------------ #
    def fence(self) -> int:
        """Advance every replica to the source's current mutation epoch.

        Returns the fenced version: after this call no *successfully
        fenced* replica will serve a snapshot older than it (reads started
        before the fence keep their pinned — complete, never half-applied —
        older snapshot).  A replica whose ``refresh()`` raises no longer
        aborts the fleet fence half-way: it is marked suspect (ISSUE 10
        satellite), the remaining replicas are still fenced, and the
        partial fence is reported via :meth:`aggregate` /
        ``last_fence_partial``.
        """
        version = self._db.version
        failed: list[str] = []
        for rep in self.replicas:
            with self._route_lock:
                eng = rep.engine
                lk = rep.lock
            try:
                if self._faults is not None:
                    self._faults.on_refresh(rep.name)
                with lk:
                    eng.refresh()
            except Exception as exc:
                failed.append(rep.name)
                with self._route_lock:
                    self._fence_failures += 1
                    self._note_failure_locked(
                        rep, f"fence refresh failed: {exc!r}"
                    )
        with self._route_lock:
            self._last_fence_partial = tuple(failed)
        return version

    def versions(self) -> list[int | None]:
        """Each replica's currently-adopted source version (for tests)."""
        out: list[int | None] = []
        for rep in self.replicas:
            with rep.lock:  # RL3: fence() mutates the engine under rep.lock
                out.append(rep.engine._version)
        return out

    def stats(self) -> list:
        """Per-replica :class:`~repro.engine.engine.EngineMetrics`."""
        return [rep.engine.stats() for rep in self.replicas]

    def aggregate(self) -> dict[str, int | float]:
        """Summed serving counters across replicas (the CLI's one-liner)."""
        agg = {
            "requests": 0, "microbatches": 0, "cache_hits": 0,
            "cache_misses": 0, "plan_builds": 0, "plan_invalidations": 0,
            "plans_resumable": 0, "plans_resumed": 0, "warm_resume_solves": 0,
            "resumes_declined": 0, "adj_rebuilds_saved": 0,
        }
        engines: dict[str, int] = {}
        for m in self.stats():
            agg["requests"] += m.requests
            agg["microbatches"] += m.microbatches
            agg["cache_hits"] += m.cache.hits
            agg["cache_misses"] += m.cache.misses
            agg["plan_builds"] += m.plan_builds
            agg["plan_invalidations"] += m.plan_invalidations
            agg["plans_resumable"] += m.plans_resumable
            agg["plans_resumed"] += m.plans_resumed
            agg["warm_resume_solves"] += m.warm_resume_solves
            agg["resumes_declined"] += m.resumes_declined
            agg["adj_rebuilds_saved"] += m.adj_rebuilds_saved
            for eng, cnt in m.engine_counts.items():
                engines[eng] = engines.get(eng, 0) + cnt
        agg["engine_counts"] = engines
        with self._route_lock:  # RL3: `batches` is mutated under _route_lock
            agg["batches_per_replica"] = [r.batches for r in self.replicas]
            agg["health"] = {r.name: r.state for r in self.replicas}
            agg["quarantines"] = sum(r.quarantines for r in self.replicas)
            agg["rebuilds"] = sum(r.rebuilds for r in self.replicas)
            agg["fence_failures"] = self._fence_failures
            agg["fence_partial"] = list(self._last_fence_partial)
        return agg
