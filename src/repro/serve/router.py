"""Replica routing: N read replicas over immutable snapshots (DESIGN.md
Sect. 10.4).

Read replicas are nearly free in this system: a :class:`~repro.db.graphdb.
GraphDB` hands out *immutable* graph snapshots, and plan-cache keys carry
the versioned fingerprint, so any number of :class:`~repro.engine.engine.
Engine` instances can serve the same database concurrently without
coordination — each owns its plan cache, its adjacency uploads, and its own
lock, and ``Engine.execute_prepared`` pins exactly one snapshot per batch.

What replicas add is *parallel service*: the solver path holds the GIL only
between XLA dispatches, so two replicas executing on a thread pool overlap
their fixpoint compute.  What they must not add is *torn reads*: a replica
adopting a mutation halfway through a batch.  Two mechanisms fence that:

* snapshot pinning — a batch refreshes at its start and never again, so
  every request in it sees one graph version (a mutation mid-batch lands in
  the *next* batch);
* mutation epochs — :meth:`ReplicaRouter.fence` refreshes every replica to
  the source's current version and returns that version; after a fence, no
  replica can serve a pre-mutation snapshot.

Routing itself is least-in-flight (ties broken round-robin), which under
uniform service times degenerates to round-robin and under skewed templates
keeps a slow solve from queueing followers behind it.
"""
from __future__ import annotations

import threading
from typing import Sequence

from repro.db.results import ResultSet
from repro.engine.engine import Engine


class Replica:
    """One read replica: a private engine, lock, and in-flight gauge."""

    __slots__ = ("name", "engine", "lock", "in_flight", "batches")

    def __init__(self, name: str, engine: Engine):
        self.name = name
        self.engine = engine
        self.lock = threading.Lock()
        # Both gauges belong to the router's routing decision, so they are
        # guarded by the *router's* lock, not this replica's engine lock.
        self.in_flight = 0  # guarded-by: self._route_lock
        self.batches = 0  # guarded-by: self._route_lock


class ReplicaRouter:
    """Route prepared batches across N engine replicas of one database.

    Replicas inherit the database's engine configuration (engine
    preference, buckets, mesh, incremental maintenance) so a routed request
    behaves exactly like ``db.query`` modulo which plan cache warms up.
    """

    def __init__(self, db, n_replicas: int = 2):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self._db = db
        proto = db._engine  # replicate the database's engine configuration
        self.replicas = [
            Replica(
                f"r{i}",
                Engine(
                    db,
                    engine=proto.engine_pref,
                    cache_capacity=proto.cache.capacity,
                    buckets=proto.buckets,
                    backend=proto.backend,
                    mesh=proto.mesh,
                    n_blocks=proto.n_blocks,
                    incremental=proto.incremental,
                ),
            )
            for i in range(n_replicas)
        ]
        self._route_lock = threading.Lock()
        self._rr = 0  # guarded-by: _route_lock (round-robin tiebreaker)

    def __len__(self) -> int:
        """Number of replicas."""
        return len(self.replicas)

    # ------------------------------------------------------------------ #
    def route(self) -> Replica:
        """Pick the least-loaded replica and count the batch in flight."""
        with self._route_lock:
            self._rr += 1
            order = self.replicas[self._rr % len(self.replicas):] + \
                self.replicas[: self._rr % len(self.replicas)]
            rep = min(order, key=lambda r: r.in_flight)
            rep.in_flight += 1
            return rep

    def release(self, rep: Replica) -> None:
        """Return a routed batch slot."""
        with self._route_lock:
            rep.in_flight -= 1
            rep.batches += 1

    def execute_isolated(
        self, prepared: Sequence
    ) -> tuple[list[ResultSet | Exception], str]:
        """Execute one prepared batch on a routed replica.

        Returns ``(outcomes, replica_name)`` where each outcome is either a
        :class:`ResultSet` or the exception *that request* raised.  The
        fast path executes the whole batch in one microbatched call; if it
        raises, the batch re-runs request-by-request so one poisoned
        request cannot take its siblings' results down with it (the same
        isolation contract as ``Session.flush``).
        """
        rep = self.route()
        try:
            with rep.lock:
                try:
                    raws = rep.engine.execute_prepared(list(prepared))
                    snap = rep.engine.db
                    return [ResultSet(r, snap) for r in raws], rep.name
                except Exception:
                    out: list[ResultSet | Exception] = []
                    for pr in prepared:
                        try:
                            raw = rep.engine.execute_prepared([pr])[0]
                            out.append(ResultSet(raw, rep.engine.db))
                        except Exception as exc:  # this request's own fault
                            out.append(exc)
                    return out, rep.name
        finally:
            self.release(rep)

    # ------------------------------------------------------------------ #
    def fence(self) -> int:
        """Advance every replica to the source's current mutation epoch.

        Returns the fenced version: after this call no replica will serve a
        snapshot older than it (reads started before the fence keep their
        pinned — complete, never half-applied — older snapshot).
        """
        version = self._db.version
        for rep in self.replicas:
            with rep.lock:
                rep.engine.refresh()
        return version

    def versions(self) -> list[int | None]:
        """Each replica's currently-adopted source version (for tests)."""
        out: list[int | None] = []
        for rep in self.replicas:
            with rep.lock:  # RL3: fence() mutates the engine under rep.lock
                out.append(rep.engine._version)
        return out

    def stats(self) -> list:
        """Per-replica :class:`~repro.engine.engine.EngineMetrics`."""
        return [rep.engine.stats() for rep in self.replicas]

    def aggregate(self) -> dict[str, int | float]:
        """Summed serving counters across replicas (the CLI's one-liner)."""
        agg = {
            "requests": 0, "microbatches": 0, "cache_hits": 0,
            "cache_misses": 0, "plan_builds": 0, "plan_invalidations": 0,
            "plans_resumable": 0, "plans_resumed": 0, "warm_resume_solves": 0,
            "resumes_declined": 0, "adj_rebuilds_saved": 0,
        }
        engines: dict[str, int] = {}
        for m in self.stats():
            agg["requests"] += m.requests
            agg["microbatches"] += m.microbatches
            agg["cache_hits"] += m.cache.hits
            agg["cache_misses"] += m.cache.misses
            agg["plan_builds"] += m.plan_builds
            agg["plan_invalidations"] += m.plan_invalidations
            agg["plans_resumable"] += m.plans_resumable
            agg["plans_resumed"] += m.plans_resumed
            agg["warm_resume_solves"] += m.warm_resume_solves
            agg["resumes_declined"] += m.resumes_declined
            agg["adj_rebuilds_saved"] += m.adj_rebuilds_saved
            for eng, cnt in m.engine_counts.items():
                engines[eng] = engines.get(eng, 0) + cnt
        agg["engine_counts"] = engines
        with self._route_lock:  # RL3: `batches` is mutated under _route_lock
            agg["batches_per_replica"] = [r.batches for r in self.replicas]
        return agg
