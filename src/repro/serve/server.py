"""Admission-controlled asyncio serving loop (DESIGN.md Sect. 10).

The paper positions dual simulation as a pre-filter *inside a database
system serving real traffic*; Pérez et al. put the worst case of that
traffic at Pspace-complete, so a production front end must bound what it
accepts — unbounded queueing turns one pathological template into
everyone's latency.  :class:`AsyncServer` is that front end over the stable
``repro.db`` surface:

* **admission control** — a bounded queue (``max_queue``), a per-request
  model-cost cap (``cost_cap``, priced by :func:`repro.engine.cost.
  admission_estimate`), and per-request deadlines.  A request that cannot
  be admitted is *shed immediately* with an explicit outcome
  (``overloaded`` / ``cost`` / ``deadline``) instead of queueing without
  bound — the backpressure contract is "a fast no, never a slow maybe".
* **per-tenant fairness** — admitted requests enter a deficit-round-robin
  scheduler (:mod:`repro.serve.fairness`); a template storm from one
  tenant cannot starve the others' dispatch slots.
* **replica routing** — batches execute on a pool of engine replicas over
  immutable snapshots (:mod:`repro.serve.router`), overlapping service.
* **real flush timer** — the dispatcher releases a batch when it fills
  (``max_batch``) or when the oldest admitted request has waited
  ``max_delay_ms``, whichever first; unlike the cooperative
  :class:`~repro.db.session.Session` policy this timer fires without any
  further submit arriving.
* **streaming delivery** — :func:`stream_pages` paginates a result set as
  an async iterator, so a large survivor set never materializes in one
  response.
* **failure handling** (DESIGN.md Sect. 14) — a transient batch failure is
  retried on a *different* replica while the riders' deadlines still
  afford it (the retry decision is ``remaining_budget > estimated_cost``,
  priced by the calibrated cost model, with capped exponential backoff); a
  per-batch **solve watchdog** bounds each routed attempt's wall clock and
  abandons overruns (the replica goes suspect, the batch retries once on a
  healthy one, then resolves with the explicit ``timeout`` outcome); and
  optional **hedging** races a duplicate dispatch once an attempt's
  service time passes the tracked p99.  Deterministic fault injection
  (:mod:`repro.faults`) drives all of it in tests; the hooks are no-ops
  when no plan is armed.

Every submitted request resolves to a :class:`ServeResult`; the server
never leaves a future unresolved, including through :meth:`AsyncServer.
stop` (queued work is drained) and including an executor that rejects the
batch outright.  All submissions must happen on the event loop that
started the server; execution happens on a thread pool slightly wider than
the replica count (abandoned attempts may linger on a worker), and
mutations go through the shared ``GraphDB`` exactly as before — the server
is a pure front end.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import time
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator

from repro.db.results import ResultSet
from repro.engine import cost as cost_mod

from .fairness import DeficitRoundRobin
from .metrics import ServeMetrics
from .router import NoHealthyReplica, ReplicaRouter

#: ServeResult.outcome values: exactly one per submitted request.
OUTCOMES = ("ok", "overloaded", "cost", "deadline", "error", "timeout")


def _consume_exception(fut) -> None:
    """Mark an (possibly abandoned) attempt future's exception as retrieved."""
    if not fut.cancelled():
        fut.exception()


def _wait_timeout(budget: float) -> float | None:
    """Convert an infinite watchdog budget to asyncio's no-timeout form."""
    return None if math.isinf(budget) else budget


@dataclasses.dataclass
class ServeResult:
    """Terminal outcome of one submitted request.

    ``outcome`` is one of :data:`OUTCOMES`; ``result`` is set iff the
    outcome is ``"ok"``.  ``queue_ms`` is admission-to-dispatch wait,
    ``service_ms`` the wall time of the microbatch the request rode in
    (a batch property, shared by its riders — the per-request fair share
    lives in ``result.timings``), ``total_ms`` submit-to-resolution.
    """

    outcome: str
    tenant: str
    result: ResultSet | None = None
    error: Exception | None = None
    detail: str = ""
    queue_ms: float = 0.0
    service_ms: float = 0.0
    total_ms: float = 0.0
    replica: str | None = None

    @property
    def ok(self) -> bool:
        """True iff the request completed with a result."""
        return self.outcome == "ok"


class _Pending:
    """One admitted request waiting in the fair scheduler."""

    __slots__ = ("prepared", "tenant", "t_submit", "deadline", "future")

    def __init__(self, prepared, tenant, t_submit, deadline, future):
        self.prepared = prepared
        self.tenant = tenant
        self.t_submit = t_submit
        self.deadline = deadline
        self.future = future


class AsyncServer:
    """Admission-controlled, tenant-fair, replicated serving loop.

    Usage::

        async with AsyncServer(db, replicas=2, max_queue=64) as server:
            results = await asyncio.gather(
                *[server.submit(q, tenant="alice") for q in queries]
            )

    Parameters: ``replicas`` engine replicas (thread-pool width);
    ``max_queue`` bounds admitted-but-undispatched requests; ``max_batch``
    caps one dispatch (default: the engine's largest microbatch bucket);
    ``max_delay_ms`` is the real flush timer; ``default_deadline_ms``
    bounds queue wait per request (a request older than its deadline at
    dispatch time is shed, never executed); ``cost_cap`` rejects requests
    whose :func:`~repro.engine.cost.admission_estimate` exceeds it;
    ``tenant_weights``/``quantum`` configure the fair scheduler.

    Failure-plane knobs (DESIGN.md Sect. 14): ``max_retries`` caps extra
    attempts per batch (each on a replica not yet tried); ``retry_backoff_
    ms`` is the first backoff, doubling per retry up to ``retry_backoff_
    cap_ms``; ``watchdog_factor``/``watchdog_min_ms`` price an attempt's
    wall-clock budget off the cost estimate and the tracked service p99
    (no signal → no watchdog), or ``watchdog_budget_ms`` pins the budget
    outright; ``hedge`` enables speculative duplicate dispatch after
    ``hedge_factor`` × the service p99 (or a pinned ``hedge_delay_ms``);
    ``fault_plan`` attaches a :class:`repro.faults.FaultPlan` (hooks stay
    no-ops until it is armed).
    """

    def __init__(
        self,
        db,
        *,
        replicas: int = 2,
        max_queue: int = 256,
        max_batch: int | None = None,
        max_delay_ms: float = 2.0,
        default_deadline_ms: float = 1000.0,
        cost_cap: float | None = None,
        tenant_weights: dict[str, float] | None = None,
        quantum: float = 4.0,
        fault_plan=None,
        max_retries: int = 1,
        retry_backoff_ms: float = 5.0,
        retry_backoff_cap_ms: float = 80.0,
        watchdog_factor: float = 8.0,
        watchdog_min_ms: float = 250.0,
        watchdog_budget_ms: float | None = None,
        hedge: bool = False,
        hedge_factor: float = 3.0,
        hedge_delay_ms: float | None = None,
    ):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self._db = db
        self.max_queue = max_queue
        self.max_batch = (
            max_batch if max_batch is not None else max(db._engine.buckets)
        )
        self.max_delay = max_delay_ms / 1e3
        self.default_deadline = default_deadline_ms / 1e3
        self.cost_cap = cost_cap
        self.max_retries = max(0, max_retries)
        self.retry_backoff = retry_backoff_ms / 1e3
        self.retry_backoff_cap = retry_backoff_cap_ms / 1e3
        self.watchdog_factor = watchdog_factor
        self.watchdog_min = watchdog_min_ms / 1e3
        self.watchdog_budget = (
            None if watchdog_budget_ms is None else watchdog_budget_ms / 1e3
        )
        self.hedge = hedge
        self.hedge_factor = hedge_factor
        self.hedge_delay = (
            None if hedge_delay_ms is None else hedge_delay_ms / 1e3
        )
        self._faults = fault_plan
        self.router = ReplicaRouter(db, replicas, fault_plan=fault_plan)
        self.metrics = ServeMetrics()
        self._scheduler = DeficitRoundRobin(
            quantum=quantum, weights=tenant_weights
        )
        # Sized for the worst concurrent attempt fan-out, not just the
        # replica count: each of the <= replicas live batches (dispatch
        # permits) can have one running attempt, up to max_retries
        # watchdog-abandoned attempts still draining on their threads, and
        # one hedge.  An undersized pool turns one wedged replica into
        # fleet-wide starvation — freshly dispatched batches sit in the
        # *pool* queue past the watchdog, and the overrun is then blamed
        # on a replica that never saw the batch.
        self._pool = ThreadPoolExecutor(
            max_workers=replicas * (self.max_retries + 2) + 2,
            thread_name_prefix="repro-serve",
        )
        self._cost_memo: dict[str, float] = {}  # template key -> admission cost
        self._loop: asyncio.AbstractEventLoop | None = None
        self._wake: asyncio.Event | None = None
        self._sem: asyncio.Semaphore | None = None
        self._dispatcher: asyncio.Task | None = None
        self._batch_tasks: set[asyncio.Task] = set()
        self._running = False
        self._stopping = False

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    async def start(self) -> "AsyncServer":
        """Bind to the running loop and start the dispatcher task."""
        if self._running:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._sem = asyncio.Semaphore(len(self.router))
        self._running = True
        self._stopping = False
        self._dispatcher = self._loop.create_task(self._dispatch_loop())
        return self

    async def stop(self) -> None:
        """Drain queued work, resolve every future, and shut down.

        The backpressure contract survives shutdown: nothing admitted is
        ever left unresolved (drained requests still honor deadlines).
        """
        if not self._running:
            return
        self._stopping = True
        self._running = False
        self._wake.set()
        await self._dispatcher
        if self._batch_tasks:
            await asyncio.gather(*self._batch_tasks)
        self._pool.shutdown(wait=True)

    async def __aenter__(self) -> "AsyncServer":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.stop()

    async def fence(self) -> int:
        """Advance every replica past the latest mutation epoch.

        Off-loop (replica locks may be held by in-flight batches).
        Returns the fenced version; see :meth:`ReplicaRouter.fence`.
        """
        return await self._loop.run_in_executor(self._pool, self.router.fence)

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def submit(
        self,
        query,
        *,
        tenant: str = "default",
        deadline_ms: float | None = None,
    ) -> "asyncio.Future[ServeResult]":
        """Admit or shed one request; returns a future of its outcome.

        Synchronous on purpose: admission is the *cheap* path (parse +
        canonicalize + O(1) checks) and must answer immediately — a shed
        request's future is already resolved when this returns.  Must be
        called on the server's event loop.  ``query`` may be text, a
        parsed query, or a ``Q`` builder.
        """
        if not self._running:
            raise RuntimeError("server is not running")
        fut: asyncio.Future[ServeResult] = self._loop.create_future()
        now = time.monotonic()
        self.metrics.on_submit(tenant)

        # gate 1: bounded queue — shed instead of queueing without bound
        if len(self._scheduler) >= self.max_queue:
            self.metrics.on_shed(tenant, "overloaded")
            fut.set_result(ServeResult(
                outcome="overloaded", tenant=tenant,
                detail=f"queue full ({self.max_queue})",
            ))
            return fut

        # parse + canonicalize once; syntax errors are the *request's*
        # fault and resolve its own future, they never enter the queue
        try:
            prepared = self._db._engine.prepare(self._db._coerce(query))
        except Exception as exc:
            self.metrics.on_error(tenant)
            fut.set_result(ServeResult(
                outcome="error", tenant=tenant, error=exc,
                detail="rejected at parse",
            ))
            return fut

        # gate 2: model-cost cap (Pspace-complete worst cases stay out)
        if self.cost_cap is not None:
            est = self._admission_cost(prepared[0])
            if est > self.cost_cap:
                self.metrics.on_shed(tenant, "cost")
                fut.set_result(ServeResult(
                    outcome="cost", tenant=tenant,
                    detail=f"estimated cost {est:.3g} > cap {self.cost_cap:.3g}",
                ))
                return fut

        # gate 3: deadline already unmeetable
        deadline_s = (
            deadline_ms if deadline_ms is not None else self.default_deadline * 1e3
        ) / 1e3
        if deadline_s <= 0:
            self.metrics.on_shed(tenant, "deadline")
            fut.set_result(ServeResult(
                outcome="deadline", tenant=tenant, detail="expired at admission",
            ))
            return fut

        item = _Pending(prepared, tenant, now, now + deadline_s, fut)
        depth = self._scheduler.enqueue(tenant, item)
        self.metrics.on_admit(depth)
        self._wake.set()
        return fut

    def _admission_cost(self, query) -> float:
        """Memoized :func:`~repro.engine.cost.admission_estimate` per query.

        Memoized on the query text (template keys collapse constants, but
        the estimate is constant-independent anyway); the memo resets when
        the graph mutates, since the estimate prices the current snapshot.
        """
        key = f"v{self._db.version}:{query!r}"
        est = self._cost_memo.get(key)
        if est is None:
            if len(self._cost_memo) > 4096:
                self._cost_memo.clear()
            # priced with the engine's machine calibration (DESIGN.md 13):
            # with a MachineSpec the estimate is seconds of sparse-engine
            # solve time, so cost_cap becomes a latency budget
            est = cost_mod.admission_estimate(
                self._db.graph, query,
                spec=getattr(self._db._engine, "spec", None),
            )
            self._cost_memo[key] = est
        return est

    # ------------------------------------------------------------------ #
    # dispatch
    # ------------------------------------------------------------------ #
    @property
    def queue_depth(self) -> int:
        """Admitted requests not yet dispatched."""
        return len(self._scheduler)

    async def _dispatch_loop(self) -> None:
        """Single dispatcher: batch release policy + fair draining.

        Releases a batch when it can fill ``max_batch``, when the oldest
        admitted request has waited ``max_delay``, or on shutdown drain.
        Runs as the only consumer of the scheduler, so the scheduler needs
        no lock (submissions happen on the same loop).
        """
        while True:
            depth = len(self._scheduler)
            if depth == 0:
                if self._stopping:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            oldest = min(p.t_submit for p in self._scheduler.heads())
            age = time.monotonic() - oldest
            if depth >= self.max_batch or age >= self.max_delay or self._stopping:
                await self._sem.acquire()
                batch = self._scheduler.take(self.max_batch)
                self.metrics.set_queue_depth(len(self._scheduler))
                task = self._loop.create_task(self._run_batch(batch))
                self._batch_tasks.add(task)
                task.add_done_callback(self._batch_tasks.discard)
            else:
                self._wake.clear()
                try:
                    await asyncio.wait_for(
                        self._wake.wait(), timeout=self.max_delay - age
                    )
                except asyncio.TimeoutError:
                    pass  # flush timer fired: release the partial batch

    async def _run_batch(self, batch) -> None:
        """Execute one fair-share batch; every rider resolves, no matter what.

        The outer except is the unresolved-future fix (ISSUE 10 satellite):
        if anything in the serve path itself raises — the executor
        rejecting work after shutdown, routing failing, a bug — every
        still-pending rider resolves with ``outcome="error"`` instead of
        being leaked when the task dies.
        """
        live: list[_Pending] = []
        try:
            now = time.monotonic()
            for tenant, p in batch:  # rl4: track=p
                if now > p.deadline:
                    # admitted but queued past its deadline: shed at
                    # dispatch, never executed — this is what bounds the
                    # tail latency of everything we *do* execute
                    self._finish(p, ServeResult(
                        outcome="deadline", tenant=tenant,
                        detail="deadline exceeded in queue",
                        queue_ms=(now - p.t_submit) * 1e3,
                        total_ms=(now - p.t_submit) * 1e3,
                    ))
                else:
                    live.append(p)
            if live:
                await self._serve_batch(live)
        except Exception as exc:
            self._fail_all(live, exc, "serve path failure")
        finally:
            self._sem.release()

    async def _serve_batch(self, live: list[_Pending]) -> None:
        """Deadline-budgeted attempt loop: route, watch, retry, hedge.

        Each attempt runs on a replica not yet tried for this batch, under
        a watchdog budget (:meth:`_watchdog_budget`).  A failed attempt
        retries while ``remaining_budget > estimated_cost + backoff`` and
        attempts remain; a watchdog overrun marks the replica suspect and
        retries on a healthy one; riders whose own deadline lapses during
        the attempts resolve with the explicit ``timeout`` outcome.
        """
        tried: set[str] = set()
        attempt = 0
        backoff = self.retry_backoff
        while True:
            now = time.monotonic()
            still: list[_Pending] = []
            for p in live:  # rl4: track=p
                if now >= p.deadline:
                    # budget exhausted riding failed attempts (or, on the
                    # first attempt, while this coroutine was scheduled)
                    self._finish(p, ServeResult(
                        outcome="timeout" if attempt else "deadline",
                        tenant=p.tenant,
                        detail=f"deadline exhausted after {attempt} attempt(s)",
                        queue_ms=0.0,
                        total_ms=(now - p.t_submit) * 1e3,
                    ))
                else:
                    still.append(p)
            live = still
            if not live:
                return
            attempt += 1
            remaining = min(p.deadline for p in live) - now
            try:
                rep = self.router.route(exclude=tried)
            except NoHealthyReplica as exc:
                self._fail_all(live, exc, "no healthy replica")
                return
            payload = [p.prepared for p in live]
            try:
                if self._faults is not None:
                    self._faults.on_dispatch()
                exec_fut = self._loop.run_in_executor(
                    self._pool, self.router.execute_on, rep, payload
                )
            except Exception as exc:
                # the executor itself rejected the batch (pool shut down,
                # injected reject): execute_on never ran, release here
                self.router.release(rep)
                self._fail_all(live, exc, "executor rejected the batch")
                return
            exec_fut.add_done_callback(_consume_exception)
            watchdog = self._watchdog_budget(live, remaining)
            t0 = time.monotonic()
            try:
                outcomes, replica = await self._await_attempt(
                    exec_fut, rep, tried, payload, watchdog
                )
            except asyncio.TimeoutError:
                # watchdog overrun: abandon the routed attempt (its thread
                # finishes in the background; health reports from it are
                # epoch-fenced), mark the replica suspect, retry elsewhere
                self.router.on_overrun(rep)
                self.metrics.on_watchdog()
                tried.add(rep.name)
                if attempt > self.max_retries:
                    self._timeout_all(
                        live,
                        f"solve watchdog fired after {watchdog * 1e3:.0f} ms; "
                        "retries exhausted",
                    )
                    return
                self.metrics.on_retry()
                continue
            except Exception as exc:
                tried.add(rep.name)
                budget = min(p.deadline for p in live) - time.monotonic()
                price = self._retry_price(live)
                if attempt > self.max_retries:
                    self._fail_all(live, exc, "retries exhausted")
                    return
                if budget <= price + backoff:
                    # the calibrated estimate says a retry cannot finish
                    # inside the riders' deadlines: fail fast instead of
                    # burning a replica slot on a doomed attempt
                    self._fail_all(live, exc, "no deadline budget for a retry")
                    return
                self.metrics.on_retry()
                await asyncio.sleep(min(backoff, budget))
                backoff = min(backoff * 2.0, self.retry_backoff_cap)
                continue
            t1 = time.monotonic()
            service_ms = (t1 - t0) * 1e3
            self.metrics.on_batch(t1 - t0, len(self._scheduler))
            for p, out in zip(live, outcomes):  # rl4: track=p
                queue_s = t0 - p.t_submit
                total_s = t1 - p.t_submit
                if isinstance(out, Exception):
                    self._finish(p, ServeResult(
                        outcome="error", tenant=p.tenant, error=out,
                        queue_ms=queue_s * 1e3, service_ms=service_ms,
                        total_ms=total_s * 1e3, replica=replica,
                    ))
                else:
                    self._finish(p, ServeResult(
                        outcome="ok", tenant=p.tenant, result=out,
                        queue_ms=queue_s * 1e3, service_ms=service_ms,
                        total_ms=total_s * 1e3, replica=replica,
                    ))
            return

    async def _await_attempt(self, exec_fut, rep, tried, payload, watchdog):
        """Await one routed attempt under its watchdog, hedging if enabled.

        Never cancels the executor future — a running solve cannot be
        interrupted; on overrun it is *abandoned* (``asyncio.wait``, not
        ``wait_for``, precisely so the watchdog fires on time instead of
        blocking until the wedged thread finishes) and
        :exc:`asyncio.TimeoutError` is raised for the caller's retry path.
        With hedging on and a tracked service p99, a secondary dispatch
        races the primary once it runs ``hedge_factor`` × p99 late; the
        first clean completion wins (reads are idempotent — duplicate
        execution is safe).
        """
        hedge_delay = self._hedge_delay() if self.hedge else None
        if hedge_delay is None or hedge_delay >= watchdog:
            done, _ = await asyncio.wait(
                {exec_fut}, timeout=_wait_timeout(watchdog)
            )
            if not done:
                raise asyncio.TimeoutError
            return exec_fut.result()
        done, _ = await asyncio.wait({exec_fut}, timeout=hedge_delay)
        if done:
            return exec_fut.result()
        try:
            rep2 = self.router.route(exclude=tried | {rep.name})
        except NoHealthyReplica:
            done, _ = await asyncio.wait(
                {exec_fut}, timeout=_wait_timeout(watchdog - hedge_delay)
            )
            if not done:
                raise asyncio.TimeoutError
            return exec_fut.result()
        tried.add(rep2.name)  # a failed hedge shouldn't be retried on rep2
        self.metrics.on_hedge()
        try:
            hedge_fut = self._loop.run_in_executor(
                self._pool, self.router.execute_on, rep2, payload
            )
        except Exception:
            self.router.release(rep2)
            done, _ = await asyncio.wait(
                {exec_fut}, timeout=_wait_timeout(watchdog - hedge_delay)
            )
            if not done:
                raise asyncio.TimeoutError
            return exec_fut.result()
        hedge_fut.add_done_callback(_consume_exception)
        pending = {exec_fut, hedge_fut}
        end = time.monotonic() + (watchdog - hedge_delay)
        while pending:
            done, pending = await asyncio.wait(
                pending,
                timeout=_wait_timeout(max(0.0, end - time.monotonic())),
                return_when=asyncio.FIRST_COMPLETED,
            )
            if not done:
                raise asyncio.TimeoutError
            for f in done:
                if f.exception() is None:
                    return f.result()
            # every completed future failed; keep waiting on the rest
        raise exec_fut.exception()  # both attempts failed: surface primary's

    # ------------------------------------------------------------------ #
    # budgets
    # ------------------------------------------------------------------ #
    def _watchdog_budget(self, live: list[_Pending], remaining: float) -> float:
        """Wall-clock budget for one routed attempt (seconds).

        Priced from the strongest signal available: the calibrated
        ``admission_estimate`` (seconds iff a MachineSpec is loaded) and
        the tracked per-batch service p99, scaled by ``watchdog_factor``,
        capped at the riders' remaining deadline, and floored at
        ``watchdog_min`` AND at twice the slowest completed service.
        Until the first service completes there is NO watchdog
        (``math.inf``): the calibrated estimate prices the solve, not XLA
        compilation, so a first-of-its-bucket attempt legitimately runs
        ~100x the estimate while its plan compiles — abandoning it on
        that evidence double-compiles the plan, poisons the health plane,
        and can resolve ``timeout`` on a request whose deadline is
        nowhere near.  The 2x-slowest floor extends the same grace to
        later cold buckets: compile spikes enter the service histogram,
        and a budget below an already-witnessed legitimate solve would
        re-fire on every repeat.  A single-replica fleet also gets no
        derived watchdog: abandoning the only replica's attempt is pure
        loss — the retry queues behind the same replica lock, inherits
        the abandoned solve's wait, and overruns again, turning one load
        stall into a spurious ``timeout``.  An explicit
        ``watchdog_budget_ms`` bypasses the derivation — operators (and
        the chaos tests) pin a known-good post-warmup budget instead.
        """
        if self.watchdog_budget is not None:
            return max(min(self.watchdog_budget, remaining), 1e-3)
        if len(self.router) <= 1:
            return math.inf
        p99 = self.metrics.service_quantile(0.99)
        if p99 is None or not math.isfinite(p99) or p99 <= 0.0:
            return math.inf
        est = self._attempt_cost_estimate(live)
        signals = [
            s for s in (est, p99)
            if s is not None and s > 0.0 and math.isfinite(s)
        ]
        spike = self.metrics.service_quantile(1.0) or 0.0
        cap = min(self.watchdog_factor * max(signals), remaining)
        return max(cap, 2.0 * spike, self.watchdog_min, 1e-3)

    def _attempt_cost_estimate(self, live: list[_Pending]) -> float | None:
        """Calibrated seconds for the costliest rider (None uncalibrated)."""
        if getattr(self._db._engine, "spec", None) is None:
            return None  # without a MachineSpec the estimate is not seconds
        return max(self._admission_cost(p.prepared[0]) for p in live)

    def _retry_price(self, live: list[_Pending]) -> float:
        """What one more attempt should cost: estimate, else measured p50."""
        est = self._attempt_cost_estimate(live)
        if est is None:
            est = self.metrics.service_quantile(0.50)
        if est is None or not math.isfinite(est):
            est = 0.0
        return est

    def _hedge_delay(self) -> float | None:
        """Seconds to wait before hedging (None without a tracked p99).

        ``hedge_delay_ms`` pins the delay explicitly, same rationale as
        ``watchdog_budget_ms``.
        """
        if self.hedge_delay is not None:
            return self.hedge_delay
        p99 = self.metrics.service_quantile(0.99)
        if p99 is None or not math.isfinite(p99):
            return None
        return self.hedge_factor * p99

    # ------------------------------------------------------------------ #
    # resolution
    # ------------------------------------------------------------------ #
    def _finish(self, p: _Pending, res: ServeResult) -> None:
        """Resolve one rider exactly once, with its outcome's metrics.

        Metrics and resolution commit together: an already-done future
        (caller cancelled, or resolved by an earlier path) is counted
        nowhere a second time.
        """
        if p.future.done():
            return
        if res.outcome == "ok":
            self.metrics.on_complete(
                p.tenant, res.queue_ms / 1e3, res.total_ms / 1e3
            )
        elif res.outcome == "error":
            self.metrics.on_error(p.tenant)
        elif res.outcome == "timeout":
            self.metrics.on_timeout(p.tenant, res.queue_ms / 1e3)
        else:
            self.metrics.on_shed(p.tenant, res.outcome, res.queue_ms / 1e3)
        p.future.set_result(res)

    def _fail_all(self, pendings: list[_Pending], exc, detail: str) -> None:
        """Resolve every still-pending rider with ``outcome="error"``."""
        now = time.monotonic()
        for p in pendings:  # rl4: track=p
            self._finish(p, ServeResult(
                outcome="error", tenant=p.tenant, error=exc, detail=detail,
                total_ms=(now - p.t_submit) * 1e3,
            ))

    def _timeout_all(self, pendings: list[_Pending], detail: str) -> None:
        """Resolve every still-pending rider with ``outcome="timeout"``."""
        now = time.monotonic()
        for p in pendings:  # rl4: track=p
            self._finish(p, ServeResult(
                outcome="timeout", tenant=p.tenant, detail=detail,
                total_ms=(now - p.t_submit) * 1e3,
            ))


async def stream_pages(
    rs: ResultSet, page_size: int = 100
) -> AsyncIterator[list[tuple[str, str, str]]]:
    """Async-paginate a result set's survivor triples.

    Yields name-triple pages of at most ``page_size``; each page
    materializes on the default executor so a huge survivor set neither
    blocks the event loop nor lands in one response.  The result set pins
    its snapshot, so pagination stays consistent across later mutations.
    """
    loop = asyncio.get_running_loop()
    offset = 0
    while True:
        page = await loop.run_in_executor(None, rs.page, offset, page_size)
        if not page:
            return
        yield page
        offset += len(page)
