"""Admission-controlled asyncio serving loop (DESIGN.md Sect. 10).

The paper positions dual simulation as a pre-filter *inside a database
system serving real traffic*; Pérez et al. put the worst case of that
traffic at Pspace-complete, so a production front end must bound what it
accepts — unbounded queueing turns one pathological template into
everyone's latency.  :class:`AsyncServer` is that front end over the stable
``repro.db`` surface:

* **admission control** — a bounded queue (``max_queue``), a per-request
  model-cost cap (``cost_cap``, priced by :func:`repro.engine.cost.
  admission_estimate`), and per-request deadlines.  A request that cannot
  be admitted is *shed immediately* with an explicit outcome
  (``overloaded`` / ``cost`` / ``deadline``) instead of queueing without
  bound — the backpressure contract is "a fast no, never a slow maybe".
* **per-tenant fairness** — admitted requests enter a deficit-round-robin
  scheduler (:mod:`repro.serve.fairness`); a template storm from one
  tenant cannot starve the others' dispatch slots.
* **replica routing** — batches execute on a pool of engine replicas over
  immutable snapshots (:mod:`repro.serve.router`), overlapping service.
* **real flush timer** — the dispatcher releases a batch when it fills
  (``max_batch``) or when the oldest admitted request has waited
  ``max_delay_ms``, whichever first; unlike the cooperative
  :class:`~repro.db.session.Session` policy this timer fires without any
  further submit arriving.
* **streaming delivery** — :func:`stream_pages` paginates a result set as
  an async iterator, so a large survivor set never materializes in one
  response.

Every submitted request resolves to a :class:`ServeResult`; the server
never leaves a future unresolved, including through :meth:`AsyncServer.
stop` (queued work is drained).  All submissions must happen on the event
loop that started the server; execution happens on a thread pool sized to
the replica count, and mutations go through the shared ``GraphDB`` exactly
as before — the server is a pure front end.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator

from repro.db.results import ResultSet
from repro.engine import cost as cost_mod

from .fairness import DeficitRoundRobin
from .metrics import ServeMetrics
from .router import ReplicaRouter

#: ServeResult.outcome values: exactly one per submitted request.
OUTCOMES = ("ok", "overloaded", "cost", "deadline", "error")


@dataclasses.dataclass
class ServeResult:
    """Terminal outcome of one submitted request.

    ``outcome`` is one of :data:`OUTCOMES`; ``result`` is set iff the
    outcome is ``"ok"``.  ``queue_ms`` is admission-to-dispatch wait,
    ``service_ms`` the wall time of the microbatch the request rode in
    (a batch property, shared by its riders — the per-request fair share
    lives in ``result.timings``), ``total_ms`` submit-to-resolution.
    """

    outcome: str
    tenant: str
    result: ResultSet | None = None
    error: Exception | None = None
    detail: str = ""
    queue_ms: float = 0.0
    service_ms: float = 0.0
    total_ms: float = 0.0
    replica: str | None = None

    @property
    def ok(self) -> bool:
        """True iff the request completed with a result."""
        return self.outcome == "ok"


class _Pending:
    """One admitted request waiting in the fair scheduler."""

    __slots__ = ("prepared", "tenant", "t_submit", "deadline", "future")

    def __init__(self, prepared, tenant, t_submit, deadline, future):
        self.prepared = prepared
        self.tenant = tenant
        self.t_submit = t_submit
        self.deadline = deadline
        self.future = future


class AsyncServer:
    """Admission-controlled, tenant-fair, replicated serving loop.

    Usage::

        async with AsyncServer(db, replicas=2, max_queue=64) as server:
            results = await asyncio.gather(
                *[server.submit(q, tenant="alice") for q in queries]
            )

    Parameters: ``replicas`` engine replicas (thread-pool width);
    ``max_queue`` bounds admitted-but-undispatched requests; ``max_batch``
    caps one dispatch (default: the engine's largest microbatch bucket);
    ``max_delay_ms`` is the real flush timer; ``default_deadline_ms``
    bounds queue wait per request (a request older than its deadline at
    dispatch time is shed, never executed); ``cost_cap`` rejects requests
    whose :func:`~repro.engine.cost.admission_estimate` exceeds it;
    ``tenant_weights``/``quantum`` configure the fair scheduler.
    """

    def __init__(
        self,
        db,
        *,
        replicas: int = 2,
        max_queue: int = 256,
        max_batch: int | None = None,
        max_delay_ms: float = 2.0,
        default_deadline_ms: float = 1000.0,
        cost_cap: float | None = None,
        tenant_weights: dict[str, float] | None = None,
        quantum: float = 4.0,
    ):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self._db = db
        self.max_queue = max_queue
        self.max_batch = (
            max_batch if max_batch is not None else max(db._engine.buckets)
        )
        self.max_delay = max_delay_ms / 1e3
        self.default_deadline = default_deadline_ms / 1e3
        self.cost_cap = cost_cap
        self.router = ReplicaRouter(db, replicas)
        self.metrics = ServeMetrics()
        self._scheduler = DeficitRoundRobin(
            quantum=quantum, weights=tenant_weights
        )
        self._pool = ThreadPoolExecutor(
            max_workers=replicas, thread_name_prefix="repro-serve"
        )
        self._cost_memo: dict[str, float] = {}  # template key -> admission cost
        self._loop: asyncio.AbstractEventLoop | None = None
        self._wake: asyncio.Event | None = None
        self._sem: asyncio.Semaphore | None = None
        self._dispatcher: asyncio.Task | None = None
        self._batch_tasks: set[asyncio.Task] = set()
        self._running = False
        self._stopping = False

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    async def start(self) -> "AsyncServer":
        """Bind to the running loop and start the dispatcher task."""
        if self._running:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._sem = asyncio.Semaphore(len(self.router))
        self._running = True
        self._stopping = False
        self._dispatcher = self._loop.create_task(self._dispatch_loop())
        return self

    async def stop(self) -> None:
        """Drain queued work, resolve every future, and shut down.

        The backpressure contract survives shutdown: nothing admitted is
        ever left unresolved (drained requests still honor deadlines).
        """
        if not self._running:
            return
        self._stopping = True
        self._running = False
        self._wake.set()
        await self._dispatcher
        if self._batch_tasks:
            await asyncio.gather(*self._batch_tasks)
        self._pool.shutdown(wait=True)

    async def __aenter__(self) -> "AsyncServer":
        return await self.start()

    async def __aexit__(self, exc_type, exc, tb) -> None:
        await self.stop()

    async def fence(self) -> int:
        """Advance every replica past the latest mutation epoch.

        Off-loop (replica locks may be held by in-flight batches).
        Returns the fenced version; see :meth:`ReplicaRouter.fence`.
        """
        return await self._loop.run_in_executor(self._pool, self.router.fence)

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def submit(
        self,
        query,
        *,
        tenant: str = "default",
        deadline_ms: float | None = None,
    ) -> "asyncio.Future[ServeResult]":
        """Admit or shed one request; returns a future of its outcome.

        Synchronous on purpose: admission is the *cheap* path (parse +
        canonicalize + O(1) checks) and must answer immediately — a shed
        request's future is already resolved when this returns.  Must be
        called on the server's event loop.  ``query`` may be text, a
        parsed query, or a ``Q`` builder.
        """
        if not self._running:
            raise RuntimeError("server is not running")
        fut: asyncio.Future[ServeResult] = self._loop.create_future()
        now = time.monotonic()
        self.metrics.on_submit(tenant)

        # gate 1: bounded queue — shed instead of queueing without bound
        if len(self._scheduler) >= self.max_queue:
            self.metrics.on_shed(tenant, "overloaded")
            fut.set_result(ServeResult(
                outcome="overloaded", tenant=tenant,
                detail=f"queue full ({self.max_queue})",
            ))
            return fut

        # parse + canonicalize once; syntax errors are the *request's*
        # fault and resolve its own future, they never enter the queue
        try:
            prepared = self._db._engine.prepare(self._db._coerce(query))
        except Exception as exc:
            self.metrics.on_error(tenant)
            fut.set_result(ServeResult(
                outcome="error", tenant=tenant, error=exc,
                detail="rejected at parse",
            ))
            return fut

        # gate 2: model-cost cap (Pspace-complete worst cases stay out)
        if self.cost_cap is not None:
            est = self._admission_cost(prepared[0])
            if est > self.cost_cap:
                self.metrics.on_shed(tenant, "cost")
                fut.set_result(ServeResult(
                    outcome="cost", tenant=tenant,
                    detail=f"estimated cost {est:.3g} > cap {self.cost_cap:.3g}",
                ))
                return fut

        # gate 3: deadline already unmeetable
        deadline_s = (
            deadline_ms if deadline_ms is not None else self.default_deadline * 1e3
        ) / 1e3
        if deadline_s <= 0:
            self.metrics.on_shed(tenant, "deadline")
            fut.set_result(ServeResult(
                outcome="deadline", tenant=tenant, detail="expired at admission",
            ))
            return fut

        item = _Pending(prepared, tenant, now, now + deadline_s, fut)
        depth = self._scheduler.enqueue(tenant, item)
        self.metrics.on_admit(depth)
        self._wake.set()
        return fut

    def _admission_cost(self, query) -> float:
        """Memoized :func:`~repro.engine.cost.admission_estimate` per query.

        Memoized on the query text (template keys collapse constants, but
        the estimate is constant-independent anyway); the memo resets when
        the graph mutates, since the estimate prices the current snapshot.
        """
        key = f"v{self._db.version}:{query!r}"
        est = self._cost_memo.get(key)
        if est is None:
            if len(self._cost_memo) > 4096:
                self._cost_memo.clear()
            # priced with the engine's machine calibration (DESIGN.md 13):
            # with a MachineSpec the estimate is seconds of sparse-engine
            # solve time, so cost_cap becomes a latency budget
            est = cost_mod.admission_estimate(
                self._db.graph, query,
                spec=getattr(self._db._engine, "spec", None),
            )
            self._cost_memo[key] = est
        return est

    # ------------------------------------------------------------------ #
    # dispatch
    # ------------------------------------------------------------------ #
    @property
    def queue_depth(self) -> int:
        """Admitted requests not yet dispatched."""
        return len(self._scheduler)

    async def _dispatch_loop(self) -> None:
        """Single dispatcher: batch release policy + fair draining.

        Releases a batch when it can fill ``max_batch``, when the oldest
        admitted request has waited ``max_delay``, or on shutdown drain.
        Runs as the only consumer of the scheduler, so the scheduler needs
        no lock (submissions happen on the same loop).
        """
        while True:
            depth = len(self._scheduler)
            if depth == 0:
                if self._stopping:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            oldest = min(p.t_submit for p in self._scheduler.heads())
            age = time.monotonic() - oldest
            if depth >= self.max_batch or age >= self.max_delay or self._stopping:
                await self._sem.acquire()
                batch = self._scheduler.take(self.max_batch)
                self.metrics.set_queue_depth(len(self._scheduler))
                task = self._loop.create_task(self._run_batch(batch))
                self._batch_tasks.add(task)
                task.add_done_callback(self._batch_tasks.discard)
            else:
                self._wake.clear()
                try:
                    await asyncio.wait_for(
                        self._wake.wait(), timeout=self.max_delay - age
                    )
                except asyncio.TimeoutError:
                    pass  # flush timer fired: release the partial batch

    async def _run_batch(self, batch) -> None:
        """Execute one fair-share batch on a routed replica."""
        try:
            now = time.monotonic()
            live: list[_Pending] = []
            for tenant, p in batch:  # rl4: track=p
                if now > p.deadline:
                    # admitted but queued past its deadline: shed at
                    # dispatch, never executed — this is what bounds the
                    # tail latency of everything we *do* execute
                    self.metrics.on_shed(tenant, "deadline", now - p.t_submit)
                    self._resolve(p, ServeResult(
                        outcome="deadline", tenant=tenant,
                        detail="deadline exceeded in queue",
                        queue_ms=(now - p.t_submit) * 1e3,
                        total_ms=(now - p.t_submit) * 1e3,
                    ))
                else:
                    live.append(p)
            if not live:
                return
            t0 = time.monotonic()
            outcomes, replica = await self._loop.run_in_executor(
                self._pool,
                self.router.execute_isolated,
                [p.prepared for p in live],
            )
            t1 = time.monotonic()
            service_ms = (t1 - t0) * 1e3
            self.metrics.on_batch(t1 - t0, len(self._scheduler))
            for p, out in zip(live, outcomes):  # rl4: track=p
                queue_s = t0 - p.t_submit
                total_s = t1 - p.t_submit
                if isinstance(out, Exception):
                    self.metrics.on_error(p.tenant)
                    self._resolve(p, ServeResult(
                        outcome="error", tenant=p.tenant, error=out,
                        queue_ms=queue_s * 1e3, service_ms=service_ms,
                        total_ms=total_s * 1e3, replica=replica,
                    ))
                else:
                    self.metrics.on_complete(p.tenant, queue_s, total_s)
                    self._resolve(p, ServeResult(
                        outcome="ok", tenant=p.tenant, result=out,
                        queue_ms=queue_s * 1e3, service_ms=service_ms,
                        total_ms=total_s * 1e3, replica=replica,
                    ))
        finally:
            self._sem.release()

    @staticmethod
    def _resolve(p: _Pending, result: ServeResult) -> None:
        if not p.future.done():  # caller may have cancelled
            p.future.set_result(result)


async def stream_pages(
    rs: ResultSet, page_size: int = 100
) -> AsyncIterator[list[tuple[str, str, str]]]:
    """Async-paginate a result set's survivor triples.

    Yields name-triple pages of at most ``page_size``; each page
    materializes on the default executor so a huge survivor set neither
    blocks the event loop nor lands in one response.  The result set pins
    its snapshot, so pagination stays consistent across later mutations.
    """
    loop = asyncio.get_running_loop()
    offset = 0
    while True:
        page = await loop.run_in_executor(None, rs.page, offset, page_size)
        if not page:
            return
        yield page
        offset += len(page)
