"""Optional-hypothesis shim (see pyproject.toml ``[test]`` extra).

``from tests._hyp import given, settings, st`` gives the real hypothesis API
when the package is installed.  When it is missing, property tests degrade
to per-test skips (the ``@given`` stub swallows the strategy arguments and
replaces the test with a zero-arg skipper) instead of killing the whole
module at collection — plain tests in the same file keep running.
"""
from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # degrade: property tests skip, plain tests run
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: every attribute/call/| yields self,
        so module-level strategy-building expressions still evaluate."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            def _skipped():
                pytest.skip("hypothesis not installed (property test)")

            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped

        return deco
