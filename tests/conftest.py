"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and only in its own process).

Machine calibration (ISSUE 9) is pinned OFF for the whole suite: a spec
persisted under results/machine/ by a local probe run would silently flip
the cost model's engine picks and make hand-tuned-model assertions
machine-dependent.  Tests that exercise calibration pass specs/models
explicitly (tests/test_cost_calibration.py) or re-enable the env var in a
monkeypatched scope."""
import os

import numpy as np
import pytest

os.environ.setdefault("REPRO_MACHINE_SPEC", "off")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _hermetic_machine_spec(monkeypatch):
    """Keep every test hermetic against locally persisted machine specs."""
    from repro.engine import machine

    monkeypatch.setenv(machine.ENV_VAR, os.environ["REPRO_MACHINE_SPEC"])
    machine.clear_spec_cache()
    yield
    machine.clear_spec_cache()
