"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and only in its own process)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
