"""Every bench entry point imports cleanly (ISSUE 9 satellite).

The seed's ``benchmarks/roofline.py`` globbed a ``results/dryrun/``
directory nothing produces, so the roofline section only failed at run
time.  This pins the repaired state: every module under ``benchmarks/``
(and the perf-gate tool it feeds) imports without side effects, and no
benchmarks source references the dead ``results/dryrun`` path again.
"""
import importlib
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

BENCH_MODULES = sorted(
    f"benchmarks.{p.stem}"
    for p in (REPO / "benchmarks").glob("*.py")
    if p.stem != "__init__"
)


def test_benchmarks_is_a_real_package_with_modules():
    assert (REPO / "benchmarks" / "__init__.py").exists()
    assert "benchmarks.roofline" in BENCH_MODULES
    assert "benchmarks.run" in BENCH_MODULES


@pytest.mark.parametrize("mod", BENCH_MODULES)
def test_bench_module_imports_cleanly(mod):
    importlib.import_module(mod)


@pytest.mark.parametrize(
    "mod",
    ["tools.perfgate", "tools.perfgate.history", "tools.perfgate.__main__"],
)
def test_perfgate_imports_cleanly(mod):
    importlib.import_module(mod)


def test_no_dryrun_references_anywhere_in_benchmarks():
    for p in sorted((REPO / "benchmarks").glob("*.py")):
        assert "dryrun" not in p.read_text(), f"{p.name} references dryrun"
