import jax.numpy as jnp
import numpy as np
from tests._hyp import given, settings, st

from repro.core import bitops


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.4
    packed = bitops.pack(jnp.asarray(bits))
    assert packed.shape[-1] == bitops.packed_width(n)
    back = np.asarray(bitops.unpack(packed, n))
    assert np.array_equal(back, bits)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 150), st.integers(0, 2**31 - 1))
def test_popcount_and_any(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((3, n)) < 0.3
    packed = bitops.pack(jnp.asarray(bits))
    assert np.array_equal(np.asarray(bitops.popcount(packed)), bits.sum(-1))
    assert np.array_equal(np.asarray(bitops.any_set(packed)), bits.any(-1))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.integers(0, 2**31 - 1))
def test_leq_matches_set_inclusion(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.random(n) < 0.3
    b = a | (rng.random(n) < 0.2)
    pa, pb = bitops.pack(jnp.asarray(a)), bitops.pack(jnp.asarray(b))
    assert bool(bitops.leq(pa, pb))
    # strict superset the other way iff b != a
    if (b & ~a).any():
        assert not bool(bitops.leq(pb, pa))


def test_ones_mask_trailing_bits():
    m = bitops.ones_mask(70)
    assert np.asarray(bitops.popcount(jnp.asarray(m))) == 70


# --------------------------------------------------------------------- #
# ISSUE 5: trailing-pad-bit hygiene at n % 32 != 0 — the exact edge the
# packed-chi while_loop's word-level convergence and leq checks depend on
# --------------------------------------------------------------------- #
def _unaligned(draw_n):
    """Remap any int onto a width with n % 32 != 0."""
    n = draw_n % 200 + 1
    return n + 1 if n % 32 == 0 else n


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_pack_np_matches_device_pack_and_roundtrips(raw_n, seed):
    n = _unaligned(raw_n)
    rng = np.random.default_rng(seed)
    bits = rng.random((4, n)) < 0.4
    host = bitops.pack_np(bits)
    dev = np.asarray(bitops.pack(jnp.asarray(bits)))
    assert host.dtype == np.uint32 and np.array_equal(host, dev)
    assert np.array_equal(bitops.unpack_np(host, n), bits)
    assert np.array_equal(np.asarray(bitops.unpack(jnp.asarray(host), n)), bits)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_trailing_pad_bits_are_always_zero(raw_n, seed):
    n = _unaligned(raw_n)
    rng = np.random.default_rng(seed)
    bits = rng.random((3, n)) < 0.5
    for packed in (bitops.pack_np(bits),
                   np.asarray(bitops.pack(jnp.asarray(bits)))):
        rem = n % bitops.WORD
        if rem:
            pad_mask = np.uint32(0xFFFFFFFF) << np.uint32(rem)
            assert not (packed[..., -1] & pad_mask).any()
        # popcount therefore counts logical bits only
        assert np.array_equal(
            np.asarray(bitops.popcount(jnp.asarray(packed))), bits.sum(-1)
        )


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_pad_bits_never_leak_into_convergence_or_leq(raw_n, seed):
    """Adversarial pad bits in one operand must not flip any_set/leq/
    convergence verdicts about the logical n bits."""
    n = _unaligned(raw_n)
    rng = np.random.default_rng(seed)
    a = rng.random(n) < 0.3
    b = a | (rng.random(n) < 0.3)
    pa, pb = bitops.pack_np(a), bitops.pack_np(b)
    # a <= b as sets, with clean pads
    assert bool(bitops.leq(jnp.asarray(pa), jnp.asarray(pb)))
    # dirty the pad bits of b only: a <= b must still hold, and masking
    # with ones_mask restores the canonical words exactly
    rem = n % bitops.WORD
    dirty = pb.copy()
    dirty[-1] |= np.uint32(0xFFFFFFFF) << np.uint32(rem)
    assert bool(bitops.leq(jnp.asarray(pa), jnp.asarray(dirty)))
    masked = dirty & bitops.ones_mask(n)
    assert np.array_equal(masked, pb)
    # word-level equality (the packed convergence test) sees canonical
    # operands as equal iff their logical bits are equal
    assert np.array_equal(bitops.pack_np(a), bitops.pack_np(a.copy()))
    if (b & ~a).any():
        assert (bitops.pack_np(b) != bitops.pack_np(a)).any()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10_000), st.integers(0, 2**31 - 1))
def test_ones_mask_is_and_identity_on_packed(raw_n, seed):
    n = _unaligned(raw_n)
    rng = np.random.default_rng(seed)
    bits = rng.random((2, n)) < 0.5
    packed = bitops.pack_np(bits)
    assert np.array_equal(packed & bitops.ones_mask(n), packed)
    m = bitops.ones_mask(n)
    assert np.asarray(bitops.popcount(jnp.asarray(m))) == n
