import jax.numpy as jnp
import numpy as np
from tests._hyp import given, settings, st

from repro.core import bitops


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.4
    packed = bitops.pack(jnp.asarray(bits))
    assert packed.shape[-1] == bitops.packed_width(n)
    back = np.asarray(bitops.unpack(packed, n))
    assert np.array_equal(back, bits)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 150), st.integers(0, 2**31 - 1))
def test_popcount_and_any(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((3, n)) < 0.3
    packed = bitops.pack(jnp.asarray(bits))
    assert np.array_equal(np.asarray(bitops.popcount(packed)), bits.sum(-1))
    assert np.array_equal(np.asarray(bitops.any_set(packed)), bits.any(-1))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.integers(0, 2**31 - 1))
def test_leq_matches_set_inclusion(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.random(n) < 0.3
    b = a | (rng.random(n) < 0.2)
    pa, pb = bitops.pack(jnp.asarray(a)), bitops.pack(jnp.asarray(b))
    assert bool(bitops.leq(pa, pb))
    # strict superset the other way iff b != a
    if (b & ~a).any():
        assert not bool(bitops.leq(pb, pa))


def test_ones_mask_trailing_bits():
    m = bitops.ones_mask(70)
    assert np.asarray(bitops.popcount(jnp.asarray(m))) == 70
