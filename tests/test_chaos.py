"""ISSUE 10 failure plane: deterministic fault injection (`repro.faults`),
replica health / quarantine / epoch-fenced rebuild, deadline-budgeted retry
and hedging, the solve watchdog behind the explicit ``timeout`` outcome,
the unresolved-future fixes, and the exactly-once property under random
seeded fault schedules (ok results bit-identical to the fault-free
``solve_worklist`` oracle)."""
import asyncio
import time

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import dualsim, pruning, soi, sparql
from repro.data import synth
from repro.db import GraphDB
from repro.faults import (
    FaultPlan,
    InjectedCrash,
    InjectedFault,
    InjectedPoison,
    InjectedReject,
)
from repro.serve import (
    HEALTHY,
    OUTCOMES,
    QUARANTINED,
    SUSPECT,
    AsyncServer,
    ReplicaRouter,
)

MEMBERS_OF = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


@pytest.fixture()
def db():
    return GraphDB(synth.lubm_like(n_universities=2, seed=0))


def _prepared(db, text):
    return db._engine.prepare(db._coerce(text))


def _oracle_mask(g, text):
    """Fault-free ground truth: parse -> SOI -> solve_worklist -> prune."""
    q = sparql.parse(text)
    mask = np.zeros(g.n_edges, dtype=bool)
    for part in sparql.union_split(q):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, _ = dualsim.solve_worklist(c, g)
        m, _ = pruning.prune_triples(s, chi, g)
        mask |= m
    return mask


# --------------------------------------------------------------------- #
# FaultPlan semantics
# --------------------------------------------------------------------- #
def test_fault_plan_disarmed_is_noop():
    plan = (
        FaultPlan(7)
        .crash_replica("r0", at_batch=1)
        .poison_matching("Poison")
        .reject_dispatch(at_dispatch=1)
        .fail_refresh("r1")
    )
    # not armed: every hook is silent
    plan.on_batch_start("r0")
    plan.on_dispatch()
    plan.on_refresh("r1")
    plan.on_execute_prepared([("q", None)])
    assert plan.solve_penalty("r0", 1.0) == 0.0
    assert plan.counts() == {}


def test_fault_plan_crash_persists_until_heal():
    plan = FaultPlan().crash_replica("r0", at_batch=2).arm()
    plan.on_batch_start("r0")  # batch 1: survives
    with pytest.raises(InjectedCrash):
        plan.on_batch_start("r0")  # batch 2: crashes
    with pytest.raises(InjectedCrash):
        plan.on_batch_start("r0")  # stays crashed (fast failure)
    plan.on_batch_start("r1")  # other replicas unaffected
    assert plan.crash_fired("r0")["batch"] == 2.0
    plan.heal("r0")
    plan.on_batch_start("r0")  # a rebuilt replica serves again
    assert plan.counts()["crash"] == 2


def test_fault_plan_reject_window_and_refresh_budget():
    plan = FaultPlan().reject_dispatch(at_dispatch=2).fail_refresh("r0").arm()
    plan.on_dispatch()  # dispatch 1 passes
    with pytest.raises(InjectedReject):
        plan.on_dispatch()  # dispatch 2 rejected
    plan.on_dispatch()  # window closed
    with pytest.raises(InjectedFault):
        plan.on_refresh("r0")
    plan.on_refresh("r0")  # budget of 1 consumed


def test_fault_plan_poison_matches_constants(db):
    plan = FaultPlan().poison_matching("Poison").arm()
    clean = _prepared(db, MEMBERS_OF.format(uni="Univ0"))
    bad = _prepared(db, MEMBERS_OF.format(uni="PoisonX"))
    plan.on_execute_prepared([clean])
    assert not plan.matches_poison(clean)
    assert plan.matches_poison(bad)
    with pytest.raises(InjectedPoison):
        plan.on_execute_prepared([clean, bad])


# --------------------------------------------------------------------- #
# router health plane
# --------------------------------------------------------------------- #
def test_router_quarantines_fast_failing_replica(db):
    # the amplification regression: raw least-in-flight would keep feeding
    # a fast-failing replica (low in-flight -> more traffic); the health
    # plane must cap its failures and quarantine it
    plan = FaultPlan().crash_replica("r0", at_batch=1).arm()
    router = ReplicaRouter(db, 3, fault_plan=plan, auto_rebuild=False)
    prepared = _prepared(db, MEMBERS_OF.format(uni="Univ0"))
    failures = ok = 0
    for _ in range(30):
        try:
            out, _name = router.execute_isolated([prepared])
            assert not isinstance(out[0], Exception)
            ok += 1
        except InjectedFault:
            failures += 1
    health = {h["name"]: h for h in router.health()}
    assert health["r0"]["state"] == QUARANTINED
    # suspect probing re-checks the broken replica a bounded number of
    # times; it must NOT capture a traffic share
    assert failures <= 5
    assert ok >= 25
    agg = router.aggregate()
    assert agg["health"]["r0"] == QUARANTINED
    assert agg["quarantines"] == 1
    events = [e["event"] for e in router.events() if e["replica"] == "r0"]
    assert "suspect" in events and "quarantined" in events


def test_router_rebuilds_crashed_replica_bit_identical(db):
    text = MEMBERS_OF.format(uni="Univ0")
    plan = FaultPlan().crash_replica("r0", at_batch=1).arm()
    router = ReplicaRouter(
        db, 2, fault_plan=plan, rebuild_backoff_s=0.01
    )
    prepared = _prepared(db, text)
    # drive traffic until the crash is noticed, probed, and quarantined;
    # the rebuild thread then heals the injected crash and swaps engines
    for _ in range(30):
        try:
            router.execute_isolated([prepared])
        except InjectedFault:
            pass
    assert router.wait_rebuilt(timeout=10.0)
    r0 = router.replicas[0]
    health = {h["name"]: h for h in router.health()}
    assert health["r0"]["state"] == HEALTHY
    assert health["r0"]["epoch"] == 1 and health["r0"]["rebuilds"] == 1
    events = [e["event"] for e in router.events() if e["replica"] == "r0"]
    assert events.count("rebuilt") == 1
    # epoch-fenced re-admission: the rebuilt engine serves, and its results
    # are bit-identical to the fault-free oracle
    with router._route_lock:  # count the slot release() will return
        r0.in_flight += 1
    out, name = router.execute_on(r0, [prepared])
    assert name == "r0" and not isinstance(out[0], Exception)
    assert np.array_equal(out[0].survivor_mask, _oracle_mask(db.graph, text))


def test_router_fence_partial_failure_marks_suspect(db):
    plan = FaultPlan().fail_refresh("r1").arm()
    router = ReplicaRouter(db, 2, fault_plan=plan, auto_rebuild=False)
    prepared = _prepared(db, MEMBERS_OF.format(uni="Univ0"))
    router.execute_isolated([prepared])  # warm one replica on v0
    db.insert([("DeptX", "subOrganizationOf", "Univ0")])
    v = router.fence()
    assert v == db.version
    agg = router.aggregate()
    assert agg["fence_failures"] == 1
    assert agg["fence_partial"] == ["r1"]
    # the fleet is half-fenced but *recorded*: r0 advanced, r1 is suspect
    assert router.versions()[0] == v
    health = {h["name"]: h for h in router.health()}
    assert health["r1"]["state"] == SUSPECT
    # the injected budget is spent: the next fence completes everywhere
    router.fence()
    assert router.versions() == [v, v]
    assert router.aggregate()["fence_partial"] == []


# --------------------------------------------------------------------- #
# server failure handling
# --------------------------------------------------------------------- #
def test_server_pool_shutdown_resolves_all_futures(db):
    # ISSUE 10 satellite: executor rejection after pool shutdown used to
    # leak every live future; now they all resolve outcome="error"
    async def go():
        async with AsyncServer(
            db, replicas=1, max_batch=4, max_delay_ms=1.0
        ) as server:
            warm = await server.submit(MEMBERS_OF.format(uni="Univ0"))
            server._pool.shutdown(wait=False)
            futs = [
                server.submit(MEMBERS_OF.format(uni="Univ1"))
                for _ in range(3)
            ]
            results = await asyncio.gather(*futs)
            snap = server.metrics.snapshot()
        return warm, results, snap

    warm, results, snap = asyncio.run(go())
    assert warm.ok
    assert [r.outcome for r in results] == ["error"] * 3
    assert all("rejected" in r.detail for r in results)
    assert snap.submitted == snap.resolved  # nothing leaked


def test_server_injected_reject_resolves_batch(db):
    plan = FaultPlan().reject_dispatch(at_dispatch=1)

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, max_batch=4, max_delay_ms=1.0,
            default_deadline_ms=10_000.0,
        ) as server:
            warm = await server.submit(MEMBERS_OF.format(uni="Univ0"))
            plan.arm()
            rejected = await asyncio.gather(
                *[server.submit(MEMBERS_OF.format(uni="Univ0"))
                  for _ in range(3)]
            )
            after = await server.submit(MEMBERS_OF.format(uni="Univ1"))
            snap = server.metrics.snapshot()
        return warm, rejected, after, snap

    warm, rejected, after, snap = asyncio.run(go())
    assert warm.ok and after.ok
    assert [r.outcome for r in rejected] == ["error"] * 3
    assert all(isinstance(r.error, InjectedReject) for r in rejected)
    assert snap.submitted == snap.resolved


def test_server_retries_crashed_replica_on_another(db):
    text = MEMBERS_OF.format(uni="Univ0")
    plan = FaultPlan().crash_replica("r0", at_batch=1)

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, max_retries=2, max_batch=4,
            max_delay_ms=1.0, default_deadline_ms=30_000.0,
        ) as server:
            await asyncio.gather(
                *[server.submit(text) for _ in range(4)]
            )  # disarmed warmup
            plan.arm()
            results = []
            for _ in range(10):
                results.append(await server.submit(text))
            snap = server.metrics.snapshot()
            events = server.router.events()
        return results, snap, events

    results, snap, events = asyncio.run(go())
    # every request survived the crash via retry on the other replica
    assert all(r.ok for r in results)
    assert snap.retries >= 1
    assert snap.submitted == snap.resolved
    # the crash was noticed (auto-rebuild may already have healed r0)
    assert any(
        e["replica"] == "r0" and e["event"] == "suspect" for e in events
    )
    g = db.graph
    oracle = _oracle_mask(g, text)
    assert all(np.array_equal(r.result.survivor_mask, oracle) for r in results)


def test_server_poison_isolated_and_not_blamed_on_replica(db):
    plan = FaultPlan().poison_matching("Poison")
    good = MEMBERS_OF.format(uni="Univ0")
    bad = MEMBERS_OF.format(uni="PoisonX")

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, max_batch=4, max_delay_ms=1.0,
            default_deadline_ms=30_000.0,
        ) as server:
            await server.submit(good)
            plan.arm()
            futs = [server.submit(good), server.submit(bad),
                    server.submit(good), server.submit(good)]
            results = await asyncio.gather(*futs)
            health = server.router.health()
            snap = server.metrics.snapshot()
        return results, health, snap

    results, health, snap = asyncio.run(go())
    assert [r.outcome for r in results] == ["ok", "error", "ok", "ok"]
    assert isinstance(results[1].error, InjectedPoison)
    # poison travels with the request: the replica is NOT penalized
    assert all(h["state"] == HEALTHY for h in health)
    assert snap.errors == 1 and snap.submitted == snap.resolved


def test_server_watchdog_times_out_wedged_attempt(db):
    text = MEMBERS_OF.format(uni="Univ0")
    plan = FaultPlan().slow_replica("r0", extra_s=0.5)

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, max_retries=0, max_batch=2,
            max_delay_ms=1.0, default_deadline_ms=5_000.0,
        ) as server:
            for _ in range(6):
                await server.submit(text)  # disarmed warmup
            # pin the budget only after warmup: the cold first solve
            # (compile) legitimately exceeds any tight budget
            server.watchdog_budget = 0.150
            plan.arm()
            results = [await server.submit(text) for _ in range(8)]
            snap = server.metrics.snapshot()
            events = server.router.events()
        return results, snap, events

    results, snap, events = asyncio.run(go())
    outcomes = {r.outcome for r in results}
    assert outcomes <= {"ok", "timeout"} and "timeout" in outcomes
    timed_out = [r for r in results if r.outcome == "timeout"]
    assert all("watchdog" in r.detail for r in timed_out)
    assert snap.watchdog_overruns >= 1
    assert snap.timeouts == len(timed_out)
    # overruns feed the health plane: r0 went suspect at least once
    assert any(
        e["replica"] == "r0" and e["event"] == "suspect" for e in events
    )
    assert snap.submitted == snap.resolved


def test_server_watchdog_overrun_retries_to_ok(db):
    text = MEMBERS_OF.format(uni="Univ0")
    plan = FaultPlan().slow_replica("r0", extra_s=0.5)

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, max_retries=2, max_batch=2,
            max_delay_ms=1.0, default_deadline_ms=10_000.0,
        ) as server:
            for _ in range(6):
                await server.submit(text)
            server.watchdog_budget = 0.150  # post-warmup (see above)
            plan.arm()
            results = [await server.submit(text) for _ in range(8)]
            snap = server.metrics.snapshot()
        return results, snap

    results, snap = asyncio.run(go())
    assert all(r.ok for r in results)  # overruns retried on the fast replica
    assert snap.watchdog_overruns >= 1 and snap.retries >= 1
    assert snap.submitted == snap.resolved


def test_server_hedges_past_tracked_p99(db):
    text = MEMBERS_OF.format(uni="Univ0")
    plan = FaultPlan().slow_replica("r0", extra_s=0.6)

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, hedge=True,
            hedge_delay_ms=100.0, max_retries=1, max_batch=2,
            max_delay_ms=1.0, default_deadline_ms=10_000.0,
            watchdog_budget_ms=5_000.0,
        ) as server:
            for _ in range(6):
                await server.submit(text)  # disarmed warmup
            plan.arm()
            t0 = time.monotonic()
            results = [await server.submit(text) for _ in range(8)]
            elapsed = time.monotonic() - t0
            snap = server.metrics.snapshot()
        return results, snap, elapsed

    results, snap, elapsed = asyncio.run(go())
    assert all(r.ok for r in results)
    assert snap.hedges >= 1  # straggling attempts raced a duplicate
    # hedging means NOT paying the straggler's 0.6 s on every slow attempt
    assert elapsed < 0.6 * 8
    assert snap.submitted == snap.resolved


# --------------------------------------------------------------------- #
# property: exactly-once + oracle-identical under random fault schedules
# --------------------------------------------------------------------- #
def _run_schedule(seed, crash_batch, poison_every, slow_extra_ms, mutate):
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    plan = (
        FaultPlan(seed)
        .crash_replica("r0", at_batch=crash_batch)
        .poison_matching("Poison")
    )
    if slow_extra_ms:
        plan.slow_replica("r1", extra_s=slow_extra_ms / 1e3)
    rng = np.random.default_rng(seed)
    n = 24
    texts, poisoned = [], []
    for i in range(n):
        if poison_every and i % poison_every == 2:
            texts.append(MEMBERS_OF.format(uni=f"Poison{i}"))
            poisoned.append(True)
        else:
            uni = "Univ0" if rng.integers(2) == 0 else "Univ1"
            texts.append(MEMBERS_OF.format(uni=uni))
            poisoned.append(False)

    async def go():
        async with AsyncServer(
            db, replicas=2, fault_plan=plan, max_retries=2, max_batch=4,
            max_delay_ms=1.0, default_deadline_ms=30_000.0,
        ) as server:
            await asyncio.gather(
                *[server.submit(MEMBERS_OF.format(uni=u))
                  for u in ("Univ0", "Univ1")]
            )  # disarmed warmup
            plan.arm()
            futs = []
            for i, text in enumerate(texts):
                futs.append(server.submit(text))
                if mutate and i == n // 2:
                    db.insert([("DeptX", "subOrganizationOf", "Univ0")])
                    await server.fence()
                if i % 4 == 3:
                    await asyncio.sleep(0.002)  # let batches interleave
            results = await asyncio.gather(*futs)
            snap = server.metrics.snapshot()
        return results, snap

    results, snap = asyncio.run(go())
    # exactly once: every admitted submit resolved, with a legal outcome
    assert len(results) == n
    assert all(r.outcome in OUTCOMES for r in results)
    for text, is_poison, r in zip(texts, poisoned, results):
        if is_poison:
            assert r.outcome == "error"
            assert isinstance(r.error, InjectedPoison)
        else:
            assert r.ok, (text, r.outcome, r.detail)
            # bit-identical to the fault-free worklist oracle on the
            # snapshot the request was actually served against
            oracle = _oracle_mask(r.result.snapshot, text)
            assert np.array_equal(r.result.survivor_mask, oracle)
    # counters sum consistently after drain
    assert snap.submitted == snap.resolved
    assert snap.admitted == (
        snap.completed + snap.errors + snap.timeouts + snap.shed["deadline"]
    )


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    crash_batch=st.integers(1, 4),
    poison_every=st.sampled_from([0, 5, 8]),
    slow_extra_ms=st.sampled_from([0.0, 25.0]),
    mutate=st.booleans(),
)
def test_fault_schedules_exactly_once_property(
    seed, crash_batch, poison_every, slow_extra_ms, mutate
):
    _run_schedule(seed, crash_batch, poison_every, slow_extra_ms, mutate)


@pytest.mark.parametrize(
    "seed,crash_batch,poison_every,slow_extra_ms,mutate",
    [
        (7, 1, 5, 0.0, False),
        (11, 2, 8, 25.0, True),
        (23, 3, 0, 0.0, True),
    ],
)
def test_fault_schedules_exactly_once_fixed(
    seed, crash_batch, poison_every, slow_extra_ms, mutate
):
    # fixed-seed twin of the hypothesis property: runs in environments
    # without hypothesis installed (the CI [test] extra has it)
    _run_schedule(seed, crash_batch, poison_every, slow_extra_ms, mutate)
