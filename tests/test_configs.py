"""Per-architecture smoke tests (required): a REDUCED same-family config per
assigned arch runs one forward/train step on CPU with exact output shapes and
no NaNs.  Full configs are exercised only via launch/dryrun.py (abstract)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import gnn, recsys, transformer as tr
from repro.models import steps as steps_mod
from repro.optimizer import adamw

LM_ARCHS = ["internlm2-1.8b", "qwen3-8b", "yi-6b", "olmoe-1b-7b", "mixtral-8x7b"]
GNN_ARCHS = ["gatedgcn", "gat-cora", "pna", "schnet"]


def test_registry_complete():
    for a in configs.ARCH_IDS:
        spec = configs.get(a)
        assert spec.id == a
        cells = spec.cells()
        assert cells, a
        for name in cells:
            spec.skip_reason(name)  # must not raise


def test_exact_assigned_configs():
    """The full configs carry the exact hyper-parameters from the assignment."""
    c = configs.get("internlm2-1.8b").cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        24, 2048, 16, 8, 8192, 92544)
    c = configs.get("qwen3-8b").cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        36, 4096, 32, 8, 12288, 151936)
    assert c.qk_norm
    c = configs.get("yi-6b").cfg
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        32, 4096, 32, 4, 11008, 64000)
    c = configs.get("olmoe-1b-7b").cfg
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k) == (16, 2048, 64, 8)
    c = configs.get("mixtral-8x7b").cfg
    assert (c.n_layers, c.moe.n_experts, c.moe.top_k, c.sliding_window) == (
        32, 8, 2, 4096)
    g = configs.get("gatedgcn").base_cfg
    assert (g.n_layers, g.d_hidden) == (16, 70)
    g = configs.get("gat-cora").base_cfg
    assert (g.n_layers, g.d_hidden, g.n_heads) == (2, 8, 8)
    g = configs.get("pna").base_cfg
    assert (g.n_layers, g.d_hidden) == (4, 75)
    g = configs.get("schnet").base_cfg
    assert (g.n_layers, g.d_hidden, g.n_rbf, g.cutoff) == (3, 64, 300, 10.0)
    r = configs.get("dcn-v2").cfg
    assert (r.n_dense, r.n_sparse, r.embed_dim, r.n_cross, r.mlp) == (
        13, 26, 16, 3, (1024, 1024, 512))


def test_long500k_skips_documented():
    for a in LM_ARCHS:
        spec = configs.get(a)
        reason = spec.skip_reason("long_500k")
        if a == "mixtral-8x7b":
            assert reason is None  # SWA -> sub-quadratic, must run
        else:
            assert reason and "full attention" in reason


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_smoke(arch):
    spec = configs.get(arch)
    cfg = spec.reduced()
    p = tr.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    # forward shape + finiteness
    hidden, aux = tr.forward(cfg, p, toks)
    assert hidden.shape == (2, 16, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    # one train step
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    step = jax.jit(steps_mod.make_train_step(
        lambda pp, bb: tr.loss_fn(cfg, pp, bb), opt_cfg))
    p2, ost, m = step(p, adamw.init(p), batch)
    assert np.isfinite(float(m["loss"]))
    # decode smoke (one token with a tiny cache)
    cache = tr.init_kv_cache(cfg, 2, 8)
    logits, cache2 = tr.decode_step(cfg, p, cache, toks[:, :1])
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape_kind", ["full_graph", "molecule"])
def test_gnn_reduced_smoke(arch, shape_kind, rng):
    spec = configs.get(arch)
    base = spec.reduced()
    task = "graph_reg" if shape_kind == "molecule" else "node_class"
    cfg = dataclasses.replace(base, task=task, n_out=1 if task == "graph_reg" else 3)
    n, e, ngr = (24, 48, 4) if shape_kind == "molecule" else (30, 90, 1)
    feat = (
        jnp.asarray(rng.integers(1, 10, n).astype(np.int32))
        if arch == "schnet" and task == "graph_reg"
        else jnp.asarray(rng.normal(size=(n, cfg.d_in)).astype(np.float32))
    )
    batch = {
        "feat": feat,
        "edges": jnp.asarray(np.stack(
            [rng.integers(0, n, e), rng.integers(0, n, e)], 1).astype(np.int32)),
        "edge_mask": jnp.ones(e, bool),
        "node_graph": jnp.asarray((np.arange(n) % ngr).astype(np.int32)),
        "positions": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    }
    if task == "graph_reg":
        batch["labels"] = jnp.asarray(rng.normal(size=ngr).astype(np.float32))
        batch["n_graphs"] = ngr
    else:
        batch["labels"] = jnp.asarray(rng.integers(0, 3, n).astype(np.int32))
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out = gnn.forward(cfg, p, batch)
    exp = (ngr, cfg.n_out) if task == "graph_reg" else (n, cfg.n_out)
    assert out.shape == exp
    assert np.isfinite(np.asarray(out)).all()
    loss = gnn.loss_fn(cfg, p, batch)
    assert np.isfinite(float(loss))


def test_recsys_reduced_smoke(rng):
    spec = configs.get("dcn-v2")
    cfg = spec.reduced()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    b = {
        "dense": jnp.asarray(rng.normal(size=(8, cfg.n_dense)).astype(np.float32)),
        "sparse": jnp.asarray((rng.random((8, cfg.n_sparse))
                               * np.asarray(cfg.vocab_sizes)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, 8).astype(np.float32)),
    }
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    step = jax.jit(steps_mod.make_train_step(
        lambda pp, bb: recsys.loss_fn(cfg, pp, bb), opt_cfg))
    p2, ost, m = step(p, adamw.init(p), b)
    assert np.isfinite(float(m["loss"]))
    logit = recsys.forward(cfg, p, b)
    assert logit.shape == (8,) and np.isfinite(np.asarray(logit)).all()


def test_abstract_states_build_without_allocation():
    """eval_shape-only state/input construction for EVERY (arch, cell)."""
    for a in configs.ARCH_IDS:
        spec = configs.get(a)
        for name, cell in spec.cells().items():
            if spec.skip_reason(name):
                continue
            state = spec.abstract_state(cell)
            ins = spec.abstract_inputs(cell)
            for leaf in jax.tree.leaves((state, ins)):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (a, name, leaf)


def test_model_flops_positive():
    for a in configs.ARCH_IDS:
        spec = configs.get(a)
        for name, cell in spec.cells().items():
            if spec.skip_reason(name):
                continue
            assert spec.model_flops(cell) > 0, (a, name)
