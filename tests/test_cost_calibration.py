"""Calibration correctness of the spec-derived cost model (ISSUE 9).

Three properties keep the calibrated model honest:

* **single-shard reduction** — at ``n_devices == 1`` the communication
  constants are never read: two specs differing only in collective
  bandwidth price every engine identically, exactly like the PR-1 model.
* **feasibility is calibration-proof** — no spec, however distorted
  (hypothesis over random ceilings), can make :func:`choose_engine` select
  an engine that ``dense_tier_feasible`` refuses: the ``[n, n]`` build
  budget is a hard gate on graph shape, not a price.
* **golden specs** — a CPU-like spec (slow interpreted kernel, fast
  word-wise XLA) must price the fused word-wise lowering below interpreted
  ``packed``; an accelerator-like spec (fast kernels, ~µs launches) must
  pick ``packed_fused`` once the graph sits past the launch-overhead knee,
  and still fall back to ``dense`` below it.
"""
import dataclasses

from repro.core import soi, sparql
from repro.core.graph import DENSE_ADJ_MAX_BYTES
from repro.data import synth
from repro.engine.cost import (
    HAND_TUNED,
    CostModel,
    choose_engine,
    dense_tier_feasible,
    estimate_costs,
    resolve_model,
)
from repro.engine.machine import MachineSpec
from tests._hyp import given, settings, st


def _compiled(q, g):
    return soi.compile_soi(soi.build_soi(sparql.parse(q)), g)


def _spec(**kw) -> MachineSpec:
    base = dict(
        backend="cpu",
        device_kind="cpu",
        fingerprint="golden-cpu",
        n_devices=1,
        stream_bytes_per_s=8e9,
        dense_elems_per_s=5e9,
        packed_words_per_s=2e7,  # interpret-mode kernel: slow
        packed_words_per_s_xla=5e8,  # word-wise XLA lowering: fast
        fused_words_per_s=5e8,
        kernel_launch_s=2e-3,
        dispatch_s=2e-5,
        trace_s=0.05,
        collective_bytes_per_s=None,
    )
    base.update(kw)
    return MachineSpec(**base)


ACCEL_SPEC = _spec(
    backend="tpu",
    device_kind="accel",
    fingerprint="golden-accel",
    n_devices=4,
    stream_bytes_per_s=8e11,
    dense_elems_per_s=1e12,
    packed_words_per_s=2.5e11,  # compiled kernel ships here
    packed_words_per_s_xla=1e11,
    fused_words_per_s=1e12,
    kernel_launch_s=5e-6,
    dispatch_s=1e-6,
    trace_s=0.02,
    collective_bytes_per_s=2e10,
)


# --------------------------------------------------------------------- #
# provenance + resolution
# --------------------------------------------------------------------- #
def test_from_spec_prices_in_seconds_with_spec_provenance():
    mdl = CostModel.from_spec(_spec())
    assert mdl.unit == "s" and mdl.source == "golden-cpu"
    # every throughput constant is the measured reciprocal, not folklore
    assert mdl.c_segor_byte == 1.0 / 8e9
    assert mdl.c_dense == 1.0 / 5e9
    assert mdl.trace_cost == 0.05


def test_resolve_model_falls_back_to_hand_tuned_without_spec():
    # conftest pins REPRO_MACHINE_SPEC=off: no spec anywhere -> hand-tuned
    assert resolve_model() is HAND_TUNED
    assert resolve_model(spec=_spec()).source == "golden-cpu"


def test_selection_reason_cites_the_spec_not_hand_tuned():
    """Acceptance: with a spec present no selection path reads a hand-tuned
    constant — the chosen-engine rationale carries the spec fingerprint and
    the seconds unit."""
    g = synth.random_graph(n_nodes=48, n_labels=2, n_edges=1500, seed=0)
    c = _compiled("{ ?a p0 ?b . ?b p1 ?c }", g)
    est = choose_engine(g, c, spec=_spec(), backend="cpu")
    assert "golden-cpu" in est.reason and "hand-tuned" not in est.reason
    bare = choose_engine(g, c, backend="cpu")
    assert "hand-tuned" in bare.reason


# --------------------------------------------------------------------- #
# single-shard reduction
# --------------------------------------------------------------------- #
def test_single_device_reduces_to_single_shard_model():
    g = synth.random_graph(n_nodes=2_000, n_labels=2, n_edges=10_000, seed=0)
    c = _compiled("{ ?a p0 ?b . ?b p1 ?c }", g)
    slow_coll = _spec(collective_bytes_per_s=1e3)
    fast_coll = _spec(collective_bytes_per_s=1e12)
    a = estimate_costs(g, c, backend="cpu", n_devices=1, spec=slow_coll)
    b = estimate_costs(g, c, backend="cpu", n_devices=1, spec=fast_coll)
    # comm constants unread at one device: identical costs, engine by engine
    assert a == b
    assert a["partitioned"] == float("inf")  # no mesh: never selectable
    # ...and they ARE read on a mesh (the sparse engine pays M collectives)
    a8 = estimate_costs(g, c, backend="cpu", n_devices=8, spec=slow_coll)
    b8 = estimate_costs(g, c, backend="cpu", n_devices=8, spec=fast_coll)
    assert a8["sparse"] > b8["sparse"]


# --------------------------------------------------------------------- #
# feasibility survives any calibration (property)
# --------------------------------------------------------------------- #
_rate = st.floats(min_value=1e3, max_value=1e15)
_overhead = st.floats(min_value=1e-9, max_value=1e-1)

# built once: ~46k nodes (first n with n*n past the [n, n] budget), 10 edges
_INFEASIBLE_GRAPH = synth.random_graph(
    n_nodes=int(DENSE_ADJ_MAX_BYTES ** 0.5) + 1, n_labels=1, n_edges=10,
    seed=0,
)
_INFEASIBLE_SOI = _compiled("{ ?a p0 ?b }", _INFEASIBLE_GRAPH)


@settings(max_examples=25, deadline=None)
@given(
    stream=_rate, dense=_rate, packed=_rate, xla=_rate, fused=_rate,
    launch=_overhead, dispatch=_overhead,
    backend=st.sampled_from(["cpu", "tpu"]),
    coll=st.none() | _rate,
    n_devices=st.integers(min_value=1, max_value=8),
)
def test_no_spec_can_unrefuse_the_dense_tier(
    stream, dense, packed, xla, fused, launch, dispatch, backend, coll,
    n_devices,
):
    g = _INFEASIBLE_GRAPH
    assert not dense_tier_feasible(g.n_nodes)
    spec = _spec(
        backend=backend, stream_bytes_per_s=stream, dense_elems_per_s=dense,
        packed_words_per_s=packed, packed_words_per_s_xla=xla,
        fused_words_per_s=fused, kernel_launch_s=launch, dispatch_s=dispatch,
        collective_bytes_per_s=coll, fingerprint="random",
    )
    est = choose_engine(
        g, _INFEASIBLE_SOI, spec=spec, backend=backend, n_devices=n_devices
    )
    for tier in ("dense", "packed", "packed_fused"):
        assert est.costs[tier] == float("inf"), tier
    assert est.engine in ("sparse", "jacobi_packed", "partitioned")


# --------------------------------------------------------------------- #
# golden specs
# --------------------------------------------------------------------- #
def test_golden_cpu_spec_prefers_wordwise_over_interpreted_packed():
    """On a CPU-like machine (kernel runs under the interpret emulator, the
    word-wise XLA lowering is ~25x faster) the calibrated model must charge
    ``packed`` the interpreted rate and ``packed_fused`` the word-wise rate,
    so the fused engine prices strictly below packed at any size."""
    mdl = CostModel.from_spec(_spec())
    assert mdl.c_packed_interpret > mdl.c_packed_fused_cpu
    g = synth.random_graph(n_nodes=2_000, n_labels=2, n_edges=20_000, seed=1)
    costs = estimate_costs(
        g, _compiled("{ ?a p0 ?b . ?b p1 ?c }", g), backend="cpu",
        spec=_spec(),
    )
    assert costs["packed_fused"] < costs["packed"]


def test_golden_accel_spec_picks_fused_past_the_launch_knee():
    """Accelerator-like ceilings: ~µs launches and a 1e12 words/s fused
    path.  Past the knee (n=4096, 2M edges) the 32x word compression beats
    both the dense product and the byte-streamed sparse sweep; below it
    (n=256) the launch overhead dominates and dense wins."""
    g_big = synth.random_graph(
        n_nodes=4096, n_labels=2, n_edges=2_000_000, seed=2
    )
    est = choose_engine(
        g_big, _compiled("{ ?a p0 ?b . ?b p1 ?c }", g_big),
        spec=ACCEL_SPEC, backend="tpu",
    )
    assert est.engine == "packed_fused"

    g_small = synth.random_graph(
        n_nodes=256, n_labels=2, n_edges=8192, seed=3
    )
    est_small = choose_engine(
        g_small, _compiled("{ ?a p0 ?b . ?b p1 ?c }", g_small),
        spec=ACCEL_SPEC, backend="tpu",
    )
    assert est_small.engine == "dense"


def test_hand_tuned_and_calibrated_share_every_field():
    """The two provenances are the same model shape: no formula can read a
    constant that exists in one and not the other."""
    fields = {f.name for f in dataclasses.fields(CostModel)}
    spec_model = CostModel.from_spec(_spec())
    for f in fields:
        assert hasattr(HAND_TUNED, f) and hasattr(spec_model, f)
