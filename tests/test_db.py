"""`repro.db` public API: GraphDB mutation + versioned plan invalidation,
Session admission/microbatching, the fluent builder round-trip contract,
lazy ResultSet materialization, and UNION coverage through the full
serving path (ISSUE 2 acceptance criteria).
"""
import math

import numpy as np
import pytest

from repro.core import dualsim, pruning, soi, sparql
from repro.data import synth
from repro.db import GraphDB, Q
from repro.engine import canonicalize

from tests._hyp import given, settings, st

MEMBERS_OF = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


@pytest.fixture()
def db():
    return GraphDB(synth.lubm_like(n_universities=2, seed=0))


def _direct_mask(q, g, engine="dense"):
    mask = np.zeros(g.n_edges, dtype=bool)
    for part in sparql.union_split(q):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, _ = dualsim.solve_compiled(c, g, engine=engine)
        m, _ = pruning.prune_triples(s, chi, g)
        mask |= m
    return mask


# --------------------------------------------------------------------- #
# GraphDB: mutation semantics
# --------------------------------------------------------------------- #
def test_insert_delete_set_semantics(db):
    v0 = db.version
    n0 = db.n_triples
    snap0 = db.snapshot()
    assert db.insert([("DeptX", "subOrganizationOf", "Univ0")]) == 1
    assert db.version == v0 + 1 and db.n_triples == n0 + 1
    assert ("DeptX", "subOrganizationOf", "Univ0") in db
    # duplicate insert: set semantics, no mutation, no version bump
    assert db.insert([("DeptX", "subOrganizationOf", "Univ0")]) == 0
    assert db.version == v0 + 1
    # delete of an unknown triple: no-op
    assert db.delete([("NoSuch", "p", "AlsoNoSuch")]) == 0
    assert db.version == v0 + 1
    assert db.delete([("DeptX", "subOrganizationOf", "Univ0")]) == 1
    assert db.version == v0 + 2 and db.n_triples == n0
    assert ("DeptX", "subOrganizationOf", "Univ0") not in db
    # snapshot semantics: the pre-mutation graph never changed
    assert snap0.n_edges == n0
    assert snap0 is not db.snapshot()


def test_insert_extends_dictionary(db):
    n_nodes = db.n_nodes
    db.insert([("BrandNewNode", "brandNewLabel", "Univ0")])
    assert db.n_nodes == n_nodes + 1
    assert "brandNewLabel" in db.label_index
    # node ids are stable: old names keep their ids in the new snapshot
    assert db.node_index["Univ0"] == db.snapshot().node_names.index("Univ0")


# --------------------------------------------------------------------- #
# versioned plan invalidation (tentpole acceptance criterion)
# --------------------------------------------------------------------- #
def test_mutation_invalidates_plans_precisely(db):
    qa = MEMBERS_OF.format(uni="Univ0")
    qb = "{ ?p publicationAuthor ?s }"

    r0 = db.query(qa)
    assert not r0.cache_hit
    assert db.query(qa).cache_hit  # warm plan
    db.query(qb)  # a second, unrelated template in the cache
    m0 = db.metrics()
    assert m0.cache.size == 2 and m0.cache.invalidations == 0

    # mutation 1: stale plans are NOT flushed (history <= 1 version) but
    # the same template rebuilds lazily against the new fingerprint
    assert db.insert([("DeptNew", "subOrganizationOf", "Univ0"),
                      ("StudentNew", "memberOf", "DeptNew")]) == 2
    r1 = db.query(qa)
    assert not r1.cache_hit  # stale plan is not reused...
    assert ("StudentNew", "memberOf", "DeptNew") in list(r1.survivor_triples())
    assert np.array_equal(r1.survivor_mask,
                          _direct_mask(sparql.parse(qa), db.graph))
    m1 = db.metrics()
    assert m1.invalidation_events == 1
    assert m1.cache.invalidations == 0  # v0 plans kept: no full-cache flush
    assert m1.cache.size == 3  # qa@v0, qb@v0, qa@v1
    assert m1.cache.evictions == m0.cache.evictions  # invalidation != LRU

    # mutation 2: v0 now falls out of the <=1-version history; exactly the
    # two v0 plans (qa@v0, qb@v0) are dropped — qa@v1 survives as history
    db.insert([("StudentNew2", "memberOf", "DeptNew")])
    r2 = db.query(qa)
    assert not r2.cache_hit
    assert ("StudentNew2", "memberOf", "DeptNew") in list(r2.survivor_triples())
    m2 = db.metrics()
    assert m2.invalidation_events == 2
    assert m2.cache.invalidations == 2  # exactly qa@v0 and qb@v0
    assert m2.cache.size == 2  # qa@v1 (history) + qa@v2

    # delete direction: survivors shrink back
    db.delete([("StudentNew", "memberOf", "DeptNew"),
               ("StudentNew2", "memberOf", "DeptNew")])
    r3 = db.query(qa)
    trips = list(r3.survivor_triples())
    assert ("StudentNew", "memberOf", "DeptNew") not in trips
    assert ("StudentNew2", "memberOf", "DeptNew") not in trips
    assert np.array_equal(r3.survivor_mask,
                          _direct_mask(sparql.parse(qa), db.graph))


def test_fingerprint_includes_name_dictionaries():
    # identical int arrays under different dictionary encodings are
    # DIFFERENT databases: constants resolve to different ids, so their
    # plans must never collide in the cache
    from repro.core.graph import Graph
    from repro.engine.engine import graph_fingerprint

    tr = np.asarray([[0, 0, 1]], np.int32)
    g1 = Graph(2, 1, tr, node_names=["a", "b"], label_names=["p"])
    g2 = Graph(2, 1, tr, node_names=["b", "a"], label_names=["p"])
    g3 = Graph(2, 1, tr, node_names=["a", "b"], label_names=["q"])
    g4 = Graph(2, 1, tr.copy(), node_names=["a", "b"], label_names=["p"])
    assert graph_fingerprint(g1) != graph_fingerprint(g2)
    assert graph_fingerprint(g1) != graph_fingerprint(g3)
    assert graph_fingerprint(g1) == graph_fingerprint(g4)
    # the node/label list boundary must be unambiguous too
    g5 = Graph(2, 1, tr, node_names=["a", "bc"], label_names=["d"])
    g6 = Graph(2, 1, tr, node_names=["a", "b"], label_names=["cd"])
    assert graph_fingerprint(g5) != graph_fingerprint(g6)


def test_execute_prepared_pins_one_snapshot():
    # regression: UNION requests used to re-run refresh() mid-batch, so one
    # execute_many call could mix two graph versions when the source mutated
    # between the microbatched solves and the multipart tail.  Drive a
    # direct (unlocked) Engine and mutate after the first microbatch.
    from repro.engine import Engine

    gdb = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    eng = Engine(gdb)
    simple_q = MEMBERS_OF.format(uni="Univ0")
    union_q = ("{ ?d subOrganizationOf Univ0 } UNION "
               "{ ?d subOrganizationOf Univ1 }")
    prepared = [eng.prepare(q) for q in (simple_q, union_q)]
    snap = gdb.graph
    expected = [_direct_mask(sparql.parse(q), snap)
                for q in (simple_q, union_q)]

    orig, fired = eng._solve_microbatch, []

    def hooked(requests, bucket=None):
        out = orig(requests, bucket=bucket)
        if not fired:  # mutate the source mid-batch, exactly once
            fired.append(True)
            gdb.insert([("DeptMid", "subOrganizationOf", "Univ0"),
                        ("SMid", "memberOf", "DeptMid")])
        return out

    eng._solve_microbatch = hooked
    res = eng.execute_prepared(prepared)
    for r, exp in zip(res, expected):
        # every result reflects the snapshot pinned at call entry — none
        # sees the mid-batch mutation (old behavior: the UNION tail
        # refreshed and answered over snap.n_edges + 2 triples)
        assert r.survivors.shape[0] == snap.n_edges
        assert np.array_equal(r.survivors, exp)
    # the next call adopts the mutation as usual
    r2 = eng.execute(simple_q)
    assert r2.survivors.shape[0] == snap.n_edges + 2


def test_results_pin_their_snapshot(db):
    qa = MEMBERS_OF.format(uni="Univ0")
    r0 = db.query(qa)
    before = list(r0.survivor_triples())
    db.insert([("DeptY", "subOrganizationOf", "Univ0"),
               ("SY", "memberOf", "DeptY")])
    # the old result still reads through its own snapshot, unchanged
    assert list(r0.survivor_triples()) == before
    assert r0.stats.n_triples == r0.snapshot.n_edges
    assert db.query(qa).stats.n_triples == r0.stats.n_triples + 2


# --------------------------------------------------------------------- #
# Session: admission policy + microbatching acceptance criterion
# --------------------------------------------------------------------- #
def _submit_all(db, reqs, **kw):
    with db.session(**kw) as s:
        futures = [s.submit(q) for q in reqs]
        results = [f.result() for f in futures]
    return s, results


def test_session_microbatching_warm_zero_recompiles(db):
    # 9 requests but only 2 distinct constant tuples: dedup happens BEFORE
    # chunking, so the whole stream is ONE fixpoint solve (duplicates ride
    # an existing instance slot and never consume bucket capacity)
    n, cap = 9, 4
    reqs = [MEMBERS_OF.format(uni=f"Univ{i % 2}") for i in range(n)]
    # warm pass builds every (template, bucket) plan the stream needs
    _submit_all(db, reqs, max_delay_ms=1e6, max_pending=cap)
    inst = canonicalize(sparql.parse(reqs[0]))
    plan2, _ = db._engine.plan_for(inst, bucket=2)
    m0 = db.metrics()
    traces0 = plan2.metrics.traces

    s, results = _submit_all(db, reqs, max_delay_ms=1e6, max_pending=cap)
    m1 = db.metrics()
    # 2 unique tuples < cap: no cap-triggered flush, one solve at close
    assert m1.microbatches - m0.microbatches == 1
    assert s.flushes == 1
    # zero recompiles and zero retraces on the warm template
    assert m1.cache.misses == m0.cache.misses
    assert plan2.metrics.traces == traces0
    assert all(r.cache_hit for r in results)
    # and every rider matches its one-shot result
    direct = _direct_mask(sparql.parse(reqs[0]), db.graph)
    assert np.array_equal(results[0].survivor_mask, direct)


def test_session_cap_counts_unique_constants(db):
    # distinct constants DO hit the cap: 4 unique tuples at cap 4 flush
    # eagerly, ceil-batching the stream
    n, cap = 9, 4
    reqs = [MEMBERS_OF.format(uni=f"Univ{i}") for i in range(n)]
    _submit_all(db, reqs, max_delay_ms=1e6, max_pending=cap)  # warm pass
    m0 = db.metrics()
    s, results = _submit_all(db, reqs, max_delay_ms=1e6, max_pending=cap)
    m1 = db.metrics()
    assert s.flushes == math.ceil(n / cap) == 3
    assert m1.microbatches - m0.microbatches == 3
    assert all(r.cache_hit for r in results)


def test_session_deadline_admission(db):
    q = MEMBERS_OF.format(uni="Univ0")
    with db.session(max_delay_ms=0.0) as s:
        fut = s.submit(q)
        # zero deadline: the submit itself flushed
        assert fut.done() and s.pending == 0
    with db.session(max_delay_ms=1e6) as s:
        fut = s.submit(q)
        assert not fut.done() and s.pending == 1
        rs = fut.result()  # forces the flush
        assert fut.done() and s.pending == 0
        assert len(rs) > 0


def test_session_close_and_reject(db):
    q = MEMBERS_OF.format(uni="Univ0")
    with db.session(max_delay_ms=1e6) as s:
        fut = s.submit(q)
    assert fut.done()  # context exit flushed
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(q)


def test_session_syntax_error_at_submit(db):
    with db.session() as s:
        with pytest.raises(SyntaxError, match="empty group"):
            s.submit("{}")
        assert s.pending == 0


# --------------------------------------------------------------------- #
# fluent builder: grammar + round-trip acceptance criterion
# --------------------------------------------------------------------- #
def test_builder_composes_the_full_algebra():
    q = (
        Q.triple("?d", "memberOf", "?u")
        .triple("?s", "advisor", "?d")
        .and_(Q.triple("?u", "subOrganizationOf", "Univ0"))
        .optional("{ ?s publicationAuthor ?p }")
        .union(("?s", "headOf", "?d"))
    )
    ast = q.build()
    assert isinstance(ast, sparql.Union_)
    assert isinstance(ast.left, sparql.Optional_)
    assert isinstance(ast.left.left, sparql.And)
    assert sparql.parse(q.sparql()) == ast


def test_builder_roundtrips_through_parse():
    cases = [
        Q.triple("?a", "p0", "?b"),
        Q.triple("?a", "p0", "?b").triple("?b", "p1", "C0"),
        Q.triple("?a", "p0", "?b").and_(Q.triple("?b", "p1", "?c")),
        Q.triple("?a", "p0", "?b").optional(Q.triple("?c", "p2", "?a")),
        Q.triple("?a", "p0", "?b").union(Q.triple("?a", "p1", "?b")),
        Q.triple("?s", "p0", "?d").optional(
            Q.triple("?d", "p1", "C0").union(Q.triple("?d", "p1", "C1"))
        ),
    ]
    for q in cases:
        assert sparql.parse(q.sparql()) == q.build(), q.sparql()


def test_builder_immutability_and_validation():
    base = Q.triple("?a", "p0", "?b")
    extended = base.triple("?b", "p1", "?c")
    assert base != extended and len(base.build().triples) == 1
    with pytest.raises(ValueError, match="empty builder"):
        Q().build()
    with pytest.raises(ValueError, match="invalid constant"):
        Q.triple("?a", "p0", "bad name with spaces")
    with pytest.raises(ValueError, match="invalid variable"):
        Q.triple("?9starts-with-digit", "p0", "?b")
    with pytest.raises(TypeError, match="composite"):
        Q.triple("?a", "p0", "?b").and_(Q.triple("?c", "p1", "?d")).triple(
            "?x", "p2", "?y"
        )
    with pytest.raises(TypeError, match="operand"):
        Q.triple("?a", "p0", "?b").and_(42)


def test_builder_queries_execute(db):
    q = (
        Q.triple("?d", "subOrganizationOf", "Univ0")
        .triple("?s", "memberOf", "?d")
    )
    rs = db.query(q)
    assert np.array_equal(rs.survivor_mask,
                          _direct_mask(q.build(), db.graph))


_BUILDER_TERMS = st.sampled_from(["?a", "?b", "?c", "C0", "C1"])
_BUILDER_TRIPLES = st.tuples(
    _BUILDER_TERMS, st.sampled_from(["p0", "p1", "p2"]), _BUILDER_TERMS
)
_BUILDER_BGPS = st.lists(_BUILDER_TRIPLES, min_size=1, max_size=3).map(
    lambda ts: sparql.bgp_of_triples(*ts)
)
_BUILDER_QUERIES = st.recursive(
    _BUILDER_BGPS,
    lambda children: st.builds(sparql.And, children, children)
    | st.builds(sparql.Optional_, children, children)
    | st.builds(sparql.Union_, children, children),
    max_leaves=5,
)


@settings(max_examples=60, deadline=None)
@given(_BUILDER_QUERIES)
def test_format_parse_roundtrip_property(q):
    """builder/format -> parse is the identity on random BGP/AND/OPTIONAL/
    UNION compositions (ISSUE 2 acceptance)."""
    assert sparql.parse(sparql.format_query(q)) == q


# --------------------------------------------------------------------- #
# ResultSet: lazy names, pagination, honest timings
# --------------------------------------------------------------------- #
def test_resultset_lazy_bindings_and_pagination(db):
    rs = db.query(MEMBERS_OF.format(uni="Univ0"))
    g = db.graph
    assert rs.variables == ("d", "s")
    # names match the mask through the snapshot's dictionary
    d_names = rs.bindings("d")
    assert d_names == [g.node_names[i]
                       for i in np.flatnonzero(rs.binding_mask("d"))]
    assert rs.binding_count("d") == len(d_names)
    assert rs.bindings("d") is rs.bindings("d")  # cached, built once
    # survivor iteration == mask rows, in database order
    all_triples = list(rs)
    assert len(all_triples) == len(rs) == rs.stats.n_after
    ids = np.flatnonzero(rs.survivor_mask)
    s0, p0, o0 = g.triples[ids[0]]
    assert all_triples[0] == (g.node_names[s0], g.label_names[p0],
                              g.node_names[o0])
    # pagination tiles the full set
    paged = []
    for off in range(0, len(rs), 7):
        page = rs.page(off, 7)
        assert len(page) <= 7
        paged += page
    assert paged == all_triples
    assert rs.page(len(rs), 7) == []


def test_per_request_timing_split(db):
    reqs = [MEMBERS_OF.format(uni=f"Univ{i % 2}") for i in range(4)]
    results = db.execute_many(reqs)
    # all four rode one microbatch: batch_total is a batch property...
    batch_totals = {r.timings["batch_total"] for r in results}
    assert len(batch_totals) == 1
    bt = batch_totals.pop()
    # ...and "total" is the fair per-request share of it
    for r in results:
        assert r.timings["total"] == pytest.approx(bt / len(reqs))
    assert sum(r.timings["total"] for r in results) == pytest.approx(bt)
    # single-request path: the two views coincide
    r1 = db.query(reqs[0])
    assert r1.timings["batch_total"] == r1.timings["total"]


# --------------------------------------------------------------------- #
# UNION through the full serving path (ISSUE 2 satellite)
# --------------------------------------------------------------------- #
def test_union_inside_optional_through_serving(db):
    qt = ("{ ?s memberOf ?d } OPTIONAL "
          "{ { ?d subOrganizationOf Univ0 } UNION "
          "{ ?d subOrganizationOf Univ1 } }")
    rs = db.query(qt)
    q = sparql.parse(qt)
    assert np.array_equal(rs.survivor_mask, _direct_mask(q, db.graph))
    # over-approximation direction of union_split: every survivor of the
    # mandatory part is kept (OPTIONAL may only add optional-side triples)
    mand_mask = _direct_mask(sparql.parse("{ ?s memberOf ?d }"), db.graph)
    assert np.all(rs.survivor_mask[mand_mask])
    assert rs.template_keys and len(rs.template_keys) == 2  # one per part


def test_union_mixed_into_session_batches(db):
    union_q = ("{ ?d subOrganizationOf Univ0 } UNION "
               "{ ?d subOrganizationOf Univ1 }")
    bgp_reqs = [MEMBERS_OF.format(uni=f"Univ{i % 2}") for i in range(4)]
    reqs = bgp_reqs[:2] + [union_q] + bgp_reqs[2:]
    _, results = _submit_all(db, reqs, max_delay_ms=1e6, max_pending=8)
    for q, rs in zip(reqs, results):
        assert np.array_equal(rs.survivor_mask,
                              _direct_mask(sparql.parse(q), db.graph)), q
    # the union rider did not break same-template grouping of the rest
    m = db.metrics()
    assert m.requests == len(reqs)


def test_union_results_after_insert_through_session(db):
    union_q = ("{ ?s memberOf DeptFresh } UNION "
               "{ ?d subOrganizationOf Univ0 }")
    r_before = db.query(union_q)
    db.insert([("StudentF", "memberOf", "DeptFresh")])
    r_after = db.query(union_q)
    assert ("StudentF", "memberOf", "DeptFresh") not in list(r_before)
    assert ("StudentF", "memberOf", "DeptFresh") in list(r_after)
    assert np.array_equal(
        r_after.survivor_mask, _direct_mask(sparql.parse(union_q), db.graph)
    )


# --------------------------------------------------------------------- #
# deprecation shim
# --------------------------------------------------------------------- #
def test_exec_result_import_warns_but_works():
    import repro.engine as eng_mod

    with pytest.warns(DeprecationWarning, match="ExecResult"):
        cls = eng_mod.ExecResult
    # still the real class used internally
    from repro.engine.engine import ExecResult as internal

    assert cls is internal


def test_engine_accepts_plain_graph_unchanged():
    # back-compat: Engine(Graph) still works without a GraphDB source
    from repro.engine import Engine

    g = synth.lubm_like(n_universities=2, seed=0)
    eng = Engine(g)
    res = eng.execute(MEMBERS_OF.format(uni="Univ0"))
    assert res.survivors.any()
    assert eng.refresh() == 0  # no source: refresh is a no-op


# --------------------------------------------------------------------- #
# review regressions
# --------------------------------------------------------------------- #
def test_insert_is_atomic_on_malformed_input(db):
    v0, n0 = db.version, db.n_triples
    with pytest.raises(TypeError, match="triple #1"):
        db.insert([("NewNode", "p", "C"), ("bad",)])
    # nothing leaked into the live indexes or the committed snapshot
    assert db.version == v0 and db.n_triples == n0
    assert "NewNode" not in db.node_index and "C" not in db.node_index
    assert "p" not in db.label_index
    with pytest.raises(TypeError, match="triple #0"):
        db.delete([None])
    assert db.version == v0


def test_builder_rejects_keyword_names():
    for bad in ("AND", "WHERE", "UNION", "AND:x"):
        with pytest.raises(ValueError, match="invalid"):
            Q.triple("?a", bad, "?b")
        with pytest.raises(ValueError, match="invalid"):
            Q.triple("?a", "p0", bad)
    # keyword *prefixes* are fine and round-trip (tokenizer uses \b now)
    for ok in ("ANDERSON", "WHERE2", "UNIONIZED"):
        q = Q.triple("?a", "p0", ok)
        assert sparql.parse(q.sparql()) == q.build()


def test_session_exception_exit_drops_pending(db):
    q = MEMBERS_OF.format(uni="Univ0")
    m0 = db.metrics()
    with pytest.raises(KeyError):
        with db.session(max_delay_ms=1e6) as s:
            fut = s.submit(q)
            raise KeyError("boom")
    assert s.pending == 0 and not fut.done()
    # the dropped request is never executed, and result() says so clearly
    with pytest.raises(RuntimeError, match="dropped"):
        fut.result()
    assert db.metrics().requests == m0.requests


def test_prepare_once_same_results(db):
    # prepared path (sessions) and plain execute_many agree bit-for-bit
    reqs = [MEMBERS_OF.format(uni=f"Univ{i % 2}") for i in range(3)]
    reqs.append("{ ?d subOrganizationOf Univ0 } UNION "
                "{ ?d subOrganizationOf Univ1 }")
    plain = db.execute_many(reqs)
    _, via_session = _submit_all(db, reqs, max_delay_ms=1e6, max_pending=8)
    for a, b in zip(plain, via_session):
        assert np.array_equal(a.survivor_mask, b.survivor_mask)
