"""Dual-simulation engine correctness: all engines vs the Ma et al. oracle
(paper Def. 2 / Prop. 1/2), plus the paper's worked examples."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import dualsim, soi
from repro.core.graph import Graph
from repro.core.hhk import dual_simulation_hhk
from repro.core.ma_baseline import dual_simulation_ma
from repro.data import synth

ENGINES = ["dense", "packed", "packed_fused", "sparse", "worklist"]


def _random_instance(seed):
    rng = np.random.default_rng(seed)
    n_labels = int(rng.integers(1, 4))
    pat = synth.random_pattern(
        n_vars=int(rng.integers(2, 5)),
        n_labels=n_labels,
        n_edges=int(rng.integers(1, 7)),
        seed=seed,
    )
    db = synth.random_graph(
        n_nodes=int(rng.integers(3, 40)),
        n_labels=n_labels,
        n_edges=int(rng.integers(5, 120)),
        seed=seed + 1,
    )
    return pat, db


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_engines_match_ma_oracle(seed):
    pat, db = _random_instance(seed)
    s_ma, _ = dual_simulation_ma(pat, db)
    for eng in ENGINES:
        s, _ = dualsim.largest_dual_simulation(pat, db, engine=eng)
        assert np.array_equal(s, s_ma), f"{eng} != Ma et al. (seed {seed})"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_hhk_matches_ma_oracle(seed):
    pat, db = _random_instance(seed)
    s_ma, _ = dual_simulation_ma(pat, db)
    s_hhk, _ = dual_simulation_hhk(pat, db)
    assert np.array_equal(s_hhk, s_ma), f"HHK != Ma et al. (seed {seed})"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_union_of_dual_simulations_is_dual_simulation(seed):
    """Prop. 1's proof ingredient: S_max contains every dual simulation, so
    adding any match-induced relation to S_max leaves it unchanged."""
    pat, db = _random_instance(seed)
    s, _ = dualsim.largest_dual_simulation(pat, db, engine="dense")
    s_ma, _ = dual_simulation_ma(pat, db)
    assert np.array_equal(s | s_ma, s_ma)


def test_paper_fig4_counterexample():
    """Fig. 4: the largest dual simulation may keep nodes in no match.
    P: v <-> w (2-cycle).  K: p1 <-> p2, and p3 -> p2, p3 -> p4, p4 -> p3
    arranged so p4 'looks' matched through distributed obligations."""
    pat = Graph.from_arrays(2, 1, [(0, 0, 1), (1, 0, 0)])
    # K: p1->p2, p2->p1 (true match); p3->p2 (p3 has out-edge into the cycle)
    # p4->p3, p3->p4: p3/p4 form their own 2-cycle -> they ARE matches;
    # instead take: p4->p1, p2->p4: p4 has in+out edges but is in no 2-cycle.
    db = Graph.from_arrays(4, 1, [(0, 0, 1), (1, 0, 0), (3, 0, 0), (1, 0, 3)])
    s, _ = dualsim.largest_dual_simulation(pat, db, engine="dense")
    s_ma, _ = dual_simulation_ma(pat, db)
    assert np.array_equal(s, s_ma)
    # p4 (id 3) survives on both pattern nodes although (p4, p1) and (p2, p4)
    # do not close a 2-cycle -> dual simulation over-approximates matches.
    assert s[0, 3] and s[1, 3]


def test_empty_propagation_disconnects_component():
    """If a pattern edge has no support, its whole connected component's
    candidate sets collapse to empty."""
    pat = Graph.from_arrays(3, 2, [(0, 0, 1), (1, 1, 2)])
    db = Graph.from_arrays(4, 2, [(0, 0, 1), (1, 0, 2)])  # label 1 missing
    for eng in ENGINES:
        s, _ = dualsim.largest_dual_simulation(pat, db, engine=eng)
        assert not s.any(), eng


def test_eq12_vs_eq13_same_fixpoint():
    """The summary-vector init (Eq. 13) is exact, not just sound."""
    pat, db = _random_instance(123)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    chi13, _ = dualsim.solve_worklist(c, db, eq13_init=True)
    chi12, _ = dualsim.solve_worklist(c, db, eq13_init=False)
    assert np.array_equal(chi13, chi12)


@pytest.mark.parametrize("heuristic", ["sparse_first", "fifo"])
def test_worklist_heuristics_same_fixpoint(heuristic):
    pat, db = _random_instance(7)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    chi, evals = dualsim.solve_worklist(c, db, heuristic=heuristic)
    s_ma, _ = dual_simulation_ma(pat, db)
    # re-order rows to pattern order
    s, _ = dualsim.largest_dual_simulation(pat, db, engine="worklist")
    assert np.array_equal(s, s_ma)
    assert evals > 0


def test_max_sweeps_cap():
    pat, db = _random_instance(5)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops = dualsim.make_dense_operands(c, db)
    chi, it = dualsim.solve_dense(ops, max_sweeps=1)
    assert int(it) <= 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000))
def test_optimized_engines_same_fixpoint(seed):
    """§Perf engines (jacobi_packed, partitioned) reach the same largest
    solution as the paper-faithful Gauss–Seidel sparse engine."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 30)) * 16  # partitionable
    db = synth.random_graph(n, 3, int(rng.integers(10, 200)), seed=seed)
    pat = synth.random_pattern(3, 3, 4, seed=seed)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops = dualsim.make_sparse_operands(c, db)
    chi_gs, _ = dualsim.solve_sparse(ops, mode="gs")
    chi_j, _ = dualsim.solve_sparse(ops, mode="jacobi_packed")
    assert np.array_equal(np.asarray(chi_gs), np.asarray(chi_j))
    ops_p = dualsim.make_partitioned_operands(c, db, n_blocks=4)
    chi_p, _ = dualsim.solve_partitioned(ops_p)
    assert np.array_equal(np.asarray(chi_gs), np.asarray(chi_p)[:, :n])


def test_partitioned_operands_layout():
    """Every edge lands in the block owning its destination; pad rows use
    the out-of-range local id and are dropped by the segment reduce."""
    db = synth.random_graph(64, 2, 300, seed=3)
    pat = synth.random_pattern(2, 2, 2, seed=3)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops = dualsim.make_partitioned_operands(c, db, n_blocks=8)
    n_local = dualsim.padded_node_count(64, 8) // 8  # 32-aligned per block
    for src_b, dst_b in zip(ops.edge_src_b, ops.edge_dst_b):
        assert src_b.shape == dst_b.shape
        d = np.asarray(dst_b)
        assert ((d >= 0) & (d <= n_local)).all()


def test_partitioned_operands_pad_unaligned_graph():
    """n % n_blocks != 0 is the partitioner's problem now: the node axis is
    padded to the next block multiple, pad columns stay dead, and the sliced
    fixpoint matches the unpartitioned engines."""
    db = synth.random_graph(61, 2, 200, seed=9)  # 61 % 8 != 0
    pat = synth.random_pattern(2, 2, 3, seed=9)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops = dualsim.make_partitioned_operands(c, db, n_blocks=8)
    n_pad = dualsim.padded_node_count(61, 8)
    # each of the 8 blocks is padded to a 32-bit word multiple (Sect. 12)
    assert n_pad == 256 and ops.init.shape[-1] == n_pad
    assert not np.asarray(ops.init)[:, 61:].any()  # pad columns dead
    chi_p, _ = dualsim.solve_partitioned(ops)
    assert not np.asarray(chi_p)[:, 61:].any()
    chi_ref, _ = dualsim.solve_sparse(dualsim.make_sparse_operands(c, db))
    assert np.array_equal(np.asarray(chi_p)[:, :61], np.asarray(chi_ref))


def test_partitioned_operands_adj_cache_shared():
    """Edge blocks depend only on (mats, graph, n_blocks): two compilations
    against one graph share the device arrays through the adjacency cache."""
    db = synth.random_graph(32, 2, 100, seed=4)
    pat = synth.random_pattern(2, 2, 2, seed=4)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    cache: dict = {}
    a = dualsim.make_partitioned_operands(c, db, n_blocks=4, adj_cache=cache)
    b = dualsim.make_partitioned_operands(c, db, n_blocks=4, adj_cache=cache)
    assert a.edge_src_b[0] is b.edge_src_b[0]
    # a different block count is a different layout, not a false hit
    d = dualsim.make_partitioned_operands(c, db, n_blocks=2, adj_cache=cache)
    assert d.edge_src_b[0] is not a.edge_src_b[0]


# --------------------------------------------------------------------- #
# cross-engine equivalence: all five batched engines vs the paper's
# sequential worklist, over random BGP / AND / OPTIONAL queries
# --------------------------------------------------------------------- #
ALL_BATCHED = (
    "dense", "packed", "packed_fused", "sparse", "jacobi_packed",
    "partitioned",
)


def _random_query(rng, n_labels: int, node_names):
    from repro.core.sparql import And, BGP, Const, Optional_, Triple, Var

    def term():
        if rng.random() < 0.15:
            return Const(str(node_names[rng.integers(len(node_names))]))
        return Var(f"v{rng.integers(4)}")

    def bgp():
        return BGP(tuple(
            Triple(term(), f"p{rng.integers(n_labels)}", term())
            for _ in range(rng.integers(1, 4))
        ))

    q = bgp()
    r = rng.random()
    if r < 0.35:
        q = And(q, bgp())
    elif r < 0.7:
        q = Optional_(q, bgp())
    return q


def _check_cross_engine(seed: int) -> None:
    rng = np.random.default_rng(seed)
    n_labels = int(rng.integers(1, 4))
    db = synth.random_graph(
        n_nodes=int(rng.integers(3, 40)),
        n_labels=n_labels,
        n_edges=int(rng.integers(5, 120)),
        seed=seed + 1,
    )
    q = _random_query(rng, n_labels, db.node_names)
    c = soi.compile_soi(soi.build_soi(q), db)
    ref, _ = dualsim.solve_worklist(c, db)
    for eng in ALL_BATCHED:
        chi, _ = dualsim.solve_compiled(c, db, engine=eng, n_blocks=4)
        assert np.array_equal(chi, ref), f"{eng} != worklist (seed {seed})"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_cross_engine_equivalence_property(seed):
    """dense / packed / packed_fused / sparse(gs) / sparse(jacobi_packed) /
    partitioned all reach solve_worklist's fixpoint on random graph x query
    instances."""
    _check_cross_engine(seed)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7, 42])
def test_cross_engine_equivalence_fixed_seeds(seed):
    """Deterministic slice of the property above (runs without hypothesis)."""
    _check_cross_engine(seed)


def test_packed_fused_impls_match():
    """Both lowerings of the fused engine (Pallas kernel in interpret mode,
    word-wise XLA) compute the worklist fixpoint in the same sweep count."""
    db = synth.random_graph(45, 3, 150, seed=13)
    pat = synth.random_pattern(3, 3, 4, seed=13)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ref, _ = dualsim.solve_worklist(c, db)
    ops = dualsim.make_packed_operands(c, db)
    chi_k, it_k = dualsim.solve_packed_fused(ops, impl="interpret")
    chi_w, it_w = dualsim.solve_packed_fused(ops, impl="words")
    assert np.array_equal(np.asarray(chi_k), ref)
    assert np.array_equal(np.asarray(chi_w), ref)
    assert int(it_k) == int(it_w)


@pytest.mark.parametrize("mode", ["gs", "jacobi_packed"])
def test_sparse_impls_match(mode):
    """Both segmented-OR lowerings (blocked Pallas kernel in interpret
    mode, word-wise XLA) drive the edge-list engine to the worklist
    fixpoint in the same sweep count, in both sweep orders."""
    db = synth.random_graph(77, 3, 260, seed=21)  # 77 % 32 != 0
    pat = synth.random_pattern(3, 3, 4, seed=21)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ref, _ = dualsim.solve_worklist(c, db)
    ops = dualsim.make_sparse_operands(c, db)
    chi_k, it_k = dualsim.solve_sparse(ops, mode=mode, impl="kernel")
    chi_w, it_w = dualsim.solve_sparse(ops, mode=mode, impl="words")
    assert np.array_equal(np.asarray(chi_k), ref)
    assert np.array_equal(np.asarray(chi_w), ref)
    assert int(it_k) == int(it_w)


# --------------------------------------------------------------------- #
# packed-chi invariants: the while_loop never packs or unpacks (ISSUE 5).
# The jaxpr machinery lives in tools.reprolint.dynamic so the same check
# runs standalone in the CI reprolint job (ISSUE 7).
# --------------------------------------------------------------------- #
from tools.reprolint import dynamic as rl_dynamic  # noqa: E402


def test_packed_fused_while_body_has_no_pack_or_unpack():
    """ISSUE 5 acceptance, asserted for the KERNEL lowering (what
    accelerators serve): chi is uint32 words through the entire
    lax.while_loop — the body jaxpr contains none of the primitives pack
    (shift_left + reduce_sum) or unpack (shift_right + 32-lane broadcast)
    lower to, no bool [V, n] plane is materialized, and the loop carry
    holds no boolean chi.  The CPU ``words`` lowering is exempt by
    construction: it extracts frontier bits with jnp shifts inside the
    body (DESIGN.md Sect. 9, "Lowerings")."""
    db = synth.random_graph(70, 2, 200, seed=3)  # 70 % 32 != 0
    pat = synth.random_pattern(3, 2, 3, seed=3)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops = dualsim.make_packed_operands(c, db)
    bodies = rl_dynamic._while_bodies(
        lambda o: dualsim.solve_packed_fused(o, impl="interpret"), ops
    )
    assert bodies, "fused solver lost its while_loop"
    for body in bodies:
        assert rl_dynamic.check_fused_body(body) == []


def test_edge_engines_while_body_is_pack_free():
    """ISSUE 8 acceptance: every edge-list engine (sparse gs,
    jacobi_packed — words and kernel lowerings — and partitioned) carries
    packed uint32 chi through the while_loop with NO per-sweep pack
    (``reduce_sum``) and no bool ``[V, n]`` plane; ``y`` arrives already
    packed from the segmented-OR primitive."""
    db = synth.random_graph(70, 2, 200, seed=4)  # 70 % 32 != 0
    pat = synth.random_pattern(3, 2, 3, seed=4)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops_s = dualsim.make_sparse_operands(c, db)
    cases = [
        (ops_s, lambda o: dualsim.solve_sparse(o, mode="gs", impl="words")),
        (ops_s, lambda o: dualsim.solve_sparse(o, mode="gs", impl="kernel")),
        (ops_s, lambda o: dualsim.solve_sparse(o, mode="jacobi_packed",
                                               impl="words")),
        (ops_s, lambda o: dualsim.solve_sparse(o, mode="jacobi_packed",
                                               impl="kernel")),
        (dualsim.make_partitioned_operands(c, db, n_blocks=4),
         dualsim.solve_partitioned),
    ]
    for ops, solve in cases:
        bodies = rl_dynamic._while_bodies(solve, ops)
        assert bodies
        for body in bodies:
            assert rl_dynamic.check_edge_body(body) == []


def test_dynamic_cross_check_runs_clean():
    """The standalone CI cross-check (all packed engines) reports clean."""
    assert rl_dynamic.check_packed_engines() == []
