"""`repro.engine` subsystem: template canonicalization, plan-cache behavior,
cost-model engine choice, microbatch demux, and end-to-end equivalence of
``Engine.execute`` with the direct solve_compiled + prune_triples path.

The zero-recompile acceptance criterion is asserted here via cache and
trace counters: a warm constant-rebound execute must not build a plan
(cache.misses unchanged = no SOI recompilation) and must not retrace the
jitted fixpoint (plan.metrics.traces unchanged)."""
import jax
import numpy as np
import pytest

from repro.core import dualsim, pruning, soi, sparql
from repro.data import synth
from repro.engine import (
    Engine,
    MicroBatcher,
    PlanCache,
    batch_layout,
    batched_soi,
    bucket_for,
    canonicalize,
    choose_engine,
)

from tests._hyp import given, settings, st


@pytest.fixture(scope="module")
def lubm():
    return synth.lubm_like(n_universities=3, seed=0)


# --------------------------------------------------------------------- #
# template canonicalization
# --------------------------------------------------------------------- #
def test_same_shape_different_constants_share_key():
    a = canonicalize(sparql.parse("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }"))
    b = canonicalize(sparql.parse("{ ?x subOrganizationOf Univ2 . ?y memberOf ?x }"))
    assert a.template.key == b.template.key
    assert a.constants == ("Univ0",) and b.constants == ("Univ2",)
    assert a.var_names == ("d", "s") and b.var_names == ("x", "y")


def test_different_shapes_differ():
    a = canonicalize(sparql.parse("{ ?a p0 ?b }"))
    b = canonicalize(sparql.parse("{ ?a p1 ?b }"))  # label is part of the shape
    c = canonicalize(sparql.parse("{ ?a p0 ?b . ?b p0 ?c }"))
    assert len({a.template.key, b.template.key, c.template.key}) == 3


def test_repeated_constant_is_one_slot():
    # same constant twice expresses an equality two distinct constants don't
    a = canonicalize(sparql.parse("{ ?a p0 C . ?b p1 C }"))
    b = canonicalize(sparql.parse("{ ?a p0 C . ?b p1 D }"))
    assert a.template.n_slots == 1 and b.template.n_slots == 2
    assert a.template.key != b.template.key


def test_operator_structure_in_key():
    a = canonicalize(sparql.parse("{ ?a p0 ?b } AND { ?b p1 ?c }"))
    b = canonicalize(sparql.parse("{ ?a p0 ?b } OPTIONAL { ?b p1 ?c }"))
    assert a.template.key != b.template.key


# --------------------------------------------------------------------- #
# plan cache
# --------------------------------------------------------------------- #
def test_plan_cache_hit_miss_eviction():
    cache = PlanCache(capacity=2)
    built = []
    for key in ["a", "b", "a", "c", "b"]:  # c evicts b (LRU), then b rebuilds
        cache.get_or_build(key, lambda k=key: built.append(k))
    assert cache.hits == 1 and cache.misses == 4 and cache.evictions == 2
    assert built == ["a", "b", "c", "b"]
    s = cache.stats()
    assert s.size == 2 and s.hit_rate == pytest.approx(0.2)


# --------------------------------------------------------------------- #
# cost model
# --------------------------------------------------------------------- #
def _compiled(q, g):
    return soi.compile_soi(soi.build_soi(sparql.parse(q)), g)


def test_cost_model_dense_on_small_dense_graph():
    g = synth.random_graph(n_nodes=48, n_labels=2, n_edges=1500, seed=0)
    est = choose_engine(g, _compiled("{ ?a p0 ?b . ?b p1 ?c }", g))
    assert est.engine == "dense"
    assert est.costs["dense"] < est.costs["sparse"]


def test_cost_model_sparse_on_large_sparse_graph():
    g = synth.random_graph(n_nodes=20_000, n_labels=4, n_edges=40_000, seed=0)
    est = choose_engine(g, _compiled("{ ?a p0 ?b . ?b p1 ?c }", g))
    assert est.engine == "sparse"


def test_cost_model_dense_infeasible_at_scale():
    # 60k nodes: stacked bool[M, n, n] blows the dense memory budget
    g = synth.random_graph(n_nodes=60_000, n_labels=2, n_edges=50_000, seed=0)
    est = choose_engine(g, _compiled("{ ?a p0 ?b }", g))
    assert est.costs["dense"] == float("inf")
    assert est.engine == "sparse"


def test_cost_model_partitioned_needs_a_mesh():
    # single device: partitioned is pure block-padding overhead — infeasible
    g = synth.random_graph(n_nodes=60_000, n_labels=2, n_edges=50_000, seed=0)
    est = choose_engine(g, _compiled("{ ?a p0 ?b }", g), n_devices=1)
    assert est.costs["partitioned"] == float("inf")
    assert est.engine == "sparse"


def test_cost_model_partitioned_on_mesh_at_scale():
    # 8 devices + a graph past the dense budget: compute divides across the
    # mesh and the packed broadcast beats M chi-sized gathers -> partitioned
    g = synth.random_graph(n_nodes=60_000, n_labels=2, n_edges=50_000, seed=0)
    c = _compiled("{ ?a p0 ?b }", g)
    est = choose_engine(g, c, n_devices=8)
    assert est.engine == "partitioned"
    assert est.costs["partitioned"] < est.costs["sparse"]
    # communication terms only exist on a mesh: Gauss-Seidel sparse pays M
    # chi-sized collectives per sweep there, nothing on one device
    single = choose_engine(g, c, n_devices=1)
    assert est.costs["sparse"] > single.costs["sparse"]


def test_cost_model_small_graph_stays_single_shard_on_mesh():
    # a mesh alone must not flip tiny graphs off the dense engine
    g = synth.random_graph(n_nodes=48, n_labels=2, n_edges=1500, seed=0)
    est = choose_engine(g, _compiled("{ ?a p0 ?b . ?b p1 ?c }", g), n_devices=8)
    assert est.engine == "dense"


def test_dense_tier_hard_gate_matches_graph_budget():
    """ISSUE 8: past DENSE_ADJ_MAX_BYTES every dense-layout tier (dense,
    packed, packed_fused) is hard-infeasible in the cost model — never
    merely expensive — because operand *construction* would raise.  The
    smallest infeasible n makes the per-sweep cost favor the dense tier,
    so only the gate (not pricing) can exclude it."""
    from repro.core.graph import DENSE_ADJ_MAX_BYTES

    n = int(DENSE_ADJ_MAX_BYTES ** 0.5) + 1  # first n with n*n > budget
    g = synth.random_graph(n_nodes=n, n_labels=1, n_edges=10, seed=0)
    est = choose_engine(g, _compiled("{ ?a p0 ?b }", g))
    for tier in ("dense", "packed", "packed_fused"):
        assert est.costs[tier] == float("inf"), tier
    assert est.engine in ("sparse", "jacobi_packed")
    # the gate mirrors the construction-time guard exactly
    with pytest.raises(MemoryError):
        g.dense_adjacency(0)
    with pytest.raises(MemoryError):
        g.packed_adjacency(0)
    # one node fewer: construction is allowed again
    g2 = synth.random_graph(n_nodes=n - 1, n_labels=1, n_edges=10, seed=0)
    assert g2.dense_adjacency(0).shape == (n - 1, n - 1)


# --------------------------------------------------------------------- #
# batcher
# --------------------------------------------------------------------- #
def test_bucket_for():
    assert [bucket_for(n) for n in (1, 2, 3, 5, 16, 99)] == [1, 2, 4, 8, 16, 16]


def test_batched_soi_instance_boundaries():
    s = soi.build_soi(sparql.parse("{ ?a p0 ?b . ?b p1 ?c }"))
    layout = batch_layout([s, s, s])
    assert layout.offsets == [0, s.n_vars, 2 * s.n_vars]
    # per-instance renaming: instance i's variables carry suffix "#i"
    union = layout.soi
    for i in range(3):
        sl = layout.chi_slice(i)
        assert all(b.endswith(f"#{i}") for b in union.base[sl])
    assert union.n_vars == 3 * s.n_vars
    assert len(union.edge_ineqs) == 3 * len(s.edge_ineqs)
    # back-compat wrapper returns the same union
    assert batched_soi([s, s, s]).base == union.base


def test_microbatcher_dedups_before_chunking():
    # 20 duplicate submits at cap 16: ONE microbatch (bucket 1), not two —
    # dedup by constants happens before chunking
    mb = MicroBatcher(buckets=(1, 2, 4, 8, 16))
    q = "{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }"
    for i in range(20):
        mb.add(i, canonicalize(sparql.parse(q)))
    groups = list(mb.drain())
    assert len(groups) == 1
    assert groups[0].bucket == 1
    assert len(groups[0].requests) == 20  # every rider still demuxes


def test_microbatcher_chunks_by_unique_constants():
    # 17 unique + 3 duplicate tuples at cap 16 -> chunks of 16 and 1 uniques
    mb = MicroBatcher(buckets=(1, 2, 4, 8, 16))
    reqs = [f"{{ ?d subOrganizationOf Univ{i} . ?s memberOf ?d }}"
            for i in range(17)]
    reqs += reqs[:3]
    for i, q in enumerate(reqs):
        mb.add(i, canonicalize(sparql.parse(q)))
    groups = list(mb.drain())
    assert [len({inst.constants for _, inst in g.requests}) for g in groups] \
        == [16, 1]
    assert sum(len(g.requests) for g in groups) == 20


def test_microbatcher_groups_by_template():
    mb = MicroBatcher(buckets=(1, 2, 4))
    q_a = ["{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }",
           "{ ?x subOrganizationOf Univ1 . ?y memberOf ?x }",
           "{ ?d subOrganizationOf Univ2 . ?s memberOf ?d }"]
    q_b = ["{ ?p publicationAuthor ?s }"]
    for i, q in enumerate(q_a + q_b):
        mb.add(i, canonicalize(sparql.parse(q)))
    groups = list(mb.drain())
    assert len(mb) == 0
    sizes = sorted(len(g.requests) for g in groups)
    assert sizes == [1, 3]
    big = next(g for g in groups if len(g.requests) == 3)
    assert big.bucket == 4  # 3 requests pad up to the 4-bucket


# --------------------------------------------------------------------- #
# warm path: zero recompiles, zero retraces (acceptance criterion)
# --------------------------------------------------------------------- #
def test_warm_rebind_no_recompile_no_retrace(lubm):
    eng = Engine(lubm)
    r0 = eng.execute("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }")
    assert not r0.cache_hit
    builds_after_cold = eng.cache.misses
    plan, _ = eng.plan_for(
        canonicalize(sparql.parse("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }"))
    )
    traces_after_cold = plan.metrics.traces
    assert traces_after_cold == 1

    for uni in ["Univ1", "Univ2", "Univ0"]:
        r = eng.execute(f"{{ ?q subOrganizationOf {uni} . ?m memberOf ?q }}")
        assert r.cache_hit
    # zero SOI recompilation (no plan builds) and zero jit retraces
    assert eng.cache.misses == builds_after_cold
    assert plan.metrics.traces == traces_after_cold
    assert plan.metrics.executions == 4


def test_packed_fused_warm_rebind_no_recompile_no_retrace(lubm):
    """The end-to-end packed engine serves constant rebinds on one trace:
    constants scatter into the packed init as uint32 words, so the warm
    path's avals never change shape or dtype (ISSUE 5 acceptance)."""
    eng = Engine(lubm, engine="packed_fused")
    r0 = eng.execute("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }")
    assert not r0.cache_hit and r0.engine == "packed_fused"
    plan, _ = eng.plan_for(
        canonicalize(sparql.parse("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }"))
    )
    assert plan.metrics.traces == 1
    for uni in ["Univ1", "Univ2", "Univ0"]:
        r = eng.execute(f"{{ ?q subOrganizationOf {uni} . ?m memberOf ?q }}")
        assert r.cache_hit and r.engine == "packed_fused"
        assert np.array_equal(
            r.survivors, _direct_mask(
                sparql.parse(f"{{ ?q subOrganizationOf {uni} . ?m memberOf ?q }}"),
                lubm,
            )
        )
    assert plan.metrics.traces == 1  # zero retraces across rebinds


def test_adjacency_shared_across_plans(lubm):
    # adjacency depends only on (engine, mats, graph): plans for different
    # batch buckets of one template must share the device arrays
    eng = Engine(lubm, engine="dense")
    qs = [
        f"{{ ?d subOrganizationOf {u} . ?s memberOf ?d }}"
        for u in ("Univ0", "Univ1")
    ]
    eng.execute(qs[0])  # bucket-1 plan
    eng.execute_many(qs)  # bucket-2 plan, same template
    inst = canonicalize(sparql.parse(qs[0]))
    p1, _ = eng.plan_for(inst, bucket=1)
    p2, _ = eng.plan_for(inst, bucket=2)
    assert p1 is not p2
    assert p1.operands.adj_dense is p2.operands.adj_dense


def test_results_differ_across_constants(lubm):
    eng = Engine(lubm)
    rows = [
        eng.execute(f"{{ ?d subOrganizationOf {u} . ?s memberOf ?d }}")
        for u in ("Univ0", "Univ1")
    ]
    assert not np.array_equal(rows[0].survivors, rows[1].survivors)
    # each answer only keeps the requested university's component
    assert rows[0].bindings["d"].sum() > 0
    assert not np.any(rows[0].bindings["d"] & rows[1].bindings["d"])


def test_unknown_constant_gives_empty_result(lubm):
    eng = Engine(lubm)
    r = eng.execute("{ ?d subOrganizationOf UnivNoSuch . ?s memberOf ?d }")
    assert r.stats.n_after == 0 and not r.survivors.any()


# --------------------------------------------------------------------- #
# end-to-end equivalence with the direct pipeline
# --------------------------------------------------------------------- #
def _direct_mask(q, g, engine="dense"):
    mask = np.zeros(g.n_edges, dtype=bool)
    for part in sparql.union_split(q):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, _ = dualsim.solve_compiled(c, g, engine=engine)
        m, _ = pruning.prune_triples(s, chi, g)
        mask |= m
    return mask


E2E_QUERIES = [
    "{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }",
    "{ ?x memberOf ?y . ?y subOrganizationOf ?z . ?x undergraduateDegreeFrom ?z }",
    "{ ?s memberOf ?d } OPTIONAL { ?s advisor ?a }",
    "{ ?d subOrganizationOf Univ0 } UNION { ?d subOrganizationOf Univ1 }",
    "{ ?p publicationAuthor ?s . ?s memberOf ?d } AND { ?d subOrganizationOf Univ2 }",
]


@pytest.mark.parametrize("qt", E2E_QUERIES)
def test_engine_matches_direct_path(lubm, qt):
    eng = Engine(lubm)
    res = eng.execute(qt)
    assert np.array_equal(res.survivors, _direct_mask(sparql.parse(qt), lubm))
    assert res.stats.n_after == int(res.survivors.sum())


@pytest.mark.parametrize(
    "engine", ["dense", "sparse", "packed", "jacobi_packed", "partitioned"]
)
def test_engine_override_same_fixpoint(lubm, engine):
    qt = "{ ?d subOrganizationOf Univ1 . ?s memberOf ?d }"
    res = Engine(lubm, engine=engine).execute(qt)
    assert res.engine == engine
    assert np.array_equal(res.survivors, _direct_mask(sparql.parse(qt), lubm))


def test_partitioned_warm_rebind_no_recompile_no_retrace(lubm):
    """Acceptance: engine="partitioned" serves constant rebinds with zero
    plan builds and zero jit retraces, like every other engine."""
    eng = Engine(lubm, engine="partitioned")
    r0 = eng.execute("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }")
    assert not r0.cache_hit and r0.engine == "partitioned"
    plan, _ = eng.plan_for(
        canonicalize(sparql.parse("{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }"))
    )
    builds, traces = eng.cache.misses, plan.metrics.traces
    for uni in ["Univ1", "Univ2", "Univ0"]:
        r = eng.execute(f"{{ ?q subOrganizationOf {uni} . ?m memberOf ?q }}")
        assert r.cache_hit
    assert eng.cache.misses == builds
    assert plan.metrics.traces == traces


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs simulated devices: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)
def test_partitioned_engine_on_device_mesh(lubm):
    """Multi-device CI job: the partitioned engine shards chi over a real
    mesh (one destination block per device) and still matches the direct
    single-shard pipeline."""
    from repro.distributed import ctx as dctx

    mesh = dctx.node_mesh()
    eng = Engine(lubm, engine="partitioned", mesh=mesh)
    assert eng.n_blocks == len(jax.devices())
    qs = [f"{{ ?d subOrganizationOf {u} . ?s memberOf ?d }}"
          for u in ("Univ0", "Univ1", "Univ2")]
    for q in qs:
        res = eng.execute(q)
        assert res.engine == "partitioned"
        assert np.array_equal(res.survivors, _direct_mask(sparql.parse(q), lubm))
    # warm path stays zero-retrace on the mesh too
    plan, hit = eng.plan_for(canonicalize(sparql.parse(qs[0])))
    assert hit and plan.metrics.traces == 1
    # chi's node axis is actually sharded across the mesh
    assert plan.chi_spec is not None
    assert plan.operands.edge_src_b[0].sharding.num_devices == len(jax.devices())


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs simulated devices: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)
def test_auto_picks_partitioned_on_mesh_past_dense_budget():
    """Acceptance: auto + a >= 2-device mesh on a graph past the dense
    budget serves through solve_partitioned, zero warm retraces."""
    from repro.distributed import ctx as dctx

    g = synth.random_graph(n_nodes=60_000, n_labels=2, n_edges=50_000, seed=0)
    eng = Engine(g, engine="auto", mesh=dctx.node_mesh())
    q = "{ ?a p0 ?b . ?b p1 ?a }"
    r0 = eng.execute(q)
    assert r0.engine == "partitioned" and not r0.cache_hit
    plan, _ = eng.plan_for(canonicalize(sparql.parse(q)))
    assert plan.cost is not None and plan.cost.engine == "partitioned"
    traces = plan.metrics.traces
    r1 = eng.execute(q)
    assert r1.cache_hit and plan.metrics.traces == traces
    assert np.array_equal(r0.survivors, r1.survivors)


def test_execute_many_matches_execute(lubm):
    reqs = [
        f"{{ ?d subOrganizationOf {u} . ?s memberOf ?d }}"
        for u in ("Univ0", "Univ1", "Univ2", "Univ0", "Univ1")
    ] + ["{ ?d subOrganizationOf Univ0 } UNION { ?d subOrganizationOf Univ1 }"]
    eng = Engine(lubm)
    batched = eng.execute_many(reqs)
    singles = [Engine(lubm).execute(q) for q in reqs]
    for b, s, q in zip(batched, singles, reqs):
        assert np.array_equal(b.survivors, s.survivors), q
        assert b.sweeps > 0
    # the five same-template requests rode one microbatch (3 unique -> bucket 4)
    assert batched[0].batch == 4
    m = eng.metrics()
    assert m.requests == len(reqs)
    assert m.microbatches >= 1


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_engine_matches_direct_path_property(seed):
    """Engine.execute survivors == direct solve_compiled + prune_triples on
    random constant-parameterized queries over lubm_like data."""
    g = synth.lubm_like(n_universities=2, seed=1)
    rng = np.random.default_rng(seed)
    unis = [n for n in g.node_names if n.startswith("Univ")]
    u = unis[rng.integers(len(unis))]
    qt = (
        f"{{ ?d subOrganizationOf {u} . ?s memberOf ?d . ?s advisor ?a }}"
        if rng.random() < 0.5
        else f"{{ ?s undergraduateDegreeFrom {u} }} OPTIONAL {{ ?p publicationAuthor ?s }}"
    )
    res = Engine(g).execute(qt)
    assert np.array_equal(res.survivors, _direct_mask(sparql.parse(qt), g))
