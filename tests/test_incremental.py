"""Incremental dual-simulation maintenance (ISSUE 4; DESIGN.md Sect. 8):
warm-resumed fixpoints equal cold re-solves across random insert/delete
sequences for all five batched engines, superseded plans are classified
resumable vs cold correctly, the delta log composes/truncates, and
adjacency rebuilds are saved when a delta touches none of a plan's labels.
"""
import numpy as np
import pytest

from repro.core import dualsim, pruning, soi, sparql
from repro.core.graph import Graph, GraphDelta
from repro.data import synth
from repro.db import GraphDB
from repro.engine.cost import resume_decision

from tests._hyp import given, settings, st

ALL_BATCHED = (
    "dense", "packed", "packed_fused", "sparse", "jacobi_packed",
    "partitioned",
)

MEMBERS_OF = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


def _random_query(rng, n_labels: int, node_names):
    from repro.core.sparql import And, BGP, Const, Optional_, Triple, Var

    def term():
        if rng.random() < 0.15:
            return Const(str(node_names[rng.integers(len(node_names))]))
        return Var(f"v{rng.integers(4)}")

    def bgp():
        return BGP(tuple(
            Triple(term(), f"p{rng.integers(n_labels)}", term())
            for _ in range(rng.integers(1, 4))
        ))

    q = bgp()
    r = rng.random()
    if r < 0.35:
        q = And(q, bgp())
    elif r < 0.7:
        q = Optional_(q, bgp())
    return q


def _mutate(rng, g: Graph) -> tuple[Graph, set[int]]:
    """One shape-stable random mutation: delete and/or insert a few edges
    between existing nodes over existing labels.  Returns the new graph and
    the set of labels whose edges were *inserted* (the destabilizers)."""
    triples = g.triples
    if len(triples) and rng.random() < 0.7:
        keep = np.ones(len(triples), bool)
        keep[rng.choice(len(triples),
                        size=min(len(triples), int(rng.integers(1, 5))),
                        replace=False)] = False
        triples = triples[keep]
    inserted_labels: set[int] = set()
    if rng.random() < 0.7:
        k = int(rng.integers(1, 5))
        new = np.stack([
            rng.integers(0, g.n_nodes, k),
            rng.integers(0, g.n_labels, k),
            rng.integers(0, g.n_nodes, k),
        ], axis=1).astype(np.int32)
        triples = np.vstack([triples, new])
        inserted_labels = {int(x) for x in np.unique(new[:, 1])}
    return Graph(g.n_nodes, g.n_labels, triples,
                 g.node_names, g.label_names), inserted_labels


def _check_resume_matches_worklist(seed: int) -> None:
    """Across a random mutation sequence, resume_fixpoint from the previous
    snapshot's chi equals the paper's cold solve_worklist fixpoint, for
    every batched engine (acceptance property of ISSUE 4)."""
    rng = np.random.default_rng(seed)
    n_labels = int(rng.integers(1, 4))
    g = synth.random_graph(
        n_nodes=int(rng.integers(8, 40)),
        n_labels=n_labels,
        n_edges=int(rng.integers(10, 120)),
        seed=seed + 1,
    )
    q = _random_query(rng, n_labels, g.node_names)
    s = soi.build_soi(q)
    chi_prev = {
        eng: dualsim.solve_compiled(soi.compile_soi(s, g), g,
                                    engine=eng, n_blocks=4)[0]
        for eng in ALL_BATCHED
    }
    for _ in range(3):
        g, ins_labels = _mutate(rng, g)
        c = soi.compile_soi(s, g)
        ref, _ = dualsim.solve_worklist(c, g)
        for eng in ALL_BATCHED:
            warm, _ = dualsim.resume_fixpoint(
                c, g, chi_prev[eng], inserted_labels=ins_labels,
                engine=eng, n_blocks=4,
            )
            assert np.array_equal(warm, ref), (
                f"{eng} warm resume != cold worklist (seed {seed})"
            )
            chi_prev[eng] = warm


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_resume_equals_worklist_property(seed):
    """Warm-resumed chi == cold worklist fixpoint on random mutation
    sequences over random BGP/AND/OPTIONAL queries, all five engines."""
    _check_resume_matches_worklist(seed)


@pytest.mark.parametrize("seed", [0, 1, 2, 5, 11])
def test_resume_equals_worklist_fixed_seeds(seed):
    """Deterministic slice of the property above (runs without hypothesis)."""
    _check_resume_matches_worklist(seed)


def test_blocked_layout_patch_keeps_shapes_and_kernel_resumes():
    """Churn through ``patch_operands`` on the edge-list-packed layout
    (ISSUE 8): the blocked segmented-OR operands keep their superseded
    shapes (the zero-retrace precondition, mirroring EDGE_PAD for the flat
    lists) and the kernel-lowering warm resume stays bit-identical to a
    cold worklist solve."""
    rng = np.random.default_rng(6)
    g = synth.random_graph(70, 3, 220, seed=6)  # 70 % 32 != 0
    q = _random_query(rng, 3, g.node_names)
    s = soi.build_soi(q)
    c = soi.compile_soi(s, g)
    ops = dualsim.make_sparse_operands(c, g)
    chi_prev = np.asarray(dualsim.solve_sparse(ops, mode="gs",
                                               impl="kernel")[0])
    for _ in range(3):
        g, ins_labels = _mutate(rng, g)
        c = soi.compile_soi(s, g)
        shapes = [tuple(a.shape) for a in ops.seg_src_b]
        wins = [tuple(w.shape) for w in ops.seg_win]
        ops = dualsim.patch_operands(ops, c, g, set(range(g.n_labels)))
        assert [tuple(a.shape) for a in ops.seg_src_b] == shapes
        assert [tuple(w.shape) for w in ops.seg_win] == wins
        chi0 = chi_prev.copy()
        chi0[dualsim.destabilized_rows(c, set(ins_labels))] = True
        warm, _ = dualsim.solve_sparse(ops, mode="gs", impl="kernel",
                                       chi0=chi0)
        ref, _ = dualsim.solve_worklist(c, g)
        assert np.array_equal(np.asarray(warm), ref)
        chi_prev = np.asarray(warm)


def test_destabilized_rows_closure():
    # v0 -p0-> v1 -p1-> v2: inserting p1 edges may grow every row that
    # (transitively) depends on a p1 operator, but only those
    q = sparql.parse("{ ?a p0 ?b . ?b p1 ?c }")
    g = synth.random_graph(n_nodes=10, n_labels=2, n_edges=30, seed=0)
    c = soi.compile_soi(soi.build_soi(q), g)
    grow = dualsim.destabilized_rows(c, {g.label_id("p1")})
    # the p1 inequalities constrain b and c directly; a depends on b via p0
    assert grow.all()
    # deletions-only: nothing destabilizes
    assert not dualsim.destabilized_rows(c, set()).any()
    # a label no operator uses: nothing destabilizes
    assert not dualsim.destabilized_rows(c, {999}).any()


def test_destabilized_rows_stops_at_independent_component():
    # two disconnected BGP components; inserting into one must not reseed
    # the other (its constraint cone never reaches a touched operator)
    q = sparql.parse("{ ?a p0 ?b } AND { ?c p1 ?d }")
    g = synth.random_graph(n_nodes=10, n_labels=2, n_edges=30, seed=1)
    s = soi.build_soi(q)
    c = soi.compile_soi(s, g)
    grow = dualsim.destabilized_rows(c, {g.label_id("p1")})
    touched = {i for i in range(c.n_vars) if grow[i]}
    p0_rows = {
        int(x)
        for lhs, rhs, m in zip(c.ineq_lhs, c.ineq_rhs, c.ineq_mat)
        if c.mats[m][0] == g.label_id("p0")
        for x in (lhs, rhs)
    }
    assert touched and not (touched & p0_rows)


# --------------------------------------------------------------------- #
# the delta log (GraphDB + GraphDelta)
# --------------------------------------------------------------------- #
def test_delta_log_records_and_composes():
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    v0 = db.version
    g0 = db.graph
    row = g0.triples[3]
    t = (g0.node_names[row[0]], g0.label_names[row[1]], g0.node_names[row[2]])
    db.delete([t])
    d1 = db.delta_since(v0)
    assert d1.shape_stable and not d1.has_insertions and d1.n_changes == 1
    assert d1.touched_labels() == {int(row[1])}
    db.insert([t])
    # delete-then-reinsert composes to a no-op delta
    d2 = db.delta_since(v0)
    assert d2.n_changes == 0 and d2.shape_stable
    # a dictionary-growing insert is not shape-stable
    db.insert([("NewNode!", "subOrganizationOf", "Univ0")])
    d3 = db.delta_since(v0)
    assert not d3.shape_stable
    # unknown / pre-log versions report as truncated
    assert db.delta_since(-1) is None
    assert db.delta_since(db.version) is None  # nothing to compose


def test_delta_log_truncates():
    from repro.db import graphdb as gdb_mod

    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    g0 = db.graph
    names, labels = g0.node_names, g0.label_names
    v0 = db.version
    limit = gdb_mod.DELTA_LOG_LIMIT
    row = g0.triples[0]
    t = (names[row[0]], labels[row[1]], names[row[2]])
    for i in range(limit + 2):
        # alternate delete/insert of one triple: every call is effective
        assert (db.delete if i % 2 == 0 else db.insert)([t]) == 1
    assert db.delta_since(v0) is None  # fell off the bounded log
    assert db.delta_since(db.version - 2) is not None


# --------------------------------------------------------------------- #
# engine classification: resumable vs cold (tentpole acceptance)
# --------------------------------------------------------------------- #
def _direct_mask(q, g, engine="dense"):
    mask = np.zeros(g.n_edges, dtype=bool)
    for part in sparql.union_split(q):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, _ = dualsim.solve_compiled(c, g, engine=engine)
        m, _ = pruning.prune_triples(s, chi, g)
        mask |= m
    return mask


@pytest.mark.parametrize("engine", ALL_BATCHED)
def test_shape_stable_mutation_resumes_through_serving(engine):
    from repro.engine import canonicalize

    db = GraphDB(synth.lubm_like(n_universities=2, seed=0), engine=engine)
    q = MEMBERS_OF.format(uni="Univ0")
    db.query(q)
    plan, _ = db._engine.plan_for(canonicalize(sparql.parse(q)), bucket=1)
    traces0 = plan.metrics.traces
    g = db.graph
    row = g.triples[int(np.flatnonzero(
        g.triples[:, 1] == g.label_id("memberOf"))[0])]
    t = (g.node_names[row[0]], g.label_names[row[1]], g.node_names[row[2]])

    assert db.delete([t]) == 1
    r1 = db.query(q)
    m1 = db.metrics()
    assert m1.plans_resumable >= 1 and m1.plans_resumed >= 1
    assert m1.warm_resume_solves >= 1
    assert m1.cache.invalidations == 0  # nothing went cold
    assert np.array_equal(r1.survivor_mask, _direct_mask(sparql.parse(q),
                                                         db.graph))
    assert db.insert([t]) == 1
    r2 = db.query(q)
    m2 = db.metrics()
    assert m2.plans_resumed >= 2
    assert np.array_equal(r2.survivor_mask, _direct_mask(sparql.parse(q),
                                                         db.graph))
    # the patched plan kept its operand shapes, so BOTH resumes re-ran the
    # existing trace — the jitted fixpoint was never retraced.  For the
    # packed-chi engines this covers the ISSUE 5 acceptance: a packed
    # _chi_memo entry resumed as a packed warm start causes zero retraces
    # (the uint32 [V, nw] warm aval matches the cold init_packed aval).
    assert plan.metrics.traces == traces0
    assert plan.metrics.patches == 2 and plan.metrics.warm_resumes == 2
    if engine in ("packed_fused", "jacobi_packed", "partitioned"):
        memo = list(plan._chi_memo.items())
        assert memo and all(v.dtype == np.uint32 for _, v in memo)


def test_dictionary_change_is_cold_never_resumed():
    """Regression (ISSUE 4 satellite): a mutation that grows the dictionary
    (new node or label) must be classified cold — the superseded plan is
    never patched or warm-started."""
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    q = MEMBERS_OF.format(uni="Univ0")
    db.query(q)
    db.insert([("NodeFromTheFuture", "memberOf", "Univ0")])  # new node
    r = db.query(q)
    m = db.metrics()
    assert m.plans_resumable == 0 and m.plans_resumed == 0
    assert m.warm_resume_solves == 0
    assert not r.cache_hit
    assert np.array_equal(r.survivor_mask, _direct_mask(sparql.parse(q),
                                                        db.graph))
    # new *label* is equally cold
    db.query(q)
    db.insert([("Univ0", "labelFromTheFuture", "Univ1")])
    db.query(q)
    m = db.metrics()
    assert m.plans_resumed == 0 and m.warm_resume_solves == 0


def test_incremental_false_disables_resumption():
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0),
                 incremental=False)
    q = MEMBERS_OF.format(uni="Univ0")
    db.query(q)
    g = db.graph
    row = g.triples[0]
    t = (g.node_names[row[0]], g.label_names[row[1]], g.node_names[row[2]])
    db.delete([t])
    r = db.query(q)
    m = db.metrics()
    assert m.plans_resumable == 0 and m.plans_resumed == 0
    assert np.array_equal(r.survivor_mask, _direct_mask(sparql.parse(q),
                                                        db.graph))


def test_resumed_plans_survive_multiple_versions():
    # plan goes stale at v1, graph moves on to v3 before the template is
    # queried again: the staged deltas compose and one patch catches up
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    q = MEMBERS_OF.format(uni="Univ0")
    db.query(q)
    g = db.graph
    rows = [g.triples[i] for i in (0, 4, 9)]
    ts = [(g.node_names[s], g.label_names[p], g.node_names[o])
          for s, p, o in rows]
    for t in ts:  # three separate version bumps, no queries in between
        assert db.delete([t]) == 1
    r = db.query(q)
    m = db.metrics()
    assert m.plans_resumed >= 1
    assert np.array_equal(r.survivor_mask, _direct_mask(sparql.parse(q),
                                                        db.graph))


def test_adjacency_kept_when_labels_untouched():
    """ISSUE 4 small fix: a delta that touches only label X must not drop
    adjacency built for label-Y-only plans — the entries re-key to the new
    snapshot and the saved rebuilds are counted."""
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    qa = MEMBERS_OF.format(uni="Univ0")  # subOrganizationOf + memberOf
    qb = "{ ?p publicationAuthor ?s }"  # disjoint label set
    db.query(qa)
    db.query(qb)
    g = db.graph
    row = g.triples[int(np.flatnonzero(
        g.triples[:, 1] == g.label_id("memberOf"))[0])]
    t = (g.node_names[row[0]], g.label_names[row[1]], g.node_names[row[2]])
    db.delete([t])
    rb = db.query(qb)  # untouched template: adjacency upload is saved
    m = db.metrics()
    assert m.adj_rebuilds_saved >= 1
    assert m.adj_invalidations == 0
    assert np.array_equal(rb.survivor_mask, _direct_mask(sparql.parse(qb),
                                                         db.graph))


def test_session_stream_resumes_across_mutation():
    db = GraphDB(synth.lubm_like(n_universities=2, seed=0))
    reqs = [MEMBERS_OF.format(uni=f"Univ{i % 2}") for i in range(4)]
    with db.session(max_delay_ms=1e6, max_pending=8) as s:
        for f in [s.submit(r) for r in reqs]:
            f.result()
    g = db.graph
    row = g.triples[2]
    t = (g.node_names[row[0]], g.label_names[row[1]], g.node_names[row[2]])
    db.delete([t])
    with db.session(max_delay_ms=1e6, max_pending=8) as s:
        futs = [s.submit(r) for r in reqs]
        results = [f.result() for f in futs]
    m = db.metrics()
    assert m.plans_resumed >= 1
    for rq, rs in zip(reqs, results):
        assert np.array_equal(rs.survivor_mask,
                              _direct_mask(sparql.parse(rq), db.graph)), rq


# --------------------------------------------------------------------- #
# cost model: the resume-vs-cold decision
# --------------------------------------------------------------------- #
def test_resume_decision_small_delta_resumes_large_goes_cold():
    g = synth.random_graph(n_nodes=200, n_labels=3, n_edges=2000, seed=0)
    c = soi.compile_soi(soi.build_soi(
        sparql.parse("{ ?a p0 ?b . ?b p1 ?c }")), g)
    small = resume_decision(g, c, engine="sparse", delta_edges=5,
                            last_sweeps=6)
    assert small.resume and small.est_resume < small.est_cold
    big = resume_decision(g, c, engine="sparse",
                          delta_edges=g.n_edges // 2, last_sweeps=6)
    assert not big.resume
    assert "cold" in big.reason and "resume" in small.reason


def test_graph_delta_compose_cancellation():
    mk = lambda ins, dele: GraphDelta(
        inserted=np.asarray(ins, np.int32).reshape(-1, 3),
        deleted=np.asarray(dele, np.int32).reshape(-1, 3),
        nodes_before=5, nodes_after=5, labels_before=2, labels_after=2,
    )
    a = mk([[0, 0, 1]], [])
    b = mk([], [[0, 0, 1], [2, 1, 3]])
    ab = a.compose(b)
    assert len(ab.inserted) == 0  # insert cancelled by the later delete
    assert [list(r) for r in ab.deleted] == [[2, 1, 3]]
    ba = b.compose(mk([[2, 1, 3]], []))
    assert len(ba.deleted) == 1  # only the uncancelled delete remains
    assert ba.touched_labels() == {0}
