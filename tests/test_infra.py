"""Checkpointing, fault tolerance, optimizer, compression, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import pipeline
from repro.distributed import fault
from repro.optimizer import adamw, compress


# --------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------- #
def _tree(rng):
    return {
        "a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
        "nested": {"b": jnp.arange(7), "c": [jnp.ones(2), jnp.zeros(3)]},
        "step": jnp.int32(17),
    }


def test_save_restore_roundtrip(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 5, t)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 1, t)
    # fake a half-written step (no COMMIT)
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.latest_steps(str(tmp_path)) == [1]


def test_corruption_detected(tmp_path, rng):
    t = _tree(rng)
    ckpt.save(str(tmp_path), 3, t)
    target = next((tmp_path / "step_00000003").glob("a.npy"))
    data = target.read_bytes()
    target.write_bytes(data[:-1] + bytes([data[-1] ^ 1]))
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), t)


def test_background_save_and_gc(tmp_path, rng):
    t = _tree(rng)
    threads = [ckpt.save(str(tmp_path), s, t, background=True, keep=2) for s in range(4)]
    for th in threads:
        th.join()
    assert ckpt.latest_steps(str(tmp_path)) == [2, 3]


def test_elastic_restore_resharding(tmp_path, rng):
    """Restore onto explicit (trivial) shardings — the elastic path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    t = _tree(rng)
    ckpt.save(str(tmp_path), 9, t)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ckpt.restore(str(tmp_path), t, shardings=sh)
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(t["a"])
    )


# --------------------------------------------------------------------- #
# fault tolerance
# --------------------------------------------------------------------- #
def test_straggler_monitor_flags_slow_host():
    mon = fault.StragglerMonitor(window=4, threshold=2.0)
    t = 0.0
    for step in range(6):
        for host, lat in [("h0", 1.0), ("h1", 1.0), ("slow", 5.0)]:
            mon.report(fault.Heartbeat(host, step, t + step * lat))
    assert mon.stragglers() == ["slow"]
    assert mon.dead(now=1e9, timeout=10) == ["h0", "h1", "slow"]


def test_restart_policy_retries_then_succeeds():
    calls = []

    def body(i):
        calls.append(i)
        if i < 2:
            raise RuntimeError("node lost")

    pol = fault.RestartPolicy(max_restarts=5, backoff_s=0)
    restarts = pol.run(body, sleep=lambda s: None)
    assert restarts == 2 and calls == [0, 1, 2]


def test_restart_policy_budget_exhausted():
    pol = fault.RestartPolicy(max_restarts=1, backoff_s=0)
    with pytest.raises(RuntimeError):
        pol.run(lambda i: (_ for _ in ()).throw(RuntimeError("x")),
                sleep=lambda s: None)


# --------------------------------------------------------------------- #
# optimizer + compression
# --------------------------------------------------------------------- #
def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0, clip_norm=100.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.3


def test_grad_clip_metric():
    cfg = adamw.AdamWConfig(clip_norm=1e-3)
    params = {"x": jnp.ones(4)}
    state = adamw.init(params)
    _, _, m = adamw.update(cfg, {"x": jnp.full(4, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


def test_topk_error_feedback_conserves_signal():
    grads = {"g": jnp.asarray(np.random.default_rng(0).normal(size=256).astype(np.float32))}
    err = compress.init_error(grads)
    kept, new_err = compress.topk_sparsify(grads, err, fraction=0.1)
    # kept + residual == grad + old error
    np.testing.assert_allclose(
        np.asarray(kept["g"] + new_err["g"]), np.asarray(grads["g"]), rtol=1e-6
    )
    nz = int((np.asarray(kept["g"]) != 0).sum())
    assert 0 < nz <= 26 + 5  # ~top 10% (ties tolerated)


def test_int8_quant_roundtrip_bounded():
    g = {"g": jnp.linspace(-4, 4, 101)}
    q, s = compress.quantize_int8(g)
    back = compress.dequantize_int8(q, s)
    assert float(jnp.abs(back["g"] - g["g"]).max()) <= float(s["g"]) * 0.51


# --------------------------------------------------------------------- #
# data pipeline determinism
# --------------------------------------------------------------------- #
def test_pipeline_deterministic_replay():
    corpus = pipeline.synthetic_corpus(vocab=50, n_tokens=5000, seed=1)
    mk = lambda start: pipeline.token_batches(
        corpus, batch=8, seq=16, seed=7,
        shard=pipeline.ShardSpec(0, 2), start_step=start,
    )
    it = mk(0)
    b0, b1, b2 = next(it), next(it), next(it)
    # replay from step 2 reproduces batch 2 exactly
    it2 = mk(2)
    b2r = next(it2)
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])


def test_pipeline_host_shards_disjoint():
    corpus = pipeline.synthetic_corpus(vocab=50, n_tokens=50_000, seed=1)
    g0 = next(pipeline.token_batches(
        corpus, batch=8, seq=16, seed=3, shard=pipeline.ShardSpec(0, 2)))
    g1 = next(pipeline.token_batches(
        corpus, batch=8, seq=16, seed=3, shard=pipeline.ShardSpec(1, 2)))
    assert g0["tokens"].shape == (4, 16)
    assert not np.array_equal(g0["tokens"], g1["tokens"])


# --------------------------------------------------------------------- #
# streaming RDF ingest (ISSUE 8)
# --------------------------------------------------------------------- #
def test_rdf_load_stream_equals_load(tmp_path):
    """Chunked streaming ingest produces the identical dictionary-encoded
    graph as the tuple-list path, across chunk boundaries."""
    from repro.data import rdf, synth

    path = str(tmp_path / "lubm.nt")
    n = rdf.dump_stream(synth.lubm_stream(n_universities=2, seed=5), path)
    assert n > 0
    g_list = rdf.load(path)
    for chunk in (1, 7, 1 << 20):  # smaller, misaligned, larger than file
        g_stream = rdf.load_stream(path, chunk_triples=chunk)
        assert g_stream.n_nodes == g_list.n_nodes
        assert g_stream.n_labels == g_list.n_labels
        assert g_stream.node_names == g_list.node_names
        assert g_stream.label_names == g_list.label_names
        np.testing.assert_array_equal(g_stream.triples, g_list.triples)


def test_lubm_stream_matches_lubm_shape():
    """The streaming generator keeps LUBM's label mix and scaling law
    (~same node/edge count per university as lubm_like)."""
    from repro.core.graph import Graph
    from repro.data import synth

    g = Graph.from_triples(synth.lubm_stream(n_universities=3, seed=0))
    ref = synth.lubm_like(n_universities=3, seed=0)
    assert set(g.label_names) == set(ref.label_names)
    assert abs(g.n_nodes - ref.n_nodes) / ref.n_nodes < 0.05
    assert abs(g.n_edges - ref.n_edges) / ref.n_edges < 0.05
