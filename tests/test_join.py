"""Join-evaluator semantics (the Tables 4/5 'database system' stand-in)."""
from repro.core import join, sparql
from repro.core.graph import Graph


def _g():
    return Graph.from_triples([
        ("a1", "knows", "b1"),
        ("a2", "knows", "b2"),
        ("b1", "likes", "c1"),
    ])


def test_bgp_join():
    q = sparql.parse("{ ?x knows ?y . ?y likes ?z }")
    m = join.evaluate(q, _g())
    assert m.n_rows == 1
    g = _g()
    assert g.node_names[m.cols["x"][0]] == "a1"


def test_optional_left_outer():
    q = sparql.parse("{ ?x knows ?y } OPTIONAL { ?y likes ?z }")
    m = join.evaluate(q, _g())
    assert m.n_rows == 2
    z = m.cols["z"]
    assert (z == -1).sum() == 1  # a2/b2 row has no likes


def test_union_concat():
    q = sparql.parse("{ ?x knows ?y } UNION { ?x likes ?y }")
    m = join.evaluate(q, _g())
    assert m.n_rows == 3


def test_and_compatibility():
    q = sparql.parse("{ ?x knows ?y } AND { ?x knows ?y }")
    m = join.evaluate(q, _g())
    assert m.n_rows == 2


def test_null_compatible_join():
    """Non-well-designed: unbound optional var joined downstream."""
    q = sparql.parse(
        "{ { ?x knows ?y } OPTIONAL { ?y likes ?z } } AND { ?z2 likes ?z }"
    )
    m = join.evaluate(q, _g())
    # row 1: z bound to c1 joins; row 2: z unbound (-1) is compatible
    assert m.n_rows == 2


def test_constants_filter():
    q = sparql.parse("{ ?x knows b2 }")
    m = join.evaluate(q, _g())
    g = _g()
    assert m.n_rows == 1 and g.node_names[m.cols["x"][0]] == "a2"


def test_missing_label_empty():
    q = sparql.parse("{ ?x owns ?y }")
    m = join.evaluate(q, _g())
    assert m.n_rows == 0


def test_required_triples_counts_existing_only():
    q = sparql.parse("{ ?x knows ?y . ?y likes ?z }")
    g = _g()
    m = join.evaluate(q, g)
    assert join.required_triples(q, g, m) == 2
