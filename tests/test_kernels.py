"""Pallas kernel sweeps: shapes x densities vs the pure-jnp oracle, in
interpret mode (CPU executes the kernel body)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops
from repro.kernels.bitmm import kernel as kmod
from repro.kernels.bitmm import ops as kops
from repro.kernels.bitmm import ref as kref


@pytest.mark.parametrize("n", [1, 31, 32, 33, 100, 257, 300])
@pytest.mark.parametrize("v", [1, 5, 9])
def test_bitmm_shape_sweep(n, v):
    rng = np.random.default_rng(n * 100 + v)
    a = rng.random((n, n)) < 0.1
    x = rng.random((v, n)) < 0.4
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    out = kops.bitmm(jnp.asarray(x), ap, interpret=True)
    exp = kref.bitmm_ref(jnp.asarray(x), ap, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("density", [0.0, 0.01, 0.5, 1.0])
def test_bitmm_density_sweep(density):
    rng = np.random.default_rng(17)
    n = 130
    a = rng.random((n, n)) < density
    x = rng.random((4, n)) < 0.5
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    out = kops.bitmm(jnp.asarray(x), ap, interpret=True)
    exp = kref.bitmm_ref(jnp.asarray(x), ap, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("blocks", [(64, 128), (128, 256), (256, 128)])
def test_bitmm_block_shapes(blocks):
    bi, bjw = blocks
    rng = np.random.default_rng(3)
    n = 520
    a = rng.random((n, n)) < 0.05
    x = rng.random((3, n)) < 0.3
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    out = kmod.bitmm_packed(
        jnp.asarray(x, jnp.uint32), ap, block_i=bi, block_jw=bjw, interpret=True
    )
    exp = kref.bitmm_packed_ref(jnp.asarray(x), ap, n)
    np.testing.assert_array_equal(np.asarray(out)[:, : exp.shape[1]], np.asarray(exp))


def test_bitmm_packed_frontier_variant():
    rng = np.random.default_rng(5)
    n = 200
    a = rng.random((n, n)) < 0.1
    x = rng.random((2, n)) < 0.4
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    xp = jnp.asarray(bitops.pack(jnp.asarray(x)))
    out = kops.bitmm_packed(xp, ap, interpret=True)
    exp = kref.bitmm_packed_ref(jnp.asarray(x), ap, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_bitmm_empty_frontier():
    n = 64
    a = np.eye(n, dtype=bool)
    x = np.zeros((2, n), dtype=bool)
    ap = jnp.asarray(bitops.pack(jnp.asarray(a)))
    out = kops.bitmm(jnp.asarray(x), ap, interpret=True)
    assert not np.asarray(out).any()


# --------------------------------------------------------------------- #
# bitmm_apply: the fused packed sweep step (ISSUE 5)
# --------------------------------------------------------------------- #
def _fused_case(seed, n, v, density=0.1):
    rng = np.random.default_rng(seed)
    a = rng.random((n, n)) < density
    chi = rng.random((v, n)) < 0.6
    flags = (rng.random((v, v)) < 0.5).astype(np.uint32)
    return (
        jnp.asarray(bitops.pack_np(chi)),
        jnp.asarray(bitops.pack_np(a)),
        jnp.asarray(flags),
        chi, a, flags,
    )


def _fused_truth(chi, a, flags):
    v, n = chi.shape
    y = np.zeros((v, n), bool)
    for q in range(v):
        if chi[q].any():
            y[q] = a[chi[q]].any(axis=0)
    new = chi.copy()
    for l in range(v):
        for r in range(v):
            if flags[l, r]:
                new[l] &= y[r]
    return new


@pytest.mark.parametrize("n", [1, 31, 32, 33, 100, 257, 300, 520])
@pytest.mark.parametrize("v", [1, 5, 9])
def test_bitmm_apply_shape_sweep(n, v):
    cp, ap, fj, chi, a, flags = _fused_case(n * 100 + v, n, v)
    out_k, ch_k = kmod.bitmm_apply_packed(cp, ap, fj, interpret=True)
    out_r, ch_r = kref.bitmm_apply_ref(cp, ap, fj, n)
    out_w, ch_w = kref.bitmm_apply_words(cp, ap, fj)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_w))
    truth = _fused_truth(chi, a, flags)
    np.testing.assert_array_equal(
        bitops.unpack_np(np.asarray(out_k), n), truth
    )
    # the changed flag agrees across kernel / oracle / word lowering, and
    # with the boolean ground truth
    moved = bool((truth != chi).any())
    assert (int(ch_k) != 0) == (int(ch_r) != 0) == (int(ch_w) != 0) == moved
    # trailing pad bits of the last word never turn on
    if n % 32:
        mask = np.uint32(0xFFFFFFFF) << np.uint32(n % 32)
        assert not (np.asarray(out_k)[:, -1] & mask).any()


@pytest.mark.parametrize("blocks", [(64, 128), (256, 128), (128, 64)])
def test_bitmm_apply_block_shapes(blocks):
    bi, bjw = blocks
    cp, ap, fj, chi, a, flags = _fused_case(9, 520, 3, density=0.05)
    out, _ = kmod.bitmm_apply_packed(
        cp, ap, fj, block_i=bi, block_jw=bjw, interpret=True
    )
    exp, _ = kref.bitmm_apply_ref(cp, ap, fj, 520)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_bitmm_apply_fixpoint_changed_goes_quiet():
    """Iterating the fused step must report changed=0 exactly when chi
    stops moving — the packed while_loop's termination signal."""
    cp, ap, fj, *_ = _fused_case(2, 130, 4, density=0.2)
    for _ in range(20):
        new, ch = kops.bitmm_apply(cp, ap, fj, interpret=True)
        if not int(ch):
            assert np.array_equal(np.asarray(new), np.asarray(cp))
            break
        assert not np.array_equal(np.asarray(new), np.asarray(cp))
        cp = new
    else:
        raise AssertionError("fused step never converged")


def test_bitmm_apply_no_flags_is_identity():
    """An operator with no inequalities leaves chi and changed untouched."""
    cp, ap, _, chi, *_ = _fused_case(7, 100, 3)
    fz = jnp.zeros((3, 3), jnp.uint32)
    out, ch = kops.bitmm_apply(cp, ap, fz, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(cp))
    assert int(ch) == 0


# --------------------------------------------------------------------- #
# segsum kernel (windowed one-hot-matmul segment sum)
# --------------------------------------------------------------------- #
from repro.kernels.segsum import ops as sops
from repro.kernels.segsum import ref as sref


@pytest.mark.parametrize("e,n,d", [(100, 64, 8), (1000, 300, 16),
                                   (37, 513, 3), (5000, 100, 70)])
def test_segsum_shape_sweep(e, n, d):
    rng = np.random.default_rng(e + n + d)
    vals = rng.normal(size=(e, d)).astype(np.float32)
    ids = rng.integers(0, n, e).astype(np.int32)
    out = sops.segsum(vals, ids, n, interpret=True)
    exp = sref.segsum_ref(jnp.asarray(vals[np.argsort(ids, kind='stable')]),
                          jnp.asarray(np.sort(ids)), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_segsum_dtypes(dtype):
    rng = np.random.default_rng(9)
    vals = rng.normal(size=(200, 5)).astype(dtype)
    ids = rng.integers(0, 40, 200).astype(np.int32)
    out = sops.segsum(vals, ids, 40, interpret=True)
    exp = sops.segsum(vals, ids, 40, use_ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5)


def test_segsum_empty_and_single_segment():
    out = sops.segsum(np.zeros((0, 4), np.float32), np.zeros(0, np.int32), 8,
                      interpret=True)
    assert out.shape == (8, 4) and not np.asarray(out).any()
    vals = np.ones((16, 4), np.float32)
    out = sops.segsum(vals, np.zeros(16, np.int32), 1, interpret=True)
    np.testing.assert_allclose(np.asarray(out), [[16.0] * 4])


def test_segsum_block_boundary_ids():
    """ids exactly at window boundaries exercise the block-split path."""
    n, bn = 600, 256
    ids = np.asarray([0, 255, 256, 257, 511, 512, 599] * 10, np.int32)
    vals = np.ones((len(ids), 2), np.float32)
    out = sops.segsum(vals, ids, n, block_n=bn, interpret=True)
    exp = sops.segsum(vals, ids, n, use_ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp))


# --------------------------------------------------------------------- #
# segor: segmented OR with bit-packed output (ISSUE 8)
# --------------------------------------------------------------------- #
def _segor_case(seed, v, e, n, density=0.4):
    rng = np.random.default_rng(seed)
    bits = (rng.random((v, e)) < density).astype(np.int8)
    ids = rng.integers(0, n, e).astype(np.int32) if e else np.zeros(0, np.int32)
    return bits, ids


def _segor_truth(bits, ids, n):
    v = bits.shape[0]
    y = np.zeros((v, n), bool)
    for e, s in enumerate(ids):
        y[:, s] |= bits[:, e].astype(bool)
    return y


@pytest.mark.parametrize("n", [1, 31, 32, 33, 100, 257, 300, 1000])
@pytest.mark.parametrize("v", [1, 5, 9])
def test_segor_shape_sweep(n, v):
    bits, ids = _segor_case(n * 100 + v, v, 4 * n, n)
    truth = _segor_truth(bits, ids, n)
    out_k = sops.segor(bits, ids, n, interpret=True)
    out_w = sops.segor(bits, ids, n, impl="words")
    out_r = sops.segor(bits, ids, n, impl="ref")
    np.testing.assert_array_equal(
        bitops.unpack_np(np.asarray(out_k), n), truth
    )
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_w))
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    # trailing pad bits of the last word never turn on (RL2)
    if n % 32:
        mask = np.uint32(0xFFFFFFFF) << np.uint32(n % 32)
        assert not (np.asarray(out_k)[:, -1] & mask).any()
        assert not (np.asarray(out_w)[:, -1] & mask).any()


@pytest.mark.parametrize("impl", ["kernel", "words", "ref"])
def test_segor_empty_edges(impl):
    """Zero edges: every lowering returns the all-zero word plane."""
    bits = np.zeros((3, 0), np.int8)
    out = sops.segor(bits, np.zeros(0, np.int32), 70, impl=impl,
                     interpret=True)
    assert out.shape == (3, 3) and not np.asarray(out).any()


def test_segor_duplicate_destinations():
    """Many edges into one destination OR together (segment semantics)."""
    n, e = 40, 200
    bits = np.ones((2, e), np.int8)
    ids = np.full(e, 7, np.int32)
    out = sops.segor(bits, ids, n, interpret=True)
    truth = np.zeros((2, n), bool)
    truth[:, 7] = True
    np.testing.assert_array_equal(bitops.unpack_np(np.asarray(out), n), truth)


def test_segor_block_boundary_ids():
    """Destination ids at window boundaries exercise the block-split and
    first-visit-init paths of the blocked kernel."""
    n, bn = 600, 256
    ids = np.asarray([0, 255, 256, 257, 511, 512, 599] * 10, np.int32)
    bits = (np.arange(2 * len(ids)).reshape(2, -1) % 3 == 0).astype(np.int8)
    out = sops.segor(bits, ids, n, block_n=bn, interpret=True)
    exp = sops.segor(bits, ids, n, impl="ref")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_segor_small_block_e():
    """block_e smaller than the edge count forces multi-block windows whose
    partial ORs accumulate into the same output row."""
    bits, ids = _segor_case(11, 4, 900, 50, density=0.2)
    out = sops.segor(bits, ids, 50, block_e=64, block_n=32, interpret=True)
    exp = sops.segor(bits, ids, 50, impl="ref")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_prepare_segor_rejects_out_of_range_ids():
    """prepare_segor consumes RAW edges only: a seg id >= num_segments
    (e.g. an EDGE_PAD sentinel) would alias a live bit after packing."""
    from repro.kernels.segsum import kernel as skern

    with pytest.raises(ValueError, match="seg_ids"):
        skern.prepare_segor(np.asarray([0, 5, 8], np.int32), 8)
