"""GNN family: reduced smoke per arch x shape regime, sampler invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, sampler

ARCHS = ["gatedgcn", "gat", "pna", "schnet"]


def _batch(rng, n=40, e=160, f=8, n_graphs=1, task="node_class", n_out=3):
    b = {
        "feat": jnp.asarray(rng.normal(size=(n, f)).astype(np.float32)),
        "edges": jnp.asarray(
            np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], 1).astype(np.int32)
        ),
        "edge_mask": jnp.ones(e, bool),
        "node_graph": jnp.asarray((np.arange(n) % n_graphs).astype(np.int32)),
        "positions": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    }
    if task == "graph_reg":
        b["labels"] = jnp.asarray(rng.normal(size=n_graphs).astype(np.float32))
        b["n_graphs"] = n_graphs
    else:
        b["labels"] = jnp.asarray(rng.integers(0, n_out, n).astype(np.int32))
    return b


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("task", ["node_class", "graph_reg"])
def test_smoke_forward_loss_grad(arch, task, rng):
    cfg = gnn.GNNConfig(
        name=arch, arch=arch, n_layers=2, d_hidden=16, d_in=8, n_out=3,
        n_heads=4, task=task, n_rbf=16, cutoff=5.0,
    )
    b = _batch(rng, n_graphs=4 if task == "graph_reg" else 1, task=task)
    if arch == "schnet" and task == "graph_reg":
        b["feat"] = jnp.asarray(rng.integers(1, 10, 40).astype(np.int32))
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    loss = gnn.loss_fn(cfg, p, b)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda pp: gnn.loss_fn(cfg, pp, b))(p)
    flat = jax.tree.leaves(jax.tree.map(lambda x: jnp.abs(x).sum(), g))
    assert np.isfinite(sum(float(x) for x in flat))


def test_edge_mask_zeroes_padded_edges(rng):
    """A padded (masked) edge must not change the output."""
    cfg = gnn.GNNConfig(name="g", arch="gatedgcn", n_layers=2, d_hidden=8,
                        d_in=4, n_out=2)
    b = _batch(rng, n=10, e=20, f=4)
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out1 = gnn.forward(cfg, p, b)
    # append a junk edge with mask=False
    b2 = dict(b)
    b2["edges"] = jnp.concatenate([b["edges"], jnp.asarray([[0, 5]], jnp.int32)])
    b2["edge_mask"] = jnp.concatenate([b["edge_mask"], jnp.asarray([False])])
    out2 = gnn.forward(cfg, p, b2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_gat_attention_normalizes(rng):
    """Per-destination attention weights sum to 1 over real edges."""
    logits = jnp.asarray(rng.normal(size=(12, 2)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 4, 12).astype(np.int32))
    alpha = gnn.seg_softmax(logits, idx, 4)
    sums = jax.ops.segment_sum(alpha, idx, num_segments=4)
    nonempty = np.isin(np.arange(4), np.asarray(idx))
    np.testing.assert_allclose(np.asarray(sums)[nonempty], 1.0, atol=1e-5)
    assert not np.asarray(sums)[~nonempty].any()  # empty segments stay zero


def test_sampler_invariants(rng):
    n, e = 300, 2500
    edges = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], 1)
    sm = sampler.NeighborSampler(n, edges, seed=1)
    seeds = rng.choice(n, 32, replace=False)
    blk = sm.sample(seeds, (5, 3))
    nmax, emax = sampler.block_sizes(32, (5, 3))
    assert blk.node_ids.shape == (nmax,) and blk.edges.shape == (emax, 2)
    n_real = int(blk.node_mask.sum())
    # seeds come first and map to themselves
    assert np.array_equal(blk.node_ids[:32], seeds)
    # all real edges reference real local nodes
    er = blk.edges[blk.edge_mask]
    assert er.max(initial=0) < n_real
    # every sampled edge exists in the original graph
    gset = {(int(s), int(d)) for s, d in edges}
    for ls, ld in er:
        gs, gd = int(blk.node_ids[ls]), int(blk.node_ids[ld])
        assert (gs, gd) in gset


def test_sampler_fanout_bounds(rng):
    n = 100
    edges = np.stack([rng.integers(0, n, 5000), rng.integers(0, n, 5000)], 1)
    sm = sampler.NeighborSampler(n, edges, seed=0)
    blk = sm.sample(np.arange(8), (4,))
    # each seed has at most 4 in-edges sampled
    dst = blk.edges[blk.edge_mask][:, 1]
    counts = np.bincount(dst, minlength=8)
    assert (counts[:8] <= 4).all()
