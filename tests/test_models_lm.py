"""LM family: reduced-config smoke + decode/prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T


def _tiny(moe=False, window=None, qk_norm=False):
    return T.LMConfig(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=101, head_dim=16, qk_norm=qk_norm,
        sliding_window=window,
        moe=T.MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=4.0)
        if moe else None,
        dtype=jnp.float32, remat=False,
    )


@pytest.mark.parametrize("moe", [False, True])
@pytest.mark.parametrize("qk_norm", [False, True])
def test_forward_and_loss_finite(moe, qk_norm):
    cfg = _tiny(moe=moe, qk_norm=qk_norm)
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    hidden, aux = T.forward(cfg, p, toks)
    assert hidden.shape == (2, 24, cfg.d_model)
    loss = T.loss_fn(cfg, p, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(loss))


def test_chunked_ce_matches_direct():
    cfg = _tiny()
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    hidden, _ = T.forward(cfg, p, toks)
    chunked = T.chunked_ce(cfg, p, hidden, toks, chunk=8)
    logits = T.logits_of(cfg, p, hidden).astype(jnp.float32)
    lp = jax.nn.log_softmax(logits, axis=-1)
    direct = -jnp.take_along_axis(lp, toks[..., None], axis=-1).mean()
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_prefill_decode_matches_forward(window):
    """Decode with a prefilled cache must reproduce teacher-forced logits."""
    cfg = _tiny(window=window)
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, s + 1), 0, cfg.vocab)
    # teacher-forced logits at position s (predicting s+1)
    hidden, _ = T.forward(cfg, p, toks)
    full_logits = T.logits_of(cfg, p, hidden)[:, s - 1 + 1]
    # hmm: decode path below predicts from token s given cache of 0..s-1
    _, cache = T.prefill_step(cfg, p, toks[:, :s])
    dec_logits, cache2 = T.decode_step(cfg, p, cache, toks[:, s : s + 1])
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits), atol=2e-4
    )
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


def test_swa_cache_is_window_sized():
    cfg = _tiny(window=8)
    cache = T.init_kv_cache(cfg, batch=2, seq=1000)
    assert cache["k"].shape[2] == 8


def test_rope_positions_shift_invariance():
    """RoPE scores depend only on relative positions."""
    cfg = _tiny()
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 16))
    pos = jnp.arange(4)[None, :]
    q1 = T.rope(q, pos, cfg.rope_theta)
    k1 = T.rope(k, pos, cfg.rope_theta)
    q2 = T.rope(q, pos + 100, cfg.rope_theta)
    k2 = T.rope(k, pos + 100, cfg.rope_theta)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
    s2 = jnp.einsum("bqhd,bkhd->bhqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_moe_capacity_drop_is_bounded():
    """With capacity_factor=1.0 overflow tokens are dropped, never NaN."""
    cfg = dataclasses.replace(
        _tiny(moe=True),
        moe=T.MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=1.0),
    )
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    loss = T.loss_fn(cfg, p, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(loss))


def test_train_step_decreases_loss():
    from repro.models import steps
    from repro.optimizer import adamw

    cfg = _tiny()
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=1, weight_decay=0.0)
    ost = adamw.init(p)
    step = jax.jit(steps.make_train_step(
        lambda pp, bb: T.loss_fn(cfg, pp, bb), opt_cfg, microbatches=2
    ))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(8):
        p, ost, m = step(p, ost, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
