"""DCN-v2 / EmbeddingBag / retrieval correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import recsys


def _cfg():
    return recsys.RecsysConfig(
        name="tiny", n_dense=4, n_sparse=3, embed_dim=8, n_cross=2,
        mlp=(32, 16), vocab_sizes=(97, 31, 53),
    )


def _batch(rng, cfg, b=16):
    return {
        "dense": jnp.asarray(rng.normal(size=(b, cfg.n_dense)).astype(np.float32)),
        "sparse": jnp.asarray(
            (rng.random((b, cfg.n_sparse)) * np.asarray(cfg.vocab_sizes)).astype(np.int32)
        ),
        "labels": jnp.asarray(rng.integers(0, 2, b).astype(np.float32)),
    }


def test_forward_shapes_and_loss(rng):
    cfg = _cfg()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(rng, cfg)
    logit = recsys.forward(cfg, p, b)
    assert logit.shape == (16,)
    loss = recsys.loss_fn(cfg, p, b)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda pp: recsys.loss_fn(cfg, pp, b))(p)
    assert np.isfinite(float(jnp.abs(g["table"]).sum()))


def test_embedding_bag_sum_and_mean(rng):
    cfg = _cfg()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    vals = jnp.asarray([3, 7, 1, 1, 9, 2], jnp.int32)
    segs = jnp.asarray([0, 0, 0, 1, 2, 2], jnp.int32)
    t = p["table"]
    out_sum = recsys.embedding_bag(t, vals, segs, 3, mode="sum")
    out_mean = recsys.embedding_bag(t, vals, segs, 3, mode="mean")
    exp0 = t[3] + t[7] + t[1]
    np.testing.assert_allclose(np.asarray(out_sum[0]), np.asarray(exp0), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out_mean[0]), np.asarray(exp0 / 3), rtol=1e-6
    )
    # empty bag -> zeros
    out3 = recsys.embedding_bag(t, vals, segs, 4)
    assert not np.asarray(out3[3]).any()


def test_multi_hot_path_equals_single_hot(rng):
    """bag with nnz=1 per (row, feature) == plain take path."""
    cfg = _cfg()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(rng, cfg, b=6)
    offs = jnp.asarray(cfg.offsets())
    idx = (b["sparse"] + offs[None, :]).reshape(-1)
    b2 = dict(b)
    b2["bag_values"] = idx
    b2["bag_segments"] = jnp.arange(6 * cfg.n_sparse, dtype=jnp.int32)
    out1 = recsys.forward(cfg, p, b)
    out2 = recsys.forward(cfg, p, b2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5)


def test_cross_layer_identity_at_zero_weights():
    """x_{l+1} = x0 * (0 + 0) + x_l = x_l when W=b=0."""
    cfg = _cfg()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    p2 = dict(p)
    p2["cross"] = [
        {"w": jnp.zeros_like(c["w"]), "b": jnp.zeros_like(c["b"])}
        for c in p["cross"]
    ]
    rng = np.random.default_rng(0)
    b = _batch(rng, cfg, b=4)
    # trunk with zero cross == trunk with no cross
    out = recsys.forward(cfg, p2, b)
    assert np.isfinite(np.asarray(out)).all()


def test_retrieval_topk_matches_numpy(rng):
    cfg = _cfg()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(rng, cfg, b=2)
    b["candidates"] = jnp.asarray(rng.normal(size=(500, cfg.mlp[-1])).astype(np.float32))
    scores, top = recsys.retrieval_score(cfg, p, b)
    s = np.asarray(scores)
    exp = np.argsort(-s, axis=1)[:, :100]
    got = np.asarray(top)
    # same score values (ties may permute indices)
    np.testing.assert_allclose(
        np.take_along_axis(s, got, 1), np.take_along_axis(s, exp, 1), rtol=1e-6
    )
