"""The perf-regression gate on fabricated trajectories (ISSUE 9).

The gate's contract, exercised without running any bench: an injected
regression past a metric's tolerance fails with a per-metric diagnostic;
run-to-run jitter inside the band passes; a record from an unseen machine
fingerprint bootstraps its own series instead of failing against another
machine's history; floors hold regardless of history; and the trajectory
writer is atomic and append-only.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.perfgate import (  # noqa: E402
    BASELINE_WINDOW,
    ENGINE_METRICS,
    MetricPolicy,
    check_history,
    series_key,
)
from tools.perfgate.__main__ import check as gate_check  # noqa: E402
from tools.perfgate.__main__ import main as gate_main  # noqa: E402
from tools.perfgate.history import append_record, load_history  # noqa: E402


def rec(**kw) -> dict:
    base = {"engine": "auto", "tiny": True, "n_devices": 1,
            "machine": "runner-a"}
    base.update(kw)
    return base


def _by_status(findings, status):
    return [f for f in findings if f.status == status]


# --------------------------------------------------------------------- #
# gate semantics
# --------------------------------------------------------------------- #
def test_injected_regression_fails_with_per_metric_diagnostic():
    hist = [rec(fused_sweeps_per_s=1000.0),
            rec(fused_sweeps_per_s=1050.0),
            rec(fused_sweeps_per_s=500.0)]  # 2x drop vs best
    findings = check_history(hist, ENGINE_METRICS)
    bad = [f for f in findings if f.failed]
    assert len(bad) == 1
    f = bad[0]
    assert f.status == "regression" and f.metric == "fused_sweeps_per_s"
    assert "fused_sweeps_per_s" in f.message and "0.48x" in f.message
    assert f.baseline == 1050.0 and f.current == 500.0


def test_jitter_within_tolerance_passes():
    hist = [rec(fused_sweeps_per_s=v, req_per_s_best=10 * v)
            for v in (1000.0, 950.0, 1020.0, 940.0)]
    findings = check_history(hist, ENGINE_METRICS)
    assert not [f for f in findings if f.failed]
    assert all(f.status in ("ok", "bootstrap") for f in findings)


def test_unseen_machine_bootstraps_instead_of_cross_comparing():
    hist = [rec(fused_sweeps_per_s=100_000.0),  # a fast machine's history
            rec(fused_sweeps_per_s=99_000.0),
            rec(fused_sweeps_per_s=900.0, machine="fresh-ci-runner")]
    findings = check_history(hist, ENGINE_METRICS)
    assert not [f for f in findings if f.failed]
    boot = _by_status(findings, "bootstrap")
    assert len(boot) == 1 and boot[0].current == 900.0
    assert "bootstrapped" in boot[0].message


def test_series_split_on_any_field_not_just_machine():
    # same machine, different n_devices: independent trajectories
    hist = [rec(fused_sweeps_per_s=1000.0),
            rec(fused_sweeps_per_s=120.0, n_devices=8)]
    assert series_key(hist[0]) != series_key(hist[1])
    findings = check_history(hist, ENGINE_METRICS)
    assert not [f for f in findings if f.failed]


def test_absolute_floor_fails_even_with_consistent_history():
    # warm_speedup floor is 5.0: a stable-but-sunk series is still a failure
    hist = [rec(warm_speedup=3.0), rec(warm_speedup=3.1),
            rec(warm_speedup=3.0)]
    findings = check_history(hist, ENGINE_METRICS)
    bad = [f for f in findings if f.failed]
    assert len(bad) == 1 and bad[0].status == "floor_violation"
    assert bad[0].metric == "warm_speedup" and "floor" in bad[0].message


def test_baseline_is_best_of_recent_window():
    # a slow leak: each run regresses 20% — the windowed best must still
    # catch the cumulative drop once old peaks age out of the window
    pol = (MetricPolicy("fused_sweeps_per_s", 0.35),)
    values = [1000.0 * (0.8 ** i) for i in range(BASELINE_WINDOW + 2)]
    findings = check_history([rec(fused_sweeps_per_s=v) for v in values], pol)
    f = findings[-1]
    assert f.status == "regression"
    assert f.baseline == pytest.approx(values[-(BASELINE_WINDOW + 1)])


def test_chaos_floors_gate_goodput_and_rebuild():
    # ISSUE 10: the chaos-soak acceptance criteria are absolute floors —
    # they fail even on a bootstrap record with no history behind it
    from tools.perfgate import CHAOS_METRICS

    ok = [rec(goodput_retained=0.9, rebuilt=1.0, bit_identical=1.0)]
    assert not [f for f in check_history(ok, CHAOS_METRICS) if f.failed]
    bad = [rec(goodput_retained=0.5, rebuilt=0.0, bit_identical=1.0)]
    failed = [f.metric for f in check_history(bad, CHAOS_METRICS) if f.failed]
    assert "goodput_retained" in failed and "rebuilt" in failed
    assert "bit_identical" not in failed


def test_null_metrics_and_missing_fields_are_skipped():
    hist = [rec(fused_sweeps_per_s=None, warm_speedup=None),
            rec()]  # no gated metric at all
    assert check_history(hist, ENGINE_METRICS) == []


def test_global_tolerance_override():
    hist = [rec(fused_sweeps_per_s=1000.0), rec(fused_sweeps_per_s=800.0)]
    assert not [f for f in check_history(hist, ENGINE_METRICS) if f.failed]
    tight = check_history(hist, ENGINE_METRICS, tolerance=0.1)
    assert [f for f in tight if f.failed]


# --------------------------------------------------------------------- #
# CLI exit statuses
# --------------------------------------------------------------------- #
def _write(path, records):
    with open(path, "w") as f:
        json.dump(records, f)
    return str(path)


def test_cli_exit_codes(tmp_path, capsys):
    good = _write(tmp_path / "good.json",
                  [rec(fused_sweeps_per_s=1000.0),
                   rec(fused_sweeps_per_s=980.0)])
    bad = _write(tmp_path / "bad.json",
                 [rec(fused_sweeps_per_s=1000.0),
                  rec(fused_sweeps_per_s=400.0)])
    missing = str(tmp_path / "missing.json")
    assert gate_check(good, missing, missing) == 0
    assert gate_check(bad, missing, missing) == 1
    out = capsys.readouterr().out
    assert "perfgate/FAIL" in out and "fused_sweeps_per_s" in out
    # argparse front end, default --check mode
    assert gate_main(["--engine-history", good,
                      "--serve-history", missing,
                      "--chaos-history", missing]) == 0
    assert gate_main(["--check", "--engine-history", bad,
                      "--serve-history", missing,
                      "--chaos-history", missing]) == 1
    # a gate with nothing to gate is a misconfiguration, not a pass
    assert gate_check(missing, missing, missing) == 1


def test_cli_tolerance_override_and_json(tmp_path, capsys):
    hist = _write(tmp_path / "h.json",
                  [rec(fused_sweeps_per_s=1000.0),
                   rec(fused_sweeps_per_s=800.0)])
    missing = str(tmp_path / "missing.json")
    assert gate_main(["--engine-history", hist, "--serve-history", missing,
                      "--chaos-history", missing,
                      "--tolerance", "0.1", "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert any(f["failed"] for f in payload)


# --------------------------------------------------------------------- #
# trajectory writer: atomic + append-only
# --------------------------------------------------------------------- #
def test_append_record_preserves_existing_history(tmp_path):
    path = tmp_path / "BENCH.json"
    _write(path, [rec(fused_sweeps_per_s=1.0)])
    out = append_record(str(path), rec(fused_sweeps_per_s=2.0))
    assert [r["fused_sweeps_per_s"] for r in out] == [1.0, 2.0]
    assert load_history(str(path)) == out
    # no temp-file litter from the atomic replace
    assert os.listdir(tmp_path) == ["BENCH.json"]


def test_append_record_creates_fresh_history(tmp_path):
    path = str(tmp_path / "new" / "BENCH.json")
    append_record(path, rec(fused_sweeps_per_s=3.0))
    assert len(load_history(path)) == 1


def test_load_history_tolerates_corrupt_file(tmp_path):
    path = tmp_path / "BENCH.json"
    path.write_text("{ not json")
    assert load_history(str(path)) == []
    # a scalar (non-list) payload wraps instead of crashing
    _write(path, {"engine": "auto"})
    assert load_history(str(path)) == [{"engine": "auto"}]
