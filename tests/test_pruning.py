"""Pruning invariants (paper Sect. 5 / Tables 3-5): dual-simulation pruning
never changes any query's result set."""
import numpy as np
from tests._hyp import given, settings, st

from repro.core import dualsim, join, pruning, soi, sparql
from repro.data import synth


def _solve_and_prune(q, g):
    mask = np.zeros(g.n_edges, dtype=bool)
    for part in sparql.union_split(q):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, _ = dualsim.solve_compiled(c, g, engine="dense")
        m, _ = pruning.prune_triples(s, chi, g)
        mask |= m
    from repro.core.graph import subgraph_triples

    return subgraph_triples(g, mask)


def _bindings_set(b):
    names = sorted(b.cols)
    return {tuple(b.cols[n][i] for n in names) for i in range(b.n_rows)} , names


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 500))
def test_bgp_results_identical_after_pruning(seed):
    g = synth.dbpedia_like(n_nodes=30, n_labels=4, n_edges=100, seed=seed)
    q = sparql.parse("{ ?a p0 ?b . ?b p1 ?c }")
    full = join.evaluate(q, g)
    pruned_g = _solve_and_prune(q, g)
    pr = join.evaluate(q, pruned_g)
    assert _bindings_set(full) == _bindings_set(pr)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 500))
def test_optional_results_identical_after_pruning(seed):
    g = synth.dbpedia_like(n_nodes=30, n_labels=4, n_edges=100, seed=seed)
    q = sparql.parse("{ ?a p0 ?b } OPTIONAL { ?b p1 ?c }")
    full = join.evaluate(q, g)
    pruned_g = _solve_and_prune(q, g)
    pr = join.evaluate(q, pruned_g)
    assert _bindings_set(full) == _bindings_set(pr)


def test_pruning_stats_lubm():
    g = synth.lubm_like(n_universities=3, seed=0)
    q = synth.lubm_l1_like()
    s = soi.build_soi(q)
    c = soi.compile_soi(s, g)
    chi, _ = dualsim.solve_compiled(c, g, engine="dense")
    _, stats = pruning.prune_triples(s, chi, g)
    assert 0 <= stats.n_after <= stats.n_triples
    assert 0.0 <= stats.fraction_pruned <= 1.0
    # every triple of every match survives
    m = join.evaluate(q, g)
    req = join.required_triples(q, g, m)
    assert req <= stats.n_after
