"""Selftests for the reprolint static-analysis suite (tools/reprolint).

Three families, mirroring the contract in DESIGN.md Sect. 11:

* **fire-on-bad** — each rule RL1-RL5 produces its documented findings on
  the deliberately-dirty fixture in ``tools/reprolint/selftest/``;
* **silent-on-good** — the corrected twin of each fixture produces none;
* **silent-on-frozen-clean** — ``clean_snapshot.py`` (a frozen copy of the
  annotated ``serve/metrics.py``) stays clean, canarying checker false
  positives introduced by later checker edits.

Plus framework-level tests: suppression markers, baseline fingerprints,
directory exclusion, and the CLI's exit-code contract.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from tools.reprolint import Finding, run_paths  # noqa: E402
from tools.reprolint.core import check_file  # noqa: E402

REPO = Path(__file__).resolve().parent.parent
SELFTEST = REPO / "tools" / "reprolint" / "selftest"


def lint(path: Path) -> list[Finding]:
    new, _ = run_paths([path], root=REPO)
    return new


def rule_ids(findings: list[Finding]) -> set[str]:
    return {f.rule_id for f in findings}


# --------------------------------------------------------------------- #
# fire-on-bad
# --------------------------------------------------------------------- #
def test_rl1_fires_on_bad_fixture():
    findings = lint(SELFTEST / "rl1_bad.py")
    assert rule_ids(findings) == {"RL1"}
    messages = " | ".join(f.message for f in findings)
    # every RL1 sub-rule is represented in the fixture
    assert "module-level" in messages          # jnp constant at import time
    assert "unhashable" in messages            # mutable static-arg default
    assert "branches on a traced value" in messages
    assert "host sync" in messages             # int()/np.asarray/.item()/float()
    assert len(findings) == 7


def test_rl2_fires_on_bad_fixture():
    findings = lint(SELFTEST / "rl2_bad.py")
    assert rule_ids(findings) == {"RL2"}
    messages = " | ".join(f.message for f in findings)
    assert "complement" in messages            # raw ~chi without ones_mask
    assert "reduction" in messages             # jnp.sum on packed words
    assert "OR with all-ones" in messages
    assert len(findings) == 4


def test_rl3_fires_on_bad_fixture():
    findings = lint(SELFTEST / "rl3_bad.py")
    assert rule_ids(findings) == {"RL3"}
    messages = " | ".join(f.message for f in findings)
    assert "accessed outside" in messages      # guarded field, no lock held
    assert "lock-order inversion" in messages
    assert "await while holding" in messages
    assert len(findings) == 3


def test_rl4_fires_on_bad_fixture():
    findings = lint(SELFTEST / "rl4_bad.py")
    assert rule_ids(findings) == {"RL4"}
    messages = " | ".join(f.message for f in findings)
    assert "unresolved at return" in messages
    assert "resolved twice" in messages
    assert "loop iteration end" in messages
    assert len(findings) == 3


def test_rl5_fires_on_bad_fixture():
    findings = lint(SELFTEST / "rl5_bad.py")
    assert rule_ids(findings) == {"RL5"}
    messages = " | ".join(f.message for f in findings)
    assert "bare `except:`" in messages
    assert "silently swallows" in messages
    assert "create_task" in messages
    # 1 bare + 3 broad swallows + 2 dropped task handles
    assert len(findings) == 6
    assert sum("create_task" in f.message for f in findings) == 2


# --------------------------------------------------------------------- #
# silent-on-good
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("rule", ["rl1", "rl2", "rl3", "rl4", "rl5"])
def test_good_fixture_is_silent(rule):
    assert lint(SELFTEST / f"{rule}_good.py") == []


def test_frozen_clean_snapshot_stays_clean():
    # clean_snapshot.py is a frozen copy of the annotated serve/metrics.py;
    # a finding here means a checker edit introduced a false positive.
    assert lint(SELFTEST / "clean_snapshot.py") == []


# --------------------------------------------------------------------- #
# framework behavior
# --------------------------------------------------------------------- #
def test_selftest_dir_excluded_from_directory_scans():
    # scanning the tools/ *directory* must skip the deliberately-dirty
    # fixtures (they are reachable only as direct file arguments)
    new, old = run_paths([REPO / "tools"], root=REPO)
    assert new == [] and old == []


def test_line_suppression_and_escape_hatch(tmp_path):
    dirty = (
        "import jax\n"
        "import jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return int(x)\n"
    )
    f = tmp_path / "dirty.py"
    f.write_text(dirty)
    assert rule_ids(lint(f)) == {"RL1"}

    f.write_text(dirty.replace(
        "return int(x)", "return int(x)  # trace-ok: concretized by caller"
    ))
    assert lint(f) == []

    f.write_text(dirty.replace(
        "return int(x)", "return int(x)  # reprolint: disable=RL1"
    ))
    assert lint(f) == []


def test_block_suppression_on_def_header(tmp_path):
    f = tmp_path / "dirty.py"
    f.write_text(
        "import jax\n"
        "@jax.jit\n"
        "def f(x):  # reprolint: disable=RL1\n"
        "    if x:\n"
        "        return int(x)\n"
        "    return x\n"
    )
    assert lint(f) == []


def test_baseline_moves_findings_out_of_new():
    bad = SELFTEST / "rl4_bad.py"
    lines = bad.read_text().splitlines()
    fresh = lint(bad)
    assert fresh
    fingerprints = {f.fingerprint(lines[f.line - 1]) for f in fresh}
    new, old = run_paths([bad], root=REPO, baseline=fingerprints)
    assert new == []
    assert len(old) == len(fresh)


def test_fingerprint_ignores_line_number():
    a = Finding("x.py", 10, "RL1", "msg")
    b = Finding("x.py", 99, "RL1", "msg")
    assert a.fingerprint("  foo()") == b.fingerprint("foo()")
    assert a.fingerprint("foo()") != a.fingerprint("bar()")


def test_syntax_error_reported_not_crashed(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def f(:\n")
    findings = check_file(f, root=tmp_path)
    assert [f.rule_id for f in findings] == ["RL0"]


# --------------------------------------------------------------------- #
# CLI contract
# --------------------------------------------------------------------- #
def _cli(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *args],
        capture_output=True, text=True, cwd=REPO,
    )


def test_cli_exit_zero_on_merged_tree():
    # the acceptance gate: the merged tree is clean with an empty baseline
    proc = _cli("src", "tests", "benchmarks", "tools")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_exit_one_and_json_artifact_on_findings(tmp_path):
    artifact = tmp_path / "findings.json"
    proc = _cli(str(SELFTEST / "rl2_bad.py"), "--json", str(artifact))
    assert proc.returncode == 1
    assert "RL2" in proc.stdout
    data = json.loads(artifact.read_text())
    assert data["baselined"] == []
    assert {f["rule_id"] for f in data["new"]} == {"RL2"}
    assert all({"file", "line", "rule_id", "message"} <= set(f) for f in data["new"])


def test_cli_rules_filter():
    proc = _cli(str(SELFTEST / "rl1_bad.py"), "--rules", "RL3,RL4")
    assert proc.returncode == 0, proc.stdout + proc.stderr
