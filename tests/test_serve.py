"""`repro.serve` subsystem: deficit-round-robin fairness, latency
histograms, admission control (queue bound / cost cap / deadlines),
replica routing across mutation epochs, streaming delivery — plus the
PR-6 concurrency satellites: per-request flush isolation in Session,
thread-consistent Engine.stats() snapshots, the real background flush
timer, and the batching invariant under concurrent sessions.
"""
import asyncio
import math
import threading
import time

import pytest

from repro.data import synth
from repro.db import GraphDB
from repro.serve import (
    AsyncServer,
    DeficitRoundRobin,
    LatencyHistogram,
    ReplicaRouter,
    ServeMetrics,
    stream_pages,
)

MEMBERS_OF = "{{ ?d subOrganizationOf {uni} . ?s memberOf ?d }}"


@pytest.fixture()
def db():
    return GraphDB(synth.lubm_like(n_universities=2, seed=0))


def _prepared(db, text):
    return db._engine.prepare(db._coerce(text))


# --------------------------------------------------------------------- #
# fairness: deficit round robin
# --------------------------------------------------------------------- #
def test_drr_fifo_within_tenant():
    drr = DeficitRoundRobin(quantum=8.0)
    for i in range(5):
        drr.enqueue("a", i)
    assert len(drr) == 5
    taken = drr.take(5)
    assert [item for _, item in taken] == [0, 1, 2, 3, 4]
    assert len(drr) == 0


def test_drr_storm_cannot_starve_trickle():
    # alice storms 20 requests ahead of bob's 2; one take(8) round with
    # quantum 4 must still carry both of bob's — the head-of-line
    # guarantee admission control alone cannot give
    drr = DeficitRoundRobin(quantum=4.0)
    for i in range(20):
        drr.enqueue("alice", f"a{i}")
    for i in range(2):
        drr.enqueue("bob", f"b{i}")
    batch = drr.take(8)
    by_tenant = {}
    for tenant, item in batch:
        by_tenant.setdefault(tenant, []).append(item)
    assert by_tenant["bob"] == ["b0", "b1"]
    assert len(by_tenant["alice"]) == 6  # alice keeps the leftover budget


def test_drr_weights_converge_to_ratio():
    # weight 3:1 with quantum 1 dequeues exactly 3 a's per b while both
    # stay backlogged
    drr = DeficitRoundRobin(quantum=1.0, weights={"a": 3.0, "b": 1.0})
    for i in range(30):
        drr.enqueue("a", i)
        drr.enqueue("b", i)
    counts = {"a": 0, "b": 0}
    for _ in range(8):
        for tenant, _item in drr.take(4):
            counts[tenant] += 1
    assert counts == {"a": 24, "b": 8}


def test_drr_idle_tenant_banks_nothing():
    drr = DeficitRoundRobin(quantum=4.0)
    drr.enqueue("a", "x")
    assert drr.take(4) == [("a", "x")]
    # emptied mid-round: deficit resets, so a later burst gets no credit
    assert drr._deficit["a"] == 0.0
    assert drr.tenants == ()


def test_drr_drain_returns_everything():
    drr = DeficitRoundRobin(quantum=2.0)
    for i in range(7):
        drr.enqueue("a" if i % 2 else "b", i)
    out = drr.drain()
    assert sorted(item for _, item in out) == list(range(7))
    assert len(drr) == 0 and drr.take(4) == []


def test_drr_rejects_nonpositive_quantum():
    with pytest.raises(ValueError):
        DeficitRoundRobin(quantum=0.0)


# --------------------------------------------------------------------- #
# metrics: histogram + consistent snapshot
# --------------------------------------------------------------------- #
def test_latency_histogram_quantiles_bound_truth():
    h = LatencyHistogram()
    samples = [i * 1e-3 for i in range(1, 101)]  # 1..100 ms
    for s in samples:
        h.add(s)
    assert h.n == 100
    assert h.mean == pytest.approx(sum(samples) / 100)
    # geometric buckets: the quantile is an upper edge within +50% of truth
    for q, truth in [(0.50, 0.050), (0.99, 0.099)]:
        est = h.quantile(q)
        assert truth <= est <= truth * 1.5


def test_latency_histogram_empty_and_overflow():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0 and h.mean == 0.0
    h.add(1e9)  # beyond the last edge: overflow bucket, inf quantile
    assert h.quantile(0.99) == float("inf")
    assert h.summary()["n"] == 1


def test_serve_metrics_snapshot_accounting():
    m = ServeMetrics()
    for _ in range(4):
        m.on_submit("a")
    m.on_shed("a", "overloaded")
    m.on_shed("a", "deadline")
    m.on_admit(depth=2)
    m.on_admit(depth=1)
    m.on_complete("a", queue_s=0.001, total_s=0.002)
    m.on_complete("a", queue_s=0.001, total_s=0.002)
    snap = m.snapshot()
    assert snap.submitted == 4 and snap.admitted == 2
    assert snap.shed == {"overloaded": 1, "cost": 0, "deadline": 1}
    assert snap.shed_total == 2 and snap.shed_rate == 0.5
    assert snap.completed == 2 and snap.queue_peak == 2
    assert snap.per_tenant["a"]["completed"] == 2
    assert snap.latency["n"] == 2


# --------------------------------------------------------------------- #
# router: least-in-flight routing + epoch fencing
# --------------------------------------------------------------------- #
def test_router_routes_least_in_flight(db):
    router = ReplicaRouter(db, n_replicas=2)
    r1, r2 = router.route(), router.route()
    assert r1 is not r2  # second batch overlaps on the other replica
    router.release(r1)
    assert router.route() is r1  # back to the now-idle one
    with pytest.raises(ValueError):
        ReplicaRouter(db, n_replicas=0)


def test_router_isolates_poisoned_request(db):
    router = ReplicaRouter(db, n_replicas=1)
    good = _prepared(db, MEMBERS_OF.format(uni="Univ0"))
    expected = len(db.query(MEMBERS_OF.format(uni="Univ0")))
    boom = RuntimeError("poisoned")
    engine = router.replicas[0].engine
    orig = engine.execute_prepared

    def failing(batch):
        if len(batch) > 1:
            raise RuntimeError("batched execution failed")
        if batch[0] is poison:
            raise boom
        return orig(batch)

    poison = _prepared(db, MEMBERS_OF.format(uni="Univ1"))
    engine.execute_prepared = failing
    outcomes, name = router.execute_isolated([good, poison, good])
    assert name == "r0"
    assert len(outcomes[0]) == expected and len(outcomes[2]) == expected
    assert outcomes[1] is boom


def test_router_fence_advances_every_replica(db):
    router = ReplicaRouter(db, n_replicas=3)
    router.execute_isolated([_prepared(db, MEMBERS_OF.format(uni="Univ0"))])
    db.insert([("DeptX", "subOrganizationOf", "Univ0")])
    fenced = router.fence()
    assert fenced == db.version
    assert router.versions() == [db.version] * 3


# --------------------------------------------------------------------- #
# server: admission control
# --------------------------------------------------------------------- #
def test_server_ok_path_matches_direct_query(db):
    queries = [MEMBERS_OF.format(uni=f"Univ{i % 2}") for i in range(6)]
    truths = [frozenset(db.query(q).survivor_triples()) for q in queries]

    async def go():
        async with AsyncServer(db, replicas=1, max_queue=32,
                               max_delay_ms=1.0) as server:
            futs = [server.submit(q, tenant=f"t{i % 2}")
                    for i, q in enumerate(queries)]
            return await asyncio.gather(*futs)

    results = asyncio.run(go())
    assert all(r.ok for r in results)
    for r, truth in zip(results, truths):
        assert frozenset(r.result.survivor_triples()) == truth
        assert r.total_ms >= r.queue_ms >= 0.0
        assert r.replica == "r0"


def test_server_metrics_drain_invariant(db):
    async def go():
        async with AsyncServer(db, replicas=1, max_delay_ms=1.0) as server:
            futs = [server.submit(MEMBERS_OF.format(uni="Univ0"))
                    for _ in range(5)]
            futs.append(server.submit("not sparql at all }}{{"))
            futs.append(server.submit(MEMBERS_OF.format(uni="Univ1"),
                                      deadline_ms=0.0))
            await asyncio.gather(*futs)
            return server.metrics.snapshot()

    snap = asyncio.run(go())
    # every submitted request reaches exactly one terminal outcome
    assert snap.submitted == snap.completed + snap.shed_total + snap.errors
    assert snap.completed == 5 and snap.errors == 1
    assert snap.shed == {"overloaded": 0, "cost": 0, "deadline": 1}
    assert snap.queue_depth == 0


def test_server_sheds_overloaded_beyond_queue_bound(db):
    async def go():
        # max_queue=1 and a long flush timer: the first request is
        # admitted and parked, the burst behind it must shed immediately
        async with AsyncServer(db, replicas=1, max_queue=1, max_batch=8,
                               max_delay_ms=500.0) as server:
            futs = [server.submit(MEMBERS_OF.format(uni="Univ0"))
                    for _ in range(4)]
            shed_now = [f.done() for f in futs]
            results = await asyncio.gather(*futs)
            return shed_now, results

    shed_now, results = asyncio.run(go())
    assert [r.outcome for r in results] == ["ok"] + ["overloaded"] * 3
    # the backpressure contract: a shed is a fast no, resolved at submit
    assert shed_now == [False, True, True, True]
    assert "queue full" in results[1].detail


def test_server_cost_cap_rejects_expensive_queries(db):
    async def go():
        async with AsyncServer(db, replicas=1, max_delay_ms=1.0,
                               cost_cap=1e-9) as server:
            capped = await server.submit(MEMBERS_OF.format(uni="Univ0"))
        async with AsyncServer(db, replicas=1, max_delay_ms=1.0,
                               cost_cap=1e18) as server:
            roomy = await server.submit(MEMBERS_OF.format(uni="Univ0"))
        return capped, roomy

    capped, roomy = asyncio.run(go())
    assert capped.outcome == "cost" and "cap" in capped.detail
    assert roomy.ok


def test_server_deadline_sheds_at_admission_and_in_queue(db):
    async def go():
        async with AsyncServer(db, replicas=1, max_batch=8,
                               max_delay_ms=120.0) as server:
            at_admission = await server.submit(
                MEMBERS_OF.format(uni="Univ0"), deadline_ms=0.0)
            # admitted, but the flush timer (120ms) outlives the 1ms
            # deadline: shed at dispatch, never executed
            in_queue = await server.submit(
                MEMBERS_OF.format(uni="Univ0"), deadline_ms=1.0)
            return at_admission, in_queue

    at_admission, in_queue = asyncio.run(go())
    assert at_admission.outcome == "deadline"
    assert at_admission.detail == "expired at admission"
    assert in_queue.outcome == "deadline"
    assert in_queue.detail == "deadline exceeded in queue"
    assert in_queue.queue_ms > 0.0 and in_queue.result is None


def test_server_parse_error_resolves_own_future(db):
    async def go():
        async with AsyncServer(db, replicas=1, max_delay_ms=1.0) as server:
            bad = server.submit("{{ ?x noclosingbrace")
            good = server.submit(MEMBERS_OF.format(uni="Univ0"))
            return await asyncio.gather(bad, good)

    bad, good = asyncio.run(go())
    assert bad.outcome == "error" and isinstance(bad.error, Exception)
    assert good.ok


def test_server_tenant_fairness_end_to_end(db):
    async def go():
        async with AsyncServer(db, replicas=1, max_queue=64, max_batch=4,
                               max_delay_ms=1.0) as server:
            futs = [server.submit(MEMBERS_OF.format(uni=f"Univ{i % 2}"),
                                  tenant="alice") for i in range(16)]
            futs += [server.submit(MEMBERS_OF.format(uni="Univ0"),
                                   tenant="bob") for _ in range(2)]
            results = await asyncio.gather(*futs)
            return results, server.metrics.snapshot()

    results, snap = asyncio.run(go())
    assert all(r.ok for r in results)
    assert snap.per_tenant["bob"]["completed"] == 2
    assert snap.per_tenant["alice"]["completed"] == 16


# --------------------------------------------------------------------- #
# server: replica consistency across a mutation epoch
# --------------------------------------------------------------------- #
def test_server_no_torn_reads_across_mutation_epoch(db):
    q = MEMBERS_OF.format(uni="Univ0")
    truth0 = frozenset(db.query(q).survivor_triples())
    delta = [("DeptNew", "subOrganizationOf", "Univ0"),
             ("StudentNew", "memberOf", "DeptNew")]

    async def go():
        async with AsyncServer(db, replicas=2, max_delay_ms=1.0) as server:
            wave0 = await asyncio.gather(
                *[server.submit(q) for _ in range(4)])
            db.insert(delta)  # a multi-triple delta: torn reads would show
            mid = await asyncio.gather(
                *[server.submit(q) for _ in range(4)])
            fenced = await server.fence()
            wave1 = await asyncio.gather(
                *[server.submit(q) for _ in range(4)])
            return wave0, mid, fenced, wave1

    wave0, mid, fenced, wave1 = asyncio.run(go())
    truth1 = frozenset(db.query(q).survivor_triples())
    assert truth0 != truth1
    for r in wave0:
        assert frozenset(r.result.survivor_triples()) == truth0
    for r in mid:
        # either epoch is legal before the fence — but always *exactly*
        # one of them: no reader ever observes a half-applied delta
        assert frozenset(r.result.survivor_triples()) in (truth0, truth1)
    assert fenced == db.version
    for r in wave1:
        # after the fence every replica serves the new epoch
        assert frozenset(r.result.survivor_triples()) == truth1


def test_stream_pages_covers_result_exactly(db):
    rs = db.query(MEMBERS_OF.format(uni="Univ0"))
    whole = rs.page(0, len(rs))
    assert len(whole) == len(rs) > 10

    async def go():
        pages = []
        async for page in stream_pages(rs, page_size=7):
            pages.append(page)
        return pages

    pages = asyncio.run(go())
    assert all(len(p) <= 7 for p in pages)
    assert [t for p in pages for t in p] == whole


# --------------------------------------------------------------------- #
# satellite: Session flush isolation (regression)
# --------------------------------------------------------------------- #
def test_session_flush_isolates_poisoned_request(db, monkeypatch):
    orig = db._execute_prepared

    def failing(batch):
        # fail the batched path whenever the poison rides along, and the
        # per-request retry only for the poison itself
        if any(inst is not None and "PoisonU" in inst.constants
               for _, inst in batch):
            raise RuntimeError("poisoned request")
        return orig(batch)

    monkeypatch.setattr(db, "_execute_prepared", failing)
    with db.session(max_delay_ms=10_000, max_pending=16) as session:
        good0 = session.submit(MEMBERS_OF.format(uni="Univ0"))
        bad = session.submit(MEMBERS_OF.format(uni="PoisonU"))
        good1 = session.submit(MEMBERS_OF.format(uni="Univ1"))
        assert session.flush() == 3
        # regression: the poisoned request used to leave ALL three
        # futures unresolved; now every sibling resolves with its result
        assert good0.done() and bad.done() and good1.done()
        assert len(good0.result()) == len(db.query(
            MEMBERS_OF.format(uni="Univ0")))
        assert len(good1.result()) == len(db.query(
            MEMBERS_OF.format(uni="Univ1")))
        with pytest.raises(RuntimeError, match="poisoned request"):
            bad.result()


# --------------------------------------------------------------------- #
# satellite: Engine.stats() consistency under a multithreaded hammer
# --------------------------------------------------------------------- #
def test_engine_stats_consistent_under_threads(db):
    db.query(MEMBERS_OF.format(uni="Univ0"))  # warm the traces first
    stop = threading.Event()
    errors = []

    def hammer(k):
        i = 0
        try:
            while not stop.is_set():
                db.query(MEMBERS_OF.format(uni=f"Univ{(i + k) % 2}"))
                i += 1
        except Exception as exc:  # pragma: no cover - the assert reports
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(k,)) for k in range(2)]
    for t in threads:
        t.start()
    try:
        last = -1
        deadline = time.monotonic() + 1.5
        while time.monotonic() < deadline:
            m = db.stats()
            # the snapshot invariant: engine_counts is incremented in the
            # same critical section as microbatches, so no interleaving
            # may ever expose sum(engine_counts) != microbatches
            assert sum(m.engine_counts.values()) == m.microbatches
            assert m.microbatches >= last
            last = m.microbatches
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    assert db.stats().microbatches > 1  # the hammer actually ran


# --------------------------------------------------------------------- #
# satellite: concurrent sessions keep the batching invariant
# --------------------------------------------------------------------- #
def test_concurrent_sessions_batching_invariant(db):
    T, N, cap = 3, 8, 4
    db.query(MEMBERS_OF.format(uni="Univ0"))  # warm
    base = db.stats().microbatches
    errors = []

    def worker(t):
        try:
            with db.session(max_delay_ms=60_000, max_pending=cap) as s:
                futs = [s.submit(MEMBERS_OF.format(uni=f"T{t}U{i}"))
                        for i in range(N)]
                for f in futs:
                    f.result()  # unknown constants: empty, never an error
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # each session's bucket cap bounds its solves at ceil(N / cap); the
    # invariant must survive interleaved flushes from concurrent threads
    assert db.stats().microbatches - base <= T * math.ceil(N / cap)


# --------------------------------------------------------------------- #
# satellite: the background flusher makes max_delay_ms a real timer
# --------------------------------------------------------------------- #
def test_background_flusher_fires_without_further_calls(db):
    db.query(MEMBERS_OF.format(uni="Univ0"))  # warm: keep the flush cheap
    session = db.session(max_delay_ms=20.0, auto_flush=True)
    try:
        fut = session.submit(MEMBERS_OF.format(uni="Univ1"))
        # no flush(), no result(), no further submit: only the timer can
        # resolve this future
        deadline = time.monotonic() + 5.0
        while not fut.done() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert fut.done()
        assert session.flushes == 1 and session.pending == 0
        assert len(fut.result()) == len(db.query(
            MEMBERS_OF.format(uni="Univ1")))
    finally:
        session.close()


# --------------------------------------------------------------------- #
# satellite (ISSUE 9): spec-calibrated admission pricing is commensurate
# with measured reality
# --------------------------------------------------------------------- #
def test_admission_estimate_calibrated_within_measured_envelope(db):
    """With a MachineSpec the admission envelope is priced in *seconds* —
    so it must land within a bounded ratio of a measured warm solve, unlike
    the hand-tuned arbitrary units (off by ~6 orders of magnitude).  The
    spec uses ceilings of a modest CPU container; the wide 1e-3..1e3 band
    absorbs the machine-to-machine spread while still ruling out any
    unit-confusion regression.
    """
    from repro.core import sparql
    from repro.engine import cost as cost_mod
    from repro.engine.machine import MachineSpec

    spec = MachineSpec(
        backend="cpu", device_kind="cpu", fingerprint="test-cpu-container",
        n_devices=1, stream_bytes_per_s=2e9, dense_elems_per_s=2.6e10,
        packed_words_per_s=1e8, packed_words_per_s_xla=3.4e8,
        fused_words_per_s=3.4e8, kernel_launch_s=4e-4, dispatch_s=3.2e-5,
        trace_s=0.22,
    )
    text = MEMBERS_OF.format(uni="Univ0")
    db.query(text)  # warm: plan cached, jit traced
    measured = min(
        _timed(lambda: db.query(text)) for _ in range(5)
    )
    est = cost_mod.admission_estimate(db.graph, sparql.parse(text), spec=spec)
    assert est > 0.0
    ratio = est / measured
    assert 1e-3 <= ratio <= 1e3, (
        f"calibrated admission {est:.3g}s vs measured {measured:.3g}s "
        f"(ratio {ratio:.3g})"
    )
    # the hand-tuned envelope is NOT commensurate: same formula, arb units
    arb = cost_mod.admission_estimate(db.graph, sparql.parse(text))
    assert arb / measured > 1e3


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
