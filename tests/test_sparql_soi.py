"""SOI construction for the SPARQL fragment S: parser, mand(), optional
renaming (Lemmas 4/5 + the Sect. 4.4 'syntactically closest' rule), and the
soundness theorem (Thm. 2) as a property test against the join evaluator."""
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import dualsim, join, soi, sparql
from repro.core.sparql import And, BGP, Optional_, Union_, format_query, parse
from repro.data import synth

LABELS = ["p0", "p1", "p2"]
VARS = ["a", "b", "c", "d"]


def test_parser_roundtrip_shapes():
    q = parse("{ ?a p0 ?b . ?b p1 ?c } OPTIONAL { ?c p2 ?d }")
    assert isinstance(q, Optional_)
    assert sparql.vars_of(q) == {"a", "b", "c", "d"}
    assert sparql.mand(q) == {"a", "b", "c"}


def test_parser_constants():
    q = parse("{ ?a p0 Berlin }")
    t = q.triples[0]
    assert isinstance(t.o, sparql.Const) and t.o.name == "Berlin"


def test_union_split_distributes():
    q = parse("{ { ?a p0 ?b } UNION { ?a p1 ?b } } AND { ?b p2 ?c }")
    parts = sparql.union_split(q)
    assert len(parts) == 2
    assert all(sparql.is_union_free(p) for p in parts)


def test_optional_renaming_x2():
    q = parse("{ ?d p0 ?m } OPTIONAL { ?d p1 ?c }")
    s = soi.build_soi(q)
    # one surrogate for ?d, linked by exactly one copy inequality
    assert s.base.count("d") == 2
    assert len(s.copy_ineqs) == 1
    lhs, rhs = s.copy_ineqs[0]
    assert s.base[lhs] == "d" and rhs == s.external_mand["d"]


def test_nested_closest_chain():
    """R1 OPT (R2 OPT R3) sharing ?z gives z_R3 <= z_R2 <= z (Sect. 4.4)."""
    q = parse("{ ?z p0 ?x } OPTIONAL { { ?z p1 ?y } OPTIONAL { ?z p2 ?u } }")
    s = soi.build_soi(q)
    z_ids = [i for i, b in enumerate(s.base) if b == "z"]
    assert len(z_ids) == 3
    copies = set(s.copy_ineqs)
    # chain: exactly two copy links among the three z occurrences
    z_copies = [(l, r) for (l, r) in copies if s.base[l] == "z"]
    assert len(z_copies) == 2
    # one of them must point at the mandatory z
    assert any(r == s.external_mand["z"] for _, r in z_copies)


def test_non_well_designed_x3():
    q = parse("{ { ?v1 p0 ?v2 } OPTIONAL { ?v3 p1 ?v2 } } AND { ?v3 p2 ?v4 }")
    s = soi.build_soi(q)
    assert s.base.count("v3") == 2  # optional occurrence renamed apart
    assert len(s.copy_ineqs) == 2  # v2_opt <= v2, v3_opt <= v3


def test_optional_only_vars_not_linked():
    """x in two optional branches, never mandatory: independent surrogates."""
    q = parse("{ { ?a p0 ?b } OPTIONAL { ?x p1 ?a } } OPTIONAL { ?x p2 ?a }")
    s = soi.build_soi(q)
    x_ids = [i for i, b in enumerate(s.base) if b == "x"]
    assert len(x_ids) == 2
    assert not any(s.base[l] == "x" for l, _ in s.copy_ineqs)


# --------------------------------------------------------------------- #
# soundness property (Theorem 2)
# --------------------------------------------------------------------- #
def _queries():
    triple = st.tuples(
        st.sampled_from(VARS), st.sampled_from(LABELS), st.sampled_from(VARS)
    ).map(lambda t: (f"?{t[0]}", t[1], f"?{t[2]}"))
    bgp = st.lists(triple, min_size=1, max_size=3).map(
        lambda ts: synth.bgp_of_triples(*ts)
    )
    return st.recursive(
        bgp,
        lambda children: st.builds(And, children, children)
        | st.builds(Optional_, children, children)
        | st.builds(Union_, children, children),
        max_leaves=4,
    )


@settings(max_examples=40, deadline=None)
@given(_queries(), st.integers(0, 1000))
def test_soundness_every_match_in_largest_solution(q, seed):
    """Thm. 2: for every match mu and var v, (v, mu(v)) is in the largest
    SOI solution — over the union-free parts, whose solutions are unioned."""
    g = synth.dbpedia_like(n_nodes=25, n_labels=3, n_edges=60, seed=seed)
    matches = join.evaluate(q, g)
    collected: dict[str, np.ndarray] = {}
    for part in sparql.union_split(q):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, _ = dualsim.solve_compiled(c, g, engine="dense")
        for var, row in soi.collect(s, chi).items():
            collected[var] = collected.get(var, np.zeros(g.n_nodes, bool)) | row
    for var, col in matches.cols.items():
        for val in np.unique(col):
            if val >= 0:
                assert collected[var][val], (
                    f"match binding {var}={val} missing from S_max"
                )


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_soundness_with_constants(seed):
    g = synth.dbpedia_like(n_nodes=20, n_labels=3, n_edges=50, seed=seed)
    const = g.node_names[seed % g.n_nodes]
    q = sparql.parse(f"{{ ?a p0 {const} . ?a p1 ?b }}")
    matches = join.evaluate(q, g)
    s = soi.build_soi(q)
    c = soi.compile_soi(s, g)
    chi, _ = dualsim.solve_compiled(c, g, engine="dense")
    res = soi.collect(s, chi)
    for var, col in matches.cols.items():
        for val in np.unique(col):
            if val >= 0:
                assert res[var][val]


def test_regression_multi_merge_stale_ids():
    """Regression (found by the soundness property test): when AND merges
    several shared variables, later merge pairs must be translated through
    earlier id compactions, or a surrogate gets merged in place of its
    mandatory original and the copy inequality inverts."""
    q = And(
        synth.bgp_of_triples(("?a", "p0", "?b")),
        Optional_(
            synth.bgp_of_triples(("?b", "p2", "?a")),
            synth.bgp_of_triples(("?a", "p0", "?a")),
        ),
    )
    s = soi.build_soi(q)
    # the surrogate (third 'a' occurrence) must be the copy LHS, never RHS
    for l, r in s.copy_ineqs:
        assert r == s.external_mand["a"]
        assert l != s.external_mand["a"]
    g = synth.dbpedia_like(n_nodes=25, n_labels=3, n_edges=60, seed=0)
    c = soi.compile_soi(s, g)
    chi, _ = dualsim.solve_compiled(c, g, engine="dense")
    res = soi.collect(s, np.asarray(chi))
    m = join.evaluate(q, g)
    for var, col in m.cols.items():
        for val in np.unique(col):
            if val >= 0:
                assert res[var][val], (var, val)


# --------------------------------------------------------------------- #
# parser hardening: empty groups, positions, EOF (ISSUE 2 satellite)
# --------------------------------------------------------------------- #
def test_parse_rejects_empty_group():
    with pytest.raises(SyntaxError, match=r"empty group '\{\}' at line 1"):
        parse("{}")
    # nested empty group too, with the *group's* position
    with pytest.raises(SyntaxError, match=r"empty group '\{\}' at line 2, column 5"):
        parse("{ ?a p0 ?b }\nAND {}")


def test_parse_error_line_and_column():
    with pytest.raises(SyntaxError, match=r"bad token at '!!"):
        parse("SELECT WHERE {\n  ?a p0 ?b .\n  !!\n}")
    try:
        parse("SELECT WHERE {\n  ?a p0 ?b .\n  !!\n}")
    except SyntaxError as e:
        assert "line 3, column 3" in str(e)


def test_parse_unexpected_eof_and_trailing():
    with pytest.raises(SyntaxError, match="unexpected end of query"):
        parse("{ ?a p0 ?b")
    with pytest.raises(SyntaxError, match="trailing tokens"):
        parse("{ ?a p0 ?b } }")
    with pytest.raises(SyntaxError, match="empty query"):
        parse("   ")
    with pytest.raises(SyntaxError, match="expected term"):
        parse("{ ?a p0 }")


# --------------------------------------------------------------------- #
# format_query: inverse of parse (ISSUE 2 builder contract)
# --------------------------------------------------------------------- #
FORMAT_SAMPLES = [
    "{ ?a p0 ?b . ?b p1 ?c }",
    "{ ?a p0 Berlin }",
    "{ ?a p0 ?b } AND { ?b p1 ?c }",
    "{ ?a p0 ?b } OPTIONAL { ?c p2 ?a }",
    "{ { ?a p0 ?b } UNION { ?a p1 ?b } } AND { ?b p2 ?c }",
    "{ ?s p0 ?d } OPTIONAL { { ?d p1 C0 } UNION { ?d p1 C1 } }",
]


@pytest.mark.parametrize("text", FORMAT_SAMPLES)
def test_format_query_roundtrip(text):
    q = parse(text)
    assert parse(format_query(q)) == q
    # idempotent: formatting the reparse formats identically
    assert format_query(parse(format_query(q))) == format_query(q)


def test_format_query_rejects_empty_bgp():
    with pytest.raises(ValueError, match="empty BGP"):
        format_query(BGP(()))
