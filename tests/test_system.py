"""End-to-end behaviour tests for the paper's system: query in, sound
pruned database + identical downstream results out — across engines,
operators, and the serving path."""
import numpy as np
import pytest

from repro.core import dualsim, join, pruning, soi, sparql
from repro.core.graph import subgraph_triples
from repro.data import synth


@pytest.fixture(scope="module")
def lubm():
    return synth.lubm_like(n_universities=4, depts_per_uni=3,
                           profs_per_dept=4, students_per_dept=10, seed=0)


QUERIES = [
    ("l0", synth.lubm_l0_like()),
    ("l1", synth.lubm_l1_like()),
    ("optional", synth.optional_query()),
    ("union", sparql.parse(
        "{ ?s memberOf ?d } UNION { ?s worksFor ?d }")),
    ("const", sparql.parse(
        "{ ?d subOrganizationOf Univ0 . ?s memberOf ?d }")),
]


@pytest.mark.parametrize("name,query", QUERIES)
@pytest.mark.parametrize("engine", ["dense", "sparse", "packed"])
def test_end_to_end_prune_preserves_results(lubm, name, query, engine):
    """The paper's pipeline: SOI -> largest dual simulation -> pruned DB.
    Downstream evaluation on the pruned DB returns exactly the original
    result set (Thm. 2 soundness + pruning completeness)."""
    g = lubm
    mask = np.zeros(g.n_edges, dtype=bool)
    for part in sparql.union_split(query):
        s = soi.build_soi(part)
        c = soi.compile_soi(s, g)
        chi, sweeps = dualsim.solve_compiled(c, g, engine=engine)
        assert sweeps >= 0
        m, stats = pruning.prune_triples(s, chi, g)
        mask |= m
        assert 0 <= stats.n_after <= stats.n_triples
    pruned = subgraph_triples(g, mask)

    full = join.evaluate(query, g)
    pr = join.evaluate(query, pruned)

    def canon(b):
        names = sorted(b.cols)
        return {tuple(b.cols[n][i] for n in names) for i in range(b.n_rows)}

    assert canon(full) == canon(pr), f"{name}/{engine} changed the result set"


def test_engines_agree_end_to_end(lubm):
    for _, query in QUERIES:
        for part in sparql.union_split(query):
            s = soi.build_soi(part)
            c = soi.compile_soi(s, lubm)
            chis = {}
            for eng in ["dense", "sparse", "packed", "worklist"]:
                chi, _ = dualsim.solve_compiled(c, lubm, engine=eng)
                chis[eng] = np.asarray(chi)
            base = chis.pop("dense")
            for eng, chi in chis.items():
                assert np.array_equal(base, chi), eng


def test_batched_serving_matches_individual(lubm):
    """engine/batcher.py's disjoint-union batching == per-query solving."""
    from repro.engine.batcher import batched_soi

    queries = [
        sparql.parse(f"{{ ?d subOrganizationOf Univ{i} . ?s memberOf ?d }}")
        for i in range(3)
    ]
    parts = [soi.build_soi(q) for q in queries]
    union = batched_soi(parts)
    c_union = soi.compile_soi(union, lubm)
    chi_union, _ = dualsim.solve_compiled(c_union, lubm, engine="sparse")
    off = 0
    for part in parts:
        c = soi.compile_soi(part, lubm)
        chi, _ = dualsim.solve_compiled(c, lubm, engine="sparse")
        np.testing.assert_array_equal(
            np.asarray(chi_union[off : off + part.n_vars]), np.asarray(chi)
        )
        off += part.n_vars


def test_pruning_monotone_in_query_strength(lubm):
    """Adding a triple pattern (more constraints) can only shrink S_max."""
    q1 = sparql.parse("{ ?s memberOf ?d }")
    q2 = sparql.parse("{ ?s memberOf ?d . ?d subOrganizationOf ?u }")
    s1, s2 = soi.build_soi(q1), soi.build_soi(q2)
    chi1, _ = dualsim.solve_compiled(soi.compile_soi(s1, lubm), lubm)
    chi2, _ = dualsim.solve_compiled(soi.compile_soi(s2, lubm), lubm)
    r1, r2 = soi.collect(s1, np.asarray(chi1)), soi.collect(s2, np.asarray(chi2))
    for v in ("s", "d"):
        assert not (r2[v] & ~r1[v]).any(), "stronger query grew the solution"
