"""Repo tooling: docs gate (``check_docs``) and static analysis (``reprolint``)."""
