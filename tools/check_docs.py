"""Documentation checks for the CI docs job (ISSUE 4 satellite).

Two passes, both over the repository root this file sits under:

1. **Cross-reference link check** — every markdown link target in README.md
   / DESIGN.md must resolve, every backticked repo path (``src/...``,
   ``tests/...``, ``benchmarks/...``, ...) must exist, and every
   ``DESIGN.md Sect. N[.M]`` citation in README.md must name a section
   heading that actually exists in DESIGN.md.
2. **Docstring coverage** — a local mirror of the ruff pydocstyle subset CI
   runs (``D100,D101,D102,D103,D104,D419``: missing/empty docstrings on
   public modules, classes, methods and functions) over ``src/repro/db``,
   ``src/repro/engine``, ``src/repro/serve``, and ``src/repro/faults``,
   so the gate can run in environments without ruff installed.

    python tools/check_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "DESIGN.md"]
DOCSTRING_DIRS = [
    "src/repro/db", "src/repro/engine", "src/repro/serve",
    "src/repro/faults", "tools/perfgate",
]
PATH_DIRS = ("src/", "tests/", "benchmarks/", "examples/", "results/",
             "tools/", ".github/")

MD_LINK = re.compile(r"\[[^\]]+\]\(([^)#][^)]*)\)")
BACKTICK_PATH = re.compile(r"`([A-Za-z0-9_./\-]+/[A-Za-z0-9_./\-]+)`")
SECT_REF = re.compile(r"DESIGN\.md\s+Sect\.?\s+(\d+(?:\.\d+)?)")


def check_links() -> list[str]:
    """Resolve markdown links, backticked paths, and section citations."""
    errors: list[str] = []
    design = (ROOT / "DESIGN.md").read_text()
    headings = set(
        re.findall(r"^#{2,3}\s+(\d+(?:\.\d+)?)[. ]", design, re.MULTILINE)
    )
    for name in DOC_FILES:
        path = ROOT / name
        if not path.exists():
            errors.append(f"{name}: file missing")
            continue
        text = path.read_text()
        for target in MD_LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (ROOT / target.split("#")[0]).exists():
                errors.append(f"{name}: broken link -> {target}")
        for target in BACKTICK_PATH.findall(text):
            bare = target.rstrip("/")
            if bare.startswith(PATH_DIRS) and not (ROOT / bare).exists():
                errors.append(f"{name}: backticked path missing -> {target}")
        for sect in SECT_REF.findall(text):
            if sect not in headings and sect.split(".")[0] not in headings:
                errors.append(
                    f"{name}: cites DESIGN.md Sect. {sect}, "
                    "but no such heading exists"
                )
    return errors


def _missing_docstrings(tree: ast.Module, rel: str) -> list[str]:
    """Public defs without a (non-empty) docstring — the D1xx mirror."""
    errors: list[str] = []

    def doc_ok(node) -> bool:
        doc = ast.get_docstring(node)
        return doc is not None and doc.strip() != ""

    if not doc_ok(tree):
        errors.append(f"{rel}: missing module docstring (D100/D104)")

    def walk(body, prefix: str, in_class: bool) -> None:
        for node in body:
            if isinstance(node, (ast.If, ast.Try)):
                walk(node.body, prefix, in_class)
                continue
            if not isinstance(
                node, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)
            ):
                continue
            if node.name.startswith("_"):
                continue  # private / magic: outside the selected rule set
            if not doc_ok(node):
                kind = (
                    "class (D101)" if isinstance(node, ast.ClassDef)
                    else "method (D102)" if in_class
                    else "function (D103)"
                )
                errors.append(
                    f"{rel}:{node.lineno}: public {kind} "
                    f"`{prefix}{node.name}` lacks a docstring"
                )
            if isinstance(node, ast.ClassDef):
                walk(node.body, f"{prefix}{node.name}.", True)

    walk(tree.body, "", False)
    return errors


def check_docstrings() -> list[str]:
    """Run the docstring mirror over the public-API source dirs."""
    errors: list[str] = []
    for d in DOCSTRING_DIRS:
        for py in sorted((ROOT / d).rglob("*.py")):
            rel = str(py.relative_to(ROOT))
            tree = ast.parse(py.read_text())
            errors += _missing_docstrings(tree, rel)
    return errors


def main() -> int:
    """Run both passes; exit non-zero (listing findings) on any failure."""
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print(
            f"docs OK: {', '.join(DOC_FILES)} cross-references resolve; "
            f"docstring coverage holds in {', '.join(DOCSTRING_DIRS)}"
        )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
