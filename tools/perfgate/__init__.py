"""Perf-regression gate over the committed bench trajectories (DESIGN.md 13.3).

``python -m tools.perfgate --check`` reads the top-level
``BENCH_engine.json`` / ``BENCH_serve.json`` histories, splits the records
into *series* (one independent trajectory per distinct combination of
:data:`SERIES_FIELDS` — engine, ``--tiny`` flag, device count, machine
fingerprint, ...), and gates each metric's latest value against its own
past.  Exit status 1 on any regression or absolute-floor violation, with a
per-metric diagnostic naming the offending series, value, and baseline.

Policy (the reframe-style noise handling):

* **baseline = best of the last K same-series values** (K =
  :data:`BASELINE_WINDOW`).  The median is the wrong statistic here: the
  committed series span machines whose absolute throughput differs by
  several x, so a genuine 2x regression can still sit above the median of
  a mixed past.  Best-of-recent compares a run against the best this exact
  series has demonstrated recently, which is what a throughput regression
  is *relative to*.
* **per-metric tolerance** — each :class:`MetricPolicy` carries the noise
  band observed for that metric on shared CI runners (e.g. sweep
  throughput is steadier than warm-speedup ratios, whose numerator is a
  one-shot cold trace).  ``--tolerance`` overrides globally for local
  what-if runs.
* **absolute floors** — ratios that are acceptance criteria of earlier
  PRs (warm >= 5x, fused-vs-packed >= 2x, ...) also gate on a floor, so a
  slow drift that never trips the relative check still cannot sink below
  the bar.  This replaces the ad-hoc ``SystemExit`` asserts that used to
  live inside ``benchmarks/engine_bench.py``.
* **bootstrap** — a series with a single record (first run on an unseen
  machine fingerprint) has no baseline: it passes and is reported as
  ``bootstrap``, becoming the baseline for the machine's next run.

The machine fingerprint in :data:`SERIES_FIELDS` is what keeps the gate
honest across heterogeneous runners: a laptop's history never gates a CI
runner and vice versa (see :func:`repro.engine.machine.machine_fingerprint`).
"""
from __future__ import annotations

import dataclasses

#: Record fields whose values split the history into independent series.
#: Absent fields read as ``None`` (old records without a machine stamp form
#: their own legacy series rather than aliasing a fingerprinted one).
SERIES_FIELDS = (
    "bench", "engine", "tiny", "n_devices", "loop", "smoke", "replicas",
    "machine",
)

#: Baseline = best of this many most-recent earlier same-series values.
BASELINE_WINDOW = 5


@dataclasses.dataclass(frozen=True)
class MetricPolicy:
    """Gate policy for one metric of a trajectory record.

    ``tolerance`` is the allowed fractional drop vs the baseline (0.40
    means the latest value must retain >= 60% of the best recent value).
    ``floor`` is an optional absolute lower bound — an acceptance bar that
    holds regardless of history.  All gated metrics are
    higher-is-better rates/ratios; ``higher_is_better=False`` flips the
    comparison for latency-style metrics if one is ever added.
    """

    name: str
    tolerance: float
    floor: float | None = None
    higher_is_better: bool = True


#: Gated metrics of ``BENCH_engine.json`` records (absent/None fields skip).
ENGINE_METRICS = (
    MetricPolicy("req_per_s_best", 0.40),
    MetricPolicy("warm_speedup", 0.60, floor=5.0),
    MetricPolicy("fused_vs_packed_sweep_speedup", 0.50, floor=2.0),
    MetricPolicy("fused_vs_xla_speedup", 0.60, floor=0.5),
    MetricPolicy("fused_sweeps_per_s", 0.35),
    MetricPolicy("packed_sweeps_per_s", 0.50),
    MetricPolicy("mutation_best_speedup", 0.60, floor=5.0),
    MetricPolicy("ingest_triples_per_s", 0.40),
)

#: Gated metrics of ``BENCH_serve.json`` records.
SERVE_METRICS = (
    MetricPolicy("capacity_burst_req_s", 0.40),
)

#: Gated metrics of ``BENCH_chaos.json`` records (ISSUE 10).  The floors
#: ARE the acceptance criteria: chaos goodput must retain >= 70% of the
#: fault-free baseline, and the crashed replica must come back rebuilt and
#: bit-identical on every soak (those two are 0/1 indicators, so the floor
#: alone gates them).
CHAOS_METRICS = (
    MetricPolicy("goodput_retained", 0.25, floor=0.70),
    MetricPolicy("goodput_chaos_req_s", 0.40),
    MetricPolicy("rebuilt", 0.0, floor=1.0),
    MetricPolicy("bit_identical", 0.0, floor=1.0),
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One gate verdict: a metric of a series, with its diagnostic line."""

    metric: str
    series: str
    status: str  # "ok" | "regression" | "floor_violation" | "bootstrap"
    current: float
    baseline: float | None
    ratio: float | None
    message: str

    @property
    def failed(self) -> bool:
        """True when this finding should fail the gate."""
        return self.status in ("regression", "floor_violation")


def series_key(record: dict) -> tuple:
    """Hashable identity of the trajectory series a record belongs to."""
    return tuple((f, record.get(f)) for f in SERIES_FIELDS)


def _series_label(key: tuple) -> str:
    parts = [f"{k}={v}" for k, v in key if v is not None]
    return " ".join(parts) or "(default)"


def check_history(
    records: list[dict],
    policies: tuple[MetricPolicy, ...],
    *,
    window: int = BASELINE_WINDOW,
    tolerance: float | None = None,
) -> list[Finding]:
    """Gate every metric of every series in ``records``.

    Records are grouped by :func:`series_key` in file order (the committed
    trajectories are chronological).  Per metric and series: the latest
    non-null value gates against the floor first, then against the best of
    up to ``window`` earlier values.  ``tolerance`` overrides every
    policy's own band when given.  Returns one :class:`Finding` per
    (series, metric) that has at least one value.
    """
    groups: dict[tuple, list[dict]] = {}
    for rec in records:
        groups.setdefault(series_key(rec), []).append(rec)
    findings: list[Finding] = []
    for key, recs in sorted(groups.items(), key=lambda kv: str(kv[0])):
        label = _series_label(key)
        for pol in policies:
            tol = tolerance if tolerance is not None else pol.tolerance
            values = [
                float(r[pol.name]) for r in recs
                if isinstance(r.get(pol.name), (int, float))
            ]
            if not values:
                continue
            current = values[-1]
            lo_ok = pol.floor is None or (
                current >= pol.floor if pol.higher_is_better
                else current <= pol.floor
            )
            if not lo_ok:
                findings.append(Finding(
                    pol.name, label, "floor_violation", current, None, None,
                    f"{pol.name}={current:.4g} violates the absolute "
                    f"{'floor' if pol.higher_is_better else 'ceiling'} "
                    f"{pol.floor:g} [{label}]",
                ))
                continue
            earlier = values[:-1][-window:]
            if not earlier:
                findings.append(Finding(
                    pol.name, label, "bootstrap", current, None, None,
                    f"{pol.name}={current:.4g}: first record for this "
                    f"series — baseline bootstrapped [{label}]",
                ))
                continue
            if pol.higher_is_better:
                baseline = max(earlier)
                ratio = current / baseline if baseline > 0 else 1.0
            else:
                baseline = min(earlier)
                ratio = baseline / current if current > 0 else 1.0
            if ratio < 1.0 - tol:
                findings.append(Finding(
                    pol.name, label, "regression", current, baseline, ratio,
                    f"{pol.name}={current:.4g} vs best-of-last-"
                    f"{len(earlier)} {baseline:.4g}: {ratio:.2f}x retained "
                    f"< {1.0 - tol:.2f} allowed [{label}]",
                ))
            else:
                findings.append(Finding(
                    pol.name, label, "ok", current, baseline, ratio,
                    f"{pol.name}={current:.4g} vs {baseline:.4g} "
                    f"({ratio:.2f}x) [{label}]",
                ))
    return findings
