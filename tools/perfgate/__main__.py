"""CLI of the perf-regression gate: ``python -m tools.perfgate``.

Modes (composable; run in the order probe -> replay -> check):

* ``--probe``  — run the ERT-style machine probe (``benchmarks.roofline``)
  so the replayed benches and the calibrated cost model see a persisted
  :class:`~repro.engine.machine.MachineSpec` for this machine.
* ``--replay`` — re-run the CI-sized bench sections in subprocesses
  (``engine_bench --tiny --fused-only``, ``serve_bench --smoke``, and
  ``chaos_bench --smoke``), each of which appends a machine-stamped
  record to its committed ``BENCH_*.json`` trajectory.
* ``--check``  — the default: gate the latest value of every metric/series
  against its own history (see :mod:`tools.perfgate`); exit 1 on any
  regression or floor violation, with per-metric diagnostics.

The gate needs no third-party imports — ``--check`` runs on a bare Python
with just the committed JSON files.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from . import CHAOS_METRICS, ENGINE_METRICS, SERVE_METRICS, Finding, check_history
from .history import load_history

REPO = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "..")
)
ENGINE_HISTORY = os.path.join(REPO, "BENCH_engine.json")
SERVE_HISTORY = os.path.join(REPO, "BENCH_serve.json")
CHAOS_HISTORY = os.path.join(REPO, "BENCH_chaos.json")


def _env() -> dict:
    env = dict(os.environ)
    extra = [os.path.join(REPO, "src"), REPO]
    if env.get("PYTHONPATH"):
        extra.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(extra)
    return env


def _run(argv: list[str]) -> int:
    print(f"# perfgate$ {sys.executable} {' '.join(argv)}", flush=True)
    return subprocess.run(
        [sys.executable, *argv], cwd=REPO, env=_env()
    ).returncode


def probe(fast: bool = True) -> int:
    """Run the machine probe; persists the spec under ``results/machine/``."""
    return _run(["-m", "benchmarks.roofline"] + (["--fast"] if fast else []))


def replay() -> int:
    """Re-run the CI bench sections that append to the trajectories."""
    rc = _run(["-m", "benchmarks.engine_bench", "--tiny", "--fused-only"])
    if rc:
        return rc
    rc = _run(["-m", "benchmarks.serve_bench", "--smoke"])
    if rc:
        return rc
    return _run(["-m", "benchmarks.chaos_bench", "--smoke"])


def check(
    engine_history: str,
    serve_history: str,
    chaos_history: str = CHAOS_HISTORY,
    tolerance: float | None = None,
    as_json: bool = False,
) -> int:
    """Gate the trajectories; print diagnostics; return the exit status."""
    findings: list[Finding] = []
    n_records = 0
    for path, policies in (
        (engine_history, ENGINE_METRICS),
        (serve_history, SERVE_METRICS),
        (chaos_history, CHAOS_METRICS),
    ):
        records = load_history(path)
        n_records += len(records)
        findings += check_history(records, policies, tolerance=tolerance)
    if as_json:
        print(json.dumps(
            [vars(f) | {"failed": f.failed} for f in findings], indent=1
        ))
    else:
        for f in findings:
            tag = "FAIL" if f.failed else f.status
            print(f"perfgate/{tag}: {f.message}")
    failed = [f for f in findings if f.failed]
    if n_records == 0:
        print("perfgate/FAIL: no trajectory records found "
              f"({engine_history}, {serve_history}, {chaos_history}) "
              "— nothing to gate",
              file=sys.stderr)
        return 1
    if failed:
        print(f"# perfgate: {len(failed)} failing metric(s) of "
              f"{len(findings)} checked", file=sys.stderr)
        return 1
    print(f"# perfgate: {len(findings)} metric series ok "
          f"({sum(1 for f in findings if f.status == 'bootstrap')} "
          f"bootstrapped)")
    return 0


def main(argv: list[str] | None = None) -> int:
    """Parse arguments and run the requested modes."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.perfgate",
        description=__doc__.splitlines()[0],
    )
    ap.add_argument("--check", action="store_true",
                    help="gate the trajectories (default mode)")
    ap.add_argument("--replay", action="store_true",
                    help="re-run the CI bench sections first (appends "
                         "machine-stamped records)")
    ap.add_argument("--probe", action="store_true",
                    help="run the machine probe first (persists the "
                         "MachineSpec the benches calibrate against)")
    ap.add_argument("--fast", action="store_true", default=True,
                    help="probe with the reduced CI sweep (default)")
    ap.add_argument("--full-probe", dest="fast", action="store_false",
                    help="probe with the full sweep")
    ap.add_argument("--engine-history", default=ENGINE_HISTORY,
                    help="path of the engine trajectory JSON")
    ap.add_argument("--serve-history", default=SERVE_HISTORY,
                    help="path of the serve trajectory JSON")
    ap.add_argument("--chaos-history", default=CHAOS_HISTORY,
                    help="path of the chaos-soak trajectory JSON")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override every metric's tolerance band")
    args = ap.parse_args(argv)

    if args.probe:
        rc = probe(fast=args.fast)
        if rc:
            return rc
    if args.replay:
        rc = replay()
        if rc:
            return rc
    # the gate always runs last: probe/replay without a check would
    # silently accept whatever they produced
    return check(
        args.engine_history, args.serve_history, args.chaos_history,
        tolerance=args.tolerance, as_json=args.json,
    )


if __name__ == "__main__":
    raise SystemExit(main())
