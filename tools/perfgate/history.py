"""Atomic, append-only access to the committed ``BENCH_*.json`` trajectories.

The top-level ``BENCH_engine.json`` / ``BENCH_serve.json`` files are the
cross-PR perf history the gate replays against: every bench run appends one
record, and a PR that deliberately refreshes the trajectory commits the
appended records.  Two invariants matter and both live here so the bench
scripts and the gate share one implementation:

* **append-only** — a run may add records, never rewrite or drop earlier
  ones (the gate's baseline is the committed past; silently truncating it
  would let any regression pass).
* **atomic** — the rewrite goes through a temp file + ``os.replace`` so an
  interrupted bench run leaves the previous history intact instead of a
  half-written JSON that the next load would discard.
"""
from __future__ import annotations

import json
import os
import tempfile


def load_history(path: str) -> list[dict]:
    """Records in file order; ``[]`` for a missing or unparseable file."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            hist = json.load(f)
    except (json.JSONDecodeError, OSError):
        return []
    if not isinstance(hist, list):
        hist = [hist]
    return [r for r in hist if isinstance(r, dict)]


def append_record(path: str, entry: dict) -> list[dict]:
    """Append ``entry`` to the trajectory at ``path``; return the new history.

    Loads the existing records, appends, and replaces the file atomically
    (``mkstemp`` in the same directory + ``os.replace``), so a crash
    mid-write can never lose the committed history.
    """
    hist = load_history(path)
    hist.append(entry)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(hist, f, indent=1, default=str)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return hist
