"""reprolint — repo-native static analysis for the dual-simulation engine.

The paper's soundness guarantee (a gfp overapproximation of SPARQL answers)
survives in this codebase only because of invariants that no general-purpose
linter knows about: JAX trace safety inside jitted fixpoints, the pad-bit
masking rule for bit-packed ``uint32`` words, lock discipline around the
threaded serving stack, the "every submitted request resolves to exactly
one outcome" futures contract, and the exception-hygiene rule that keeps
the failure plane's accounting honest.  reprolint mechanizes those rules as small
stdlib-``ast`` checkers and gates CI on them (DESIGN.md Sect. 11 has the full
rule catalog with the bug that motivated each rule).

Rules
-----
* **RL1 trace-safety** — inside ``@jax.jit``-reachable functions and
  ``lax.while_loop`` / ``lax.scan`` bodies: no host syncs
  (``bool()/int()/float()/.item()/np.asarray`` on traced values), no Python
  branching on traced parameters, no module-level ``jnp`` array constants
  (they initialize the backend before ``XLA_FLAGS`` is read), no unhashable
  values for declared-static jit arguments.
* **RL2 pad-bit hygiene** — outside ``core/bitops.py`` and ``kernels/``,
  raw bitwise complements and reductions on packed ``uint32 [V, nw]`` arrays
  must apply the pad mask (``bitops.ones_mask``) or go through the sanctioned
  ``bitops`` helpers.  "Packed" is a lightweight taint inferred from
  ``pack`` / ``pack_np`` / ``.init_packed`` / ``.adj_packed`` call sites.
* **RL3 lock discipline** — see the ``# guarded-by:`` convention below.
* **RL4 exactly-once futures** — every path through a function that creates
  (or is annotated as owning) a future/request object must resolve it exactly
  once: one of ``set_result`` / ``set_exception`` / ``_resolve`` / ``_reject``
  / ``cancel``, or an explicit hand-off (passing it to a call, storing it in
  a container, or returning it).
* **RL5 exception hygiene** — no bare ``except:`` (it catches
  ``SystemExit`` / ``KeyboardInterrupt`` / ``CancelledError`` too); no
  ``except Exception`` / ``except BaseException`` handler whose body is only
  ``pass`` / ``continue`` / ``...`` (specific exception types stay allowed —
  ``except asyncio.TimeoutError: pass`` is the waiting idiom, not a
  swallow); no ``create_task(...)`` whose Task handle is dropped as a bare
  expression statement (keep the handle + ``add_done_callback``, or await).

CONTRIBUTING — annotation conventions
-------------------------------------
``# guarded-by: <lock>``
    Placed on (or on the line above) a ``self.<field> = ...`` assignment in
    ``__init__``.  Declares that every later read or write of that field must
    happen inside a lexical ``with self.<lock>:`` block in the same class.
    ``<lock>`` is the attribute name of the lock (e.g. ``_lock``,
    ``_route_lock``, ``cv``).  A *dotted* lock path (e.g.
    ``guarded-by: self._route_lock`` on a ``Replica`` gauge) is matched
    verbatim against the accessor's held with-items — for fields whose lock
    lives on the accessing object rather than the receiver.  RL3 enforces
    the declaration; it also flags ``await`` while any registered lock is
    held, and acquisition orders that invert between two functions.

``# requires-lock: <lock>``
    Function-level annotation (on the ``def`` line or the line above): the
    body is only ever entered with ``<lock>`` already held, so guarded-field
    accesses inside it are legal.  Used for private helpers like
    ``GraphDB._commit`` that are documented as "caller holds the lock".

``# rl4: track=<var>``
    Opt a variable into RL4 tracking in functions where creation is not
    syntactically visible (e.g. the request object arrives as a parameter).

Suppressions (use sparingly; every suppression needs a reason)
--------------------------------------------------------------
``# reprolint: disable=RL1``   silence any rule on this line (or a whole
                               ``def``/``class`` when placed on its header)
``# trace-ok: <reason>``       RL1 line-level escape hatch
``# packed-ok: <reason>``      RL2 line-level escape hatch
``# lock-ok: <reason>``        RL3 line-level escape hatch
``# future-ok: <reason>``      RL4 line-level escape hatch
``# rl5: swallow-ok — <reason>``  RL5 line-level escape hatch (on the
                               swallowing line or the ``except`` above it)

Baseline: ``tools/reprolint/baseline.json`` holds fingerprints of findings
grandfathered during a migration.  Policy: the baseline is **empty at merge**
— new findings are fixed, not baselined.

CLI::

    python -m tools.reprolint src tests benchmarks

Exit status is non-zero iff any non-baselined finding remains.
"""

from tools.reprolint.core import Checker, Context, Finding, run_paths

__all__ = ["Checker", "Context", "Finding", "run_paths"]
