"""CLI entry point: ``python -m tools.reprolint src tests benchmarks``.

Prints findings as ``file:line: RULE message``, optionally dumps them as a
JSON artifact for CI, and exits non-zero iff any non-baselined finding
remains.  The baseline (``tools/reprolint/baseline.json``) is a migration
aid only — repo policy is an empty baseline at merge.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.reprolint.checkers import ALL_CHECKERS
from tools.reprolint.core import load_baseline, run_paths

REPO_ROOT = Path(__file__).resolve().parent.parent.parent
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="Repo-native static analysis (RL1 trace-safety, RL2 pad-bit "
        "hygiene, RL3 lock discipline, RL4 exactly-once futures).",
    )
    parser.add_argument("paths", nargs="*", default=["src"], help="files or directories")
    parser.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE)
    parser.add_argument("--json", type=Path, default=None, help="write findings JSON here")
    parser.add_argument("--rules", default=None, help="comma-separated rule subset, e.g. RL1,RL3")
    args = parser.parse_args(argv)

    checkers = [cls() for cls in ALL_CHECKERS]
    if args.rules:
        wanted = {r.strip().upper() for r in args.rules.split(",")}
        checkers = [c for c in checkers if c.rule_id in wanted]

    baseline = load_baseline(args.baseline)
    new, old = run_paths(args.paths or ["src"], root=REPO_ROOT, baseline=baseline, checkers=checkers)

    for finding in new:
        print(finding.render())
    if old:
        print(f"[reprolint] {len(old)} baselined finding(s) suppressed", file=sys.stderr)

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(
            json.dumps(
                {
                    "new": [dataclass_dict(f) for f in new],
                    "baselined": [dataclass_dict(f) for f in old],
                },
                indent=2,
            )
            + "\n"
        )

    if new:
        print(f"[reprolint] {len(new)} finding(s)", file=sys.stderr)
        return 1
    print(f"[reprolint] clean ({len(checkers)} checkers)", file=sys.stderr)
    return 0


def dataclass_dict(finding) -> dict:
    """JSON-friendly view of a Finding."""
    return {
        "file": finding.file,
        "line": finding.line,
        "rule_id": finding.rule_id,
        "message": finding.message,
    }


if __name__ == "__main__":
    raise SystemExit(main())
