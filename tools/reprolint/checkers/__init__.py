"""Checker registry: the five repo-native rule families (RL1–RL5)."""

from tools.reprolint.checkers.rl1_trace import TraceSafetyChecker
from tools.reprolint.checkers.rl2_padbits import PadBitChecker
from tools.reprolint.checkers.rl3_locks import LockDisciplineChecker
from tools.reprolint.checkers.rl4_futures import ExactlyOnceFutureChecker
from tools.reprolint.checkers.rl5_exceptions import ExceptionHygieneChecker

ALL_CHECKERS = [
    TraceSafetyChecker,
    PadBitChecker,
    LockDisciplineChecker,
    ExactlyOnceFutureChecker,
    ExceptionHygieneChecker,
]

__all__ = [
    "ALL_CHECKERS",
    "TraceSafetyChecker",
    "PadBitChecker",
    "LockDisciplineChecker",
    "ExactlyOnceFutureChecker",
    "ExceptionHygieneChecker",
]
