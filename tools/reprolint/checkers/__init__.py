"""Checker registry: the four repo-native rule families (RL1–RL4)."""

from tools.reprolint.checkers.rl1_trace import TraceSafetyChecker
from tools.reprolint.checkers.rl2_padbits import PadBitChecker
from tools.reprolint.checkers.rl3_locks import LockDisciplineChecker
from tools.reprolint.checkers.rl4_futures import ExactlyOnceFutureChecker

ALL_CHECKERS = [
    TraceSafetyChecker,
    PadBitChecker,
    LockDisciplineChecker,
    ExactlyOnceFutureChecker,
]

__all__ = [
    "ALL_CHECKERS",
    "TraceSafetyChecker",
    "PadBitChecker",
    "LockDisciplineChecker",
    "ExactlyOnceFutureChecker",
]
