"""Shared AST helpers for reprolint checkers."""

from __future__ import annotations

import ast
from typing import Iterator

FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted(node: ast.AST) -> str:
    """Render a Name/Attribute chain as ``a.b.c`` ('' if not a plain chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    if isinstance(node, ast.Call):
        # e.g. ``jax.jit(f)(x)`` — render the callee chain.
        inner = dotted(node.func)
        return f"{inner}()" if inner else ""
    return ""


def names_in(node: ast.AST) -> set[str]:
    """All Name identifiers loaded anywhere inside ``node``."""
    return {
        n.id
        for n in ast.walk(node)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
    }


def param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def positional_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args]


def iter_functions(tree: ast.AST) -> Iterator[ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, FuncDef):
            yield node


def enclosing_function_map(tree: ast.AST) -> dict[ast.AST, ast.AST | None]:
    """Map each node to its nearest enclosing function def (None = module)."""
    out: dict[ast.AST, ast.AST | None] = {}

    def walk(node: ast.AST, fn: ast.AST | None) -> None:
        out[node] = fn
        inner = node if isinstance(node, FuncDef) else fn
        for child in ast.iter_child_nodes(node):
            walk(child, inner)

    walk(tree, None)
    return out


def const_str_seq(node: ast.AST) -> list[str]:
    """Extract a list of strings from a str constant or tuple/list of them."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def const_int_seq(node: ast.AST) -> list[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int)
        ]
    return []


def contains_shield_attr(node: ast.AST) -> bool:
    """True if the expression touches a static/trace-safe attribute.

    ``.shape`` / ``.dtype`` / ``.ndim`` / ``.size`` are static at trace time,
    and ``len()`` / ``isinstance()`` only apply to static structure.
    """
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "dtype", "ndim", "size"):
            return True
        if isinstance(n, ast.Call):
            callee = dotted(n.func)
            if callee in ("len", "isinstance", "type", "hasattr"):
                return True
    return False


def is_identity_compare(node: ast.AST) -> bool:
    """True if the test is only ``is`` / ``is not`` comparisons (trace-safe)."""
    comparisons = [n for n in ast.walk(node) if isinstance(n, ast.Compare)]
    if not comparisons:
        return False
    return all(
        all(isinstance(op, (ast.Is, ast.IsNot)) for op in c.ops) for c in comparisons
    )
