"""RL1 — trace safety inside jit-reachable code.

Protects the zero-retrace / no-host-sync contract of the fixpoint engines
(DESIGN.md Sect. 11): inside a ``@jax.jit``-reachable function or a
``lax.while_loop`` / ``lax.scan`` body, a ``bool()/int()/float()/.item()``
or ``np.asarray`` on a traced value blocks on device transfer (or raises a
``TracerError``), and Python ``if``/``while`` on a tracer is a concretization
error.  Also flags module-level ``jnp`` constants (they initialize the JAX
backend at import time, before ``XLA_FLAGS`` can be set — the exact bug PR 5
fixed in ``core/dualsim.py``) and unhashable values bound to declared-static
jit arguments (every call retraces or raises).

Reachability is module-local: jit entry points are found from decorators
(``@jax.jit``, ``@functools.partial(jax.jit, static_argnames=...)``),
``jax.jit(f)`` call sites, and functions passed to ``lax.while_loop`` /
``lax.scan`` / ``lax.cond`` / ``lax.fori_loop``; the traced/static split of
each parameter follows ``static_argnames``/``static_argnums`` and, for plain
helpers, whether any call site passes a traced expression.

Escape hatch: ``# trace-ok: <reason>``.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Iterator

from tools.reprolint.checkers.common import (
    FuncDef,
    const_int_seq,
    const_str_seq,
    contains_shield_attr,
    dotted,
    enclosing_function_map,
    is_identity_compare,
    names_in,
    param_names,
    positional_params,
)
from tools.reprolint.core import Checker, Context, Finding

JIT_CALLEES = {"jax.jit", "jit", "functools.partial", "partial"}
HOST_CASTS = {"bool", "int", "float", "complex"}
NP_SYNC_CALLS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "onp.asarray", "onp.array",
}
MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)

# callee -> indices of function-valued arguments whose bodies are trace regions
STAGED_CALLEES = {
    "lax.while_loop": (0, 1),
    "jax.lax.while_loop": (0, 1),
    "lax.scan": (0,),
    "jax.lax.scan": (0,),
    "lax.cond": (1, 2),
    "jax.lax.cond": (1, 2),
    "lax.fori_loop": (2,),
    "jax.lax.fori_loop": (2,),
    "lax.switch": (1,),
    "jax.lax.switch": (1,),
}


@dataclasses.dataclass
class _FnInfo:
    node: ast.FunctionDef | ast.AsyncFunctionDef
    static: set[str] = dataclasses.field(default_factory=set)
    traced: set[str] = dataclasses.field(default_factory=set)
    reached: bool = False
    is_jit_entry: bool = False


def _walk_skip_nested(stmts: Iterable[ast.stmt]) -> Iterator[ast.AST]:
    """Walk statements without descending into nested function/class defs."""
    stack: list[ast.AST] = list(stmts)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncDef + (ast.ClassDef,)):
                continue
            stack.append(child)


class TraceSafetyChecker(Checker):
    """RL1: host syncs, tracer branching, early backend init, retrace hazards."""

    rule_id = "RL1"
    title = "trace safety in jit-reachable code"

    def visit(self, ctx: Context) -> Iterable[Finding]:
        fns: dict[str, _FnInfo] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, FuncDef):
                fns[node.name] = _FnInfo(node)

        self._mark_decorated_entries(fns)
        lambda_regions = self._mark_callsite_entries(ctx.tree, fns)
        self._propagate_reachability(fns)

        findings: list[Finding] = []
        for info in fns.values():
            if info.reached:
                findings.extend(self._check_region(ctx, info.node.body, info.traced))
        for lam, traced in lambda_regions:
            findings.extend(self._check_expr_region(ctx, lam.body, traced))
        findings.extend(self._check_module_constants(ctx))
        findings.extend(self._check_static_hashability(ctx, fns))
        return findings

    # -- entry discovery ---------------------------------------------------

    def _mark_decorated_entries(self, fns: dict[str, _FnInfo]) -> None:
        for info in fns.values():
            for dec in info.node.decorator_list:
                static = self._jit_static_params(dec, info.node)
                if static is not None:
                    info.is_jit_entry = True
                    info.static |= static

    def _jit_static_params(self, expr: ast.AST, fn) -> set[str] | None:
        """If ``expr`` is a jit wrapper, return its static param names."""
        name = dotted(expr)
        if name in ("jax.jit", "jit"):
            return set()
        if not isinstance(expr, ast.Call):
            return None
        callee = dotted(expr.func)
        wraps_jit = callee in ("jax.jit", "jit") or (
            callee in ("functools.partial", "partial")
            and expr.args
            and dotted(expr.args[0]) in ("jax.jit", "jit")
        )
        if not wraps_jit:
            return None
        static: set[str] = set()
        pos = positional_params(fn)
        for kw in expr.keywords:
            if kw.arg == "static_argnames":
                static |= set(const_str_seq(kw.value))
            elif kw.arg == "static_argnums":
                for i in const_int_seq(kw.value):
                    if 0 <= i < len(pos):
                        static.add(pos[i])
        return static

    def _mark_callsite_entries(self, tree, fns):
        """``jax.jit(f)`` call sites and staged-callee (while/scan) bodies."""
        lambda_regions: list[tuple[ast.Lambda, set[str]]] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted(node.func)
            if callee in ("jax.jit", "jit") and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name) and target.id in fns:
                    info = fns[target.id]
                    info.is_jit_entry = True
                    pos = positional_params(info.node)
                    for kw in node.keywords:
                        if kw.arg == "static_argnames":
                            info.static |= set(const_str_seq(kw.value))
                        elif kw.arg == "static_argnums":
                            for i in const_int_seq(kw.value):
                                if 0 <= i < len(pos):
                                    info.static.add(pos[i])
            if callee in STAGED_CALLEES:
                for idx in STAGED_CALLEES[callee]:
                    if idx >= len(node.args):
                        continue
                    arg = node.args[idx]
                    if isinstance(arg, ast.Name) and arg.id in fns:
                        info = fns[arg.id]
                        info.is_jit_entry = True  # loop bodies: all params traced
                    elif isinstance(arg, ast.Lambda):
                        lambda_regions.append((arg, set(param_names(arg))))
        return lambda_regions

    def _propagate_reachability(self, fns: dict[str, _FnInfo]) -> None:
        for info in fns.values():
            if info.is_jit_entry:
                info.reached = True
                info.traced = {
                    p for p in param_names(info.node) if p not in info.static and p != "self"
                }
        # Worklist: a call from a reached function marks the callee reached,
        # with callee params traced iff some call site passes a traced expr.
        changed = True
        while changed:
            changed = False
            for info in fns.values():
                if not info.reached:
                    continue
                for node in _walk_skip_nested(info.node.body):
                    if not isinstance(node, ast.Call):
                        continue
                    callee = dotted(node.func)
                    target = fns.get(callee) or fns.get(callee.rpartition(".")[2])
                    if target is None or target is info:
                        continue
                    traced_params = self._callsite_traced_params(node, target, info.traced)
                    if not target.reached or traced_params - target.traced:
                        target.reached = True
                        target.traced |= traced_params
                        changed = True

    def _callsite_traced_params(self, call: ast.Call, target: _FnInfo, caller_traced):
        pos = [p for p in positional_params(target.node) if p != "self"]
        traced: set[str] = set()
        for i, arg in enumerate(call.args):
            if i < len(pos) and self._is_traced_expr(arg, caller_traced):
                traced.add(pos[i])
        for kw in call.keywords:
            if kw.arg and self._is_traced_expr(kw.value, caller_traced):
                traced.add(kw.arg)
        return traced

    @staticmethod
    def _is_traced_expr(expr: ast.AST, traced: set[str]) -> bool:
        if isinstance(expr, ast.Constant):
            return False
        if contains_shield_attr(expr):
            return False
        return bool(names_in(expr) & traced)

    # -- region checks -----------------------------------------------------

    def _check_region(self, ctx, stmts, traced_params: set[str]) -> list[Finding]:
        findings: list[Finding] = []
        traced = set(traced_params)
        for node in _walk_skip_nested(stmts):
            # Flow-insensitive taint: anything assigned from a traced
            # expression is itself traced (one pass is enough in practice).
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = node.value
                if value is not None and self._is_traced_expr(value, traced):
                    targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                traced.add(n.id)
        for node in _walk_skip_nested(stmts):
            findings.extend(self._check_node(ctx, node, traced))
        return findings

    def _check_expr_region(self, ctx, expr: ast.AST, traced: set[str]) -> list[Finding]:
        return [f for node in ast.walk(expr) for f in self._check_node(ctx, node, traced)]

    def _check_node(self, ctx, node: ast.AST, traced: set[str]) -> list[Finding]:
        out: list[Finding] = []
        if isinstance(node, ast.Call):
            callee = dotted(node.func)
            if callee in HOST_CASTS and node.args:
                if self._is_traced_expr(node.args[0], traced):
                    out.append(self.finding(
                        ctx, node,
                        f"host sync: `{callee}()` on a traced value inside a "
                        f"jit-reachable region (blocks on device transfer or "
                        f"raises TracerError)",
                    ))
            elif callee in NP_SYNC_CALLS and node.args:
                if self._is_traced_expr(node.args[0], traced):
                    out.append(self.finding(
                        ctx, node,
                        f"host sync: `{callee}` on a traced value inside a "
                        f"jit-reachable region; use `jnp.asarray` or keep the "
                        f"value on device",
                    ))
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"
                and self._is_traced_expr(node.func.value, traced)
            ):
                out.append(self.finding(
                    ctx, node,
                    "host sync: `.item()` on a traced value inside a "
                    "jit-reachable region",
                ))
        elif isinstance(node, (ast.If, ast.While, ast.IfExp)):
            test = node.test
            if (
                self._is_traced_expr(test, traced)
                and not is_identity_compare(test)
            ):
                kind = "while" if isinstance(node, ast.While) else "if"
                out.append(self.finding(
                    ctx, node,
                    f"Python `{kind}` branches on a traced value inside a "
                    f"jit-reachable region; use `lax.cond`/`jnp.where` or make "
                    f"the argument static",
                ))
        elif isinstance(node, ast.Assert):
            if self._is_traced_expr(node.test, traced):
                out.append(self.finding(
                    ctx, node,
                    "assert on a traced value inside a jit-reachable region "
                    "(host sync); use checkify or assert on static structure",
                ))
        return out

    # -- module-scope checks -----------------------------------------------

    def _check_module_constants(self, ctx) -> list[Finding]:
        findings = []
        enclosing = enclosing_function_map(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or enclosing.get(node) is not None:
                continue
            callee = dotted(node.func)
            if callee.startswith("jnp.") or callee.startswith("jax.numpy."):
                findings.append(self.finding(
                    ctx, node,
                    f"module-level `{callee}(...)` constant initializes the JAX "
                    f"backend at import time, before `XLA_FLAGS` is read; build "
                    f"it with numpy or inside a function",
                ))
        return findings

    def _check_static_hashability(self, ctx, fns: dict[str, _FnInfo]) -> list[Finding]:
        findings = []
        for info in fns.values():
            if not info.static:
                continue
            a = info.node.args
            pos = a.posonlyargs + a.args
            for param, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
                if param.arg in info.static and isinstance(default, MUTABLE_LITERALS):
                    findings.append(self.finding(
                        ctx, default,
                        f"unhashable default for static jit arg `{param.arg}` "
                        f"(retraces or raises on every call); use a tuple or "
                        f"frozen value",
                    ))
            for param, default in zip(a.kwonlyargs, a.kw_defaults):
                if (
                    default is not None
                    and param.arg in info.static
                    and isinstance(default, MUTABLE_LITERALS)
                ):
                    findings.append(self.finding(
                        ctx, default,
                        f"unhashable default for static jit arg `{param.arg}`; "
                        f"use a tuple or frozen value",
                    ))
        # Call sites passing mutable literals to declared-static params.
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted(node.func)
            target = fns.get(callee) or fns.get(callee.rpartition(".")[2])
            if target is None or not target.static:
                continue
            pos = [p for p in positional_params(target.node) if p != "self"]
            for i, arg in enumerate(node.args):
                if i < len(pos) and pos[i] in target.static and isinstance(arg, MUTABLE_LITERALS):
                    findings.append(self.finding(
                        ctx, arg,
                        f"unhashable value for static jit arg `{pos[i]}` of "
                        f"`{target.node.name}` (retraces or raises); pass a tuple",
                    ))
            for kw in node.keywords:
                if kw.arg in target.static and isinstance(kw.value, MUTABLE_LITERALS):
                    findings.append(self.finding(
                        ctx, kw.value,
                        f"unhashable value for static jit arg `{kw.arg}` of "
                        f"`{target.node.name}` (retraces or raises); pass a tuple",
                    ))
        return findings
