"""RL2 — pad-bit hygiene for packed ``uint32 [V, nw]`` arrays.

The packed chi representation (DESIGN.md Sect. 9) keeps the trailing pad
bits of the last word **zero**.  AND-only dataflow preserves that invariant;
a raw complement (``~w`` / ``jnp.bitwise_not``) or an OR with all-ones turns
the pad bits on, and any later popcount/reduction silently counts phantom
query nodes — the class of bug the ``bitops.ones_mask`` discipline exists to
prevent.  Outside the sanctioned homes (``core/bitops.py`` and ``kernels/``,
which implement the masking), this checker flags:

* complements of packed values whose result is not immediately AND-masked,
* raw reductions (``jnp.sum`` / ``.sum()`` / ``lax.population_count`` /
  ``count_nonzero``) on packed values — use ``bitops.popcount`` /
  ``bitops.any_set``, which are pad-aware,
* OR-ing a packed value with an all-ones constant.

"Packed" is a lightweight per-function taint seeded at ``pack`` /
``pack_np`` / the segmented-OR entry points (``segor`` / ``segor_words`` /
``segor_ref`` / ``segor_blocks``, whose results are packed words — ISSUE 8)
/ ``.init_packed`` / ``.adj_packed`` call sites and cleared by ``unpack`` /
``popcount`` / ``any_set`` (their results are not word arrays).

Escape hatch: ``# packed-ok: <reason>``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from tools.reprolint.checkers.common import FuncDef, dotted
from tools.reprolint.core import Checker, Context, Finding

EXEMPT_PATH_PARTS = ("core/bitops.py", "kernels/")

TAINT_CALL_SUFFIXES = (
    "pack", "pack_np",
    # segmented-OR primitives return packed words (ISSUE 8)
    "segor", "segor_words", "segor_ref", "segor_blocks",
)
TAINT_ATTRS = ("init_packed", "adj_packed", "chi_packed")
# Calls whose result leaves the packed-word domain (taint sinks).
CLEARING_SUFFIXES = ("unpack", "unpack_np", "popcount", "any_set", "leq")

COMPLEMENT_CALLS = {
    "jnp.bitwise_not", "jnp.invert", "jax.numpy.bitwise_not", "jax.numpy.invert",
    "np.bitwise_not", "np.invert",
}
REDUCTION_CALLS = {
    "jnp.sum", "np.sum", "jnp.count_nonzero", "np.count_nonzero",
    "lax.population_count", "jax.lax.population_count",
}
ALL_ONES_VALUES = {0xFFFFFFFF}


def _callee_suffix(call: ast.Call) -> str:
    return dotted(call.func).rpartition(".")[2]


def _is_source(node: ast.AST) -> bool:
    if isinstance(node, ast.Call) and _callee_suffix(node) in TAINT_CALL_SUFFIXES:
        return True
    if isinstance(node, ast.Attribute) and node.attr in TAINT_ATTRS:
        return True
    return False


def _is_clearing_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and _callee_suffix(node) in CLEARING_SUFFIXES


class PadBitChecker(Checker):
    """RL2: unmasked bitwise ops / reductions on packed words outside bitops."""

    rule_id = "RL2"
    title = "pad-bit hygiene on packed words"

    def visit(self, ctx: Context) -> Iterable[Finding]:
        rel = ctx.rel.replace("\\", "/")
        if any(part in rel for part in EXEMPT_PATH_PARTS):
            return []
        findings: list[Finding] = []
        # Module level plus each function is its own taint scope.
        scopes: list[list[ast.stmt]] = [ctx.tree.body]
        for node in ast.walk(ctx.tree):
            if isinstance(node, FuncDef):
                scopes.append(node.body)
        for body in scopes:
            findings.extend(self._check_scope(ctx, body))
        return findings

    # -- taint -------------------------------------------------------------

    def _tainted_names(self, body: list[ast.stmt]) -> set[str]:
        tainted: set[str] = set()
        for _ in range(2):  # two passes handle simple forward chains
            for node in self._walk_scope(body):
                if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    value = node.value
                    if value is None or _is_clearing_call(value):
                        continue
                    if self._expr_tainted(value, tainted):
                        targets = (
                            node.targets if isinstance(node, ast.Assign) else [node.target]
                        )
                        for t in targets:
                            for n in ast.walk(t):
                                if isinstance(n, ast.Name):
                                    tainted.add(n.id)
        return tainted

    def _expr_tainted(self, expr: ast.AST, tainted: set[str]) -> bool:
        for n in ast.walk(expr):
            if _is_source(n):
                return True
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) and n.id in tainted:
                return True
        return False

    @staticmethod
    def _walk_scope(body: list[ast.stmt]):
        stack: list[ast.AST] = list(body)
        while stack:
            node = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncDef + (ast.ClassDef,)):
                    continue
                stack.append(child)

    # -- checks ------------------------------------------------------------

    def _check_scope(self, ctx: Context, body: list[ast.stmt]) -> list[Finding]:
        tainted = self._tainted_names(body)
        findings: list[Finding] = []
        for stmt in self._statements(body):
            parents = _parent_map(stmt)
            stmt_uses_mask = any(
                isinstance(n, (ast.Name, ast.Attribute))
                and (getattr(n, "id", None) == "ones_mask" or getattr(n, "attr", None) == "ones_mask")
                for n in ast.walk(stmt)
            )
            for node in ast.walk(stmt):
                findings.extend(
                    self._check_expr(ctx, node, tainted, parents, stmt_uses_mask)
                )
        return findings

    @staticmethod
    def _statements(body: list[ast.stmt]):
        stack: list[ast.AST] = list(body)
        while stack:
            node = stack.pop()
            if isinstance(node, FuncDef + (ast.ClassDef,)):
                continue
            if isinstance(node, ast.stmt):
                yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.stmt):
                    stack.append(child)

    def _check_expr(self, ctx, node, tainted, parents, stmt_uses_mask) -> list[Finding]:
        out: list[Finding] = []
        is_complement = (
            isinstance(node, ast.UnaryOp)
            and isinstance(node.op, ast.Invert)
            and self._expr_tainted(node.operand, tainted)
        ) or (
            isinstance(node, ast.Call)
            and dotted(node.func) in COMPLEMENT_CALLS
            and node.args
            and self._expr_tainted(node.args[0], tainted)
        )
        if is_complement:
            if not stmt_uses_mask and not _under_bitand(node, parents):
                out.append(self.finding(
                    ctx, node,
                    "complement of packed words turns the pad bits on; AND the "
                    "result with `bitops.ones_mask(n)` (or use `bitops.bnot`)",
                ))
        if isinstance(node, ast.Call):
            callee = dotted(node.func)
            if callee in REDUCTION_CALLS and node.args and self._expr_tainted(
                node.args[0], tainted
            ):
                out.append(self.finding(
                    ctx, node,
                    f"raw reduction `{callee}` on packed words counts pad bits "
                    f"after any complement; use `bitops.popcount` / "
                    f"`bitops.any_set`",
                ))
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "sum"
                and self._expr_tainted(node.func.value, tainted)
            ):
                out.append(self.finding(
                    ctx, node,
                    "raw `.sum()` on packed words; use `bitops.popcount` "
                    "(pad-masked) instead",
                ))
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
            for side, other in ((node.left, node.right), (node.right, node.left)):
                if (
                    self._expr_tainted(side, tainted)
                    and isinstance(other, ast.Constant)
                    and other.value in ALL_ONES_VALUES
                ):
                    out.append(self.finding(
                        ctx, node,
                        "OR with all-ones sets the pad bits of packed words; "
                        "mask with `bitops.ones_mask(n)`",
                    ))
                    break
        return out


def _parent_map(root: ast.AST) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(root):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _under_bitand(node: ast.AST, parents: dict[ast.AST, ast.AST]) -> bool:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, ast.BinOp) and isinstance(cur.op, ast.BitAnd):
            return True
        if isinstance(cur, ast.Call):
            suffix = _callee_suffix(cur)
            if suffix in ("band", "bitwise_and", "where"):
                return True
        cur = parents.get(cur)
    return False
