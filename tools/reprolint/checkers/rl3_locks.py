"""RL3 — lock discipline via the ``# guarded-by:`` annotation registry.

The serving stack (PR 6) is threaded: ``Engine`` counters, ``GraphDB``
mutation state, ``Session`` pending maps, router replica stats, and serve
metrics are all shared across threads and guarded by explicit locks.  The
torn-``Engine``-metrics bug fixed in PR 6 is the motivating example — a
reader walked counter fields without the lock while a writer updated them.

Conventions (documented in ``tools/reprolint/__init__.py``):

* ``# guarded-by: <lock>`` on a ``self.<field> = ...`` assignment registers
  the field; every later access must sit inside a lexical
  ``with <receiver>.<lock>:`` block (receiver-matched: ``self.X`` needs
  ``with self.<lock>``, ``rep.X`` needs ``with rep.<lock>``).
* ``# requires-lock: <lock>`` on a ``def`` marks a helper whose callers are
  documented to hold the lock; its body is checked as if the lock were held.
* ``await`` inside a *sync* ``with`` of a registered lock is flagged — a
  threading lock held across a suspension point blocks the event loop.
* Nested acquisition orders are collected per function (including one level
  of same-module call expansion); an A→B order in one function and B→A in
  another is flagged as a potential deadlock inversion.

Escape hatch: ``# lock-ok: <reason>``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from tools.reprolint.checkers.common import FuncDef, dotted
from tools.reprolint.core import Checker, Context, Finding

GUARDED_MARKER = "guarded-by:"
REQUIRES_MARKER = "requires-lock:"
LOCKISH_HINTS = ("lock", "cv", "mutex", "cond")

EXEMPT_METHODS = {"__init__", "__del__"}


def _lock_attr_of(expr: ast.AST, known_locks: set[str]) -> str | None:
    """If a with-item context expr looks like a lock, return its dotted path."""
    path = dotted(expr)
    if not path:
        return None
    leaf = path.rpartition(".")[2]
    if leaf in known_locks or any(h in leaf.lower() for h in LOCKISH_HINTS):
        return path
    return None


class LockDisciplineChecker(Checker):
    """RL3: guarded-field access, await-under-lock, lock-order inversions."""

    rule_id = "RL3"
    title = "lock discipline (# guarded-by registry)"

    def visit(self, ctx: Context) -> Iterable[Finding]:
        registry, lock_names = self._build_registry(ctx)
        if not registry and GUARDED_MARKER not in ctx.source:
            # No annotations in this file: only the order-inversion check
            # could apply, and without a registry there is nothing to anchor.
            return []
        findings: list[Finding] = []
        # field -> lock, only for fields unique module-wide (receiver-based
        # checks on non-self objects need an unambiguous owner).
        field_locks: dict[str, str] = {}
        seen: dict[str, int] = {}
        for fields in registry.values():
            for f, lock in fields.items():
                seen[f] = seen.get(f, 0) + 1
                field_locks[f] = lock
        unique_fields = {f: lk for f, lk in field_locks.items() if seen[f] == 1}

        acquisitions: dict[str, set[str]] = {}  # function name -> locks acquired
        order_edges: list[tuple[str, str, ast.AST, str]] = []

        for cls in [n for n in ast.walk(ctx.tree) if isinstance(n, ast.ClassDef)]:
            fields = registry.get(cls.name, {})
            for meth in [n for n in cls.body if isinstance(n, FuncDef)]:
                self._scan_function(
                    ctx, meth, fields, unique_fields, lock_names,
                    findings, acquisitions, order_edges,
                )
        for fn in [n for n in ctx.tree.body if isinstance(n, FuncDef)]:
            self._scan_function(
                ctx, fn, {}, unique_fields, lock_names,
                findings, acquisitions, order_edges,
            )

        findings.extend(self._order_inversions(ctx, order_edges, acquisitions))
        return findings

    # -- registry ----------------------------------------------------------

    def _build_registry(self, ctx: Context):
        """Collect ``# guarded-by:`` annotations on ``self.X = ...`` lines."""
        registry: dict[str, dict[str, str]] = {}
        lock_names: set[str] = set()
        for cls in [n for n in ast.walk(ctx.tree) if isinstance(n, ast.ClassDef)]:
            fields: dict[str, str] = {}
            for node in ast.walk(cls):
                if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                    continue
                comment = ctx.comment_on_or_above(node.lineno)
                if GUARDED_MARKER not in comment:
                    continue
                lock = comment.split(GUARDED_MARKER, 1)[1].split()[0].strip("`")
                lock_names.add(lock.rpartition(".")[2])
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for t in targets:
                    if (
                        isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                    ):
                        fields[t.attr] = lock
                        lock_names.add(lock)
            if fields:
                registry[cls.name] = fields
        return registry, lock_names

    # -- per-function scan ---------------------------------------------------

    def _requires_locks(self, ctx: Context, fn) -> set[str]:
        held: set[str] = set()
        comment = ctx.comment_on_or_above(fn.lineno)
        if REQUIRES_MARKER in comment:
            lock = comment.split(REQUIRES_MARKER, 1)[1].split()[0].strip("`")
            held.add(f"self.{lock}")
        return held

    def _scan_function(
        self, ctx, fn, fields, unique_fields, lock_names,
        findings, acquisitions, order_edges,
    ) -> None:
        exempt = fn.name in EXEMPT_METHODS
        base_held = self._requires_locks(ctx, fn)
        acquired: set[str] = set()

        def walk(node: ast.AST, held: tuple[str, ...], sync_held: tuple[str, ...]):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                new_locks = []
                for item in node.items:
                    path = _lock_attr_of(item.context_expr, lock_names)
                    if path is not None:
                        new_locks.append(path)
                        acquired.add(path.rpartition(".")[2])
                        for outer in held:
                            order_edges.append(
                                (outer.rpartition(".")[2], path.rpartition(".")[2],
                                 item.context_expr, fn.name)
                            )
                inner_held = held + tuple(new_locks)
                inner_sync = sync_held + (
                    tuple(new_locks) if isinstance(node, ast.With) else ()
                )
                for child in node.body:
                    walk(child, inner_held, inner_sync)
                return
            if isinstance(node, FuncDef + (ast.ClassDef,)) and node is not fn:
                return
            if isinstance(node, ast.Await) and sync_held:
                findings.append(self.finding(
                    ctx, node,
                    f"await while holding {', '.join(f'`{h}`' for h in sync_held)} "
                    f"(threading lock held across a suspension point stalls the "
                    f"event loop)",
                ))
            if isinstance(node, ast.Call):
                # One-level call expansion for the order graph: calling a
                # same-module function that itself acquires locks while we
                # hold one records an ordering edge.
                callee_leaf = dotted(node.func).rpartition(".")[2]
                if held and callee_leaf:
                    for outer in held:
                        order_edges.append(
                            (outer.rpartition(".")[2], f"call:{callee_leaf}",
                             node, fn.name)
                        )
            if isinstance(node, ast.Attribute) and not exempt:
                self._check_field_access(
                    ctx, node, fields, unique_fields, held, findings
                )
            for child in ast.iter_child_nodes(node):
                walk(child, held, sync_held)

        for stmt in fn.body:
            walk(stmt, tuple(sorted(base_held)), tuple(sorted(base_held)))
        acquisitions[fn.name] = acquired

    def _check_field_access(self, ctx, node, fields, unique_fields, held, findings):
        recv = dotted(node.value)
        field = node.attr
        if recv == "self" and field in fields:
            lock = fields[field]
        elif recv and recv != "self" and field in unique_fields:
            lock = unique_fields[field]
        else:
            return
        # A dotted lock path (e.g. `self._route_lock` on a Replica gauge) is
        # matched verbatim against the held with-items: the lock lives on the
        # accessor, not on the receiver object.
        required = lock if "." in lock else f"{recv}.{lock}"
        if required in held:
            return
        findings.append(self.finding(
            ctx, node,
            f"`{recv}.{field}` is guarded-by `{lock}` but accessed outside "
            f"`with {required}:`",
        ))

    # -- lock-order inversions ----------------------------------------------

    def _order_inversions(self, ctx, order_edges, acquisitions) -> list[Finding]:
        # Expand call edges one level: (A, call:m) becomes (A, B) for each
        # lock B acquired directly in m.
        expanded: dict[tuple[str, str], tuple[ast.AST, str]] = {}
        for outer, inner, node, fn_name in order_edges:
            if inner.startswith("call:"):
                callee = inner[len("call:"):]
                for lock in acquisitions.get(callee, ()):
                    if lock != outer:
                        expanded.setdefault((outer, lock), (node, fn_name))
            else:
                if inner != outer:
                    expanded.setdefault((outer, inner), (node, fn_name))
        findings = []
        reported: set[frozenset] = set()
        for (a, b), (node, fn_name) in expanded.items():
            if (b, a) in expanded and frozenset((a, b)) not in reported:
                reported.add(frozenset((a, b)))
                other_fn = expanded[(b, a)][1]
                findings.append(self.finding(
                    ctx, node,
                    f"lock-order inversion: `{fn_name}` acquires `{a}` then "
                    f"`{b}`, but `{other_fn}` acquires `{b}` then `{a}` "
                    f"(deadlock hazard)",
                ))
        return findings
