"""RL4 — exactly-once resolution of futures / request objects.

PR 6's contract: every request submitted to the serving stack resolves to
exactly one explicit outcome — shed, error, or result — and PR 6's worst bug
(a poisoned ``Session.flush`` raising mid-batch and leaving *sibling* futures
unresolved forever) is exactly a violation of it.  This checker runs a
path-insensitive def-use analysis over "owned" future variables:

* **Tracking starts** at ``x = ...create_future()`` / ``x = ResultFuture(...)``
  assignments at function-statement level, at parameters named in a
  ``# rl4: track=<var>`` annotation on the ``def`` line, or at for-loop
  targets named in the same annotation on the ``for`` line (per-iteration
  ownership — the ``Session.flush`` shape).
* **Resolution** is a direct call ``x.set_result/set_exception/cancel/
  _resolve/_reject(...)``.
* **Handoff** (ownership transfer, equally discharging) is passing ``x`` as
  an argument to any call (enqueueing a ``_Pending``, ``list.append``,
  ``self._resolve(p, ...)``), storing it into an attribute/subscript, or
  yielding it.  A bare ``return x`` is NOT a discharge: the caller awaits the
  future, it does not adopt the duty to resolve it.

Each ``return``, ``raise``, loop-iteration end, and function end must be
reached with the variable ALWAYS discharged; a direct resolver call on an
already-discharged path is flagged as a double resolution.

Escape hatch: ``# future-ok: <reason>``.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterable

from tools.reprolint.checkers.common import FuncDef, dotted
from tools.reprolint.core import Checker, Context, Finding

TRACK_MARKER = "rl4: track="
CREATION_LEAVES = {"create_future", "Future", "ResultFuture"}
RESOLVER_METHODS = {"set_result", "set_exception", "cancel", "_resolve", "_reject"}

NEVER, MAYBE, ALWAYS = 0, 1, 2


def _join(a: int, b: int) -> int:
    return a if a == b else MAYBE


@dataclasses.dataclass
class _Out:
    state: int
    term: bool = False  # every path through the block returned or raised


class ExactlyOnceFutureChecker(Checker):
    """RL4: every path resolves or hands off each owned future exactly once."""

    rule_id = "RL4"
    title = "exactly-once future resolution"

    def visit(self, ctx: Context) -> Iterable[Finding]:
        findings: list[Finding] = []
        for fn in [n for n in ast.walk(ctx.tree) if isinstance(n, FuncDef)]:
            findings.extend(self._check_function(ctx, fn))
        return findings

    # -- tracked-variable discovery ----------------------------------------

    def _check_function(self, ctx: Context, fn) -> list[Finding]:
        findings: list[Finding] = []

        # Parameters opted in on the def line: tracked from function start.
        header = ctx.comment_on_or_above(fn.lineno)
        if TRACK_MARKER in header:
            var = header.split(TRACK_MARKER, 1)[1].split()[0]
            out = self._analyze(ctx, fn.body, var, NEVER, findings, loop_body=False)
            if not out.term and out.state != ALWAYS:
                findings.append(self._unresolved(ctx, fn, var, out.state, "function end"))

        # Creations at function-statement level: tracked from the next stmt.
        # `with` blocks are flattened first — they neither branch nor raise
        # resolution events, and futures are routinely created under a lock.
        flat = self._flatten_withs(fn.body)
        for i, stmt in enumerate(flat):
            var = self._creation_target(stmt)
            if var is None:
                continue
            out = self._analyze(
                ctx, flat[i + 1:], var, NEVER, findings, loop_body=False
            )
            if not out.term and out.state != ALWAYS:
                findings.append(self._unresolved(ctx, fn, var, out.state, "function end"))

        # Annotated for-loops: per-iteration ownership of the loop target.
        for node in ast.walk(fn):
            if not isinstance(node, (ast.For, ast.AsyncFor)):
                continue
            comment = ctx.comment_on_or_above(node.lineno)
            if TRACK_MARKER not in comment:
                continue
            var = comment.split(TRACK_MARKER, 1)[1].split()[0]
            out = self._analyze(ctx, node.body, var, NEVER, findings, loop_body=True)
            if not out.term and out.state != ALWAYS:
                findings.append(self._unresolved(ctx, node, var, out.state, "loop iteration end"))

        return findings

    @classmethod
    def _flatten_withs(cls, stmts) -> list[ast.stmt]:
        flat: list[ast.stmt] = []
        for stmt in stmts:
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                flat.extend(cls._flatten_withs(stmt.body))
            else:
                flat.append(stmt)
        return flat

    @staticmethod
    def _creation_target(stmt: ast.stmt) -> str | None:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            return None
        target = stmt.targets[0]
        if not isinstance(target, ast.Name):
            return None
        value = stmt.value
        if isinstance(value, ast.Call) and dotted(value.func).rpartition(".")[2] in CREATION_LEAVES:
            return target.id
        return None

    def _unresolved(self, ctx, node, var, state, where) -> Finding:
        qualifier = "may leave" if state == MAYBE else "leaves"
        return self.finding(
            ctx, node,
            f"{qualifier} `{var}` unresolved at {where}: every path must call "
            f"exactly one of set_result/set_exception/_resolve/_reject or hand "
            f"the future off",
        )

    # -- path-state analysis ------------------------------------------------

    def _analyze(self, ctx, stmts, var, state, findings, loop_body) -> _Out:
        term = False
        for stmt in stmts:
            if term:
                break
            state, term = self._step(ctx, stmt, var, state, findings, loop_body)
        return _Out(state, term)

    def _step(self, ctx, stmt, var, state, findings, loop_body):
        if isinstance(stmt, ast.If):
            state = self._apply_events(ctx, stmt.test, var, state, findings)
            b = self._analyze(ctx, stmt.body, var, state, findings, loop_body)
            e = self._analyze(ctx, stmt.orelse, var, state, findings, loop_body)
            if b.term and e.term:
                return state, True
            if b.term:
                return e.state, False
            if e.term:
                return b.state, False
            return _join(b.state, e.state), False

        if isinstance(stmt, ast.Try):
            b = self._analyze(ctx, stmt.body, var, state, findings, loop_body)
            else_out = self._analyze(
                ctx, stmt.orelse, var, b.state, findings, loop_body
            ) if not b.term else b
            # A handler can run with the body's work partially done; be
            # conservative and analyze it from the pre-try state.
            branch_outs = [else_out]
            for handler in stmt.handlers:
                branch_outs.append(
                    self._analyze(ctx, handler.body, var, state, findings, loop_body)
                )
            live = [o for o in branch_outs if not o.term]
            if not live:
                out_state, out_term = state, True
            else:
                out_state = live[0].state
                for o in live[1:]:
                    out_state = _join(out_state, o.state)
                out_term = False
            if stmt.finalbody:
                f = self._analyze(ctx, stmt.finalbody, var, out_state, findings, loop_body)
                out_state, out_term = f.state, out_term or f.term
            return out_state, out_term

        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            if isinstance(stmt, ast.While):
                state = self._apply_events(ctx, stmt.test, var, state, findings)
            else:
                state = self._apply_events(ctx, stmt.iter, var, state, findings)
            body_out = self._analyze(ctx, stmt.body, var, state, findings, loop_body)
            else_out = self._analyze(ctx, stmt.orelse, var, state, findings, loop_body)
            merged = state if body_out.term else _join(state, body_out.state)
            if not else_out.term:
                merged = _join(merged, else_out.state)
            return merged, False

        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                state = self._apply_events(ctx, item.context_expr, var, state, findings)
            out = self._analyze(ctx, stmt.body, var, state, findings, loop_body)
            return out.state, out.term

        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                state = self._apply_events(ctx, stmt.value, var, state, findings)
            if state != ALWAYS:
                findings.append(self._unresolved(ctx, stmt, var, state, "return"))
            return state, True

        if isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                state = self._apply_events(ctx, stmt.exc, var, state, findings)
            if state != ALWAYS:
                findings.append(self._unresolved(ctx, stmt, var, state, "raise"))
            return state, True

        if isinstance(stmt, (ast.Continue, ast.Break)) and loop_body:
            if state != ALWAYS:
                findings.append(self._unresolved(
                    ctx, stmt, var, state,
                    "continue" if isinstance(stmt, ast.Continue) else "break",
                ))
            return state, True

        if isinstance(stmt, FuncDef + (ast.ClassDef,)):
            return state, False

        # Plain statement (Expr, Assign, AugAssign, Assert, Delete, ...):
        # apply resolver/handoff events found anywhere inside it.
        new_state = state
        for node in ast.walk(stmt):
            new_state = self._apply_node_event(ctx, node, stmt, var, new_state, findings)
        return new_state, False

    def _apply_events(self, ctx, expr, var, state, findings) -> int:
        for node in ast.walk(expr):
            state = self._apply_node_event(ctx, node, expr, var, state, findings)
        return state

    def _apply_node_event(self, ctx, node, stmt, var, state, findings) -> int:
        if isinstance(node, ast.Call):
            # Direct resolver: `var.set_result(...)` etc.
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in RESOLVER_METHODS
                and isinstance(f.value, ast.Name)
                and f.value.id == var
            ):
                if state == ALWAYS:
                    findings.append(self.finding(
                        ctx, node,
                        f"`{var}.{f.attr}()` on an already-discharged path: the "
                        f"future may be resolved twice",
                    ))
                return ALWAYS
            # Handoff: var passed as an argument to any call.
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if any(
                    isinstance(n, ast.Name) and n.id == var for n in ast.walk(arg)
                ):
                    return ALWAYS
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            # Handoff: var stored into an attribute or container slot.
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            value = node.value
            if value is not None and any(
                isinstance(n, ast.Name) and n.id == var for n in ast.walk(value)
            ):
                for t in targets:
                    if isinstance(t, (ast.Attribute, ast.Subscript)):
                        return ALWAYS
        if isinstance(node, (ast.Yield, ast.YieldFrom)) and node.value is not None:
            if any(isinstance(n, ast.Name) and n.id == var for n in ast.walk(node.value)):
                return ALWAYS
        return state
    # NOTE: `return var` is deliberately NOT a discharge — see module docstring.
